"""Fused quantize→bit-plane matmul vs the unfused composition and the
pure-jnp reference: exact int32 equality and bit-exact scales across all
supported (w_bits, a_bits) pairs, signednesses, and ragged shapes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitplane
from repro.core.quant import QuantConfig
from repro.core.quantized_linear import pack_weight, qmatmul, unpack_weight
from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _weight_codes(k, n, w_bits):
    lo, hi = -(1 << (w_bits - 1)), (1 << (w_bits - 1)) - 1
    return RNG.integers(lo, hi + 1, (k, n)).astype(np.int32)


@pytest.mark.parametrize("w_bits", [2, 4, 8])
@pytest.mark.parametrize("a_bits", list(range(2, 9)))
def test_fused_equals_unfused_all_precisions(w_bits, a_bits):
    """Acceptance sweep: (w_bits, a_bits) ∈ {2,4,8}×{2..8}, exact."""
    m, k, n = 9, 72, 13
    x = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(_weight_codes(k, n, w_bits))
    q, s = ops.quantize_rows(x, bits=a_bits)
    acc_unfused = ops.bitplane_matmul(q, w, a_bits=a_bits)
    acc_fused, s_fused = ops.fused_quantize_matmul(x, w, a_bits=a_bits)
    np.testing.assert_array_equal(np.asarray(acc_fused), np.asarray(acc_unfused))
    np.testing.assert_array_equal(np.asarray(s_fused), np.asarray(s))


@pytest.mark.parametrize("a_bits,signed", [(2, False), (4, False), (5, True),
                                           (8, False), (8, True)])
def test_fused_signedness(a_bits, signed):
    m, k, n = 17, 50, 21
    x = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    if not signed:
        x = jnp.abs(x)  # post-ReLU-style unsigned activations
    w = jnp.asarray(_weight_codes(k, n, 8))
    q, s = ops.quantize_rows(x, bits=a_bits, signed=signed)
    acc_u = ops.bitplane_matmul(q, w, a_bits=a_bits, act_signed=signed)
    acc_f, s_f = ops.fused_quantize_matmul(x, w, a_bits=a_bits, act_signed=signed)
    np.testing.assert_array_equal(np.asarray(acc_f), np.asarray(acc_u))
    np.testing.assert_array_equal(np.asarray(s_f), np.asarray(s))


@pytest.mark.parametrize("m,k,n", [(1, 8, 1), (3, 100, 5), (7, 129, 33),
                                   (128, 300, 130), (40, 512, 256)])
def test_fused_ragged_shapes(m, k, n):
    """Non-multiple-of-block shapes: padding must not leak into results."""
    x = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(_weight_codes(k, n, 4))
    q, s = ops.quantize_rows(x, bits=6)
    acc_u = ops.bitplane_matmul(q, w, a_bits=6)
    acc_f, s_f = ops.fused_quantize_matmul(x, w, a_bits=6)
    np.testing.assert_array_equal(np.asarray(acc_f), np.asarray(acc_u))
    np.testing.assert_array_equal(np.asarray(s_f), np.asarray(s))


@pytest.mark.parametrize("a_bits,signed", [(4, True), (8, False), (3, True)])
def test_fused_matches_reference_backend(a_bits, signed):
    """interpret and reference backends agree bit-for-bit."""
    m, k, n = 11, 64, 19
    x = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(_weight_codes(k, n, 8))
    acc_i, s_i = ops.fused_quantize_matmul(x, w, a_bits=a_bits, act_signed=signed)
    acc_r, s_r = ops.fused_quantize_matmul(x, w, a_bits=a_bits, act_signed=signed,
                                           backend="reference")
    np.testing.assert_array_equal(np.asarray(acc_i), np.asarray(acc_r))
    np.testing.assert_array_equal(np.asarray(s_i), np.asarray(s_r))


def test_fused_explicit_blocks_do_not_change_results():
    """Integer accumulation is exact, so block plans are value-neutral."""
    m, k, n = 24, 160, 48
    x = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(_weight_codes(k, n, 8))
    base, s0 = ops.fused_quantize_matmul(x, w, a_bits=8)
    for blocks in [(8, 16, 32), (16, 48, 160), (24, 8, 80)]:
        acc, s = ops.fused_quantize_matmul(x, w, a_bits=8, blocks=blocks)
        np.testing.assert_array_equal(np.asarray(acc), np.asarray(base))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(s0))


def test_quantize_rows_unsigned_8bit_codes_survive_storage():
    """Regression: float→int8 saturation used to corrupt unsigned 8-bit
    codes (255 → 127); the int32 hop stores the wrapped bit pattern and the
    bit-plane matmul reconstructs it mod 2^8."""
    x = jnp.asarray(np.abs(RNG.standard_normal((4, 32))) + 0.1, jnp.float32)
    q, s = ops.quantize_rows(x, bits=8, signed=False)
    codes = np.asarray(q).view(np.uint8)
    assert codes.max() == 255, "row absmax must map to code 255"
    w = jnp.asarray(_weight_codes(32, 3, 8))
    acc = ops.bitplane_matmul(q, w, a_bits=8, act_signed=False)
    want = codes.astype(np.int64) @ np.asarray(w)
    np.testing.assert_array_equal(np.asarray(acc), want)


@pytest.mark.parametrize("w_bits,a_bits", [(8, 8), (4, 8), (2, 4), (4, 6)])
def test_serve_matmul_kernel_path_uses_fused(w_bits, a_bits):
    """qmatmul(use_kernel=True) — the serve hot path — stays numerically
    within the same error budget as before the fusion."""
    x = jnp.asarray(RNG.standard_normal((24, 128)), jnp.float32)
    wf = jnp.asarray(RNG.standard_normal((128, 48)) * 0.1, jnp.float32)
    cfg = QuantConfig(w_bits=w_bits, a_bits=a_bits)
    pw = pack_weight(wf, cfg)
    y = qmatmul(x, pw, cfg, use_kernel=True)
    y_ref = x @ wf
    rel = float(jnp.linalg.norm(y - y_ref) / jnp.linalg.norm(y_ref))
    budget = {(8, 8): 0.02, (4, 8): 0.18, (4, 6): 0.19, (2, 4): 0.55}
    assert rel < budget[(w_bits, a_bits)], rel


def test_serve_kernel_path_equals_unfused_composition():
    """The fused serve path reproduces the manual unfused pipeline exactly
    (same codes, same int accumulator, same dequant)."""
    x = jnp.asarray(RNG.standard_normal((12, 64)), jnp.float32)
    wf = jnp.asarray(RNG.standard_normal((64, 24)) * 0.1, jnp.float32)
    cfg = QuantConfig(w_bits=4, a_bits=8)
    pw = pack_weight(wf, cfg)
    wq = unpack_weight(pw)
    q, s = ops.quantize_rows(x, bits=8)
    acc = ops.bitplane_matmul(q, wq, a_bits=8)
    manual = np.asarray(acc, np.float32) * np.asarray(s) * np.asarray(pw.scale)
    got = np.asarray(qmatmul(x, pw, cfg, use_kernel=True))
    np.testing.assert_array_equal(got, manual.astype(np.float32))


def test_packed_matmul_wrapper_fused():
    """ops.packed_matmul (the packed serve composition) vs dequant math."""
    k, n = 96, 40
    wq = _weight_codes(k, n, 4)
    packed = bitplane.pack_weights(jnp.asarray(wq), 4, axis=0)
    scale = jnp.asarray(RNG.uniform(0.001, 0.01, (n,)), jnp.float32)
    x = jnp.asarray(RNG.standard_normal((10, k)), jnp.float32)
    got = ops.packed_matmul(x, packed, scale, w_bits=4, a_bits=8)
    q, s = ops.quantize_rows(x, bits=8)
    want = (np.asarray(q).astype(np.int64) @ wq) * np.asarray(s) * \
        np.asarray(scale).reshape(1, -1)
    np.testing.assert_allclose(np.asarray(got, np.float64), want, rtol=1e-6)
