"""Seeded fault-injection chaos harness over the serving stack.

Every fault kind degrades ONE request or ONE call, never the engine:
alloc faults become ordinary pool pressure (queueing / preemption /
bypass), kernel faults fall back to the bitwise-identical reference
backend, NaN-corrupted logits fail exactly the poisoned request, and
raising callbacks are contained. The sweep at the bottom replays seeded
schedules end-to-end and asserts the three global properties the ISSUE
demands: no deadlock (bounded steps), every request terminal (tokens or
error, never both missing), pool invariants intact after every run —
and survivors bitwise identical to the no-fault run.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.serving import (
    ContinuousScheduler,
    FaultInjector,
    InjectedFault,  # noqa: F401  (exported surface)
    Request,
    assert_pool_invariants,
)

KEY = jax.random.PRNGKey(0)
P8 = (np.arange(8) * 3 + 1) % 64
P11 = (np.arange(11) * 5 + 2) % 64


@pytest.fixture(scope="module")
def olmo():
    cfg = get_reduced_config("olmo-1b")
    params = build_model(cfg).init(KEY)
    return cfg, params


def _sched(cfg, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_ctx", 64)
    kw.setdefault("bucket", 16)
    kw.setdefault("paged", True)
    kw.setdefault("block_size", 4)
    return ContinuousScheduler(cfg, params, **kw)


def _drain(sched, cap=400):
    out = []
    steps = 0
    while sched.num_active or sched.num_waiting:
        out.extend(sched.step())
        steps += 1
        assert steps < cap, "scheduler failed to drain under faults"
    assert_pool_invariants(sched)
    return out


def _workload(n=4):
    return [Request(i, (P8 if i % 2 else P11) + i, max_new_tokens=6)
            for i in range(n)]


def _serve(cfg, params, chaos=None, **kw):
    sched = _sched(cfg, params, chaos=chaos, **kw)
    for r in _workload():
        sched.submit(r)
    done = _drain(sched)
    return sched, {r.rid: r for r in done}


# -- the injector itself ---------------------------------------------------


def test_injector_is_deterministic():
    a = FaultInjector(7, p_kernel=0.3, p_nan=0.1)
    b = FaultInjector(7, p_kernel=0.3, p_nan=0.1)
    sched_a = [a.fire("kernel") for _ in range(50)]
    sched_b = [b.fire("kernel") for _ in range(50)]
    assert sched_a == sched_b
    assert any(sched_a)
    assert a.counts() == b.counts()


def test_injector_streams_are_independent():
    """Enabling one kind never shifts another kind's schedule: each seam
    draws from its own (seed, kind) stream."""
    solo = FaultInjector(3, p_nan=0.2)
    both = FaultInjector(3, p_nan=0.2, p_kernel=0.9)
    solo_sched, both_sched = [], []
    for i in range(40):
        both.fire("kernel")           # interleaved visits to another seam
        solo_sched.append(solo.fire("nan"))
        both_sched.append(both.fire("nan"))
    assert solo_sched == both_sched


def test_injector_zero_rate_never_draws_entropy():
    inj = FaultInjector(0, p_alloc=0.0)
    assert not any(inj.fire("alloc") for _ in range(20))
    assert inj.draws["alloc"] == 20 and inj.fired["alloc"] == 0


def test_injector_max_faults_cap():
    inj = FaultInjector(1, p_kernel=1.0, max_faults=3)
    fires = [inj.fire("kernel") for _ in range(10)]
    assert sum(fires) == 3 and fires[:3] == [True] * 3
    assert inj.total_fired == 3


def test_injector_validation():
    with pytest.raises(ValueError, match="p_nan"):
        FaultInjector(0, p_nan=1.5)
    with pytest.raises(ValueError, match="max_faults"):
        FaultInjector(0, max_faults=-1)
    inj = FaultInjector(5)
    assert {inj.pick(3) for _ in range(50)} <= {0, 1, 2}


# -- one seam at a time ----------------------------------------------------


def test_kernel_fault_falls_back_bit_identically(olmo):
    """Every decode dispatch 'fails' (capped): the reference-backend
    fallback keeps each stream bitwise the fault-free run."""
    cfg, params = olmo
    _, clean = _serve(cfg, params)
    sched, done = _serve(
        cfg, params, FaultInjector(11, p_kernel=1.0, max_faults=8))
    assert sched.kernel_fallbacks == 8
    for rid, r in done.items():
        assert r.error is None
        assert r.out_tokens == clean[rid].out_tokens


def test_nan_fault_fails_only_poisoned_request(olmo):
    cfg, params = olmo
    _, clean = _serve(cfg, params)
    sched, done = _serve(
        cfg, params, FaultInjector(2, p_nan=1.0, max_faults=1))
    assert sched.nan_logit_events == 1
    poisoned = [r for r in done.values() if r.error == "nan-logits"]
    assert len(poisoned) == 1
    for r in done.values():
        if r.error is None:
            assert r.out_tokens == clean[r.rid].out_tokens


def test_alloc_fault_degrades_to_pool_pressure(olmo):
    """A failed reservation behaves exactly like a full pool: the request
    waits (or preempts/bypasses) and everyone still completes, bitwise
    the clean run."""
    cfg, params = olmo
    _, clean = _serve(cfg, params)
    sched, done = _serve(
        cfg, params, FaultInjector(4, p_alloc=0.5, max_faults=6))
    assert sched.pool_pressure_events >= 1
    for rid, r in done.items():
        assert r.error is None
        assert r.out_tokens == clean[rid].out_tokens


def test_callback_fault_is_contained(olmo):
    cfg, params = olmo
    seen = []
    sched = _sched(cfg, params, on_token=lambda r, t: seen.append(t),
                   chaos=FaultInjector(9, p_callback=1.0, max_faults=1))
    for r in _workload():
        sched.submit(r)
    done = {r.rid: r for r in _drain(sched)}
    assert sched.callback_errors == 1
    errored = [r for r in done.values() if r.error]
    assert len(errored) == 1 and "callback" in errored[0].error
    assert len(seen) > 0              # the stream kept flowing


# -- seeded end-to-end sweep ----------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_seeded_chaos_sweep(olmo, seed):
    """All four seams armed at once over an overcommitted pool, three
    seeds: bounded steps (no deadlock), every request terminal, pool
    invariants after every drain, survivors bitwise the no-fault run —
    and the same seed replays the same fault counts."""
    cfg, params = olmo
    kw = dict(pool_blocks=10)
    _, clean = _serve(cfg, params, **kw)

    def chaos():
        return FaultInjector(seed, p_alloc=0.15, p_kernel=0.15,
                             p_nan=0.05, p_callback=0.05, max_faults=12)

    sched, done = _serve(cfg, params, chaos(), **kw)
    assert len(done) == 4
    for r in done.values():
        assert r.out_tokens is not None           # terminal, always
        if r.error is None:
            assert len(r.out_tokens) == 6
            assert r.out_tokens == clean[r.rid].out_tokens
    counts = sched.chaos.counts()

    sched2, done2 = _serve(cfg, params, chaos(), **kw)
    assert sched2.chaos.counts() == counts        # same seed, same schedule
    assert {rid: r.error for rid, r in done2.items()} == {
        rid: r.error for rid, r in done.items()}
    assert {rid: r.out_tokens for rid, r in done2.items()} == {
        rid: r.out_tokens for rid, r in done.items()}


def test_chaos_counts_surface_in_pool_stats(olmo):
    cfg, params = olmo
    sched, _ = _serve(cfg, params,
                      FaultInjector(6, p_kernel=0.5, max_faults=2))
    ch = sched.pool_stats()["chaos"]
    assert ch["seed"] == 6
    assert ch["total_fired"] == 2
    assert ch["fired"]["kernel"] == 2
    assert ch["draws"]["kernel"] >= 2
