"""End-to-end driver smoke tests: train CLI → checkpoint → serve CLI with
the quantized + int8-cache path (subprocesses, reduced configs)."""
import os
import pytest
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def _run(args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", *args], capture_output=True, text=True,
        timeout=timeout, env=env, cwd=REPO,
    )


@pytest.mark.slow
def test_train_then_serve_roundtrip(tmp_path):
    ck = tmp_path / "ckpt"
    r = _run(["repro.launch.train", "--arch", "olmo-1b", "--reduced",
              "--steps", "6", "--global-batch", "4", "--seq", "32",
              "--ckpt", str(ck)])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "done:" in r.stdout
    assert any(p.name.isdigit() for p in ck.iterdir()), "no checkpoint written"

    r2 = _run(["repro.launch.serve", "--arch", "olmo-1b", "--reduced",
               "--ckpt", str(ck), "--quant", "w4a8", "--kv-int8",
               "--requests", "2", "--max-new", "4"])
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "restored checkpoint" in r2.stdout
    assert "2 requests, 8 tokens" in r2.stdout


@pytest.mark.slow
def test_train_resumes_on_fake_mesh(tmp_path):
    """Elastic path: train on 1 device, resume on a fake 2x2 mesh."""
    ck = tmp_path / "ckpt"
    r = _run(["repro.launch.train", "--arch", "olmo-1b", "--reduced",
              "--steps", "4", "--global-batch", "4", "--seq", "32",
              "--ckpt", str(ck)])
    assert r.returncode == 0, r.stdout + r.stderr
    r2 = _run(["repro.launch.train", "--arch", "olmo-1b", "--reduced",
               "--steps", "8", "--global-batch", "4", "--seq", "32",
               "--ckpt", str(ck), "--fake-devices", "4",
               "--mesh-shape", "2,2"])
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "'data': 2, 'model': 2" in r2.stdout
