"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype/
precision sweeps with exact integer equality or tight allclose."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import QuantConfig
from repro.core.quantized_linear import pack_weight, qmatmul
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("m,k,n", [(1, 32, 1), (7, 64, 33), (16, 256, 128),
                                   (128, 512, 256), (3, 100, 5)])
@pytest.mark.parametrize("a_bits", [2, 3, 5, 8])
def test_bitplane_matmul_exact(m, k, n, a_bits):
    lo, hi = -(1 << (a_bits - 1)), (1 << (a_bits - 1)) - 1
    x = RNG.integers(lo, hi + 1, (m, k)).astype(np.int32)
    w = RNG.integers(-128, 128, (k, n)).astype(np.int32)
    got = np.asarray(ops.bitplane_matmul(jnp.asarray(x), jnp.asarray(w),
                                         a_bits=a_bits))
    np.testing.assert_array_equal(got, x @ w)


@pytest.mark.parametrize("a_bits,signed", [(4, False), (6, False), (8, True)])
def test_bitplane_matmul_unsigned(a_bits, signed):
    lo, hi = (-(1 << (a_bits - 1)), (1 << (a_bits - 1)) - 1) if signed \
        else (0, (1 << a_bits) - 1)
    x = RNG.integers(lo, hi + 1, (9, 48)).astype(np.int32)
    w = RNG.integers(-128, 128, (48, 17)).astype(np.int32)
    got = np.asarray(ops.bitplane_matmul(jnp.asarray(x), jnp.asarray(w),
                                         a_bits=a_bits, act_signed=signed))
    np.testing.assert_array_equal(got, x @ w)


@pytest.mark.parametrize("blocks", [(8, 128, 128), (16, 256, 256)])
def test_bitplane_matmul_block_shapes(blocks):
    bm, bn, bk = blocks
    x = RNG.integers(-8, 8, (40, 300)).astype(np.int32)
    w = RNG.integers(-8, 8, (300, 130)).astype(np.int32)
    got = np.asarray(ops.bitplane_matmul(
        jnp.asarray(x), jnp.asarray(w), a_bits=4, blocks=(bm, bn, bk)))
    np.testing.assert_array_equal(got, x @ w)


@pytest.mark.parametrize("m,k", [(1, 8), (37, 129), (256, 1024)])
@pytest.mark.parametrize("bits", [2, 4, 6, 8])
def test_quantize_rows_matches_ref(m, k, bits):
    x = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    q, s = ops.quantize_rows(x, bits=bits)
    qr, sr = ref.quantize_pack_ref(x, bits)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


@pytest.mark.parametrize("T,H,K,V", [(64, 2, 16, 16), (96, 1, 8, 8),
                                     (33, 3, 32, 32)])
@pytest.mark.parametrize("chunk", [16, 32])
def test_wkv6_kernel_vs_scan_oracle(T, H, K, V, chunk):
    r = jnp.asarray(RNG.standard_normal((T, H, K)) * 0.5, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((T, H, K)) * 0.5, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((T, H, V)) * 0.5, jnp.float32)
    w = jnp.asarray(RNG.uniform(0.5, 0.999, (T, H, K)), jnp.float32)
    u = jnp.asarray(RNG.standard_normal((H, K)) * 0.5, jnp.float32)
    want = np.asarray(ref.wkv6_ref(r, k, v, w, u))
    got = np.asarray(ops.wkv6(r, k, v, w, u, chunk=chunk))
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_wkv6_extreme_decay_stability():
    """Near-zero decays must not produce inf/nan (log-space path)."""
    T, H, K = 64, 1, 8
    r = jnp.ones((T, H, K), jnp.float32)
    k = jnp.ones((T, H, K), jnp.float32)
    v = jnp.ones((T, H, K), jnp.float32)
    w = jnp.full((T, H, K), 1e-6, jnp.float32)
    u = jnp.zeros((H, K), jnp.float32)
    out = np.asarray(ops.wkv6(r, k, v, w, u, chunk=16))
    assert np.all(np.isfinite(out))
    want = np.asarray(ref.wkv6_ref(r, k, v, w, u))
    np.testing.assert_allclose(out, want, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("w_bits,a_bits", [(8, 8), (4, 8), (2, 4), (4, 6)])
def test_packed_matmul_end_to_end(w_bits, a_bits):
    x = jnp.asarray(RNG.standard_normal((24, 128)), jnp.float32)
    wf = jnp.asarray(RNG.standard_normal((128, 48)) * 0.1, jnp.float32)
    cfg = QuantConfig(w_bits=w_bits, a_bits=a_bits)
    pw = pack_weight(wf, cfg)
    y = qmatmul(x, pw, cfg)
    y_ref = x @ wf
    rel = float(jnp.linalg.norm(y - y_ref) / jnp.linalg.norm(y_ref))
    # Error budget grows as precision drops (4-bit Gaussian weights carry
    # ~12% relative RMS by themselves — SQNR ≈ 17 dB).
    budget = {(8, 8): 0.02, (4, 8): 0.18, (4, 6): 0.19, (2, 4): 0.55}
    assert rel < budget[(w_bits, a_bits)], rel


def test_mixed_group_matmul_vs_ref():
    x = jnp.asarray(RNG.standard_normal((16, 64)), jnp.float32)
    w8 = RNG.integers(-128, 128, (64, 16)).astype(np.int32)
    wl = RNG.integers(-8, 8, (64, 32)).astype(np.int32)
    s8 = jnp.asarray(RNG.uniform(0.001, 0.01, (16,)), jnp.float32)
    sl = jnp.asarray(RNG.uniform(0.001, 0.01, (32,)), jnp.float32)
    from repro.core import bitplane

    packed_l = bitplane.pack_weights(jnp.asarray(wl), 4, axis=0)
    got = ops.mixed_group_matmul(
        x, jnp.asarray(w8), packed_l, s8, sl, w_bits=4, a_bits=8
    )
    want = ref.mixed_group_matmul_ref(
        x, jnp.asarray(w8), jnp.asarray(wl), s8, sl, 8
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_packed_weight_hbm_bytes_scale_with_precision():
    wf = jnp.asarray(RNG.standard_normal((1024, 256)), jnp.float32)
    sizes = {}
    for bits in (2, 4, 8):
        pw = pack_weight(wf, QuantConfig(w_bits=bits, a_bits=8))
        sizes[bits] = pw.hbm_bytes()
    # The paper's throughput scaling becomes bandwidth scaling on TPU.
    assert sizes[8] / sizes[4] == pytest.approx(2.0, rel=0.05)
    assert sizes[8] / sizes[2] == pytest.approx(4.0, rel=0.05)


def test_block_shape_selector_vmem_budget():
    bm, bn, bk = ops.pick_matmul_blocks(4096, 4096, 8192)
    assert bm % 8 == 0 and bn % 128 == 0 and bk % 128 == 0
    assert 2 * (bm * bk + bk * bn) + 4 * bm * bn <= (4 << 20)


@pytest.mark.parametrize("shape,causal,window,off", [
    ((2, 64, 64, 32), True, 0, 0),
    ((1, 100, 100, 16), True, 0, 0),
    ((2, 64, 128, 32), True, 16, 0),
    ((1, 1, 96, 32), True, 0, 95),     # decode: 1 query vs long context
    ((2, 48, 48, 32), False, 0, 0),    # bidirectional (encoder)
])
def test_flash_attention_kernel_vs_ref(shape, causal, window, off):
    BH, Tq, Tk, D = shape
    q = jnp.asarray(RNG.standard_normal((BH, Tq, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((BH, Tk, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((BH, Tk, D)), jnp.float32)
    from repro.kernels.flash_attention import flash_attention

    got = np.asarray(flash_attention(q, k, v, causal=causal, window=window,
                                     q_offset=off, bq=32, bk=32))
    want = np.asarray(ref.flash_attention_ref(q, k, v, causal, window, off))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_attention_gqa_wrapper_matches_model_attention():
    """ops.flash_attention (GQA dispatch) vs the model stack's chunked
    online-softmax attention — the two implementations of the same spec."""
    from repro.models import common as cm

    B, T, NQ, NKV, H = 2, 48, 8, 2, 16
    q = jnp.asarray(RNG.standard_normal((B, T, NQ, H)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, T, NKV, H)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, T, NKV, H)), jnp.float32)
    got = np.asarray(ops.flash_attention(q, k, v, causal=True, bq=16, bk=16))
    want = np.asarray(cm.chunked_attention(
        q, k, v, cm.AttnMask(causal=True), q_chunk=16, kv_chunk=16))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)
