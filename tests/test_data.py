"""Data pipeline: determinism, host sharding, checkpointable state."""
import numpy as np

from repro.configs import get_reduced_config
from repro.data import DataIterator


def _it(**kw):
    cfg = get_reduced_config("olmo-1b")
    defaults = dict(global_batch=4, seq_len=16, seed=7)
    defaults.update(kw)
    return DataIterator(cfg, **defaults)


def test_deterministic_across_instances():
    a = _it().batch_at(3)
    b = _it().batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_steps_differ():
    it = _it()
    assert not np.array_equal(it.batch_at(0)["tokens"], it.batch_at(1)["tokens"])


def test_host_sharding_disjoint_and_sized():
    h0 = _it(host_id=0, host_count=2).batch_at(0)["tokens"]
    h1 = _it(host_id=1, host_count=2).batch_at(0)["tokens"]
    assert h0.shape == (2, 16) and h1.shape == (2, 16)
    assert not np.array_equal(h0, h1)


def test_iterator_protocol_and_state_restore():
    it = _it()
    batches = [next(it) for _ in range(3)]
    state = it.get_state()
    assert state["step"] == 3
    it2 = _it()
    it2.set_state(state)
    b3 = next(it2)
    b3_ref = it.batch_at(3)
    np.testing.assert_array_equal(b3["tokens"], b3_ref["tokens"])


def test_vlm_and_encoder_batches():
    vlm = get_reduced_config("paligemma-3b")
    it = DataIterator(vlm, global_batch=2, seq_len=16, seed=0)
    b = it.batch_at(0)
    assert b["patches"].shape == (2, vlm.num_prefix_embeds, vlm.frontend_dim)
    assert b["tokens"].shape == (2, 16 - vlm.num_prefix_embeds)

    enc = get_reduced_config("hubert-xlarge")
    it = DataIterator(enc, global_batch=2, seq_len=16, seed=0)
    b = it.batch_at(0)
    assert b["frames"].shape == (2, 16, enc.frontend_dim)
    assert b["labels"].shape == (2, 16)
    assert b["labels"].max() < enc.vocab


def test_token_distribution_is_learnable():
    """Markov structure: successor table bounds bigram diversity."""
    it = _it(global_batch=8, seq_len=256, branch=4)
    toks = it.batch_at(0)["tokens"]
    # transitions reuse a small successor table → repeated bigrams
    bigrams = set(zip(toks[:, :-1].reshape(-1), toks[:, 1:].reshape(-1)))
    assert len(bigrams) < 0.7 * toks.size
