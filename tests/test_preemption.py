"""Pool-pressure preemption with warm bit-identical resume, victim
policies, head-of-line bypass, and graceful tier degradation.

The headline contract: a preempted request — its slot released under
pool pressure, its resident prompt+generated blocks registered in the
prefix index, itself requeued as ``prompt ++ generated`` — produces
EXACTLY the token stream of an uninterrupted run, across the bf16 and
int8 pools, precision tiers, sampling, and self-speculation. Preemption
must be invisible in the outputs and visible only in the counters.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.quant import QuantConfig
from repro.models import build_model
from repro.serving import ContinuousScheduler, Request, assert_pool_invariants

KEY = jax.random.PRNGKey(0)
Q8 = QuantConfig(w_bits=8, a_bits=8)
P4 = (np.arange(4) * 3 + 2) % 64
P8 = (np.arange(8) * 3 + 1) % 64
P11 = (np.arange(11) * 5 + 2) % 64
P16 = (np.arange(16) * 7 + 3) % 64


@pytest.fixture(scope="module")
def olmo():
    cfg = get_reduced_config("olmo-1b")
    params = build_model(cfg).init(KEY)
    return cfg, params


def _sched(cfg, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_ctx", 64)
    kw.setdefault("bucket", 16)
    kw.setdefault("paged", True)
    kw.setdefault("block_size", 4)
    kw.setdefault("chunked_prefill", False)
    return ContinuousScheduler(cfg, params, **kw)


def _drain(sched, cap=300):
    out = []
    steps = 0
    while sched.num_active or sched.num_waiting:
        out.extend(sched.step())
        steps += 1
        assert steps < cap, "scheduler failed to drain (deadlock?)"
    assert_pool_invariants(sched)
    return out


def _solo(cfg, params, req, **kw):
    """Uninterrupted reference stream: same scheduler settings, a pool
    big enough that pressure never occurs."""
    kw.setdefault("pool_blocks", 64)
    sched = _sched(cfg, params, **kw)
    sched.submit(req)
    _drain(sched)
    assert sched.preemptions == 0
    return req.out_tokens


def _preempt_scenario(cfg, params, *, r1_kw=None, r2_kw=None, **sched_kw):
    """r1 decodes alone until r2's admission can't fit the pool: r1 is
    preempted, r2 serves, r1 resumes warm. Returns (sched, r1, r2)."""
    sched_kw.setdefault("pool_blocks", 10)
    sched = _sched(cfg, params, **sched_kw)
    r1 = Request(1, P8, max_new_tokens=12, **(r1_kw or {}))
    r2 = Request(2, P16, max_new_tokens=8, **(r2_kw or {}))
    sched.submit(r1)
    for _ in range(3):
        sched.step()
    sched.submit(r2)
    _drain(sched)
    assert sched.preemptions >= 1
    assert r1.preemptions >= 1 and r2.preemptions == 0
    assert r1.error is None and r2.error is None
    return sched, r1, r2


# -- the bit-identity contract --------------------------------------------


@pytest.mark.parametrize("kv_int8", [False, True])
def test_preempt_resume_bit_identical(olmo, kv_int8):
    cfg, params = olmo
    if kv_int8:
        cfg = dataclasses.replace(cfg, kv_cache_quant=True)
    sched, r1, r2 = _preempt_scenario(cfg, params)
    assert r1.out_tokens == _solo(
        cfg, params, Request(1, P8, max_new_tokens=12))
    assert r2.out_tokens == _solo(
        cfg, params, Request(2, P16, max_new_tokens=8))
    # The resume was warm: re-admission hit the blocks preemption
    # registered (the whole prompt at minimum).
    assert sched.pool_stats()["prefix_hit_tokens"] >= len(P8)


def test_preempt_resume_bit_identical_sampled(olmo):
    """Sampling survives interruption too: the per-request PRNG is a pure
    function of (seed, rid, step index), and the resume re-enters at
    step index = tokens already emitted."""
    cfg, params = olmo
    _, r1, _ = _preempt_scenario(
        cfg, params, r1_kw=dict(temperature=0.8, top_k=8))
    assert r1.out_tokens == _solo(
        cfg, params, Request(1, P8, max_new_tokens=12,
                             temperature=0.8, top_k=8))


def test_preempt_resume_bit_identical_tiers(olmo):
    cfg, params = olmo
    kw = dict(quant=Q8, tiers="w8a8,w4a8")
    sched, r1, r2 = _preempt_scenario(
        cfg, params, r1_kw=dict(tier="w8a8"), r2_kw=dict(tier="w4a8"), **kw)
    assert r1.degraded_to is None          # preemption never degrades
    assert r1.out_tokens == _solo(
        cfg, params, Request(1, P8, max_new_tokens=12, tier="w8a8"), **kw)
    assert r2.out_tokens == _solo(
        cfg, params, Request(2, P16, max_new_tokens=8, tier="w4a8"), **kw)


def test_preempt_resume_bit_identical_speculative(olmo):
    cfg, params = olmo
    kw = dict(quant=Q8, speculate=2, draft_policy="w4a8")
    sched, r1, r2 = _preempt_scenario(cfg, params, **kw)
    # Contract is transitive: spec == non-spec == uninterrupted.
    assert r1.out_tokens == _solo(
        cfg, params, Request(1, P8, max_new_tokens=12), quant=Q8)
    assert r2.out_tokens == _solo(
        cfg, params, Request(2, P16, max_new_tokens=8), quant=Q8)


def test_preempted_twice_never(olmo):
    """Anti-thrash: a request that has already been preempted is never
    chosen to make room again — it waits instead."""
    cfg, params = olmo
    sched, r1, _ = _preempt_scenario(cfg, params)
    assert r1.preemptions == 1
    assert sched.preemptions == 1


# -- victim policies -------------------------------------------------------


def _two_live_plus_head(cfg, params, head_kw=None, r1_kw=None, r2_kw=None,
                        **sched_kw):
    """Rows 1 (5+ blocks) and 2 (3 blocks) live; request 3 needs more
    than the remaining pool, forcing a victim choice between them."""
    sched_kw.setdefault("max_batch", 3)
    sched_kw.setdefault("pool_blocks", 12)
    sched = _sched(cfg, params, **sched_kw)
    r1 = Request(1, P11, max_new_tokens=12, **(r1_kw or {}))
    r2 = Request(2, P8, max_new_tokens=4, **(r2_kw or {}))
    sched.submit(r1)
    sched.submit(r2)
    sched.step()
    r3 = Request(3, P16, max_new_tokens=8, **(head_kw or {}))
    sched.submit(r3)
    _drain(sched)
    assert all(r.error is None for r in (r1, r2, r3))
    return sched, r1, r2, r3


def test_victim_policy_most_blocks(olmo):
    cfg, params = olmo
    sched, r1, r2, _ = _two_live_plus_head(cfg, params,
                                           victim_policy="most-blocks")
    assert r1.preemptions == 1 and r2.preemptions == 0


def test_victim_policy_lowest_tier(olmo):
    """lowest-tier evicts the cheapest-precision slot (least recompute
    cost) even though the other frees more blocks."""
    cfg, params = olmo
    sched, r1, r2, _ = _two_live_plus_head(
        cfg, params, victim_policy="lowest-tier",
        quant=Q8, tiers="w8a8,w2a8",
        r1_kw=dict(tier="w8a8"), r2_kw=dict(tier="w2a8"),
        head_kw=dict(tier="w8a8"))
    assert r2.preemptions == 1 and r1.preemptions == 0


def test_victim_policy_latest_deadline(olmo):
    """latest-deadline evicts the slot with the most slack: a request
    with no deadline outranks one racing a step budget."""
    cfg, params = olmo
    sched, r1, r2, _ = _two_live_plus_head(
        cfg, params, victim_policy="latest-deadline",
        r1_kw=dict(deadline_steps=60))
    assert r2.preemptions == 1 and r1.preemptions == 0


def test_bad_victim_policy_rejected(olmo):
    cfg, params = olmo
    with pytest.raises(ValueError, match="victim_policy"):
        _sched(cfg, params, victim_policy="coin-flip")


def test_preempt_requires_paged_pool(olmo):
    cfg, params = olmo
    with pytest.raises(ValueError, match="preempt"):
        _sched(cfg, params, paged=False, preempt=True)


# -- head-of-line bypass & starvation freedom ------------------------------


def test_bounded_bypass_is_starvation_free(olmo):
    """With preemption off, a pool-blocked big head lets smaller arrivals
    through — but only max_head_bypass consecutive times, so the head
    admits (and finishes) once capacity frees instead of starving behind
    an endless small stream."""
    cfg, params = olmo
    admitted = []                     # first-token emission == admission

    def first_seen(req, tok):
        if req.rid not in admitted:
            admitted.append(req.rid)

    sched = _sched(cfg, params, pool_blocks=8, preempt=False,
                   max_head_bypass=2, on_token=first_seen)
    hog = Request(0, P8, max_new_tokens=20)
    sched.submit(hog)
    sched.step()
    big = Request(1, P16, max_new_tokens=4)
    smalls = [Request(10 + i, P4 + i, max_new_tokens=1) for i in range(4)]
    sched.submit(big)
    for s in smalls:
        sched.submit(s)
    done = _drain(sched)
    assert all(r.error is None for r in done)
    stats = sched.pool_stats()
    assert stats["preemptions"] == 0          # preempt=False honoured
    assert stats["pool_pressure_events"] > 0
    assert stats["queue_wait_steps"] > 0
    assert stats["head_bypasses"] == 2        # the bound, not the stream
    # Exactly the bounded number of smalls were ADMITTED past the blocked
    # head; the rest waited their FIFO turn behind it.
    assert admitted.index(10) < admitted.index(1)
    assert admitted.index(11) < admitted.index(1)
    assert admitted.index(1) < admitted.index(12)
    assert admitted.index(1) < admitted.index(13)


# -- graceful degradation --------------------------------------------------


def test_degrade_under_sustained_pressure(olmo):
    """--degrade: after degrade_after consecutive pressure steps, new
    admissions are pinned (for life) to the cheapest tier — and the
    degraded stream is bitwise the solo run of that tier."""
    cfg, params = olmo
    kw = dict(quant=Q8, tiers="w8a8,w2a8")
    sched = _sched(cfg, params, pool_blocks=8, preempt=False,
                   degrade=True, degrade_after=1, **kw)
    hog = Request(0, P11, max_new_tokens=10, tier="w8a8")
    sched.submit(hog)
    sched.step()
    late = Request(1, P16, max_new_tokens=6, tier="w8a8")
    sched.submit(late)
    _drain(sched)
    assert late.error is None
    assert late.degraded_to == "w2a8"
    assert hog.degraded_to is None
    assert sched.degraded_requests == 1
    low = _solo(cfg, params,
                Request(1, P16, max_new_tokens=6, tier="w2a8"), **kw)
    asked = _solo(cfg, params,
                  Request(1, P16, max_new_tokens=6, tier="w8a8"), **kw)
    assert late.out_tokens == low
    assert late.out_tokens != asked   # the degradation is real


def test_degrade_requires_tiers(olmo):
    cfg, params = olmo
    with pytest.raises(ValueError, match="degrade"):
        _sched(cfg, params, degrade=True)
