"""Host-RAM spill tier under the paged pool + durable prefix index.

The headline contract extends PR 5's warm≡cold row: a prefix chunk that
was evicted to the host store and swapped back into a free device slot
serves EXACTLY the tokens a cold prefill would — across the bf16 and
int8 pools, precision tiers, and mid-decode admission. The tier must be
invisible in the outputs and visible only in the swap/host-hit counters.
Alongside: `block-to-host` preemption (the victim's resident K/V spills
to host instead of dying with the slot), the host byte budget, and the
versioned JSON prefix index surviving process restarts and scheduler
rebuilds with a warm hit-rate > 0.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.quant import QuantConfig
from repro.models import build_model
from repro.serving import (
    ContinuousScheduler,
    Request,
    ServingEngine,
    assert_pool_invariants,
)

KEY = jax.random.PRNGKey(0)
Q8 = QuantConfig(w_bits=8, a_bits=8)
SYS = np.arange(24) % 64                      # shared prefix: 6 blocks @4
HOSTKB = 1 << 20                              # roomy host budget


@pytest.fixture(scope="module")
def olmo():
    cfg = get_reduced_config("olmo-1b")
    params = build_model(cfg).init(KEY)
    return cfg, params


@pytest.fixture(scope="module")
def olmo_int8():
    cfg = dataclasses.replace(get_reduced_config("olmo-1b"),
                              kv_cache_quant=True)
    params = build_model(cfg).init(KEY)
    return cfg, params


def _sched(cfg, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_ctx", 48)
    kw.setdefault("bucket", 16)
    kw.setdefault("paged", True)
    kw.setdefault("block_size", 4)
    kw.setdefault("chunked_prefill", False)
    return ContinuousScheduler(cfg, params, **kw)


def _drain(sched, cap=400):
    out, steps = [], 0
    while sched.num_active or sched.num_waiting:
        out.extend(sched.step())
        steps += 1
        assert steps < cap, "scheduler failed to drain (deadlock?)"
    assert_pool_invariants(sched)
    return out


def _requests(n=4, tail=3, max_new=4, **kw):
    rng = np.random.default_rng(7)
    return [Request(rid=i,
                    prompt=np.concatenate(
                        [SYS, rng.integers(0, 64, tail + i)]).astype(np.int64),
                    max_new_tokens=max_new, temperature=0.0, **kw)
            for i in range(n)]


def _serve_twice(cfg, params, host_bytes, **kw):
    """Serve the same request stream twice through one scheduler (pool
    small enough that round 1's cached blocks get evicted before round
    2), returning (sched, round1 tokens, round2 tokens)."""
    kw.setdefault("pool_blocks", 14)
    sched = _sched(cfg, params, host_pool_bytes=host_bytes, **kw)
    a = _requests()
    sched.run(a)
    assert_pool_invariants(sched)
    b = _requests()
    sched.run(b)
    assert_pool_invariants(sched)
    return (sched, [r.out_tokens for r in a], [r.out_tokens for r in b])


# -- the bit-identity contract --------------------------------------------


@pytest.mark.parametrize("fixture", ["olmo", "olmo_int8"])
def test_warm_from_host_bit_identical(fixture, request):
    """Round 2 re-serves round 1's prompts after the pool churned their
    blocks out to host; every stream must equal the no-host-tier run,
    and the swap counters must show the tier actually carried hits."""
    cfg, params = request.getfixturevalue(fixture)
    _, c1, c2 = _serve_twice(cfg, params, 0)
    sched, h1, h2 = _serve_twice(cfg, params, HOSTKB)
    assert h1 == c1 and h2 == c2
    st = sched.pool_stats()
    assert st["host_tier"] and st["swap_outs"] > 0
    assert st["swap_ins"] > 0 and st["host_hit_blocks"] > 0
    assert st["host_hit_rate"] > 0
    assert st["host_bytes"] <= st["host_pool_bytes"]


@pytest.mark.slow
def test_warm_from_host_bit_identical_tiers(olmo):
    """Digest chains are tier-scoped, so a w4a8 request never hits a
    w8a8 chunk — through the host tier too."""
    cfg, params = olmo
    kw = dict(quant=Q8, tiers="w8a8,w4a8")

    def reqs():
        rng = np.random.default_rng(7)
        return [Request(rid=i,
                        prompt=np.concatenate(
                            [SYS, rng.integers(0, 64, 3 + i)]
                        ).astype(np.int64),
                        max_new_tokens=4, temperature=0.0,
                        tier=("w8a8", "w4a8")[i % 2])
                for i in range(4)]

    def toks(done):
        return [r.out_tokens for r in sorted(done, key=lambda r: r.rid)]

    cold = _sched(cfg, params, pool_blocks=14, **kw)
    cold.run(reqs())
    c1 = toks(cold.run(reqs()))
    warm = _sched(cfg, params, pool_blocks=14,
                  host_pool_bytes=HOSTKB, **kw)
    warm.run(reqs())
    w1 = toks(warm.run(reqs()))
    assert w1 == c1
    assert_pool_invariants(warm)
    assert warm.pool_stats()["swap_ins"] > 0


def test_warm_from_host_mid_decode(olmo):
    """A host-resident prefix admitted while another row is mid-decode
    swaps back in without disturbing either stream."""
    cfg, params = olmo

    def run(host_bytes):
        sched = _sched(cfg, params, pool_blocks=14,
                       host_pool_bytes=host_bytes)
        sched.run(_requests())               # populate, then churn out
        long = Request(90, (np.arange(9) * 5 + 1) % 64, max_new_tokens=10,
                       temperature=0.0)
        sched.submit(long)
        for _ in range(3):
            sched.step()
        rejoin = _requests(n=1, max_new=6)[0]
        sched.submit(rejoin)
        _drain(sched)
        return sched, long.out_tokens, rejoin.out_tokens

    _, cold_long, cold_rejoin = run(0)
    sched, warm_long, warm_rejoin = run(HOSTKB)
    assert warm_long == cold_long
    assert warm_rejoin == cold_rejoin
    assert sched.pool_stats()["swap_ins"] > 0


# -- block-to-host preemption ---------------------------------------------


def test_block_to_host_preempt_resume_bit_identical(olmo):
    """Preemption with victim_policy=block-to-host spills the victim's
    resident blocks to host; its warm resume still produces exactly the
    uninterrupted stream."""
    cfg, params = olmo
    P8 = (np.arange(8) * 3 + 1) % 64
    P16 = (np.arange(16) * 7 + 3) % 64

    def scenario(**kw):
        sched = _sched(cfg, params, pool_blocks=10, max_ctx=64, **kw)
        r1 = Request(1, P8, max_new_tokens=12)
        r2 = Request(2, P16, max_new_tokens=8)
        sched.submit(r1)
        for _ in range(3):
            sched.step()
        sched.submit(r2)
        _drain(sched)
        assert r1.error is None and r2.error is None
        return sched, r1, r2

    solo = _sched(cfg, params, pool_blocks=64, max_ctx=64)
    ref1 = Request(1, P8, max_new_tokens=12)
    ref2 = Request(2, P16, max_new_tokens=8)
    solo.run([ref1]); solo.run([ref2])  # noqa: E702

    sched, r1, r2 = scenario(host_pool_bytes=HOSTKB,
                             victim_policy="block-to-host")
    assert sched.preemptions >= 1 and r1.preemptions >= 1
    assert r1.out_tokens == ref1.out_tokens
    assert r2.out_tokens == ref2.out_tokens
    st = sched.pool_stats()
    assert st["victim_policy"] == "block-to-host"
    assert st["swap_outs"] > 0
    assert st["prefix_hit_tokens"] >= len(P8)


def test_block_to_host_requires_host_tier(olmo):
    cfg, params = olmo
    with pytest.raises(ValueError, match="block-to-host"):
        _sched(cfg, params, victim_policy="block-to-host")
    with pytest.raises(ValueError, match="host_pool_bytes"):
        _sched(cfg, params, paged=False, host_pool_bytes=HOSTKB)


# -- the byte budget -------------------------------------------------------


def test_host_budget_evicts_oldest(olmo):
    """A budget smaller than the working set evicts oldest-first and
    never overshoots; the pool invariants (incl. host-byte conservation)
    hold throughout."""
    cfg, params = olmo
    probe = _sched(cfg, params, host_pool_bytes=HOSTKB)
    one = probe._host_block_nbytes()
    budget = 2 * one                        # room for exactly two blocks
    sched, _, _ = _serve_twice(cfg, params, budget)
    st = sched.pool_stats()
    assert st["host_bytes"] <= budget
    assert st["host_blocks"] <= 2
    assert st["host_evictions"] > 0
    assert_pool_invariants(sched)


# -- durable prefix index --------------------------------------------------


def _engine(cfg, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("bucket", 16)
    kw.setdefault("paged", True)
    kw.setdefault("block_size", 4)
    kw.setdefault("pool_blocks", 40)
    kw.setdefault("chunked_prefill", False)
    kw.setdefault("preempt", False)
    kw.setdefault("host_pool_bytes", HOSTKB)
    return ServingEngine(cfg, params, **kw)


@pytest.mark.parametrize("fixture", ["olmo", "olmo_int8"])
def test_index_survives_restart(fixture, request, tmp_path):
    """save_index → fresh engine → load_index (deferred until the first
    scheduler build) serves the repeat stream warm-from-host, tokens
    bitwise the first process's."""
    cfg, params = request.getfixturevalue(fixture)
    path = tmp_path / "idx.json"
    e1 = _engine(cfg, params)
    out1 = [r.out_tokens for r in e1.generate(_requests())]
    assert e1.save_index(path) > 0

    e2 = _engine(cfg, params)
    assert e2.load_index(path) > 0          # deferred: no scheduler yet
    out2 = [r.out_tokens for r in e2.generate(_requests())]
    assert out2 == out1
    st = e2.pool_stats()
    assert st["host_hit_rate"] > 0 and st["swap_ins"] > 0
    assert_pool_invariants(e2._sched)


def test_index_survives_scheduler_rebuild(olmo):
    """A max_ctx-growth rebuild re-imports the old scheduler's exported
    index into the new host tier: re-admissions after the rebuild hit
    warm (acceptance criterion: hit-rate > 0 across a rebuild)."""
    cfg, params = olmo
    eng = _engine(cfg, params)
    out1 = [r.out_tokens for r in eng.generate(_requests())]
    old = eng._sched
    big = Request(99, np.concatenate([SYS, np.arange(40) % 64]).astype(
        np.int64), max_new_tokens=4, temperature=0.0)
    eng.generate([big])
    assert eng._sched is not old, "growth should have rebuilt"
    out2 = [r.out_tokens for r in eng.generate(_requests())]
    assert out2 == out1
    st = eng.pool_stats()
    assert st["host_hit_rate"] > 0
    assert_pool_invariants(eng._sched)


def test_index_roundtrip_before_first_generate(olmo, tmp_path):
    """An engine that loaded an index but never served can still save it
    back verbatim (the --index flag's save-on-exit path)."""
    cfg, params = olmo
    path, path2 = tmp_path / "a.json", tmp_path / "b.json"
    e1 = _engine(cfg, params)
    e1.generate(_requests())
    n = e1.save_index(path)
    e2 = _engine(cfg, params)
    assert e2.load_index(path) == n
    assert e2.save_index(path2) == n


def test_index_geometry_mismatch_cold_starts(olmo, tmp_path):
    """An index saved from a different pool geometry (block size) warns
    and loads nothing — never crashes, never corrupts the pool."""
    cfg, params = olmo
    path = tmp_path / "idx.json"
    e1 = _engine(cfg, params, block_size=4)
    e1.generate(_requests())
    e1.save_index(path)
    other = _engine(cfg, params, block_size=8)
    other.generate(_requests(n=1))
    with pytest.warns(UserWarning, match="geometry"):
        assert other._sched.load_index(path) == 0
    assert_pool_invariants(other._sched)


def test_import_skipped_when_tier_off(olmo, tmp_path):
    cfg, params = olmo
    path = tmp_path / "idx.json"
    e1 = _engine(cfg, params)
    e1.generate(_requests(n=2))
    e1.save_index(path)
    off = _engine(cfg, params, host_pool_bytes=0)
    off.generate(_requests(n=1))
    with pytest.warns(UserWarning, match="host"):
        assert off._sched.load_index(path) == 0
    assert_pool_invariants(off._sched)
