"""Partition rules + small-mesh jit integration (subprocess: 4 devices)."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_reduced_config
from repro.models import build_model
from repro.parallel import sharding as sh

REPO = Path(__file__).resolve().parents[1]


def test_rules_cover_all_arch_params():
    """Every 2-D+ parameter of every arch must match a non-default rule or
    be a small vector (norms/biases). Catches renamed params silently
    falling to replicated."""
    rules = sh.default_param_rules(fsdp=True)
    for arch in ARCH_IDS:
        cfg = get_reduced_config(arch)
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
        leaves = jax.tree_util.tree_flatten_with_path(shapes)[0]
        for path, leaf in leaves:
            pstr = sh.tree_path_str(path)
            if leaf.ndim < 2 or min(leaf.shape[-2:]) < 16:
                continue
            matched = None
            import re

            for pat, template in rules:
                if re.fullmatch(pat, pstr):
                    matched = template
                    break
            assert matched is not None and matched != (), (arch, pstr, leaf.shape)


def test_spec_fit_drops_indivisible_axes():
    mesh_shape = {"data": 4, "model": 4}

    class FakeMesh:
        axis_names = tuple(mesh_shape)

        class devices:
            shape = tuple(mesh_shape.values())

    spec = sh._fit_spec(("data", "model"), (6, 16), FakeMesh)
    assert spec == jax.sharding.PartitionSpec(None, "model")
    spec = sh._fit_spec(("model",), (3, 8), FakeMesh)  # left-pad stacked dims
    assert spec == jax.sharding.PartitionSpec(None, "model")


def test_constrain_noop_without_mesh():
    sh.set_mesh_context(None)
    x = jnp.ones((4, 4))
    assert sh.constrain(x, "batch", None) is x


def test_batch_sharding_fallback_for_batch_one():
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, sys
sys.path.insert(0, "SRC")
from repro.parallel import sharding as sh
mesh = jax.make_mesh((2, 2), ("data", "model"))
s = sh.batch_sharding(mesh, jax.ShapeDtypeStruct((1, 8), jnp.int32), ("data",))
assert s.spec == jax.sharding.PartitionSpec(), s.spec
s = sh.batch_sharding(mesh, jax.ShapeDtypeStruct((4, 8), jnp.int32), ("data",))
assert s.spec == jax.sharding.PartitionSpec(("data",), None), s.spec
print("OK")
""".replace("SRC", str(REPO / "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr


def test_small_mesh_sharded_train_step_executes():
    """End-to-end: reduced olmo train step under a 2×2 mesh with the
    production partition rules — values must match the unsharded step."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np, sys
sys.path.insert(0, "SRC")
from repro.configs import get_reduced_config
from repro.configs.base import TrainConfig
from repro.models import build_model
from repro.parallel import sharding as sh
from repro.train.loop import init_train_state, make_train_step
from repro.data import DataIterator

cfg = get_reduced_config("olmo-1b")
model = build_model(cfg)
tc = TrainConfig(lr=1e-3)
params = model.init(jax.random.PRNGKey(0))
state = init_train_state(params, tc)
batch = jax.tree_util.tree_map(jnp.asarray,
                               DataIterator(cfg, 4, 32, seed=0).batch_at(0))
step = make_train_step(model, tc)
_, m_ref = step(state, batch)

mesh = jax.make_mesh((2, 2), ("data", "model"))
sh.set_mesh_context(mesh, ("data",))
pshard = sh.make_param_shardings(params, mesh, fsdp=True)
from repro.optim import adamw
state_sh = jax.device_put(state, type(state)(
    params=pshard,
    opt=adamw.AdamState(step=sh.replicated(mesh), mu=pshard, nu=pshard),
    err=None))
bshard = jax.tree_util.tree_map(
    lambda s: sh.batch_sharding(mesh, s, ("data",)), batch)
batch_sh = jax.device_put(batch, bshard)
with mesh:
    _, m = jax.jit(step)(state_sh, batch_sh)
np.testing.assert_allclose(float(m["loss"]), float(m_ref["loss"]), rtol=2e-2)
print("OK", float(m["loss"]), float(m_ref["loss"]))
""".replace("SRC", str(REPO / "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
