"""Gradient compression with error feedback + hetero partitioner."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hetero import EngineRate, balanced_group_ratio, split_q, tile_latency, utilization
from repro.parallel import collectives


def test_block_quant_roundtrip_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, s = collectives.quantize_block(x, bits=8, block=256)
    back = collectives.dequantize_block(q, s, x.shape, block=256)
    # per-block absmax 8-bit: error <= scale/2 per element
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert err.max() <= float(jnp.max(s)) * 0.5 + 1e-6


def test_error_feedback_accumulates_residual():
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.standard_normal((64,)), jnp.float32)}
    err = collectives.init_error(g)
    # constant gradient: with EF, the *running sum* of decompressed grads
    # converges to the running sum of true grads.
    total_true = np.zeros(64)
    total_deq = np.zeros(64)
    for _ in range(50):
        _, err, deq = collectives.compress_gradients(g, err, bits=4)
        total_true += np.asarray(g["w"])
        total_deq += np.asarray(deq["w"])
    rel = np.abs(total_deq - total_true).max() / np.abs(total_true).max()
    assert rel < 0.02


def test_compressed_bytes_ratio():
    g = {"w": jnp.zeros((4096,), jnp.float32)}
    cb = collectives.compressed_bytes(g, bits=8, block=256)
    raw = 4096 * 4
    assert cb < raw / 3  # ≥3x reduction incl. scale overhead


def test_split_q_balance():
    bpe = EngineRate("bpe", 30.0)
    dsp = EngineRate("dsp", 10.0)
    qb, qd = split_q(16, bpe, dsp)
    assert qb + qd == 16 and qb == 12
    assert split_q(8, EngineRate("x", 0.0), dsp) == (0, 8)
    assert split_q(8, bpe, EngineRate("x", 0.0)) == (8, 0)
    with pytest.raises(ValueError):
        split_q(8, EngineRate("a", 0.0), EngineRate("b", 0.0))


def test_tile_latency_max_semantics():
    t, qb, qd = tile_latency(1000.0, 10, EngineRate("b", 10.0), EngineRate("d", 10.0))
    assert qb == qd == 5
    assert t == pytest.approx(50.0)


def test_balanced_group_ratio():
    assert balanced_group_ratio(1.0, 1.0) == pytest.approx(0.5)
    assert balanced_group_ratio(1.0, 3.0) == pytest.approx(0.25)
    assert balanced_group_ratio(0.0, 3.0) == 0.0


def test_utilization():
    assert utilization(16, 4, 4) == 1.0
    assert utilization(17, 4, 4) == pytest.approx(17 / 32)
    assert utilization(0, 4, 4) == 0.0
