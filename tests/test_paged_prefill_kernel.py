"""Fused paged chunked-prefill kernel vs the scatter-then-attend oracle.

The specification is ``ref.paged_prefill_ref``: write the chunk's K/V
into the row's pool blocks (``kv_cache.paged_chunk_write``), gather the
whole table back, and attend the chunk's queries causally over
[pool-resident prefix ++ chunk]. The fused kernel must reproduce it
*bitwise* — attention output, pool bytes, and int8 scale planes — across
cold and warm prefixes, partial-block chunk starts, padded chunks, both
pool dtypes, softcap, and every head tiling.

Two contract subtleties the tests encode:

* The reference is compared **jitted**. Eager ``quantize_kv`` compiles
  ``absmax / 127.0`` differently from the jitted strength-reduced form
  (1 ULP on some scales); every real consumer (scheduler, transformer)
  runs jitted, so the bitwise contract is stated in the jit context.
* Pool block 0 is the trash block: freed/non-destination writes land
  there and its contents are undefined, so pool comparisons skip it.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels.registry import get_registry, pick_paged_prefill_blocks

BS = 4          # pool block size
NKV, G, H = 2, 3, 16


def _case(seed, *, quantized, start, length, lc, mb, alloc):
    """Random pool + one row's block table with `alloc` live blocks.
    The chunk covers prompt positions [start, start+length) inside an
    Lc=`lc` padded call; `start` need not be block-aligned (warm prefix
    ending mid-block)."""
    assert start + length <= alloc * BS
    rng = np.random.default_rng(seed)
    nb = 8
    if quantized:
        pk = jnp.asarray(rng.integers(-128, 128, (nb, BS, NKV, H)), jnp.int8)
        pv = jnp.asarray(rng.integers(-128, 128, (nb, BS, NKV, H)), jnp.int8)
        ks = jnp.asarray(rng.random((nb, BS, NKV, 1)) * 0.02, jnp.float32)
        vs = jnp.asarray(rng.random((nb, BS, NKV, 1)) * 0.02, jnp.float32)
    else:
        pk = jnp.asarray(rng.standard_normal((nb, BS, NKV, H)), jnp.bfloat16)
        pv = jnp.asarray(rng.standard_normal((nb, BS, NKV, H)), jnp.bfloat16)
        ks = vs = None
    q = jnp.asarray(rng.standard_normal((1, lc, NKV * G, H)), jnp.bfloat16)
    kn = jnp.asarray(rng.standard_normal((1, lc, NKV, H)), jnp.bfloat16)
    vn = jnp.asarray(rng.standard_normal((1, lc, NKV, H)), jnp.bfloat16)
    blocks = np.full(mb, -1, np.int32)
    blocks[:alloc] = rng.permutation(np.arange(1, nb))[:alloc]
    return (q, kn, vn, pk, pv, jnp.asarray(blocks),
            jnp.int32(start), jnp.int32(length), ks, vs)


def _both(case, *, bh, softcap=0.0):
    ref = jax.jit(functools.partial(kref.paged_prefill_ref,
                                    softcap=softcap))(
        *case[:8], k_scale=case[8], v_scale=case[9])
    out = ops.paged_prefill(*case[:8], k_scale=case[8], v_scale=case[9],
                            softcap=softcap, blocks_plan=(bh, BS, H),
                            backend="interpret")
    return ref, out


def _assert_bitwise(ref, out):
    names = ("attn", "pool_k", "pool_v", "k_scale", "v_scale")
    for name, r, o in zip(names, ref, out):
        if r is None:
            assert o is None
            continue
        r, o = np.asarray(r), np.asarray(o)
        if name != "attn":
            r, o = r[1:], o[1:]  # trash block: contents undefined
        assert np.array_equal(r, o), name


@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("bh", [1, 2])
def test_cold_full_chunk_bitwise(quantized, bh):
    """Cold prefill, chunk fills the call exactly: attention and the
    written pool blocks match the oracle bit-for-bit."""
    case = _case(0, quantized=quantized, start=0, length=8, lc=8,
                 mb=4, alloc=2)
    _assert_bitwise(*_both(case, bh=bh))


@pytest.mark.parametrize("quantized", [False, True])
def test_warm_prefix_partial_block_start(quantized):
    """Chunk starts mid-block (warm prefix of 6 tokens, bs=4): the
    kernel merges pool-resident rows with chunk rows inside the shared
    block and never clobbers the resident prefix."""
    case = _case(1, quantized=quantized, start=6, length=7, lc=8,
                 mb=6, alloc=4)
    _assert_bitwise(*_both(case, bh=2))


@pytest.mark.parametrize("start,length,lc", [(0, 5, 8), (9, 1, 4), (4, 0, 4)])
def test_padded_short_and_empty_chunks(start, length, lc):
    """length < Lc (padded tail), a single-token chunk, and the empty
    chunk: padded query rows produce zeros, padded K/V rows never reach
    the pool, and a zero-length call is the identity on the pool."""
    case = _case(2, quantized=False, start=start, length=length, lc=lc,
                 mb=4, alloc=3)
    ref, out = _both(case, bh=2)
    _assert_bitwise(ref, out)
    if length == 0:
        assert np.array_equal(np.asarray(out[1])[1:],
                              np.asarray(case[3])[1:])


def test_softcap_int8():
    """Logit softcap composes with in-kernel dequantization."""
    case = _case(3, quantized=True, start=3, length=6, lc=8, mb=6, alloc=3)
    _assert_bitwise(*_both(case, bh=2, softcap=30.0))


def test_resident_prefix_blocks_untouched():
    """Blocks wholly before the chunk start keep their exact input
    bytes — the epilogue only writes destination blocks (j >= start//bs),
    everything earlier aliases through unchanged."""
    case = _case(4, quantized=False, start=8, length=4, lc=4, mb=4, alloc=3)
    _, out = _both(case, bh=2)
    tbl = np.asarray(case[5])
    for blk in tbl[:2]:  # blocks 0,1 cover positions [0, 8) — all prefix
        assert np.array_equal(np.asarray(out[1])[blk],
                              np.asarray(case[3])[blk])
        assert np.array_equal(np.asarray(out[2])[blk],
                              np.asarray(case[4])[blk])


def test_trash_block_garbage_never_leaks():
    """Huge garbage in pool block 0 (where dead writes land) must not
    change the chunk's attention output."""
    case = _case(5, quantized=False, start=4, length=6, lc=8, mb=4, alloc=3)
    clean = ops.paged_prefill(*case[:8], backend="interpret",
                              blocks_plan=(2, BS, H))
    pk = case[3].at[0].set(jnp.full(case[3].shape[1:], 1e4, case[3].dtype))
    pv = case[4].at[0].set(jnp.full(case[4].shape[1:], 1e4, case[4].dtype))
    dirty = ops.paged_prefill(*case[:3], pk, pv, *case[5:8],
                              backend="interpret", blocks_plan=(2, BS, H))
    assert np.array_equal(np.asarray(clean[0]), np.asarray(dirty[0]))


def test_reference_backend_dispatch():
    """backend="reference" routes to paged_prefill_ref itself."""
    case = _case(6, quantized=False, start=0, length=8, lc=8, mb=4, alloc=2)
    out = ops.paged_prefill(*case[:8], backend="reference")
    ref = jax.jit(kref.paged_prefill_ref)(*case[:8])
    for r, o in zip(ref[:3], out[:3]):
        np.testing.assert_allclose(np.asarray(r, np.float32),
                                   np.asarray(o, np.float32))


# -- registry plan plumbing --------------------------------------------------


def test_planner_registered_and_divides_heads():
    """The paged_prefill planner returns a head tile that divides n_kv
    and shrinks under a tight VMEM budget."""
    bh, bs, h = pick_paged_prefill_blocks(4, BS, H)
    assert bh >= 1 and 4 % bh == 0 and (bs, h) == (BS, H)
    tight = pick_paged_prefill_blocks(4, 128, 128, vmem_budget=1 << 16)
    assert tight[0] == 1


def test_plan_round_trips_through_file(tmp_path):
    """A recorded paged_prefill plan survives save_plans/load_plans and
    overrides the heuristic afterwards."""
    reg = get_registry()
    reg.record_plan("paged_prefill", 2, BS, H, (1, BS, H), "interpret")
    path = tmp_path / "plans.json"
    assert reg.save_plans(str(path)) >= 1
    reg._plans.pop(("paged_prefill", "interpret", (2, BS, H)))
    assert reg.load_plans(str(path)) >= 1
    assert reg.paged_prefill_plan(2, BS, H, backend="interpret") == (1, BS, H)
