"""Sarathi-style chunked prefill: bit-identity with whole-prompt prefill
and the decode-stall bound.

The contract: splitting admission prefill into ``prefill_budget``-token
chunks through the fused paged-prefill kernel changes *scheduling only*.
Every request's greedy tokens are bit-identical to the solo static
baseline across chunk sizes (including budget=1 and non-divisors of the
block size), mid-decode admission, the int8 pool, and warm prefix hits —
and a live decoding slot never loses more than one chunk's worth of time
per scheduler step to an admission in progress (each step runs at most
one budgeted chunk, and decode always runs alongside it)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.serving import ContinuousScheduler, Request, ServingEngine

KEY = jax.random.PRNGKey(0)
BS = 4  # paged block size used throughout — small, so chunks cross blocks
PROMPT_SHORT = np.arange(8) % 64
PROMPT_LONG = (np.arange(23) * 5 + 2) % 64  # not a multiple of BS or bucket


@pytest.fixture(scope="module")
def olmo():
    cfg = get_reduced_config("olmo-1b")
    params = build_model(cfg).init(KEY)
    return cfg, params


def _solo(cfg, params, prompt, n):
    eng = ServingEngine(cfg, params, max_batch=2, bucket=16)
    return eng.generate_static(
        [Request(1, prompt, max_new_tokens=n)])[0].out_tokens


def _sched(cfg, params, budget, **kw):
    kw.setdefault("max_ctx", 64)
    return ContinuousScheduler(cfg, params, max_batch=2, bucket=16,
                               paged=True, block_size=BS,
                               chunked_prefill=True, prefill_budget=budget,
                               **kw)


def _drain(sched):
    out = []
    while sched.num_active or sched.num_waiting:
        out.extend(sched.step())
    return out


@pytest.mark.parametrize("budget", [1, BS - 1, BS, 3 * BS + 1])
def test_chunked_matches_whole_prefill(olmo, budget):
    """Every chunk size in the satellite sweep — a single token, one
    short of a block, exactly a block, and a non-divisor spanning three
    blocks — reproduces the solo static baseline bit-for-bit."""
    cfg, params = olmo
    ref = _solo(cfg, params, PROMPT_LONG, 6)
    sched = _sched(cfg, params, budget)
    sched.submit(Request(1, PROMPT_LONG, max_new_tokens=6))
    done = _drain(sched)
    assert done[0].out_tokens == ref
    assert sched.prefill_chunks_run == -(-len(PROMPT_LONG) // budget)


@pytest.mark.parametrize("kv_int8", [False, True])
def test_mid_decode_admission(olmo, kv_int8):
    """A long prompt admitted into a live decoding batch: both the
    in-flight request and the chunk-admitted one match their solo runs,
    on the bf16 and the int8 pool."""
    cfg, params = olmo
    if kv_int8:
        cfg = dataclasses.replace(cfg, kv_cache_quant=True)
    ref_a = _solo(cfg, params, PROMPT_SHORT, 12)
    ref_b = _solo(cfg, params, PROMPT_LONG, 6)
    sched = _sched(cfg, params, 5)
    sched.submit(Request(0, PROMPT_SHORT, max_new_tokens=12))
    done = []
    for _ in range(3):
        done.extend(sched.step())
    sched.submit(Request(1, PROMPT_LONG, max_new_tokens=6))
    done.extend(_drain(sched))
    got = {r.rid: r.out_tokens for r in done}
    assert got[0] == ref_a
    assert got[1] == ref_b
    assert sched.prefill_chunks_run > 0


def test_warm_prefix_then_chunked_tail(olmo):
    """Prefix cache + chunked prefill compose: the second request's warm
    block-aligned prefix stays resident (never rewritten by the chunk
    kernel) and only the uncached tail is chunk-prefilled."""
    cfg, params = olmo
    sched = _sched(cfg, params, 5, prefix_cache=True)
    sched.submit(Request(0, PROMPT_LONG, max_new_tokens=6))
    done = _drain(sched)
    chunks_cold = sched.prefill_chunks_run
    ext = np.concatenate([PROMPT_LONG, np.asarray([9, 11, 13])])
    sched.submit(Request(1, ext, max_new_tokens=6))
    done.extend(_drain(sched))
    got = {r.rid: r.out_tokens for r in done}
    assert got[0] == _solo(cfg, params, PROMPT_LONG, 6)
    assert got[1] == _solo(cfg, params, ext, 6)
    # 20 of 26 tokens warm (5 whole blocks): the tail is one 6-token plan.
    assert sched.prefix_hit_tokens == 20
    assert sched.prefill_chunks_run == chunks_cold + 2


def test_stall_bound_one_chunk_per_step(olmo):
    """While a long admission is chunk-prefilling, the live slot emits a
    token on EVERY scheduler step — no decode step is skipped for more
    than one budget's worth of prefill tokens."""
    cfg, params = olmo
    sched = _sched(cfg, params, 4)
    sched.submit(Request(0, PROMPT_SHORT, max_new_tokens=32))
    for _ in range(2):
        sched.step()
    sched.submit(Request(1, PROMPT_LONG, max_new_tokens=4))
    live = sched._slots.index(next(r for r in sched._slots
                                   if r is not None and r.rid == 0))
    tokens_before = len(sched._slots[live].out_tokens)
    stalled_before = sched.decode_steps_stalled
    steps = 0
    while True:
        start_chunks = sched.prefill_chunk_tokens
        sched.step()  # first iteration admits AND runs the first chunk
        steps += 1
        # at most one budget of prefill tokens spent this step...
        assert sched.prefill_chunk_tokens - start_chunks <= 4
        # ...and the live slot still decoded (one new token per step).
        assert len(sched._slots[live].out_tokens) == tokens_before + steps
        if sched.prefill_chunks_run and not sched._chunk_plans:
            break
    assert steps == -(-len(PROMPT_LONG) // 4)
    assert sched.decode_steps_stalled - stalled_before == steps
    _drain(sched)


def test_counters_in_pool_stats(olmo):
    """pool_stats() surfaces the interleave counters serve.py reports."""
    cfg, params = olmo
    sched = _sched(cfg, params, 8)
    sched.submit(Request(0, PROMPT_LONG, max_new_tokens=4))
    _drain(sched)
    stats = sched.pool_stats()
    assert stats["chunked_prefill"] is True
    assert stats["prefill_budget"] == 8
    assert stats["prefill_chunks_run"] == sched.prefill_chunks_run == 3
    assert stats["decode_steps_stalled"] == sched.decode_steps_stalled
    assert stats["prefill_tokens_per_step"] > 0


def test_explicit_chunked_on_unpaged_raises(olmo):
    """chunked_prefill=True without the paged pool is a config error
    (auto mode silently falls back instead)."""
    cfg, params = olmo
    with pytest.raises(ValueError, match="chunked prefill"):
        ContinuousScheduler(cfg, params, max_batch=2, max_ctx=64, bucket=16,
                            paged=False, chunked_prefill=True)
    sched = ContinuousScheduler(cfg, params, max_batch=2, max_ctx=64,
                                bucket=16, paged=False)
    assert sched.chunked_prefill is False


def test_engine_threads_knobs(olmo):
    """ServingEngine passes the chunked-prefill knobs through to its
    scheduler, and engine-level generate stays bit-identical to static."""
    cfg, params = olmo
    eng = ServingEngine(cfg, params, max_batch=2, bucket=16,
                        prefill_budget=6)
    reqs = [Request(0, PROMPT_LONG, max_new_tokens=6)]
    out = eng.generate(reqs)[0].out_tokens
    assert out == _solo(cfg, params, PROMPT_LONG, 6)
    sched = eng._sched
    assert sched.prefill_budget == 6 and sched.prefill_chunks_run > 0
    eng2 = ServingEngine(cfg, params, max_batch=2, bucket=16,
                        chunked_prefill=False)
    out2 = eng2.generate([Request(0, PROMPT_LONG, max_new_tokens=6)])
    assert out2[0].out_tokens == out
    assert eng2._sched.prefill_chunks_run == 0
