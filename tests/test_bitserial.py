"""Cycle-exact MAC2 / bit-serial semantics vs integer arithmetic (the
paper's §IV-F dataflow must be *exactly* an integer matmul)."""
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis — deterministic fallback
    from hypothesis_fallback import given, settings, strategies as st

from repro.core import bitplane, bitserial

A_BITS = st.sampled_from([2, 3, 4, 5, 6, 7, 8])
W_BITS = st.sampled_from([2, 4, 8])


@st.composite
def mac2_case(draw):
    ab = draw(A_BITS)
    signed = draw(st.booleans())
    lo, hi = (-(1 << (ab - 1)), (1 << (ab - 1)) - 1) if signed else (0, (1 << ab) - 1)
    n = draw(st.integers(1, 16))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    w1 = rng.integers(-128, 128, n)
    w2 = rng.integers(-128, 128, n)
    i1 = rng.integers(lo, hi + 1, n)
    i2 = rng.integers(lo, hi + 1, n)
    return ab, signed, w1, w2, i1, i2


@settings(max_examples=50, deadline=None)
@given(mac2_case())
def test_mac2_exact(case):
    ab, signed, w1, w2, i1, i2 = case
    got = bitserial.mac2_bitserial(
        jnp.asarray(w1), jnp.asarray(w2), jnp.asarray(i1), jnp.asarray(i2),
        ab, act_signed=signed,
    )
    np.testing.assert_array_equal(np.asarray(got), w1 * i1 + w2 * i2)


@settings(max_examples=25, deadline=None)
@given(A_BITS, st.integers(1, 24), st.integers(1, 8), st.integers(0, 2**31 - 1))
def test_dot_bitserial_is_integer_matmul(ab, k, n, seed):
    rng = np.random.default_rng(seed)
    lo, hi = -(1 << (ab - 1)), (1 << (ab - 1)) - 1
    w = rng.integers(-8, 8, (k, n))
    x = rng.integers(lo, hi + 1, (3, k))
    got = bitserial.dot_bitserial(jnp.asarray(w), jnp.asarray(x), ab)
    np.testing.assert_array_equal(np.asarray(got), x @ w)


@settings(max_examples=25, deadline=None)
@given(A_BITS, st.sampled_from([1, 2]), st.booleans(), st.integers(0, 2**31 - 1))
def test_bitplane_reference_matches(ab, plane_bits, signed, seed):
    rng = np.random.default_rng(seed)
    lo, hi = (-(1 << (ab - 1)), (1 << (ab - 1)) - 1) if signed else (0, (1 << ab) - 1)
    x = rng.integers(lo, hi + 1, (5, 12))
    w = rng.integers(-128, 128, (12, 7))
    got = bitserial.matmul_bitplane_reference(
        jnp.asarray(x), jnp.asarray(w), ab, act_signed=signed, plane_bits=plane_bits
    )
    np.testing.assert_array_equal(np.asarray(got), x @ w)


@settings(max_examples=30, deadline=None)
@given(A_BITS, st.sampled_from([1, 2]), st.booleans(), st.integers(0, 2**31 - 1))
def test_bitplane_roundtrip(ab, plane_bits, signed, seed):
    rng = np.random.default_rng(seed)
    lo, hi = (-(1 << (ab - 1)), (1 << (ab - 1)) - 1) if signed else (0, (1 << ab) - 1)
    q = jnp.asarray(rng.integers(lo, hi + 1, (9, 5)), jnp.int32)
    planes, offset = bitplane.to_bitplanes(q, ab, plane_bits, signed)
    back = bitplane.from_bitplanes(planes, offset, plane_bits)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


@settings(max_examples=30, deadline=None)
@given(W_BITS, st.integers(1, 6), st.integers(1, 12), st.integers(0, 2**31 - 1))
def test_pack_unpack_weights(bits, rows16, cols, seed):
    rng = np.random.default_rng(seed)
    k = rows16 * 16
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    q = jnp.asarray(rng.integers(lo, hi + 1, (k, cols)), jnp.int32)
    packed = bitplane.pack_weights(q, bits, axis=0)
    assert packed.dtype == jnp.int8
    assert packed.shape[0] == k * bits // 8
    back = bitplane.unpack_weights(packed, bits, axis=0)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


def test_mac2_cycles_match_paper():
    # §IV-F: (n+2) sync, (n/2+2) double-pumped.
    assert bitserial.mac2_cycles(8, False) == 10
    assert bitserial.mac2_cycles(8, True) == 6
    assert bitserial.mac2_cycles(5, True) == 5  # ceil(5/2)+2
    assert bitserial.mac2_cycles(2, False) == 4


def test_lanes_per_block_match_fig7b():
    # M4BRAM-S: one 8b / two 4b / four 2b weights per BPE, 4 BPEs.
    assert bitserial.lanes_per_block(8, large=False) == 4
    assert bitserial.lanes_per_block(4, large=False) == 8
    assert bitserial.lanes_per_block(2, large=False) == 16
    # M4BRAM-L doubles everything.
    assert bitserial.lanes_per_block(8, large=True) == 8


def test_parallelism_configs_cover_fig4():
    cfgs = bitserial.parallelism_configs(8, large=False)
    assert (4, 1) in cfgs and (2, 2) in cfgs and (1, 4) in cfgs
