"""Kernel backend registry: dispatch, scoped selection, block-plan cache,
small-shape plan fixes, and the deprecated set_interpret shim."""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.registry import (
    KernelBackend,
    KernelRegistry,
    get_registry,
    pick_fused_blocks,
    pick_matmul_blocks,
    pick_paged_attention_blocks,
    use_backend,
)

RNG = np.random.default_rng(7)


def test_default_backends_registered():
    reg = KernelRegistry()
    assert set(reg.names()) >= {"interpret", "mosaic", "reference"}
    assert reg.get("mosaic").interpret is False
    assert reg.get("reference").is_reference


def test_default_active_backend_is_platform_dependent():
    reg = KernelRegistry()
    # CPU test container: interpret is the resolved default.
    assert reg.default_name() in ("interpret", "mosaic")
    assert reg.active.name == reg.default_name()


def test_unknown_backend_raises_with_listing():
    reg = KernelRegistry()
    with pytest.raises(KeyError, match="interpret"):
        reg.get("cuda")


def test_use_backend_is_scoped():
    reg = get_registry()
    before = reg.active.name
    with reg.use("reference") as be:
        assert be.is_reference
        assert reg.active.name == "reference"
    assert reg.active.name == before


def test_per_call_backend_override():
    x = RNG.integers(-8, 8, (5, 40)).astype(np.int32)
    w = RNG.integers(-8, 8, (40, 7)).astype(np.int32)
    got_i = ops.bitplane_matmul(jnp.asarray(x), jnp.asarray(w), a_bits=4)
    got_r = ops.bitplane_matmul(jnp.asarray(x), jnp.asarray(w), a_bits=4,
                                backend="reference")
    np.testing.assert_array_equal(np.asarray(got_i), x @ w)
    np.testing.assert_array_equal(np.asarray(got_r), x @ w)


def test_reference_backend_end_to_end_ops():
    """Every op dispatches on the reference backend without Pallas."""
    with use_backend("reference"):
        x = jnp.asarray(RNG.standard_normal((4, 32)), jnp.float32)
        q, s = ops.quantize_rows(x, bits=4)
        assert q.shape == (4, 32) and s.shape == (4, 1)
        w = jnp.asarray(RNG.integers(-8, 8, (32, 6)), jnp.int32)
        acc = ops.bitplane_matmul(q, w, a_bits=4)
        np.testing.assert_array_equal(
            np.asarray(acc), np.asarray(q).astype(np.int64) @ np.asarray(w))


def test_set_interpret_is_deprecated_shim():
    reg = get_registry()
    before = reg.active.name
    try:
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            ops.set_interpret(False)
        assert any(issubclass(w.category, DeprecationWarning) for w in rec)
        assert reg.active.name == "mosaic"
        ops.set_interpret(True)
        assert reg.active.name == "interpret"
    finally:
        reg._active = None if before == reg.default_name() else before


# -- block plans ------------------------------------------------------------


def test_pick_matmul_blocks_large_shapes_keep_mxu_tiles():
    bm, bn, bk = pick_matmul_blocks(4096, 4096, 8192)
    assert bm % 8 == 0 and bn % 128 == 0 and bk % 128 == 0
    assert 2 * (bm * bk + bk * bn) + 4 * bm * bn <= (4 << 20)


def test_pick_matmul_blocks_small_shapes_no_overpad():
    """Regression: n < 128 / k < 512 used to force 128+ blocks, padding a
    (3, 100, 5) matmul out to (8, 128, 128)."""
    bm, bn, bk = pick_matmul_blocks(3, 5, 100, n_align=8, k_align=8)
    assert bm == 8
    assert bn == 8          # was 128
    assert bk == 104        # was 128
    # The registry hands interpret-backend plans the relaxed alignment.
    plan = get_registry().matmul_plan(3, 5, 100, "interpret")
    assert plan[1] <= 8 and plan[2] <= 104
    # Mosaic keeps the MXU lane contract even for tiny shapes.
    plan_m = get_registry().matmul_plan(3, 5, 100, "mosaic")
    assert plan_m[1] % 128 == 0 and plan_m[2] % 128 == 0


def test_pick_fused_blocks_shrink_bm_for_long_rows():
    """Fused kernel keeps full fp32 rows resident: bm must shrink as K
    grows to stay inside the VMEM budget."""
    bm, bn, bk = pick_fused_blocks(256, 256, 65536)
    assert 8 * bm * 65536 + 2 * bk * bn + 4 * bm * bn <= (8 << 20)
    assert bm < 128


def test_plan_cache_memoizes():
    reg = KernelRegistry()
    p1 = reg.matmul_plan(64, 64, 64, "interpret")
    before = reg.cache_info()
    p2 = reg.matmul_plan(64, 64, 64, "interpret")
    after = reg.cache_info()
    assert p1 == p2
    assert after["hits"] == before["hits"] + 1


def test_record_plan_overrides_heuristic():
    reg = KernelRegistry()
    reg.record_plan("bitplane_matmul", 64, 64, 64, (8, 8, 8), "interpret")
    assert reg.matmul_plan(64, 64, 64, "interpret") == (8, 8, 8)


def test_autotune_caches_winner_and_skips_failures():
    reg = KernelRegistry()
    calls = []

    def run(blocks):
        if blocks[2] > 64:
            raise RuntimeError("candidate does not fit")
        calls.append(blocks)

    win = reg.autotune("bitplane_matmul", 64, 64, 64, run,
                       candidates=[(8, 8, 128), (8, 8, 64), (8, 8, 32)],
                       backend="interpret")
    assert win[2] <= 64
    n_calls = len(calls)
    again = reg.autotune("bitplane_matmul", 64, 64, 64, run,
                         backend="interpret")
    assert again == win
    assert len(calls) == n_calls  # cached — no re-measurement


def test_paged_attention_plan_bh_divides_heads():
    reg = KernelRegistry()
    bh, bs, hd = reg.paged_attention_plan(8, 16, 128, "interpret")
    assert 8 % bh == 0 and (bs, hd) == (16, 128)
    # Huge working sets shrink bh to a smaller divisor of NKV.
    bh2, _, _ = pick_paged_attention_blocks(8, 512, 4096)
    assert 8 % bh2 == 0 and bh2 < 8


def test_paged_attention_autotune_candidates_are_divisors():
    reg = KernelRegistry()
    seen = []
    reg.autotune("paged_attention", 6, 16, 64, seen.append,
                 backend="interpret")
    assert set(c[0] for c in seen) == {1, 2, 3, 6}
    assert all(c[1:] == (16, 64) for c in seen)


def test_save_and_load_plans_roundtrip(tmp_path):
    """Satellite: autotune winners survive process restarts via the JSON
    plan cache."""
    reg = KernelRegistry()
    reg.record_plan("bitplane_matmul", 64, 64, 64, (8, 8, 8), "interpret")
    reg.record_plan("paged_attention", 4, 16, 64, (2, 16, 64), "interpret")
    reg.matmul_plan(128, 256, 512, "mosaic")  # heuristic entry persists too
    path = tmp_path / "plans.json"
    assert reg.save_plans(path) == 3

    fresh = KernelRegistry()
    assert fresh.load_plans(path) == 3
    assert fresh.matmul_plan(64, 64, 64, "interpret") == (8, 8, 8)
    assert fresh.paged_attention_plan(4, 16, 64, "interpret") == (2, 16, 64)
    assert (fresh.matmul_plan(128, 256, 512, "mosaic")
            == reg.matmul_plan(128, 256, 512, "mosaic"))
    # Loaded plans count as cache hits, not misses: no re-planning.
    info = fresh.cache_info()
    assert info["plans"] == 3


def test_load_plans_rejects_unknown_version(tmp_path):
    """A wrong schema version warns and cold-starts (0 plans) — it must
    never crash the process that passed --plans."""
    path = tmp_path / "bad.json"
    path.write_text('{"version": 99, "plans": []}')
    reg = KernelRegistry()
    with pytest.warns(UserWarning, match="version"):
        assert reg.load_plans(path) == 0
    assert reg.cache_info()["plans"] == 0


def test_custom_backend_registration():
    reg = KernelRegistry()
    reg.register(KernelBackend("emulator", interpret=True, n_align=8, k_align=8))
    assert "emulator" in reg.names()
    with pytest.raises(ValueError):
        reg.register(KernelBackend("emulator", interpret=True))


def test_no_module_global_interpret_flag_left():
    assert not hasattr(ops, "_INTERPRET")
