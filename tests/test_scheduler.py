"""Continuous-batching scheduler: mid-flight join/retire equivalence,
ring-buffer caches under per-slot positions, EOS retirement, and
(seed, rid)-keyed sampling reproducibility."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.serving import ContinuousScheduler, Request, ServingEngine

KEY = jax.random.PRNGKey(0)
PROMPT_A = np.arange(8) % 64
PROMPT_B = (np.arange(8) + 3) % 64


@pytest.fixture(scope="module")
def olmo():
    cfg = get_reduced_config("olmo-1b")
    params = build_model(cfg).init(KEY)
    return cfg, params


def _drain(sched):
    out = []
    while sched.num_active or sched.num_waiting:
        out.extend(sched.step())
    return out


def _midflight(cfg, params, req_first, req_join, steps_before_join=3,
               max_ctx=48):
    """Serve `req_first`, admit `req_join` after a few decode steps."""
    sched = ContinuousScheduler(cfg, params, max_batch=2, max_ctx=max_ctx,
                                bucket=16)
    sched.submit(req_first)
    done = []
    for _ in range(steps_before_join):
        done.extend(sched.step())
    sched.submit(req_join)
    done.extend(_drain(sched))
    return done


def test_midflight_join_matches_solo_and_static(olmo):
    """A request's greedy tokens are bit-identical whether served solo,
    in a static batch, or admitted mid-decode into a live batch."""
    cfg, params = olmo
    solo = ServingEngine(cfg, params, max_batch=2, bucket=16).generate_static(
        [Request(1, PROMPT_B, max_new_tokens=6)])[0].out_tokens

    static_pair = ServingEngine(cfg, params, max_batch=2,
                                bucket=16).generate_static(
        [Request(0, PROMPT_A, max_new_tokens=9),
         Request(1, PROMPT_B, max_new_tokens=6)])
    assert static_pair[1].out_tokens == solo

    cont = ServingEngine(cfg, params, max_batch=2, bucket=16).generate(
        [Request(0, PROMPT_A, max_new_tokens=9),
         Request(1, PROMPT_B, max_new_tokens=6)])
    assert cont[1].out_tokens == solo
    assert cont[0].out_tokens == static_pair[0].out_tokens

    joined = Request(1, PROMPT_B, max_new_tokens=6)
    _midflight(cfg, params, Request(0, PROMPT_A, max_new_tokens=9), joined)
    assert joined.out_tokens == solo


def test_ring_buffer_under_per_slot_positions(olmo):
    """Sliding-window ring caches stay correct when slots sit at different
    depths: prompt longer than the window, decode past another wrap."""
    cfg, _ = olmo
    cfg = dataclasses.replace(cfg, attn_window=8)
    params = build_model(cfg).init(KEY)
    long_b = (np.arange(24) + 3) % 64
    solo = ServingEngine(cfg, params, max_batch=2, bucket=16).generate_static(
        [Request(1, long_b, max_new_tokens=10)])[0].out_tokens

    joined = Request(1, long_b, max_new_tokens=10)
    _midflight(cfg, params, Request(0, np.arange(24) % 64, max_new_tokens=14),
               joined)
    assert joined.out_tokens == solo


def test_eos_retirement_frees_slot(olmo):
    """EOS truncates a request and its slot is immediately reused."""
    cfg, params = olmo
    ref = Request(1, PROMPT_B, max_new_tokens=6)
    ServingEngine(cfg, params, max_batch=1, bucket=16).generate_static([ref])
    # Pick the second greedy token as EOS (the first may repeat later).
    eos = ref.out_tokens[1]
    stop_at = ref.out_tokens.index(eos) + 1

    sched = ContinuousScheduler(cfg, params, max_batch=1, max_ctx=48,
                                bucket=16)
    r1 = Request(1, PROMPT_B, max_new_tokens=6, eos_id=eos)
    r2 = Request(2, PROMPT_A, max_new_tokens=4)
    done = sched.run([r1, r2])
    assert r1.out_tokens == ref.out_tokens[:stop_at]
    assert len(r2.out_tokens) == 4
    assert [r.rid for r in done] == [1, 2]  # r1 retired first, r2 backfilled

    # Static path applies the same EOS rule.
    r3 = Request(1, PROMPT_B, max_new_tokens=6, eos_id=eos)
    ServingEngine(cfg, params, max_batch=1, bucket=16).generate_static([r3])
    assert r3.out_tokens == r1.out_tokens


def test_sampling_reproducible_across_composition(olmo):
    """Sampled outputs derive from (seed, rid, step): identical whether a
    request is served alone or admitted after others, and across modes."""
    cfg, params = olmo
    prompt = (np.arange(8) + 5) % 64

    def req():
        return Request(7, prompt.copy(), max_new_tokens=8, temperature=0.9,
                       top_k=12)

    alone = req()
    ContinuousScheduler(cfg, params, max_batch=2, max_ctx=48, bucket=16,
                        seed=3).run([alone])
    crowded = req()
    ContinuousScheduler(cfg, params, max_batch=3, max_ctx=48, bucket=16,
                        seed=3).run([
        Request(0, PROMPT_A, max_new_tokens=12),
        Request(1, PROMPT_B, max_new_tokens=3),
        crowded,
    ])
    assert crowded.out_tokens == alone.out_tokens

    static = req()
    ServingEngine(cfg, params, max_batch=2, bucket=16,
                  seed=3).generate_static([static])
    assert static.out_tokens == alone.out_tokens


@pytest.mark.parametrize("arch", ["rwkv6-3b", "recurrentgemma-9b"])
def test_recurrent_state_midflight_join(arch):
    """Slot scatter covers recurrent families: RWKV wkv/token-shift state
    and Griffin RG-LRU hidden + conv tail + local-attention ring."""
    cfg = get_reduced_config(arch)
    params = build_model(cfg).init(KEY)
    solo = ServingEngine(cfg, params, max_batch=2, bucket=16).generate_static(
        [Request(1, PROMPT_B, max_new_tokens=4)])[0].out_tokens
    joined = Request(1, PROMPT_B, max_new_tokens=4)
    _midflight(cfg, params, Request(0, PROMPT_A, max_new_tokens=7), joined,
               steps_before_join=2, max_ctx=32)
    assert joined.out_tokens == solo


def test_static_early_exit_matches_full_loop(olmo):
    """The static decode loop exits once every sequence is done; mixed
    max_new batches still produce exactly the per-request token counts."""
    cfg, params = olmo
    eng = ServingEngine(cfg, params, max_batch=4, bucket=16)
    reqs = [Request(i, PROMPT_A, max_new_tokens=m)
            for i, m in enumerate((1, 3, 8))]
    eng.generate_static(reqs)
    assert [len(r.out_tokens) for r in reqs] == [1, 3, 8]
    # identical prompts → shared greedy prefix
    assert reqs[0].out_tokens == reqs[2].out_tokens[:1]
    assert reqs[1].out_tokens == reqs[2].out_tokens[:3]
