"""Checkpoint manager: atomicity, retention, restore, corruption safety."""
import json
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs.base import TrainConfig
from repro.train.loop import init_train_state


def _state():
    params = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
              "b": jnp.ones((4,), jnp.bfloat16)}
    return init_train_state(params, TrainConfig())


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = _state()
    mgr.save(10, state, data_state={"step": 10, "seed": 0, "host_id": 0})
    restored, data_state, step = mgr.restore(_state)
    assert step == 10 and data_state["step"] == 10
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_keep_k_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state())
    steps = sorted(int(p.name) for p in tmp_path.iterdir() if p.name.isdigit())
    assert steps == [3, 4]
    assert mgr.latest_step() == 4


def test_uncommitted_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(5, _state())
    # simulate a crash mid-save at step 6: directory without COMMIT marker
    (tmp_path / "6").mkdir()
    (tmp_path / "6" / "manifest.json").write_text(json.dumps({"leaves": []}))
    assert mgr.latest_step() == 5
    _, _, step = mgr.restore(_state)
    assert step == 5


def test_restore_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _state())

    def bad_template():
        params = {"w": jnp.zeros((5, 5)), "b": jnp.zeros((4,), jnp.bfloat16)}
        return init_train_state(params, TrainConfig())

    with pytest.raises(ValueError, match="shape mismatch"):
        mgr.restore(bad_template)


def test_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=True)
    mgr.save(7, _state())
    mgr.wait()
    assert mgr.latest_step() == 7


def test_elastic_restore_with_shardings(tmp_path):
    """Leaves re-laid-out via device_put against caller shardings (the
    single-device degenerate case of elastic restore)."""
    mgr = CheckpointManager(tmp_path)
    state = _state()
    mgr.save(3, state)
    sds = jax.tree_util.tree_map(
        lambda l: jax.sharding.SingleDeviceSharding(jax.devices()[0]), state
    )
    restored, _, _ = mgr.restore(_state, shardings=sds)
    np.testing.assert_array_equal(
        np.asarray(restored.params["w"]), np.asarray(state.params["w"])
    )
