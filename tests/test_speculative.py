"""Self-speculative decoding from the resident bit-plane weights.

The contract has three layers:

  * plane truncation is *requantization by arithmetic shift*: contracting
    only planes [lo:] of b-bit codes equals quantizing the codes to
    (b - 2·lo) bits (shift) and matmul-ing at the lower width — exact
    integer equality, kernel and reference;
  * the draft is a *view*: ``derive_draft_params`` shares every packed
    buffer with the target params by identity — speculation never copies
    weight bytes;
  * greedy speculation is a *scheduling* change only: every emitted token
    is a full-policy verify argmax (the draft only decides how many land
    per step), so the token stream is bitwise identical to non-speculative
    greedy decode — across solo/continuous serving, bf16/int8 pools,
    draft precisions, and mid-decode admission.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.quant import QuantConfig
from repro.core.quantized_linear import PackedWeight, quantize_params_for_serving
from repro.kernels import ops, ref
from repro.models import build_model
from repro.serving import ContinuousScheduler, Request, assert_pool_invariants
from repro.serving.speculative import (
    derive_draft_params,
    greedy_accept,
    plane_offset,
)

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(7)
BS = 4
Q8 = QuantConfig(w_bits=8, a_bits=8)
PROMPT_A = np.zeros(8, np.int64)          # degenerate: drafts stay on-script
PROMPT_B = (np.arange(11) * 5 + 2) % 64   # non-divisor of block/bucket


@pytest.fixture(scope="module")
def olmo():
    cfg = get_reduced_config("olmo-1b")
    params = build_model(cfg).init(KEY)
    return cfg, params


def _sched(cfg, params, speculate, draft="w4a8", **kw):
    kw.setdefault("max_ctx", 64)
    return ContinuousScheduler(cfg, params, max_batch=2, bucket=16,
                               quant=Q8, paged=True, block_size=BS,
                               chunked_prefill=True, prefill_budget=8,
                               speculate=speculate, draft_policy=draft, **kw)


def _drain(sched):
    out = []
    while sched.num_active or sched.num_waiting:
        out.extend(sched.step())
    assert_pool_invariants(sched)
    return out


def _serve_one(cfg, params, prompt, n, speculate, draft="w4a8", **kw):
    sched = _sched(cfg, params, speculate, draft, **kw)
    sched.submit(Request(1, prompt, max_new_tokens=n))
    return _drain(sched)[0].out_tokens, sched


# -- plane truncation = shift requantization (exact, kernel + ref) --------

TRUNCATIONS = [(8, 2), (8, 3), (4, 1)]  # w8->w4, w8->w2, w4->w2


@pytest.mark.parametrize("w_bits,lo", TRUNCATIONS)
@pytest.mark.parametrize("act_signed", [True, False])
def test_truncated_matmul_is_requantized_matmul(w_bits, lo, act_signed):
    """bitplane_matmul with w_plane_lo equals quantizing the codes to
    (w_bits - 2*lo) bits (arithmetic shift — sign plane stays on top) and
    contracting at the lower width. Exact integers, both backends."""
    a_lo, a_hi = (-128, 128) if act_signed else (0, 256)
    x = RNG.integers(a_lo, a_hi, (9, 64)).astype(np.int32)
    w = RNG.integers(-(1 << (w_bits - 1)), 1 << (w_bits - 1),
                     (64, 17)).astype(np.int32)
    w_low = w >> (2 * lo)                      # requantized codes
    # the shifted codes are valid signed (w_bits - 2*lo)-bit codes
    b = w_bits - 2 * lo
    assert w_low.min() >= -(1 << (b - 1)) and w_low.max() < (1 << (b - 1))
    want = x @ w_low
    got_k = np.asarray(ops.bitplane_matmul(
        jnp.asarray(x), jnp.asarray(w), a_bits=8, act_signed=act_signed,
        w_plane_lo=lo))
    got_r = np.asarray(ref.bitplane_matmul_ref(
        jnp.asarray(x), jnp.asarray(w), 8, act_signed, w_plane_lo=lo))
    np.testing.assert_array_equal(got_k, want)
    np.testing.assert_array_equal(got_r, want)


@pytest.mark.parametrize("w_bits,lo", TRUNCATIONS)
def test_fused_matmul_plane_lo(w_bits, lo):
    """The fused quantize+matmul path truncates identically."""
    x = jnp.asarray(RNG.standard_normal((5, 64)), jnp.float32)
    w = RNG.integers(-(1 << (w_bits - 1)), 1 << (w_bits - 1),
                     (64, 9)).astype(np.int32)
    acc, xs = ops.fused_quantize_matmul(x, jnp.asarray(w), a_bits=8,
                                        w_plane_lo=lo)
    acc0, xs0 = ops.fused_quantize_matmul(x, jnp.asarray(w >> (2 * lo)),
                                          a_bits=8)
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(acc0))
    np.testing.assert_array_equal(np.asarray(xs), np.asarray(xs0))


def test_plane_offset():
    assert plane_offset(8, 4) == 2
    assert plane_offset(8, 2) == 3
    assert plane_offset(4, 2) == 1
    assert plane_offset(4, 8) == 0          # nothing to drop
    with pytest.raises(ValueError):
        plane_offset(8, 3)                  # odd gap: not whole planes


# -- the draft is a pure view of the resident packed weights --------------

def test_draft_params_share_packed_buffers(olmo):
    cfg, params = olmo
    qp = quantize_params_for_serving(params, Q8, min_size=1024)
    draft, truncated = derive_draft_params(qp, "w4a8")
    assert truncated > 0
    packed = [l for l in jax.tree_util.tree_leaves(
        qp, is_leaf=lambda l: isinstance(l, PackedWeight))
        if isinstance(l, PackedWeight)]
    draft_packed = [l for l in jax.tree_util.tree_leaves(
        draft, is_leaf=lambda l: isinstance(l, PackedWeight))
        if isinstance(l, PackedWeight)]
    assert len(packed) == len(draft_packed)
    for a, b in zip(packed, draft_packed):
        assert b.packed is a.packed         # identity: zero weight bytes
        assert b.scale is a.scale
        assert b.plane_lo == plane_offset(a.bits, 4)


def test_draft_spec_validation(olmo):
    cfg, params = olmo
    with pytest.raises(ValueError, match="quant policy"):
        derive_draft_params(params, "w4a8")  # no packed leaves
    qp = quantize_params_for_serving(params, Q8, min_size=1024)
    with pytest.raises(ValueError, match="truncates no leaf"):
        derive_draft_params(qp, "w8a8")
    with pytest.raises(ValueError, match="activation precision"):
        derive_draft_params(qp, "w4a4")
    with pytest.raises(ValueError, match="mixed"):
        derive_draft_params(qp, "w4a8r25")


def test_greedy_accept():
    # no drafts match: only the verify token at position 0 lands
    assert greedy_accept([5, 6, 7], [9, 9]) == [5]
    # all match: k accepted + the bonus token
    assert greedy_accept([5, 6, 7], [5, 6]) == [5, 6, 7]
    # prefix match
    assert greedy_accept([5, 6, 7], [5, 9]) == [5, 6]
    assert greedy_accept([5], []) == [5]


# -- greedy bit-identity across the serving matrix ------------------------

@pytest.mark.parametrize("k", [2, 4])
@pytest.mark.parametrize("draft", ["w2a8", "w4a8"])
def test_bit_identity_solo(olmo, k, draft):
    cfg, params = olmo
    ref_toks, _ = _serve_one(cfg, params, PROMPT_B, 10, 0)
    got, sched = _serve_one(cfg, params, PROMPT_B, 10, k, draft)
    assert got == ref_toks
    assert sched.spec_rounds > 0
    assert sched.spec_draft_tokens > 0


@pytest.mark.parametrize("kv_int8", [False, True])
def test_bit_identity_int8_pool(olmo, kv_int8):
    cfg, params = olmo
    if kv_int8:
        cfg = dataclasses.replace(cfg, kv_cache_quant=True)
    ref_toks, _ = _serve_one(cfg, params, PROMPT_A, 12, 0)
    got, sched = _serve_one(cfg, params, PROMPT_A, 12, 4, "w4a8")
    assert got == ref_toks
    assert sched.pool_stats()["spec_acceptance_rate"] > 0


def test_bit_identity_mid_decode_admission(olmo):
    """A request admitted into a live speculating batch: both streams
    match their non-speculative runs, and a sampled (non-greedy) slot
    sharing the batch decodes normally throughout."""
    cfg, params = olmo

    def serve(k):
        sched = _sched(cfg, params, k)
        sched.submit(Request(0, PROMPT_A, max_new_tokens=14))
        done = []
        for _ in range(3):
            done.extend(sched.step())
        sched.submit(Request(1, PROMPT_B, max_new_tokens=8,
                             temperature=0.7))
        done.extend(_drain(sched))
        return {r.rid: r.out_tokens for r in done}, sched

    ref_streams, _ = serve(0)
    got, sched = serve(4)
    assert got == ref_streams
    assert sched.spec_rounds > 0


def test_acceptance_counters(olmo):
    cfg, params = olmo
    sched = _sched(cfg, params, 4, "w4a8")
    req = Request(1, PROMPT_A, max_new_tokens=16)
    sched.submit(req)
    _drain(sched)
    st = sched.pool_stats()
    assert st["speculate"] == 4
    assert st["spec_draft_tokens"] >= st["spec_accepted_tokens"] > 0
    assert st["spec_acceptance_rate"] == pytest.approx(
        st["spec_accepted_tokens"] / st["spec_draft_tokens"])
    # the per-request counters mirror the scheduler totals (solo run)
    assert req.spec_drafted == st["spec_draft_tokens"]
    assert req.spec_accepted == st["spec_accepted_tokens"]
    assert req.spec_acceptance_rate == pytest.approx(
        st["spec_acceptance_rate"])


def test_speculation_requires_packed_weights(olmo):
    cfg, params = olmo
    with pytest.raises(ValueError, match="quant policy"):
        ContinuousScheduler(cfg, params, max_batch=2, paged=True,
                            block_size=BS, max_ctx=64, speculate=4)


# -- prefix cache: partial-block invariant survives rollback --------------

def test_prefix_cache_after_speculative_retirement(olmo):
    """A speculating request's retirement registers its partial prompt
    block as usual; a same-prompt follower hits the prefix cache and
    still matches the non-speculative stream (speculative writes only
    ever land at positions >= the prompt length, so registered prompt
    bytes are never touched by a rejected draft)."""
    cfg, params = olmo
    ref_toks, _ = _serve_one(cfg, params, PROMPT_B, 10, 0)

    sched = _sched(cfg, params, 4, "w4a8")
    sched.submit(Request(1, PROMPT_B, max_new_tokens=10))
    first = _drain(sched)[0].out_tokens
    sched.submit(Request(2, PROMPT_B, max_new_tokens=10))
    second = _drain(sched)[0].out_tokens
    st = sched.pool_stats()
    assert first == ref_toks
    assert second == ref_toks
    assert st["prefix_hit_tokens"] > 0      # follower reused prompt blocks


# -- satellite: chunk-plan round-robin fairness ---------------------------

@pytest.mark.slow
def test_chunk_queue_round_robin(olmo):
    """Two admissions with in-flight chunk plans share the per-step chunk
    budget round-robin: both plans make progress while both are live,
    instead of the second prompt's first token waiting for the first
    prompt to finish prefilling entirely."""
    cfg, params = olmo
    long_a = (np.arange(40) * 3 + 1) % 64
    long_b = (np.arange(40) * 7 + 5) % 64
    ref_a, _ = _serve_one(cfg, params, long_a, 4, 0, max_ctx=64)
    ref_b, _ = _serve_one(cfg, params, long_b, 4, 0, max_ctx=64)

    sched = _sched(cfg, params, 0, max_ctx=64, pool_blocks=40)
    sched.submit(Request(0, long_a, max_new_tokens=4))
    sched.step()                            # admit A, run its first chunk
    sched.submit(Request(1, long_b, max_new_tokens=4))
    interleaved = False
    done = []
    for _ in range(40):
        done.extend(sched.step())
        progress = {b: plan["next"] for b, plan in sched._chunk_plans.items()}
        if len(progress) == 2 and all(0 < p for p in progress.values()):
            interleaved = True
        if not (sched.num_active or sched.num_waiting):
            break
    assert interleaved, "both plans should advance while both are live"
    got = {r.rid: r.out_tokens for r in done}
    assert got[0] == ref_a and got[1] == ref_b


# -- satellite: prefill_tokens_per_step isn't diluted by late decodes -----

def test_prefill_tokens_per_step_stable_after_plans_retire(olmo):
    cfg, params = olmo
    sched = _sched(cfg, params, 0)
    sched.submit(Request(0, PROMPT_B, max_new_tokens=2))
    sched.submit(Request(1, np.zeros(30, np.int64), max_new_tokens=20))
    while sched._chunk_plans or sched.num_waiting:
        sched.step()
    at_retire = sched.pool_stats()["prefill_tokens_per_step"]
    assert at_retire > 0
    _drain(sched)                           # many pure-decode steps
    st = sched.pool_stats()
    assert st["prefill_tokens_per_step"] == pytest.approx(at_retire)
    assert st["prefill_chunk_steps"] > 0
