"""Corrupt/stale-file robustness of BOTH JSON persistence paths.

`--plans` (kernel-registry block-plan cache) and `--index` (serving
prefix index) share one contract: a missing, truncated, garbage, or
wrong-schema file — and an index whose digest table references an
out-of-range block — warns and cold-starts with 0 entries loaded.
Neither path may ever raise out of `load_*`: a stale cache file must
not take down a process that can simply re-autotune / re-prefill.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.kernels.registry import KernelRegistry
from repro.models import build_model
from repro.serving import Request, ServingEngine, assert_pool_invariants

KEY = jax.random.PRNGKey(0)
SYS = np.arange(24) % 64


@pytest.fixture(scope="module")
def olmo():
    cfg = get_reduced_config("olmo-1b")
    params = build_model(cfg).init(KEY)
    return cfg, params


def _engine(cfg, params):
    return ServingEngine(cfg, params, max_batch=2, bucket=16, paged=True,
                         block_size=4, pool_blocks=40, prefix_cache=True,
                         chunked_prefill=False, preempt=False,
                         host_pool_bytes=1 << 20)


def _requests(n=2):
    rng = np.random.default_rng(7)
    return [Request(rid=i,
                    prompt=np.concatenate(
                        [SYS, rng.integers(0, 64, 3 + i)]).astype(np.int64),
                    max_new_tokens=3, temperature=0.0)
            for i in range(n)]


@pytest.fixture(scope="module")
def saved_index(olmo, tmp_path_factory):
    """One good index file + the engine stream that produced it."""
    cfg, params = olmo
    path = tmp_path_factory.mktemp("idx") / "good.json"
    eng = _engine(cfg, params)
    out = [r.out_tokens for r in eng.generate(_requests())]
    assert eng.save_index(path) > 0
    return path, out


# -- the registry plan cache (--plans) -------------------------------------


def _good_plans(tmp_path):
    reg = KernelRegistry()
    reg.record_plan("bitplane_matmul", 64, 64, 64, (8, 8, 8), "interpret")
    path = tmp_path / "plans.json"
    reg.save_plans(path)
    return path


@pytest.mark.parametrize("mutate", [
    pytest.param(lambda txt: txt[: len(txt) // 2], id="truncated"),
    pytest.param(lambda txt: "not json {{{", id="garbage"),
    pytest.param(
        lambda txt: json.dumps({**json.loads(txt), "version": 99}),
        id="wrong-version"),
    pytest.param(lambda txt: json.dumps({"version": 1, "plans": [
        {"op": "bitplane_matmul"}]}), id="missing-fields"),
    pytest.param(lambda txt: json.dumps([1, 2, 3]), id="not-a-dict"),
])
def test_load_plans_corrupt_cold_starts(tmp_path, mutate):
    path = _good_plans(tmp_path)
    path.write_text(mutate(path.read_text()))
    reg = KernelRegistry()
    with pytest.warns(UserWarning):
        assert reg.load_plans(path) == 0
    assert reg.cache_info()["plans"] == 0
    # The registry still plans heuristically — cold start, not dead.
    assert reg.matmul_plan(64, 64, 64, "interpret")


def test_load_plans_missing_file_cold_starts(tmp_path):
    reg = KernelRegistry()
    with pytest.warns(UserWarning, match="cold start"):
        assert reg.load_plans(tmp_path / "nope.json") == 0


def test_load_plans_corrupt_entry_loads_nothing(tmp_path):
    """A file that parses but has one corrupt entry loads ZERO plans —
    no partially-applied cache."""
    path = _good_plans(tmp_path)
    obj = json.loads(path.read_text())
    obj["plans"].append({"op": "x", "backend": "y", "shape": "bad",
                         "blocks": [1]})
    path.write_text(json.dumps(obj))
    reg = KernelRegistry()
    with pytest.warns(UserWarning, match="corrupt"):
        assert reg.load_plans(path) == 0
    assert reg.cache_info()["plans"] == 0


# -- the serving prefix index (--index) ------------------------------------


@pytest.mark.parametrize("mutate", [
    pytest.param(lambda d, txt: txt[: len(txt) // 2], id="truncated"),
    pytest.param(lambda d, txt: "not json {{{", id="garbage"),
    pytest.param(lambda d, txt: json.dumps({**d, "version": 99}),
                 id="wrong-version"),
    pytest.param(lambda d, txt: json.dumps({**d, "schema": "other"}),
                 id="wrong-schema"),
    pytest.param(
        lambda d, txt: json.dumps(
            {**d, "digests": {next(iter(d["digests"])): 9999}}),
        id="digest-out-of-range"),
    pytest.param(
        lambda d, txt: json.dumps(
            {**d, "digests": {"zz-not-hex": 0}}),
        id="digest-not-hex"),
    pytest.param(lambda d, txt: json.dumps({**d, "blocks": "bad"}),
                 id="blocks-not-a-list"),
    pytest.param(
        lambda d, txt: json.dumps(
            {**d, "blocks": [{"k": "AAAA", "v": "AAAA",
                              "k_scale": None, "v_scale": None}]
             * len(d["blocks"])}),
        id="block-bytes-wrong-size"),
])
def test_load_index_corrupt_cold_starts(olmo, saved_index, tmp_path,
                                        mutate):
    """Every corruption mode warns, loads 0 digests, leaves the pool
    invariant-clean, and the engine still serves (cold)."""
    cfg, params = olmo
    good_path, good_out = saved_index
    data = json.loads(good_path.read_text())
    bad = tmp_path / "bad.json"
    bad.write_text(mutate(data, good_path.read_text()))

    eng = _engine(cfg, params)
    eng.generate(_requests(n=1))   # live scheduler → validated load path
    with pytest.warns(UserWarning):
        assert eng.load_index(bad) == 0
    out = [r.out_tokens for r in eng.generate(_requests())]
    assert out == good_out                  # cold serve, same tokens
    assert_pool_invariants(eng._sched)
    assert eng.pool_stats()["swap_ins"] == 0


def test_load_index_missing_file_cold_starts(olmo, tmp_path):
    cfg, params = olmo
    eng = _engine(cfg, params)
    with pytest.warns(UserWarning, match="cold start"):
        assert eng.load_index(tmp_path / "nope.json") == 0
    # Live-scheduler path too (post-first-generate load).
    eng.generate(_requests(n=1))
    with pytest.warns(UserWarning, match="cold start"):
        assert eng._sched.load_index(tmp_path / "nope.json") == 0
    assert_pool_invariants(eng._sched)


def test_load_index_good_file_still_loads(olmo, saved_index):
    """The robustness shell must not reject the happy path."""
    cfg, params = olmo
    good_path, good_out = saved_index
    eng = _engine(cfg, params)
    assert eng.load_index(good_path) > 0
    out = [r.out_tokens for r in eng.generate(_requests())]
    assert out == good_out
    assert eng.pool_stats()["swap_ins"] > 0
