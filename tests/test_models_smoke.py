"""Per-arch smoke tests (assignment requirement): reduced config of each
family, one forward/train step on CPU, output shapes + no NaNs; plus
prefill→decode consistency against the full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced_config
from repro.core.quant import QuantConfig
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finiteness(arch):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = model.smoke_batch(jax.random.PRNGKey(1), seq_len=32, batch=2)
    loss, metrics = model.train_loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    grads = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_configs_match_assignment(arch):
    cfg = get_config(arch)
    expected = {
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "rwkv6-3b": (32, 2560, 0, 0, 8960, 65536),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected


def test_param_counts_in_expected_range():
    """Analytic param counts should be within ~35% of the arch's nameplate
    size (these are public configs; embedding/glu conventions differ)."""
    anchors = {
        "nemotron-4-15b": 15e9,
        "olmo-1b": 1.2e9,
        "nemotron-4-340b": 340e9,
        "stablelm-12b": 12e9,
        "rwkv6-3b": 3e9,
        "recurrentgemma-9b": 9e9,
    }
    for arch, target in anchors.items():
        n = get_config(arch).param_count()
        assert 0.6 * target < n < 1.5 * target, (arch, n)


DECODE_ARCHS = [a for a in ARCH_IDS if a != "hubert-xlarge"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_consistency(arch):
    """decode(prefill(tokens[:T]), tokens[T]) must match the full forward
    logits at the last position (teacher forcing)."""
    # fp32 to remove bf16 order noise; no-drop MoE capacity because Switch-
    # style dropping legitimately couples a token's output to its co-batch.
    cfg = dataclasses.replace(
        get_reduced_config(arch), dtype="float32", moe_capacity_factor=16.0
    )
    model = build_model(cfg)
    params = model.init(KEY)
    T = 24
    batch_full = model.smoke_batch(jax.random.PRNGKey(2), seq_len=T + 1, batch=2)
    tokens = batch_full["tokens"]
    batch_prefix = dict(batch_full)
    batch_prefix["tokens"] = tokens[:, :-1]

    # full forward logits at the final position
    hidden_logits = _full_logits(model, cfg, params, batch_full)
    cache, _ = model.prefill(params, batch_prefix)
    _, dec_logits = model.decode_step(params, cache, tokens[:, -1:])

    a = np.asarray(hidden_logits[:, -1], np.float32)
    b = np.asarray(dec_logits[:, -1], np.float32)
    np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-2)


def _full_logits(model, cfg, params, batch):
    if cfg.family == "ssm":
        from repro.models import rwkv6

        hidden, _ = rwkv6._forward(params, cfg, batch["tokens"], None)
        from repro.models import common as cm

        return cm.logits_head(hidden, params["head"])
    if cfg.family == "hybrid":
        from repro.models import griffin
        from repro.models import common as cm

        hidden, _ = griffin._forward(params, cfg, batch["tokens"], False)
        return cm.logits_head(hidden, params["head"])
    from repro.models import transformer

    hidden, _ = transformer.forward_hidden(params, cfg, batch)
    return transformer.compute_logits(params, cfg, hidden)


def test_quantized_training_runs():
    cfg = get_reduced_config("olmo-1b").with_quant(QuantConfig(w_bits=4, a_bits=6))
    model = build_model(cfg)
    params = model.init(KEY)
    batch = model.smoke_batch(jax.random.PRNGKey(3), seq_len=16, batch=2)
    loss, _ = model.train_loss(params, batch)
    assert bool(jnp.isfinite(loss))
    g = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree_util.tree_leaves(g))


def test_scan_vs_unrolled_equivalence():
    cfg = get_reduced_config("olmo-1b")
    model = build_model(cfg)
    params = model.init(KEY)
    batch = model.smoke_batch(jax.random.PRNGKey(4), seq_len=16, batch=2)
    loss_scan, _ = model.train_loss(params, batch)
    cfg2 = dataclasses.replace(cfg, scan_layers=False)
    model2 = build_model(cfg2)
    loss_unroll, _ = model2.train_loss(params, batch)
    # scan and unrolled layers accumulate fp32 in different orders; 1e-4
    # still catches real wiring differences (observed delta ~7e-5).
    np.testing.assert_allclose(float(loss_scan), float(loss_unroll), rtol=1e-4)


def test_moe_routes_to_multiple_experts():
    cfg = get_reduced_config("mixtral-8x22b")
    from repro.models import moe as moe_mod

    key = jax.random.PRNGKey(5)
    p = moe_mod.init_moe(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
    out, aux = moe_mod.moe_apply(p, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) > 0.5  # load-balance loss near 1 for random router


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor=1.25 and a random router, output magnitude is
    close to the un-dropped dense mixture (sanity on dispatch/combine)."""
    cfg = get_reduced_config("llama4-maverick-400b-a17b")
    from repro.models import moe as moe_mod

    key = jax.random.PRNGKey(6)
    p = moe_mod.init_moe(key, cfg)
    x = jax.random.normal(key, (1, 64, cfg.d_model), jnp.float32)
    out, _ = moe_mod.moe_apply(p, x, cfg)
    nonzero = float(jnp.mean((jnp.abs(out) > 0).any(axis=-1).astype(jnp.float32)))
    assert nonzero > 0.85  # ≥85% of tokens got an expert (≤15% dropped)
