"""Cross-request prefix caching over the paged pool.

Covers: the bit-identity contract (a prefix-hit request's greedy tokens
equal a cold request's — solo, concurrent live sharing, mid-decode
admission, bf16 and int8 pools, including a fully-cached prompt that
admits without scattering any KV), and the refcounted allocator's edge
cases (retirement of two rows sharing blocks never double-frees,
copy-on-write when a row appends into a shared partial block, LRU
eviction racing admission reservations, int8 scale-plane sharing,
hit/CoW counters, and prefix_cache=False restoring exclusive
ownership)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.serving import (
    ContinuousScheduler,
    Request,
    ServingEngine,
    assert_pool_invariants,
)

KEY = jax.random.PRNGKey(0)
SYS = np.arange(10) % 64                       # shared prefix, 10 tokens
PROMPT_A = np.concatenate([SYS, [7, 9]])       # 12 tokens = 3 full blocks @4
PROMPT_B = np.concatenate([SYS, [11, 3]])
PROMPT_C = SYS                                 # partial last block @4


@pytest.fixture(scope="module")
def olmo():
    cfg = get_reduced_config("olmo-1b")
    params = build_model(cfg).init(KEY)
    return cfg, params


@pytest.fixture(scope="module")
def olmo_int8():
    cfg = dataclasses.replace(get_reduced_config("olmo-1b"),
                              kv_cache_quant=True)
    params = build_model(cfg).init(KEY)
    return cfg, params


def _drain(sched):
    out = []
    while sched.num_active or sched.num_waiting:
        out.extend(sched.step())
    assert_pool_invariants(sched)
    return out


def _cold(cfg, params, reqs):
    done = ServingEngine(cfg, params, max_batch=2,
                         bucket=16).generate_static(reqs)
    return {r.rid: r.out_tokens for r in done}


def _sched(cfg, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_ctx", 48)
    kw.setdefault("bucket", 16)
    kw.setdefault("paged", True)
    kw.setdefault("block_size", 4)
    return ContinuousScheduler(cfg, params, **kw)


def _assert_drained_invariants(sched):
    """The shared structural checker, plus what only holds once every
    request has retired: no live blocks, no refcounts outstanding, full
    capacity available again."""
    assert_pool_invariants(sched)
    assert sched._live_blocks == 0
    assert sched._refcnt[1:].sum() == 0
    assert len(sched._free) + len(sched._lru) == sched.pool_blocks
    assert sched._avail == sched.pool_blocks
    assert (sched._block_tab == -1).all()


# --------------------------------------------------------------------------
# Bit-identity: prefix hits must be invisible in the outputs
# --------------------------------------------------------------------------


@pytest.mark.parametrize("fixture", ["olmo", "olmo_int8"])
def test_prefix_hit_bit_identical(fixture, request):
    """Warm admissions — live sharing between concurrent rows, a fully
    cached resubmitted prompt, and a mid-decode join onto resident blocks
    — all produce exactly the cold (static-engine) greedy tokens, on both
    the bf16 and the int8 pool."""
    cfg, params = request.getfixturevalue(fixture)
    ref = _cold(cfg, params, [Request(0, PROMPT_A, max_new_tokens=8),
                              Request(1, PROMPT_B, max_new_tokens=8)])

    # Concurrent: request 1 shares request 0's live prefix blocks.
    sched = _sched(cfg, params)
    assert sched.prefix_cache
    r0 = Request(0, PROMPT_A, max_new_tokens=8)
    r1 = Request(1, PROMPT_B, max_new_tokens=8)
    sched.run([r0, r1])
    assert r0.out_tokens == ref[0]
    assert r1.out_tokens == ref[1]
    stats = sched.pool_stats()
    assert stats["prefix_hit_blocks"] >= 2      # SYS = 2 full blocks
    assert stats["prefix_hit_tokens"] >= 8

    # Fully cached prompt: every position resident → admission scatters
    # no KV (suffix prefill computes only the last token's logits).
    r2 = Request(2, PROMPT_A, max_new_tokens=8)
    sched.run([r2])
    assert r2.out_tokens == ref[0]
    assert sched.pool_stats()["prefix_hit_tokens"] >= 8 + len(PROMPT_A)

    # Mid-decode join onto resident blocks.
    mid = _sched(cfg, params)
    first = Request(0, PROMPT_A, max_new_tokens=12)
    mid.submit(first)
    for _ in range(3):
        mid.step()
    joined = Request(1, PROMPT_B, max_new_tokens=8)
    mid.submit(joined)
    _drain(mid)
    assert mid.pool_stats()["prefix_hit_blocks"] > 0
    assert joined.out_tokens == ref[1]
    assert first.out_tokens == _cold(
        cfg, params, [Request(0, PROMPT_A, max_new_tokens=12)])[0]
    _assert_drained_invariants(mid)


def test_prefix_cache_off_keeps_exclusive_ownership(olmo):
    """prefix_cache=False restores the PR 3/4 behaviour: no sharing, no
    retention — every block returns to the free list on retirement."""
    cfg, params = olmo
    ref = _cold(cfg, params, [Request(0, PROMPT_A, max_new_tokens=6)])
    sched = _sched(cfg, params, prefix_cache=False)
    r0 = Request(0, PROMPT_A, max_new_tokens=6)
    r1 = Request(1, PROMPT_A, max_new_tokens=6)
    sched.run([r0, r1])
    assert r0.out_tokens == ref[0] and r1.out_tokens == ref[0]
    stats = sched.pool_stats()
    assert not stats["prefix_cache"]
    assert stats["prefix_hit_blocks"] == 0
    assert len(sched._free) == sched.pool_blocks
    assert len(sched._lru) == 0


def test_prefix_cache_requires_paged_support(olmo):
    cfg, params = olmo
    with pytest.raises(ValueError, match="prefix caching"):
        ContinuousScheduler(cfg, params, max_batch=1, max_ctx=32,
                            bucket=16, paged=False, prefix_cache=True)


# --------------------------------------------------------------------------
# Allocator refcount edge cases
# --------------------------------------------------------------------------


def test_shared_retirement_never_double_frees(olmo):
    """Two rows sharing prefix blocks retire one after the other: the
    shared blocks must be decref'd once per row — not freed twice — and
    the pool must come back to exactly full capacity."""
    cfg, params = olmo
    sched = _sched(cfg, params)
    r0 = Request(0, PROMPT_A, max_new_tokens=10)   # retires second
    r1 = Request(1, PROMPT_B, max_new_tokens=3)    # retires first
    sched.submit(r0)
    sched.step()
    sched.submit(r1)
    _drain(sched)
    assert sched.pool_stats()["prefix_hit_blocks"] >= 2
    _assert_drained_invariants(sched)


def test_cow_on_shared_partial_block(olmo):
    """A retained partial prompt block revived by two rows: each row's
    first decode append must copy-on-write (the pristine cached block
    survives), and outputs stay bit-identical to cold."""
    cfg, params = olmo
    ref = _cold(cfg, params, [Request(0, PROMPT_C, max_new_tokens=6)])
    sched = _sched(cfg, params)
    a = Request(0, PROMPT_C, max_new_tokens=6)
    sched.run([a])                     # registers the partial block
    b = Request(1, PROMPT_C, max_new_tokens=6)
    c = Request(2, PROMPT_C, max_new_tokens=6)
    sched.submit(b)
    sched.submit(c)
    _drain(sched)
    stats = sched.pool_stats()
    assert stats["cow_copies"] >= 1
    assert a.out_tokens == ref[0]
    assert b.out_tokens == ref[0]
    assert c.out_tokens == ref[0]
    _assert_drained_invariants(sched)
    # The pristine partial block is still cached: a fourth identical
    # request hits the full prompt again.
    hits = stats["prefix_hit_tokens"]
    d = Request(3, PROMPT_C, max_new_tokens=6)
    sched.run([d])
    assert d.out_tokens == ref[0]
    assert sched.pool_stats()["prefix_hit_tokens"] >= hits + len(PROMPT_C)


def test_eviction_races_reservation(olmo):
    """A pool mostly occupied by retained prefix blocks must evict them —
    never a live row's blocks — when a later admission's allocations need
    the space; evicted hashes leave the index, accounting stays exact."""
    cfg, params = olmo
    ref_a = _cold(cfg, params, [Request(0, PROMPT_A, max_new_tokens=6)])
    ref_b = _cold(cfg, params, [Request(1, PROMPT_B, max_new_tokens=13)])
    # Pool of 6. A (12-token prompt = 3 full blocks, max_new 6) uses 5
    # blocks, retires, retains its 3 registered prompt blocks. B shares
    # the 2 SYS blocks but needs ceil((12+13-1)/4) = 6 blocks total: its
    # boundary allocations drain the free list and must evict A's
    # remaining retained block mid-decode.
    sched = _sched(cfg, params, pool_blocks=6, max_ctx=32)
    a = Request(0, PROMPT_A, max_new_tokens=6)
    sched.run([a])
    assert sched.pool_stats()["retained_prefix_blocks"] >= 3
    b = Request(1, PROMPT_B, max_new_tokens=13)
    sched.run([b])
    stats = sched.pool_stats()
    assert stats["prefix_evictions"] >= 1
    assert stats["prefix_hit_blocks"] >= 2
    assert not b.failed and b.out_tokens == ref_b[1]
    assert a.out_tokens == ref_a[0]
    assert len(sched._prefix_index) == sum(
        len(hs) for hs in sched._block_hash.values())
    _assert_drained_invariants(sched)


def test_int8_scale_plane_sharing(olmo_int8):
    """int8 pool: shared prefix blocks share their fp32 scale planes too —
    hits occur and warm outputs match the cold int8 static engine."""
    cfg, params = olmo_int8
    ref = _cold(cfg, params, [Request(0, PROMPT_C, max_new_tokens=6)])
    sched = _sched(cfg, params)
    assert sched.cache.kv.quantized
    a = Request(0, PROMPT_C, max_new_tokens=6)
    b = Request(1, PROMPT_C, max_new_tokens=6)
    sched.run([a])
    sched.run([b])
    stats = sched.pool_stats()
    assert stats["prefix_hit_blocks"] >= 3      # 2 full + partial
    assert a.out_tokens == ref[0]
    assert b.out_tokens == ref[0]
    _assert_drained_invariants(sched)


def test_pool_stats_counters(olmo):
    """pool_stats() reports the prefix-cache counters the serve driver and
    CI smoke rely on."""
    cfg, params = olmo
    sched = _sched(cfg, params)
    sched.run([Request(0, PROMPT_A, max_new_tokens=4)])
    sched.run([Request(1, PROMPT_A, max_new_tokens=4)])
    stats = sched.pool_stats()
    for key in ("prefix_cache", "prefix_hit_blocks", "prefix_hit_tokens",
                "prefix_hit_rate", "cow_copies", "prefix_evictions",
                "retained_prefix_blocks", "cached_prefix_blocks",
                "prompt_tokens"):
        assert key in stats, key
    assert stats["prefix_cache"] is True
    assert stats["prefix_hit_tokens"] >= len(PROMPT_A)
    assert 0.0 < stats["prefix_hit_rate"] <= 1.0
    assert stats["prompt_tokens"] == 2 * len(PROMPT_A)


# --------------------------------------------------------------------------
# Decode-generated blocks are cached too (multi-turn warm re-admission)
# --------------------------------------------------------------------------


def test_multi_turn_resubmission_is_warm(olmo):
    """Retirement registers the blocks holding decode-GENERATED tokens,
    not just the prompt's: a follow-up turn whose prompt is the prior
    conversation (prompt ++ answer ++ new user tokens) hits past the
    original prompt into the generated blocks, and its output is bitwise
    the cold run of the same concatenated prompt."""
    cfg, params = olmo
    first = Request(0, PROMPT_A, max_new_tokens=9)
    sched = _sched(cfg, params, pool_blocks=24, max_ctx=64)
    sched.run([first])
    hits0 = sched.pool_stats()["prefix_hit_tokens"]

    turn2 = np.concatenate([PROMPT_A, first.out_tokens, [5, 13]])
    ref = _cold(cfg, params, [Request(1, turn2, max_new_tokens=6)])
    r = Request(1, turn2, max_new_tokens=6)
    sched.run([r])
    assert r.out_tokens == ref[1]
    # Warm past the original prompt: everything the first turn wrote
    # (prompt + all but the last generated token) is resident.
    pos = len(PROMPT_A) + len(first.out_tokens) - 1
    bs = sched.block_size
    assert sched.pool_stats()["prefix_hit_tokens"] - hits0 >= (
        pos // bs) * bs > len(PROMPT_A)
    _assert_drained_invariants(sched)
