"""Per-layer PrecisionPolicy: rule matching, spec parsing, the DSE bridge,
and end-to-end packing/serving with mixed per-layer precision."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import simulate as sim
from repro.core import workloads
from repro.core.precision import (
    LayerRule,
    PrecisionPolicy,
    as_policy,
    parse_policy_spec,
    policy_from_dse,
)
from repro.core.quant import QuantConfig
from repro.core.quantized_linear import (
    PackedWeight,
    qmatmul,
    quantize_params_for_serving,
)

W4A8 = QuantConfig(w_bits=4, a_bits=8)
W8A8 = QuantConfig(w_bits=8, a_bits=8)
W2A4 = QuantConfig(w_bits=2, a_bits=4)
W2A8 = QuantConfig(w_bits=2, a_bits=8)


def test_uniform_policy_matches_everything():
    pol = PrecisionPolicy.uniform(W4A8)
    assert pol.for_path("blocks/wq") == W4A8
    assert pol.for_path("anything/at/all") == W4A8


def test_rules_first_match_wins():
    pol = PrecisionPolicy(
        default=W4A8,
        rules=(LayerRule(r"(^|/)wo$", W8A8), LayerRule(r"ffn", W2A4)),
    )
    assert pol.for_path("blocks/wo") == W8A8
    assert pol.for_path("blocks/ffn/w_up") == W2A4
    assert pol.for_path("blocks/wq") == W4A8


def test_as_policy_normalizes():
    assert as_policy(None) is None
    assert as_policy(W4A8) == PrecisionPolicy.uniform(W4A8)
    pol = PrecisionPolicy.uniform(W8A8)
    assert as_policy(pol) is pol
    with pytest.raises(TypeError):
        as_policy("w4a8")


def test_parse_policy_spec():
    pol = parse_policy_spec("w4a8;wo=w8a8;moe/w_up=w2a4r10")
    assert pol.default == W4A8
    assert pol.for_path("blocks/wo") == W8A8
    got = pol.for_path("moe/w_up")
    assert (got.w_bits, got.a_bits, got.mixed_ratio_8b) == (2, 4, 0.10)
    assert "w4a8" in pol.describe()


def test_parse_policy_spec_rejects_bad_input():
    with pytest.raises(ValueError):
        parse_policy_spec("wo=w8a8")  # no default
    with pytest.raises(ValueError):
        parse_policy_spec("w4a8;w8a8")  # duplicate default
    with pytest.raises(ValueError):
        parse_policy_spec("w5a8")  # unsupported bits


def test_packed_leaf_carries_activation_precision():
    from repro.core.quantized_linear import pack_weight

    pw = pack_weight(jnp.ones((32, 16), jnp.float32), W2A4)
    assert (pw.bits, pw.a_bits, pw.act_signed) == (2, 4, True)
    # pytree round-trip keeps the aux data
    leaves, tdef = jax.tree_util.tree_flatten(pw)
    pw2 = jax.tree_util.tree_unflatten(tdef, leaves)
    assert (pw2.bits, pw2.a_bits, pw2.act_signed) == (2, 4, True)


def test_quantize_params_per_layer_policy():
    rng = np.random.default_rng(0)
    params = {
        "blocks": {
            "wq": jnp.asarray(rng.standard_normal((128, 128)), jnp.float32),
            "wo": jnp.asarray(rng.standard_normal((128, 128)), jnp.float32),
        },
        "embed": jnp.asarray(rng.standard_normal((128, 128)), jnp.float32),
    }
    pol = PrecisionPolicy(default=W4A8, rules=(LayerRule(r"(^|/)wo$", W8A8),))
    packed = quantize_params_for_serving(params, pol, min_size=1024)
    assert packed["blocks"]["wq"].bits == 4
    assert packed["blocks"]["wq"].a_bits == 8
    assert packed["blocks"]["wo"].bits == 8
    assert not isinstance(packed["embed"], PackedWeight)  # excluded


def test_uniform_config_still_accepted():
    rng = np.random.default_rng(1)
    params = {"w": jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)}
    packed = quantize_params_for_serving(params, W4A8, min_size=1024)
    assert packed["w"].bits == 4


def test_qmatmul_uses_leaf_precision_without_cfg():
    """A packed leaf's own a_bits drives the serve matmul when no global
    config is passed — the per-layer policy reaches the kernel."""
    from repro.core.quantized_linear import pack_weight

    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    wf = jnp.asarray(rng.standard_normal((64, 32)) * 0.1, jnp.float32)
    pw8 = pack_weight(wf, QuantConfig(w_bits=8, a_bits=8))
    pw2 = pack_weight(wf, QuantConfig(w_bits=8, a_bits=2))
    y8 = np.asarray(qmatmul(x, pw8, None, use_kernel=True))
    y2 = np.asarray(qmatmul(x, pw2, None, use_kernel=True))
    # 2-bit activations are a much coarser grid → outputs must differ, and
    # the 8-bit path must be far more accurate.
    ref = np.asarray(x @ wf)
    err8 = np.linalg.norm(y8 - ref) / np.linalg.norm(ref)
    err2 = np.linalg.norm(y2 - ref) / np.linalg.norm(ref)
    assert err8 < 0.03 < err2


def test_serving_engine_accepts_policy():
    """End-to-end: a per-layer policy serves and packs layers differently."""
    from repro.configs import get_reduced_config
    from repro.serving import Request, ServingEngine

    cfg = get_reduced_config("olmo-1b")
    import jax as _jax

    from repro.models import build_model

    params = build_model(cfg).init(_jax.random.PRNGKey(0))
    pol = parse_policy_spec("w4a8;wo=w8a8")
    eng = ServingEngine(cfg, params, max_batch=2, quant=pol, bucket=16)
    bits = {}
    def collect(path, leaf):
        if isinstance(leaf, PackedWeight):
            bits[jax.tree_util.keystr(path)] = leaf.bits
    jax.tree_util.tree_map_with_path(
        collect, eng.params,
        is_leaf=lambda x: isinstance(x, PackedWeight))
    assert bits, "policy must pack at least one layer"
    assert any("wo" in p for p in bits)
    assert all(b == 8 for p, b in bits.items() if "wo" in p)
    assert all(b == 4 for p, b in bits.items() if "wq" in p)
    out = eng.generate([Request(0, np.arange(6) % 64, max_new_tokens=3)])[0]
    assert len(out.out_tokens) == 3


def _small_net():
    return [
        workloads.Layer("l0", 64, 64, 3, 3, 8, 8),
        workloads.Layer("l1", 64, 128, 3, 3, 8, 8),
        workloads.Layer("l2", 128, 128, 1, 1, 4, 4),
    ]


def test_policy_from_dse_smoke():
    fpga = sim.Fpga("toy", 128, 256)
    cim = sim.M4BRAM_S_DP
    pol = policy_from_dse(_small_net(), fpga, cim, a_bits=8)
    assert len(pol.rules) == 3
    # Boundary layers protected at 8-bit.
    assert pol.for_path("l0").w_bits == 8
    assert pol.for_path("l2").w_bits == 8
    # Every assigned precision is a supported weight width.
    for rule in pol.rules:
        assert rule.cfg.w_bits in (2, 4, 8)
        assert rule.cfg.a_bits == 8
    # Unknown layers fall back to the conservative default.
    assert pol.for_path("unseen_layer").w_bits == 8


def test_policy_from_dse_unprotected_boundaries():
    """protect_boundary=False lets the DSE pick even the first/last
    layers' precision on cycles alone — every rule is still a supported
    width and anchors exactly one layer name."""
    fpga = sim.Fpga("toy", 128, 256)
    cim = sim.M4BRAM_S_DP
    pol = policy_from_dse(_small_net(), fpga, cim, a_bits=8,
                          protect_boundary=False)
    assert len(pol.rules) == 3
    for rule, layer in zip(pol.rules, _small_net()):
        assert rule.matches(layer.name)
        assert rule.cfg.w_bits in (2, 4, 8)
    # A name that merely *contains* a layer name must not match its
    # anchored rule ("l0_extra" vs "(^|/)l0$") — it falls to the default.
    assert pol.for_path("l0_extra") == pol.default


def test_policy_from_dse_single_candidate():
    """With one candidate width there is nothing to choose: every layer
    lands on it (boundary protection can't pin 8-bit that isn't
    offered)."""
    fpga = sim.Fpga("toy", 128, 256)
    cim = sim.M4BRAM_S_DP
    pol = policy_from_dse(_small_net(), fpga, cim, a_bits=8,
                          w_candidates=(4,))
    for layer in _small_net():
        assert pol.for_path(layer.name).w_bits == 4


def test_overlapping_rules_first_match_wins_over_specificity():
    """Rule order is the ONLY precedence: an earlier broad pattern beats
    a later more-specific one on paths both match."""
    pol = parse_policy_spec("w4a8;wo=w8a8;blocks/wo=w2a4")
    # both rules match "blocks/wo"; the first listed wins
    assert pol.for_path("blocks/wo") == W8A8
    # the specific rule still exists for paths only it matches? No —
    # "wo" (unanchored) matches every path containing "wo", so the
    # second rule is fully shadowed. Reversing the order un-shadows it.
    rev = parse_policy_spec("w4a8;blocks/wo=w2a4;wo=w8a8")
    assert rev.for_path("blocks/wo") == W2A4
    assert rev.for_path("attn/wo") == W8A8


# -- precision tiers: spec parsing + view validation ----------------------


def test_parse_tier_specs_roundtrip():
    from repro.core.precision import parse_tier_specs, quant_token

    tiers = parse_tier_specs("w8a8, w4a8,w2a8")
    assert [quant_token(t) for t in tiers] == ["w8a8", "w4a8", "w2a8"]
    # Sequence form (tokens or QuantConfigs) parses identically.
    assert parse_tier_specs(["w8a8", W4A8]) == (W8A8, W4A8)


def test_parse_tier_specs_rejects_mixed_ratio_and_duplicates():
    from repro.core.precision import parse_tier_specs

    # Table-III "rZZ" re-assigns CHANNELS to 8-bit; a tier is a PLANE
    # subset of the resident codes — the two are incompatible.
    with pytest.raises(ValueError, match="plane subset"):
        parse_tier_specs("w8a8,w4a8r10")
    with pytest.raises(ValueError, match="duplicate"):
        parse_tier_specs(["w4a8", W4A8])
    with pytest.raises(ValueError, match="empty"):
        parse_tier_specs("")


def test_truncate_view_rejects_activation_mismatch():
    """A tier may only lower WEIGHT bits of a packed leaf: serving w8a8
    storage at w4a4 would need requantized activations, not a plane
    subset — the error must say so clearly."""
    from repro.core.precision import truncate_policy_view

    rng = np.random.default_rng(3)
    params = {"w": quantize_params_for_serving(
        {"w": jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)},
        W8A8, min_size=1024)["w"]}
    with pytest.raises(ValueError, match="activation precision"):
        truncate_policy_view(params, "w4a4")
    # matching a_bits: fine, truncates one leaf
    view, n = truncate_policy_view(params, "w4a8")
    assert n == 1 and view["w"].plane_lo == 2


def test_truncate_view_requires_packed_leaves():
    from repro.core.precision import truncate_policy_view

    with pytest.raises(ValueError, match="quant policy"):
        truncate_policy_view({"w": jnp.ones((8, 8))}, "w4a8")


def test_truncate_view_is_per_leaf_cap():
    """Mixed per-layer storage under one tier: leaves above the tier
    truncate, leaves already at/below it serve as stored (plane_lo=0) —
    and a whole-plane gap is enforced per leaf."""
    from repro.core.precision import truncate_policy_view

    rng = np.random.default_rng(4)
    raw = {k: jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
           for k in ("hi", "lo")}
    pol = PrecisionPolicy(default=W8A8, rules=(LayerRule(r"(^|/)lo$", W2A8),))
    params = quantize_params_for_serving(raw, pol, min_size=1024)
    view, n = truncate_policy_view(params, "w4a8")
    assert n == 1
    assert view["hi"].plane_lo == 2        # w8 capped to w4
    assert view["lo"].plane_lo == 0        # already below the cap
    assert view["lo"] is params["lo"]      # untouched leaf, same object
