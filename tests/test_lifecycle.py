"""Request lifecycle: cancellation, deadlines, and callback fault
containment.

The contract: a request leaves the scheduler in exactly one terminal
state — retired (error None), or failed with ``error`` set to why
("cancelled", "deadline", a reject reason, "nan-logits", a callback
traceback) — and EVERY terminal path frees the slot's blocks,
reservation, and chunk plan exactly like a normal retirement
(:func:`assert_pool_invariants` holds at any step boundary). A failing
request never takes the engine or its batch neighbours down with it.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.serving import ContinuousScheduler, Request, assert_pool_invariants

KEY = jax.random.PRNGKey(0)
PROMPT_A = (np.arange(8) * 3 + 1) % 64
PROMPT_B = (np.arange(11) * 5 + 2) % 64
LONG = (np.arange(40) * 7 + 3) % 64


@pytest.fixture(scope="module")
def olmo():
    cfg = get_reduced_config("olmo-1b")
    params = build_model(cfg).init(KEY)
    return cfg, params


def _sched(cfg, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_ctx", 64)
    kw.setdefault("bucket", 16)
    kw.setdefault("paged", True)
    kw.setdefault("block_size", 4)
    return ContinuousScheduler(cfg, params, **kw)


def _drain(sched, cap=300):
    out = []
    steps = 0
    while sched.num_active or sched.num_waiting:
        out.extend(sched.step())
        steps += 1
        assert steps < cap, "scheduler failed to drain (deadlock?)"
    assert_pool_invariants(sched)
    return out


# -- cancellation ----------------------------------------------------------


def test_cancel_queued_request(olmo):
    cfg, params = olmo
    sched = _sched(cfg, params, max_batch=1)
    live = Request(0, PROMPT_A, max_new_tokens=8)
    queued = Request(1, PROMPT_B, max_new_tokens=8)
    sched.submit(live)
    sched.step()                      # rid 0 occupies the only slot
    sched.submit(queued)
    assert sched.cancel(1)
    done = {r.rid: r for r in _drain(sched)}
    assert done[1].error == "cancelled"
    assert done[1].out_tokens == []
    assert done[0].error is None and len(done[0].out_tokens) == 8
    assert sched.cancellations == 1
    assert sched.cancel(1) is False   # already terminal
    assert sched.cancel(99) is False  # never seen


def test_cancel_live_request_frees_slot_for_next(olmo):
    cfg, params = olmo
    sched = _sched(cfg, params, max_batch=1)
    victim = Request(0, PROMPT_A, max_new_tokens=40)
    sched.submit(victim)
    for _ in range(4):
        sched.step()
    assert sched.cancel(0)
    nxt = Request(1, PROMPT_B, max_new_tokens=5)
    sched.submit(nxt)
    done = {r.rid: r for r in _drain(sched)}
    assert done[0].error == "cancelled"
    assert 0 < len(done[0].out_tokens) < 40   # partial output handed back
    assert done[1].error is None and len(done[1].out_tokens) == 5


def test_cancel_mid_chunk_plan(olmo):
    """Cancelling a request whose chunked-prefill plan is still landing
    must drop the plan and its reserved blocks (the partially-written
    blocks never enter the prefix index)."""
    cfg, params = olmo
    sched = _sched(cfg, params, chunked_prefill=True, prefill_budget=8,
                   max_ctx=96)
    sched.submit(Request(0, LONG, max_new_tokens=4))
    sched.step()                      # plan enqueued, first chunk landed
    assert sched.cancel(0)
    done = _drain(sched)
    assert done[0].error == "cancelled"
    assert_pool_invariants(sched)
    assert sched._avail == sched.pool_blocks
    # The pool is pristine: a fresh request serves normally.
    r = Request(1, PROMPT_A, max_new_tokens=4)
    sched.submit(r)
    _drain(sched)
    assert r.error is None and len(r.out_tokens) == 4


def test_cancel_from_on_token_callback(olmo):
    """cancel() is safe to call from inside an on_token callback: it
    takes effect at the next step boundary."""
    cfg, params = olmo

    def stop_after_three(req, tok):
        if len(req.out_tokens or ()) >= 3:
            sched.cancel(req.rid)

    cfg, params = olmo
    sched = _sched(cfg, params)
    r = Request(0, PROMPT_A, max_new_tokens=30, on_token=stop_after_three)
    sched.submit(r)
    _drain(sched)
    assert r.error == "cancelled"
    assert 3 <= len(r.out_tokens) <= 5


# -- deadlines -------------------------------------------------------------


def test_deadline_steps_live(olmo):
    cfg, params = olmo
    sched = _sched(cfg, params)
    r = Request(0, PROMPT_A, max_new_tokens=50, deadline_steps=5)
    ok = Request(1, PROMPT_B, max_new_tokens=4)
    sched.submit(r)
    sched.submit(ok)
    done = {q.rid: q for q in _drain(sched)}
    assert done[0].error == "deadline"
    assert 0 < len(done[0].out_tokens) < 50
    assert done[1].error is None and len(done[1].out_tokens) == 4
    assert sched.deadline_misses == 1


def test_deadline_steps_expires_in_queue(olmo):
    cfg, params = olmo
    sched = _sched(cfg, params, max_batch=1)
    hog = Request(0, PROMPT_A, max_new_tokens=12)
    starved = Request(1, PROMPT_B, max_new_tokens=4, deadline_steps=2)
    sched.submit(hog)
    sched.step()
    sched.submit(starved)
    done = {q.rid: q for q in _drain(sched)}
    assert done[1].error == "deadline"
    assert done[1].out_tokens == []
    assert done[0].error is None


def test_deadline_wall_clock_via_run(olmo):
    """deadline_s is wall-clock relative to arrival, evaluated only when
    run() drives the clock: an already-expired deadline fails immediately,
    a generous one doesn't fire."""
    cfg, params = olmo
    sched = _sched(cfg, params)
    dead = Request(0, PROMPT_A, max_new_tokens=8, deadline_s=0.0)
    fine = Request(1, PROMPT_B, max_new_tokens=8, deadline_s=60.0)
    done = {r.rid: r for r in sched.run([dead, fine])}
    assert done[0].error == "deadline"
    assert done[1].error is None and len(done[1].out_tokens) == 8
    assert_pool_invariants(sched)


def test_deadline_ignored_without_clock(olmo):
    """Manual step() loops have no wall clock: deadline_s never fires
    there (deadline_steps is the deterministic equivalent)."""
    cfg, params = olmo
    sched = _sched(cfg, params)
    r = Request(0, PROMPT_A, max_new_tokens=6, deadline_s=0.0)
    sched.submit(r)
    _drain(sched)
    assert r.error is None and len(r.out_tokens) == 6


# -- callback fault containment (satellite regression) ---------------------


def test_raising_request_callback_fails_only_that_request(olmo):
    """Regression: an on_token callback that raises used to propagate out
    of step() and kill the engine loop. It must instead fail that one
    request (error recorded) while its batch neighbour completes."""
    cfg, params = olmo

    def boom(req, tok):
        raise RuntimeError("user callback exploded")

    sched = _sched(cfg, params)
    bad = Request(0, PROMPT_A, max_new_tokens=8, on_token=boom)
    good = Request(1, PROMPT_B, max_new_tokens=8)
    sched.submit(bad)
    sched.submit(good)
    done = {r.rid: r for r in _drain(sched)}
    assert "callback" in done[0].error
    assert "user callback exploded" in done[0].error
    assert done[1].error is None and len(done[1].out_tokens) == 8
    assert sched.callback_errors >= 1
    assert_pool_invariants(sched)


def test_raising_scheduler_callback_survives(olmo):
    """The engine-level on_token stream hook gets the same containment."""
    cfg, params = olmo
    calls = []

    def flaky(req, tok):
        calls.append(tok)
        if len(calls) == 2:
            raise ValueError("stream sink hiccup")

    sched = _sched(cfg, params, on_token=flaky)
    a = Request(0, PROMPT_A, max_new_tokens=6)
    b = Request(1, PROMPT_B, max_new_tokens=6)
    sched.submit(a)
    sched.submit(b)
    done = {r.rid: r for r in _drain(sched)}
    assert sched.callback_errors == 1
    assert sum(1 for r in done.values() if r.error) == 1
    assert sum(1 for r in done.values() if r.error is None) == 1
    assert len(calls) >= 2


# -- lifecycle counters surface ---------------------------------------------


def test_lifecycle_counters_in_pool_stats(olmo):
    cfg, params = olmo
    sched = _sched(cfg, params)
    sched.submit(Request(0, PROMPT_A, max_new_tokens=4))
    _drain(sched)
    stats = sched.pool_stats()
    for key in ("preemptions", "cancellations", "deadline_misses",
                "pool_pressure_events", "queue_wait_steps", "head_bypasses",
                "degraded_requests", "callback_errors", "nan_logit_events",
                "kernel_fallbacks", "victim_policy", "preempt", "chaos"):
        assert key in stats, key
    assert stats["chaos"] is None
    assert stats["preempt"] is True    # auto-on with the paged pool
