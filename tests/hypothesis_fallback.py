"""Minimal deterministic stand-in for `hypothesis` (property tests).

The CI/container image may not ship hypothesis (it is declared in
pyproject.toml but can't always be installed). Property tests fall back to
this shim: each `@given` test runs `max_examples` deterministic examples
drawn from a per-test seeded numpy Generator — not real shrinking/coverage,
but the same assertions over a reproducible sample, and zero skipped tests.

Only the API surface the test-suite uses is implemented:
  strategies.integers / floats / booleans / sampled_from / composite,
  @given (positional or keyword strategies; non-strategy parameters
  stay visible to pytest, so module-scoped fixtures compose with
  @given exactly like under real hypothesis),
  @settings(max_examples=, deadline=).
"""
from __future__ import annotations

import functools
import hashlib
import inspect

import numpy as np

_DEFAULT_EXAMPLES = 20


class Strategy:
    """A value generator: `example(rng)` draws one value."""

    def __init__(self, draw_fn):
        self._draw = draw_fn

    def example(self, rng):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value: float, max_value: float) -> Strategy:
    return Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def booleans() -> Strategy:
    return Strategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(seq) -> Strategy:
    options = list(seq)
    return Strategy(lambda rng: options[int(rng.integers(0, len(options)))])


def composite(fn):
    """@composite strategies: fn(draw, *args) -> value."""

    def builder(*args, **kwargs):
        def draw_fn(rng):
            return fn(lambda strat: strat.example(rng), *args, **kwargs)

        return Strategy(draw_fn)

    return builder


def given(*strats, **kwstrats):
    def deco(fn):
        @functools.wraps(fn)
        def run(*args, **kwargs):
            n = getattr(run, "_max_examples", _DEFAULT_EXAMPLES)
            # Per-test deterministic seed: stable across runs and machines.
            seed0 = int.from_bytes(
                hashlib.sha256(fn.__qualname__.encode()).digest()[:4], "little"
            )
            for i in range(n):
                rng = np.random.default_rng(seed0 + i)
                vals = [s.example(rng) for s in strats]
                kvals = {k: s.example(rng) for k, s in kwstrats.items()}
                try:
                    fn(*args, *vals, **kwargs, **kvals)
                except Exception as e:  # noqa: BLE001 — annotate the example
                    raise AssertionError(
                        f"falsifying example #{i}: {vals!r} {kvals!r}"
                    ) from e

        run._hypothesis_fallback = True
        # Hide the strategy-supplied parameters from pytest's fixture
        # resolution (the strategies provide them); everything else —
        # e.g. module-scoped model fixtures — stays visible so pytest
        # injects it, mirroring hypothesis' fixture interop. Positional
        # strategies fill the RIGHTMOST parameters, like hypothesis.
        del run.__wrapped__
        params = list(inspect.signature(fn).parameters.values())
        if strats:
            params = params[: -len(strats)]
        params = [p for p in params if p.name not in kwstrats]
        run.__signature__ = inspect.Signature(params)
        return run

    return deco


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


class _StrategiesNamespace:
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    booleans = staticmethod(booleans)
    sampled_from = staticmethod(sampled_from)
    composite = staticmethod(composite)


strategies = _StrategiesNamespace()
