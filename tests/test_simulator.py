"""Simulator fidelity vs the paper's published claims.

_BPE_EFFICIENCY is calibrated once against Fig 12; every assertion below is
a *prediction band* around the paper's numbers (generous tolerances — the
paper's own simulator embeds DLA details we reconstruct from [28]/[35]).
"""
import pytest

from repro.core import dse, simulate as sim
from repro.core.workloads import NETWORKS, network_macs


def test_dsp_packing_breakpoints():
    # Fig 9's observed behaviour: at Pw=8 the packing factor doubles when
    # activations reach 5 bits; uniform ladder 8b:2, 4b:4, 2b:8.
    assert sim.dsp_packing(8, 8) == 2
    assert sim.dsp_packing(8, 6) == 2
    assert sim.dsp_packing(8, 5) == 4
    assert sim.dsp_packing(8, 4) == 4
    assert sim.dsp_packing(4, 4) == 4
    assert sim.dsp_packing(2, 2) == 8


def test_cim_arch_table2_constants():
    a = sim.CIM_ARCHS
    assert a["DP-M4S"].lanes(8) == 4 and a["DP-M4S"].lanes(2) == 16
    assert a["SY-M4L"].lanes(8) == 8
    assert a["BRAMAC-1DA"].lanes(8) == 5 and a["BRAMAC-2SA"].lanes(8) == 10
    assert a["DP-M4S"].one_port and not a["BRAMAC-1DA"].one_port
    assert a["SY-M4L"].mac2_cycles(8) == 10          # n+2
    assert a["DP-M4L"].mac2_cycles(8) == 6           # n/2+2
    assert a["DP-M4S"].area_overhead == pytest.approx(0.196)
    assert a["SY-M4L"].area_overhead == pytest.approx(0.334)


def test_workload_macs_sane():
    assert 6e8 < network_macs("alexnet") < 9e8
    assert 1.5e10 < network_macs("vgg16") < 1.6e10
    assert 1.7e9 < network_macs("resnet18") < 2.0e9
    assert 3.4e9 < network_macs("resnet34") < 4.0e9


@pytest.fixture(scope="module")
def fig9_speedups():
    nets = ("alexnet", "vgg16", "resnet18")
    out = {}
    for cfg_name in ("DP-M4S", "SY-M4L", "DP-M4L"):
        cim = sim.CIM_ARCHS[cfg_name]
        vals = [dse.speedup(NETWORKS[n], 8, 6, sim.GX650, cim) for n in nets]
        out[cfg_name] = sum(vals) / len(vals)
    return out


def test_fig9_average_band(fig9_speedups):
    # Paper: DP-M4S 1.92x, SY-M4L 2.26x, DP-M4L 2.31x at 6-bit activations;
    # overall average 2.16x. Bands: ±35% per config, ±25% overall.
    paper = {"DP-M4S": 1.92, "SY-M4L": 2.26, "DP-M4L": 2.31}
    for k, target in paper.items():
        assert 0.65 * target < fig9_speedups[k] < 1.45 * target, (k, fig9_speedups)
    overall = sum(fig9_speedups.values()) / 3
    assert 0.75 * 2.16 < overall < 1.30 * 2.16


def test_fig9_speedup_grows_when_activation_bits_drop():
    """The paper's headline property: SY-M4L hetero speedup increases
    monotonically as activation precision drops from 8 → 6 (the DLA
    baseline is flat there while the BPE's (n+2) latency shrinks)."""
    cim = sim.CIM_ARCHS["SY-M4L"]
    s = [dse.speedup(NETWORKS["vgg16"], 8, a, sim.GX650, cim) for a in (8, 7, 6)]
    assert s[0] <= s[1] <= s[2], s


def test_fig9_dip_at_5_bits():
    """At a=5 the DLA baseline doubles its packing → hetero speedup dips."""
    cim = sim.CIM_ARCHS["SY-M4L"]
    s6 = dse.speedup(NETWORKS["vgg16"], 8, 6, sim.GX650, cim)
    s5 = dse.speedup(NETWORKS["vgg16"], 8, 5, sim.GX650, cim)
    assert s5 < s6


def test_fig10_m4bram_beats_bramac():
    """Directional claim + ratio band (paper: 1.43x average advantage)."""
    nets = ("alexnet", "vgg16", "resnet18")
    ratios = []
    for net in nets:
        m4 = dse.speedup(NETWORKS[net], 4, 4, sim.GX400, sim.CIM_ARCHS["DP-M4S"])
        br = dse.speedup(NETWORKS[net], 4, 4, sim.GX400, sim.CIM_ARCHS["BRAMAC-1DA"])
        ratios.append(m4 / br)
        assert m4 >= br * 0.98, (net, m4, br)
    avg = sum(ratios) / len(ratios)
    assert 1.05 < avg < 1.8, ratios


def test_fig12_calibration_band():
    gx_m4 = sim.Fpga("GX-M4", 0, 2489)
    gx_dsp = sim.Fpga("GX-DSP", 640, 2489)
    for cfg_name, paper in (("SY-M4L", 1.98), ("DP-M4L", 2.95)):
        cim = sim.CIM_ARCHS[cfg_name]
        vals = []
        for net in ("alexnet", "resnet18"):
            for a in (4, 6, 8):
                b = dse.search(NETWORKS[net], 8, a, gx_dsp, None)
                m = dse.search(NETWORKS[net], 8, a, gx_m4, cim)
                vals.append(b.cycles / m.cycles)
        avg = sum(vals) / len(vals)
        assert 0.7 * paper < avg < 1.35 * paper, (cfg_name, avg)


def test_table3_speedup_band_and_trend():
    """R=5% ≈ 2.33x over all-4b DLA; non-increasing in R (paper Table III)."""
    vals = {}
    for r in (0.05, 0.15, 0.25):
        base = dse.search(NETWORKS["resnet34"], 4, 6, sim.GX400, None)
        het = dse.search(NETWORKS["resnet34"], 4, 6, sim.GX400,
                         sim.CIM_ARCHS["SY-M4L"], pw8_fraction=r)
        vals[r] = base.cycles / het.cycles
    assert 0.7 * 2.33 < vals[0.05] < 1.3 * 2.33, vals
    assert vals[0.05] >= vals[0.15] >= vals[0.25] - 1e-9, vals


def test_bramac_mixed_precision_unsupported_semantics():
    """BRAMAC archs are uniform-precision only (Table II) — the DSE must
    not be asked for a≠w; CimArch records the capability."""
    assert not sim.CIM_ARCHS["BRAMAC-1DA"].mixed_precision
    assert sim.CIM_ARCHS["DP-M4S"].mixed_precision


def test_dse_resource_report_within_budget():
    best = dse.search(NETWORKS["resnet34"], 4, 6, sim.GX400,
                      sim.CIM_ARCHS["SY-M4L"])
    n_dsp, n_bram = best.resources
    assert n_dsp <= sim.GX400.n_dsp
    assert n_bram <= sim.GX400.n_bram
