"""HLO cost parser: trip-count correction, collective accounting."""
import subprocess
import sys
from pathlib import Path

import pytest

from repro.roofline import hlo_costs, hw
from repro.roofline.analysis import model_flops

REPO = Path(__file__).resolve().parents[1]

SYNTH_HLO = """
HloModule test

%cond (arg: (s32[], f32[8,8])) -> pred[] {
  %arg = (s32[], f32[8,8]) parameter(0)
  %iv = s32[] get-tuple-element(%arg), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%iv, %c), direction=LT
}

%body (arg.1: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %arg.1 = (s32[], f32[8,8]) parameter(0)
  %iv.1 = s32[] get-tuple-element(%arg.1), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%arg.1), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}
  %one = s32[] constant(1)
  %ivn = s32[] add(%iv.1, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ivn, %ar)
}

ENTRY %main (p: f32[8,8]) -> f32[8,8] {
  %p = f32[8,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %tup = (s32[], f32[8,8]) tuple(%zero, %p)
  %w = (s32[], f32[8,8]) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_synthetic_while_trip_count():
    costs = hlo_costs.analyze_hlo(SYNTH_HLO)
    # 5 iterations × (2·8·8·8 flops) from the dot inside the body
    assert costs.flops == 5 * 2 * 8 * 8 * 8
    # all-reduce inside the loop: 5 × 2 × 256 bytes
    assert costs.collective_bytes == 5 * 2 * 8 * 8 * 4
    assert costs.loop_trip_counts.get("body") == 5


def test_scan_matches_unrolled_flops():
    """The critical property: a scanned L-layer model must report ≈ the
    unrolled model's flops (runs a subprocess with 4 fake devices)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, jax, jax.numpy as jnp, sys
sys.path.insert(0, "SRC")
from repro.configs import get_reduced_config
from repro.models import build_model
from repro.roofline import hlo_costs
cfg = dataclasses.replace(get_reduced_config("olmo-1b"), num_layers=4, remat=False)
out = {}
for scan in (True, False):
    c = dataclasses.replace(cfg, scan_layers=scan)
    m = build_model(c)
    params = jax.eval_shape(m.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 128), jnp.int32)}
    comp = jax.jit(lambda p, b: m.train_loss(p, b)[0]).lower(params, batch).compile()
    out[scan] = hlo_costs.analyze_hlo(comp.as_text()).flops
ratio = out[True] / out[False]
assert 0.9 < ratio < 1.15, ratio
print("OK", ratio)
""".replace("SRC", str(REPO / "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


def test_model_flops_conventions():
    from repro.configs import get_config

    cfg = get_config("olmo-1b")
    n = cfg.param_count()
    assert model_flops(cfg, "train", 4096, 256) == 6.0 * n * 4096 * 256
    assert model_flops(cfg, "prefill", 32768, 32) == 2.0 * n * 32768 * 32
    assert model_flops(cfg, "decode", 32768, 128) == 2.0 * n * 128

    moe = get_config("mixtral-8x22b")
    assert moe.active_param_count() < 0.45 * moe.param_count()


def test_hw_constants():
    assert hw.PEAK_BF16_FLOPS == 197e12
    assert hw.HBM_BW == 819e9
    assert hw.ICI_LINK_BW == 50e9
    assert hw.CHIPS_PER_POD == 256
