"""AdamW + schedules (from scratch — these tests are the spec)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.optim import adamw


def test_adamw_converges_on_quadratic():
    tc = TrainConfig(lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adamw.init_state(params)
    lr_fn = adamw.cosine_schedule(tc)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state, _ = adamw.apply_updates(params, g, state, tc, lr_fn)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_weight_decay_only_on_matrices():
    tc = TrainConfig(lr=0.1, weight_decay=0.5, warmup_steps=0, total_steps=10)
    params = {"mat": jnp.ones((4, 4)), "vec": jnp.ones((4,))}
    zero_g = jax.tree_util.tree_map(jnp.zeros_like, params)
    state = adamw.init_state(params)
    p2, _, _ = adamw.apply_updates(params, zero_g, state, tc)
    assert float(jnp.max(jnp.abs(p2["vec"] - 1.0))) < 1e-7   # no decay
    assert float(jnp.max(p2["mat"])) < 1.0                   # decayed


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), float(np.sqrt(250.0)), rtol=1e-6)
    np.testing.assert_allclose(float(adamw.global_norm(clipped)), 1.0, rtol=1e-5)


def test_cosine_schedule_shape():
    tc = TrainConfig(lr=1.0, warmup_steps=10, total_steps=100, lr_min_ratio=0.1)
    lr = adamw.cosine_schedule(tc)
    assert float(lr(jnp.asarray(0))) < 0.11
    assert abs(float(lr(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(lr(jnp.asarray(55))) < 1.0
    assert abs(float(lr(jnp.asarray(100))) - 0.1) < 1e-6  # floor


def test_moments_are_fp32_and_param_shaped():
    params = {"w": jnp.ones((3, 5), jnp.bfloat16)}
    st = adamw.init_state(params)
    assert st.mu["w"].dtype == jnp.float32
    assert st.mu["w"].shape == (3, 5)
