"""Paged block-table KV cache + serving-path bugfix regressions.

Covers: paged ≡ contiguous ≡ static greedy bit-identity (solo / static
batch / mid-decode admission, across block boundaries), pool-full
queueing and block reuse, oversized-request failure isolation (no
mid-run crash), the admission capacity off-by-one, bucketed right-pad
prefill exactness vs exact-length prefill, and the static engine's
overflow guard / cache growth past the prefill headroom."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.serving import ContinuousScheduler, Request, ServingEngine

KEY = jax.random.PRNGKey(0)
PROMPT_A = np.arange(8) % 64
PROMPT_B = (np.arange(8) + 3) % 64


@pytest.fixture(scope="module")
def olmo():
    cfg = get_reduced_config("olmo-1b")
    params = build_model(cfg).init(KEY)
    return cfg, params


def _drain(sched):
    out = []
    while sched.num_active or sched.num_waiting:
        out.extend(sched.step())
    return out


def test_paged_matches_contiguous_and_static(olmo):
    """Greedy tokens are bit-identical between the paged pool and the
    contiguous cache — served solo, in a static batch, and admitted
    mid-decode — with a block size small enough that every request
    crosses several block boundaries."""
    cfg, params = olmo
    reqs = lambda: [Request(0, PROMPT_A, max_new_tokens=10),
                    Request(1, PROMPT_B, max_new_tokens=7)]
    static = ServingEngine(cfg, params, max_batch=2,
                           bucket=16).generate_static(reqs())

    contig = ContinuousScheduler(cfg, params, max_batch=2, max_ctx=48,
                                 bucket=16, paged=False)
    c_done = {r.rid: r for r in contig.run(reqs())}

    paged = ContinuousScheduler(cfg, params, max_batch=2, max_ctx=48,
                                bucket=16, paged=True, block_size=4)
    assert paged.paged
    p_done = {r.rid: r for r in paged.run(reqs())}

    for r in static:
        assert c_done[r.rid].out_tokens == r.out_tokens
        assert p_done[r.rid].out_tokens == r.out_tokens

    # Mid-decode admission into the paged pool: join after 3 steps.
    sched = ContinuousScheduler(cfg, params, max_batch=2, max_ctx=48,
                                bucket=16, paged=True, block_size=4)
    first = Request(0, PROMPT_A, max_new_tokens=10)
    joined = Request(1, PROMPT_B, max_new_tokens=7)
    sched.submit(first)
    for _ in range(3):
        sched.step()
    sched.submit(joined)
    _drain(sched)
    assert joined.out_tokens == static[1].out_tokens
    assert first.out_tokens == static[0].out_tokens


def test_paged_pool_full_queues_and_reuses_blocks(olmo):
    """A pool too small for two concurrent requests queues the second
    (no crash, no partial admission); retirement frees blocks that the
    queued request then reuses; outputs are unchanged."""
    cfg, params = olmo
    ref = ServingEngine(cfg, params, max_batch=2, bucket=16).generate_static(
        [Request(0, PROMPT_A, max_new_tokens=6),
         Request(1, PROMPT_B, max_new_tokens=6)])

    # Each request needs ceil((8 + 6 - 1) / 4) = 4 blocks; a 6-block pool
    # holds one at a time even though two slots are free.
    sched = ContinuousScheduler(cfg, params, max_batch=2, max_ctx=48,
                                bucket=16, paged=True, block_size=4,
                                pool_blocks=6)
    r0 = Request(0, PROMPT_A, max_new_tokens=6)
    r1 = Request(1, PROMPT_B, max_new_tokens=6)
    sched.submit(r0)
    sched.submit(r1)
    saw_queued = False
    out = []
    while sched.num_active or sched.num_waiting:
        out.extend(sched.step())
        saw_queued |= (sched.num_active == 1 and sched.num_waiting == 1)
    assert saw_queued, "pool should have forced the second request to wait"
    assert r0.out_tokens == ref[0].out_tokens
    assert r1.out_tokens == ref[1].out_tokens
    # Every block back to reclaimable capacity (free, or retained by the
    # prefix cache for future hits — reclaimed on demand); tables cleared.
    assert len(sched._free) + len(sched._lru) == sched.pool_blocks
    assert sched._avail == sched.pool_blocks
    assert (sched._block_tab == -1).all()
    stats = sched.pool_stats()
    assert stats["allocated_blocks"] == 0
    assert 0 < stats["peak_allocated_blocks"] <= sched.pool_blocks


@pytest.mark.parametrize("paged", [True, False])
def test_oversized_request_fails_without_crashing(olmo, paged):
    """An oversized request arriving mid-run is rejected individually
    (Request.error set, no tokens) — run() keeps serving and the other
    requests' outputs are unchanged."""
    cfg, params = olmo
    ref = ServingEngine(cfg, params, max_batch=2, bucket=16).generate_static(
        [Request(0, PROMPT_A, max_new_tokens=6),
         Request(2, PROMPT_B, max_new_tokens=5)])

    sched = ContinuousScheduler(cfg, params, max_batch=1, max_ctx=32,
                                bucket=16, paged=paged)
    r0 = Request(0, PROMPT_A, max_new_tokens=6)
    big = Request(1, PROMPT_B, max_new_tokens=1000)      # can never fit
    r2 = Request(2, PROMPT_B, max_new_tokens=5)
    sched.submit(r0)
    sched.step()                                         # r0 live mid-decode
    sched.submit(big)
    sched.submit(r2)
    done = _drain(sched)
    assert {r.rid for r in done} == {0, 1, 2}
    assert big.failed and big.out_tokens == [] and "capacity" in big.error
    assert not r0.failed and not r2.failed
    assert r0.out_tokens == ref[0].out_tokens
    assert r2.out_tokens == ref[1].out_tokens


@pytest.mark.parametrize("paged", [True, False])
def test_admission_capacity_boundary(olmo, paged):
    """The first sampled token comes from prefill logits and writes no
    cache slot, so a request needing exactly `capacity` slots (prompt +
    max_new - 1) must be admitted; one more must be rejected."""
    cfg, params = olmo
    sched = ContinuousScheduler(cfg, params, max_batch=1, max_ctx=32,
                                bucket=16, paged=paged)
    cap = sched._capacity
    n = len(PROMPT_A)
    fits = Request(0, PROMPT_A, max_new_tokens=cap - n + 1)   # == capacity
    sched.run([fits])
    assert not fits.failed
    assert len(fits.out_tokens) == cap - n + 1

    over = Request(1, PROMPT_A, max_new_tokens=cap - n + 2)   # capacity + 1
    sched.run([over])
    assert over.failed and over.out_tokens == []


def test_zero_max_new_reserves_prompt_blocks(olmo):
    """max_new_tokens <= 0 still emits the prefill token, so it must
    reserve like max_new = 1 — under-reservation used to let prompt-block
    allocation outrun the reservation and crash the pool invariant."""
    cfg, params = olmo
    sched = ContinuousScheduler(cfg, params, max_batch=2, max_ctx=32,
                                bucket=16, paged=True, block_size=4,
                                pool_blocks=8)
    reqs = [Request(0, PROMPT_A, max_new_tokens=0),
            Request(1, PROMPT_B, max_new_tokens=0),
            Request(2, PROMPT_A, max_new_tokens=3)]
    done = sched.run(reqs)
    assert {r.rid for r in done} == {0, 1, 2}
    assert [len(r.out_tokens) for r in reqs] == [1, 1, 3]
    # all blocks back to reclaimable capacity (free or prefix-retained)
    assert len(sched._free) + len(sched._lru) == sched.pool_blocks
    assert sched._avail == sched.pool_blocks


@pytest.mark.parametrize("arch", ["olmo-1b", "recurrentgemma-9b", "rwkv6-3b"])
def test_bucketed_prefill_matches_exact_length(arch):
    """A solo prefill of an 8-token prompt bucketed to 64 produces the
    same greedy continuation as an exact-length prefill: right-padding
    keeps pad tokens out of the cache, the recurrent state, the length
    accounting, and the rope positions."""
    cfg = get_reduced_config(arch)
    params = build_model(cfg).init(KEY)
    exact = ServingEngine(cfg, params, max_batch=1, bucket=8).generate_static(
        [Request(0, PROMPT_B, max_new_tokens=6)])[0].out_tokens
    bucketed = ServingEngine(cfg, params, max_batch=1,
                             bucket=64).generate_static(
        [Request(0, PROMPT_B, max_new_tokens=6)])[0].out_tokens
    assert bucketed == exact

    cont = ServingEngine(cfg, params, max_batch=1, bucket=64).generate(
        [Request(0, PROMPT_B, max_new_tokens=6)])[0].out_tokens
    assert cont == exact


def test_static_decode_grows_past_headroom(olmo):
    """generate_static with max_new far beyond the prefill headroom used
    to silently rewrite the last cache slot (write_slot's clamp); the
    cache now grows and tokens match the continuous scheduler's."""
    cfg, params = olmo
    long_static = ServingEngine(cfg, params, max_batch=1,
                                bucket=16).generate_static(
        [Request(0, PROMPT_A, max_new_tokens=24)])[0].out_tokens
    long_cont = ServingEngine(cfg, params, max_batch=1, bucket=16).generate(
        [Request(0, PROMPT_A, max_new_tokens=24)])[0].out_tokens
    assert long_static == long_cont
    assert len(long_static) == 24


def test_static_overflow_guard_raises(olmo):
    """With max_ctx capping the engine, a static batch that would write
    past it raises instead of silently overwriting the last slot; the
    continuous path enforces the same cap per-request (error, no raise)."""
    cfg, params = olmo
    eng = ServingEngine(cfg, params, max_batch=1, bucket=16, max_ctx=24)
    with pytest.raises(ValueError, match="max_ctx"):
        eng.generate_static([Request(0, PROMPT_A, max_new_tokens=20)])

    over = Request(0, PROMPT_A, max_new_tokens=40)
    ok = Request(1, PROMPT_B, max_new_tokens=4)
    eng.generate([over, ok])           # must not raise
    assert over.failed and over.out_tokens == []
    assert not ok.failed and len(ok.out_tokens) == 4


def test_ring_cache_ignores_paged_flag(olmo):
    """Sliding-window archs keep the contiguous ring; asking for paged
    explicitly is a clear error, auto mode silently stays contiguous."""
    cfg, _ = olmo
    cfg = dataclasses.replace(cfg, attn_window=8)
    params = build_model(cfg).init(KEY)
    sched = ContinuousScheduler(cfg, params, max_batch=1, max_ctx=32,
                                bucket=16)
    assert not sched.paged
    with pytest.raises(ValueError, match="paged"):
        ContinuousScheduler(cfg, params, max_batch=1, max_ctx=32,
                            bucket=16, paged=True)
