"""Behavioural tests for the M4BRAM block model (modes, shuffler, eFSM)."""
import numpy as np
import pytest

from repro.core import m4bram
from repro.core.m4bram import CimInstruction, M4BramBlock, M4BramConfig


def test_memory_mode_byte_enable():
    blk = M4BramBlock(M4BramConfig())
    blk.write(3, 0xAABBCCDD)
    assert blk.read(3) == 0xAABBCCDD
    blk.write(3, 0x11223344, byte_enable=0b0101)  # bytes 0 and 2 only
    assert blk.read(3) == 0xAA22CC44


def test_weight_vector_roundtrip_signed():
    blk = M4BramBlock(M4BramConfig(w_bits=4))
    codes = [-8, 7, -1, 3, 0, -5, 2, 1]
    blk.write_weight_vector(0, codes)
    assert blk._read_weight_codes(0) == codes


def test_compute_dot_product_all_precisions():
    rng = np.random.default_rng(0)
    for pw in (2, 4, 8):
        for ab in (2, 5, 8):
            lanes_per_bpe = 8 // pw
            n_out = 4 * lanes_per_bpe
            K = 6
            blk = M4BramBlock(M4BramConfig(w_bits=pw, dp_factor=1))
            blk.set_mode("compute")
            lo_w, hi_w = -(1 << (pw - 1)), (1 << (pw - 1)) - 1
            lo_a, hi_a = -(1 << (ab - 1)), (1 << (ab - 1)) - 1
            W = rng.integers(lo_w, hi_w + 1, (K, n_out))
            I = rng.integers(lo_a, hi_a + 1, K)
            for k in range(0, K, 2):
                blk.write_weight_vector(0, W[k])
                blk.write_weight_vector(1, W[k + 1])
                a1 = tuple(int(I[k]) for _ in range(4))
                a2 = tuple(int(I[k + 1]) for _ in range(4))
                blk.issue_mac2(
                    CimInstruction(0, activations=a1, in_clr=True, a_bits=ab),
                    CimInstruction(1, activations=a2),
                )
            res = blk.read_result().reshape(-1)
            np.testing.assert_array_equal(res, I @ W)


def test_shuffler_broadcast_dp4():
    rng = np.random.default_rng(1)
    blk = M4BramBlock(M4BramConfig(w_bits=8, dp_factor=4))
    blk.set_mode("compute")
    wv = [int(v) for v in rng.integers(-128, 128, 4)]
    blk.write_weight_vector(0, wv)
    blk.write_weight_vector(1, [0, 0, 0, 0])
    acts = tuple(int(v) for v in rng.integers(-8, 8, 4))
    for sel in range(4):
        blk.clear_acc()
        blk.issue_mac2(
            CimInstruction(0, addr_dp=sel, activations=acts, in_clr=True, a_bits=4),
            CimInstruction(1, addr_dp=sel, activations=(0, 0, 0, 0)),
        )
        res = blk.read_result().reshape(-1)
        np.testing.assert_array_equal(res, [wv[sel] * a for a in acts])


def test_shuffler_dp2_pairs():
    blk = M4BramBlock(M4BramConfig(w_bits=8, dp_factor=2))
    blk.set_mode("compute")
    blk.write_weight_vector(0, [10, 20, 30, 40])
    blk.write_weight_vector(1, [0, 0, 0, 0])
    acts = (1, 2, 3, 4)
    blk.issue_mac2(
        CimInstruction(0, addr_dp=0, activations=acts, in_clr=True, a_bits=4),
        CimInstruction(1, addr_dp=0, activations=(0, 0, 0, 0)),
    )
    # dp=2: BPE0/1 share slice A(=10), BPE2/3 share slice B(=20).
    res = blk.read_result().reshape(-1)
    np.testing.assert_array_equal(res, [10 * 1, 10 * 2, 20 * 3, 20 * 4])


def test_in_clr_reconfigures_precision():
    blk = M4BramBlock(M4BramConfig(w_bits=8))
    blk.set_mode("compute")
    blk.write_weight_vector(0, [3, 0, 0, 0])
    blk.write_weight_vector(1, [0, 0, 0, 0])
    # 2-bit signed activations: value -2 is representable; +3 is not.
    blk.issue_mac2(
        CimInstruction(0, activations=(-2, 0, 0, 0), in_clr=True, a_bits=2),
        CimInstruction(1, activations=(0, 0, 0, 0)),
    )
    res = blk.read_result()
    assert res[0, 0] == -6
    assert blk.a_bits == 2


def test_memory_mode_available_during_compute():
    """The one-port property: memory reads/writes still work while the
    accumulators hold partial results (dual use, §IV-B)."""
    blk = M4BramBlock(M4BramConfig(w_bits=8))
    blk.set_mode("compute")
    blk.write_weight_vector(0, [5, 6, 7, 8])
    blk.write_weight_vector(1, [0, 0, 0, 0])
    blk.issue_mac2(
        CimInstruction(0, activations=(2, 2, 2, 2), in_clr=True, a_bits=4),
        CimInstruction(1, activations=(0, 0, 0, 0)),
    )
    blk.write(100, 0xDEADBEEF)          # port not occupied by BPE
    assert blk.read(100) == 0xDEADBEEF  # DSP-side read during CIM
    res = blk.read_result().reshape(-1)
    np.testing.assert_array_equal(res, [10, 12, 14, 16])


def test_invalid_configs_raise():
    with pytest.raises(ValueError):
        M4BramConfig(w_bits=3)
    with pytest.raises(ValueError):
        M4BramConfig(dp_factor=3)
    blk = M4BramBlock(M4BramConfig())
    blk.set_mode("compute")
    with pytest.raises(ValueError):
        blk.issue_mac2(
            CimInstruction(0, in_clr=True, a_bits=9),
            CimInstruction(1),
        )


def test_geometry_constants_match_table2():
    assert m4bram.M4BRAM_S.lanes(8) == 4 and m4bram.M4BRAM_L.lanes(8) == 8
    assert m4bram.M4BRAM_S.readout_stall_cycles() == 4
    assert m4bram.M4BRAM_L.readout_stall_cycles() == 8
    assert m4bram.M4BRAM_S.area_overhead == pytest.approx(0.196)
    assert m4bram.M4BRAM_L.area_overhead == pytest.approx(0.334)
