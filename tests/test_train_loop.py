"""Training loop integration: loss decreases, microbatch equivalence,
compression path, fault-tolerant resume."""
import dataclasses

import pytest

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_reduced_config
from repro.configs.base import TrainConfig
from repro.data import DataIterator
from repro.models import build_model
from repro.train.loop import StragglerMonitor, init_train_state, make_train_step, run_training


def _setup(arch="olmo-1b", steps=30, **tc_kw):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    tc = TrainConfig(lr=1e-2, warmup_steps=2, total_steps=steps, log_every=5,
                     checkpoint_every=10, **tc_kw)
    # branch=4: strongly structured Markov stream a tiny model can learn
    # within tens of steps.
    data = DataIterator(cfg, global_batch=8, seq_len=64, seed=0, branch=4)
    return cfg, model, tc, data


def test_loss_decreases():
    cfg, model, tc, data = _setup(steps=80)
    state, history = run_training(model, tc, data)
    losses = [h["loss"] for h in history]
    assert losses[-1] < losses[0] - 0.5, losses


@pytest.mark.slow
def test_microbatch_grads_match_full_batch():
    cfg, model, tc, data = _setup()
    batch = data.batch_at(0)
    batch = jax.tree_util.tree_map(jnp.asarray, batch)
    s1 = init_train_state(model.init(jax.random.PRNGKey(0)), tc)
    tc2 = dataclasses.replace(tc, microbatches=2)
    s2 = init_train_state(model.init(jax.random.PRNGKey(0)), tc2)
    n1, _ = make_train_step(model, tc)(s1, batch)
    n2, _ = make_train_step(model, tc2)(s2, batch)
    for a, b in zip(jax.tree_util.tree_leaves(n1.params),
                    jax.tree_util.tree_leaves(n2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-2)


def test_grad_compression_training_still_learns():
    cfg, model, tc, data = _setup(steps=80, grad_compress_bits=8)
    state, history = run_training(model, tc, data)
    assert state.err is not None
    losses = [h["loss"] for h in history]
    assert losses[-1] < losses[0] - 0.5, losses


def test_resume_from_checkpoint(tmp_path):
    cfg, model, tc, data = _setup()
    mgr = CheckpointManager(tmp_path, keep=2)
    run_training(model, tc, data, checkpoint_mgr=mgr)
    assert mgr.latest_step() == 30
    # A "restarted job" resumes at 30 and runs to a larger horizon.
    tc2 = dataclasses.replace(tc, total_steps=35)
    data2 = DataIterator(cfg, global_batch=4, seq_len=32, seed=0)
    state, history = run_training(model, tc2, data2, checkpoint_mgr=mgr)
    assert data2.step >= 35
    assert all(h["step"] >= 30 for h in history)


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=2.0)
    assert not mon.observe(1.0)
    for _ in range(5):
        assert not mon.observe(1.0)
    assert mon.observe(5.0)
    assert mon.flagged == 1
