"""Serving engine: batched generate, greedy determinism, quantized path."""
import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.core.quant import QuantConfig
from repro.models import build_model
from repro.serving import Request, ServingEngine


def _engine(quant=None, arch="olmo-1b", max_batch=4):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return ServingEngine(cfg, params, max_batch=max_batch, quant=quant, bucket=16)


def test_generate_batch_shapes():
    eng = _engine()
    reqs = [Request(rid=i, prompt=np.arange(5 + i) % 64, max_new_tokens=4)
            for i in range(3)]
    done = eng.generate(reqs)
    for r in done:
        assert len(r.out_tokens) == 4
        assert all(0 <= t < eng.cfg.vocab for t in r.out_tokens)


def test_greedy_is_deterministic():
    eng = _engine()
    r1 = eng.generate([Request(0, np.arange(8) % 64, max_new_tokens=5)])[0]
    eng2 = _engine()
    r2 = eng2.generate([Request(0, np.arange(8) % 64, max_new_tokens=5)])[0]
    assert r1.out_tokens == r2.out_tokens


def test_batching_does_not_change_greedy_output():
    eng = _engine(max_batch=2)
    solo = eng.generate([Request(0, np.arange(8) % 64, max_new_tokens=3)])[0]
    eng2 = _engine(max_batch=2)
    pair = eng2.generate([
        Request(0, np.arange(8) % 64, max_new_tokens=3),
        Request(1, (np.arange(8) + 3) % 64, max_new_tokens=3),
    ])
    assert solo.out_tokens == pair[0].out_tokens


def test_quantized_serving_runs():
    eng = _engine(quant=QuantConfig(w_bits=4, a_bits=8))
    from repro.core.quantized_linear import PackedWeight

    packed = [l for l in jax.tree_util.tree_leaves(
        eng.params, is_leaf=lambda x: isinstance(x, PackedWeight))
        if isinstance(x := l, PackedWeight)]
    assert packed, "serving quantization should pack at least one weight"
    out = eng.generate([Request(0, np.arange(6) % 64, max_new_tokens=3)])[0]
    assert len(out.out_tokens) == 3


def test_temperature_sampling_varies():
    eng = _engine()
    reqs = [Request(i, np.arange(8) % 64, max_new_tokens=8, temperature=5.0)
            for i in range(2)]
    done = eng.generate(reqs)
    assert done[0].out_tokens != done[1].out_tokens or True  # smoke: no crash
