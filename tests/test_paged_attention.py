"""Fused paged-attention decode kernel vs the gather-then-attend reference.

The specification is the surviving reference composition —
``kv_cache.paged_gather`` → ``models.common.decode_attention`` — swept
over ragged block tables, block-boundary positions, GQA group sizes,
bf16 and int8 pools, and freed-slot rows (trash-block garbage must never
leak into a live row's output). The transformer-level test drives the
whole ``_decode_step_paged`` both ways; the scheduler-level test checks
the int8 paged pool serves greedy bit-identically to the contiguous int8
cache (the restriction PR 4 lifted)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.kernels import ops
from repro.models import build_model
from repro.models.common import decode_attention
from repro.models.kv_cache import paged_gather, quantize_kv

KEY = jax.random.PRNGKey(0)


def _case(seed, *, B, n_kv, group, H, bs, maxb, quantized,
          positions=None, tables=None):
    """Random pool + ragged tables. Row b gets `tables[b]` live blocks
    (defaults: a ragged mix incl. a freed row when B >= 3); positions
    default to the last slot of each row's live span."""
    rng = np.random.default_rng(seed)
    nb = B * maxb + 1
    kf = jnp.asarray(rng.normal(size=(nb, bs, n_kv, H)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(nb, bs, n_kv, H)), jnp.float32)
    if quantized:
        pool_k, k_scale = quantize_kv(kf)
        pool_v, v_scale = quantize_kv(vf)
    else:
        pool_k, pool_v = kf.astype(jnp.bfloat16), vf.astype(jnp.bfloat16)
        k_scale = v_scale = None
    if tables is None:
        live = [max(1, maxb - b) for b in range(B)]
        if B >= 3:
            live[B - 1] = 0  # freed slot: table all -1
        tables = live
    tbl = np.full((B, maxb), -1, np.int32)
    free = list(range(1, nb))
    rng.shuffle(free)  # non-contiguous pool blocks: table order != pool order
    for b, n in enumerate(tables):
        for j in range(n):
            tbl[b, j] = free.pop()
    if positions is None:
        positions = [max(0, n * bs - 1) for n in tables]
    q = jnp.asarray(rng.normal(size=(B, 1, n_kv * group, H)), jnp.bfloat16)
    return (q, pool_k, pool_v, jnp.asarray(tbl),
            jnp.asarray(positions, jnp.int32), k_scale, v_scale)


def _reference(q, pool_k, pool_v, tbl, pos, k_scale, v_scale):
    k_r, v_r, kpos, ks_r, vs_r = paged_gather(pool_k, pool_v, tbl,
                                              k_scale, v_scale)
    return decode_attention(q, k_r, v_r, kpos, pos,
                            k_scale=ks_r, v_scale=vs_r)


@pytest.mark.parametrize("n_kv,group", [(4, 1), (2, 2), (2, 4)])
@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("bh", [0, 1, 2])  # 0 = planner default (all heads)
def test_fused_matches_gather_reference(n_kv, group, quantized, bh):
    """Ragged tables + freed row, every GQA grouping, both pool dtypes,
    and every head-tiling the autotuner / a loaded plan file can pick
    (bh < NKV runs the multi-step head grid): the fused kernel reproduces
    the gather-based reference on live rows (bitwise after the output's
    bf16 cast)."""
    case = _case(1, B=3, n_kv=n_kv, group=group, H=16, bs=4, maxb=4,
                 quantized=quantized)
    blocks = (bh, 4, 16) if bh else None
    out = ops.paged_attention(case[0], *case[1:3], *case[3:5],
                              k_scale=case[5], v_scale=case[6],
                              blocks=blocks, backend="interpret")
    ref = _reference(*case)
    # Row 2 is freed (table all -1): its output is discarded by the
    # scheduler and differs by construction (fused -> zeros, reference ->
    # uniform average); live rows must agree exactly in bf16.
    assert np.array_equal(np.asarray(out[:2]), np.asarray(ref[:2]))
    assert np.all(np.asarray(out[2]) == 0)


def test_reference_backend_is_gather_composition():
    """backend="reference" must agree with the explicit paged_gather →
    decode_attention composition (it IS the specification)."""
    case = _case(2, B=2, n_kv=2, group=2, H=16, bs=4, maxb=3,
                 quantized=False)
    out = ops.paged_attention(case[0], *case[1:3], *case[3:5],
                              backend="reference")
    ref = _reference(*case)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("pos", [0, 3, 4, 7, 8, 15])
def test_block_boundary_positions(pos):
    """Positions at, just before, and just after every block boundary
    (bs=4): the kernel's per-element visibility mask must match the
    reference's kpos <= q_pos on both sides of each crossing."""
    case = _case(3, B=2, n_kv=2, group=2, H=16, bs=4, maxb=4,
                 quantized=False, tables=[4, 4], positions=[pos, pos])
    out = ops.paged_attention(case[0], *case[1:3], *case[3:5],
                              backend="interpret")
    ref = _reference(*case)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("quantized", [False, True])
def test_trash_block_never_leaks_into_live_rows(quantized):
    """Fill the trash block (pool block 0) with huge garbage — the writes
    freed slots and unallocated virtual blocks land on. No live row's
    output may change."""
    case = _case(4, B=3, n_kv=2, group=2, H=16, bs=4, maxb=4,
                 quantized=quantized)
    q, pool_k, pool_v, tbl, pos, ks, vs = case
    clean = ops.paged_attention(q, pool_k, pool_v, tbl, pos,
                                k_scale=ks, v_scale=vs, backend="interpret")
    big = 120 if quantized else 1e4
    pool_k = pool_k.at[0].set(jnp.full(pool_k.shape[1:], big, pool_k.dtype))
    pool_v = pool_v.at[0].set(jnp.full(pool_v.shape[1:], big, pool_v.dtype))
    if quantized:
        ks = ks.at[0].set(jnp.full(ks.shape[1:], 1e4, ks.dtype))
        vs = vs.at[0].set(jnp.full(vs.shape[1:], 1e4, vs.dtype))
    dirty = ops.paged_attention(q, pool_k, pool_v, tbl, pos,
                                k_scale=ks, v_scale=vs, backend="interpret")
    assert np.array_equal(np.asarray(clean[:2]), np.asarray(dirty[:2]))


def test_paged_gather_max_blocks_clamp():
    """The clamped gather returns exactly the prefix of the full gather
    (satellite: stop copying guaranteed-dead trash-block columns)."""
    case = _case(5, B=3, n_kv=2, group=1, H=8, bs=4, maxb=6,
                 quantized=True, tables=[2, 3, 1])
    _, pool_k, pool_v, tbl, pos, ks, vs = case
    k_f, v_f, kpos_f, ks_f, vs_f = paged_gather(pool_k, pool_v, tbl, ks, vs)
    k_c, v_c, kpos_c, ks_c, vs_c = paged_gather(pool_k, pool_v, tbl, ks, vs,
                                                max_blocks=3)
    n = 3 * 4
    for full, clamped in ((k_f, k_c), (v_f, v_c), (kpos_f, kpos_c),
                          (ks_f, ks_c), (vs_f, vs_c)):
        assert clamped.shape[1] == n
        assert np.array_equal(np.asarray(full[:, :n]), np.asarray(clamped))
    # And attention over the clamp is bit-identical when it covers every
    # live block (softmax weights on masked slots are exactly zero).
    full = decode_attention(case[0], k_f, v_f, kpos_f, pos,
                            k_scale=ks_f, v_scale=vs_f)
    clam = decode_attention(case[0], k_c, v_c, kpos_c, pos,
                            k_scale=ks_c, v_scale=vs_c)
    assert np.array_equal(np.asarray(full), np.asarray(clam))


@pytest.mark.parametrize("quantized", [False, True])
def test_decode_step_fused_vs_reference_path(quantized):
    """Whole-model check: _decode_step_paged with the fused kernel vs the
    gather-then-attend path — same pool writes (bitwise) and same logits
    (bf16-exact), on both pool dtypes."""
    from repro.models import transformer

    cfg = dataclasses.replace(get_reduced_config("olmo-1b"),
                              kv_cache_quant=quantized)
    params = build_model(cfg).init(KEY)
    cache = transformer.init_paged_cache(cfg, batch=2, num_blocks=9,
                                         block_size=4, max_blocks=4)
    tbl = jnp.asarray([[1, 2, 3, -1], [4, 5, -1, -1]], jnp.int32)
    kv = dataclasses.replace(cache.kv, block_table=tbl,
                             length=jnp.asarray([9, 5], jnp.int32))
    cache = dataclasses.replace(cache, kv=kv,
                                pos=jnp.asarray([9, 5], jnp.int32))
    toks = jnp.asarray([[7], [11]], jnp.int32)
    c_f, lg_f = transformer.decode_step(params, cfg, cache, toks)
    c_r, lg_r = transformer.decode_step(params, cfg, cache, toks,
                                        paged_fused=False)
    assert np.array_equal(np.asarray(lg_f), np.asarray(lg_r))
    assert np.array_equal(np.asarray(c_f.kv.k), np.asarray(c_r.kv.k))
    assert np.array_equal(np.asarray(c_f.kv.v), np.asarray(c_r.kv.v))
    if quantized:
        assert c_f.kv.quantized
        assert np.array_equal(np.asarray(c_f.kv.k_scale),
                              np.asarray(c_r.kv.k_scale))


def test_quantizing_paged_cache_write():
    """paged_cache_write with scale planes quantizes on the way in: the
    written slots hold exactly quantize_kv's codes and scales."""
    from repro.models.kv_cache import paged_cache_write

    rng = np.random.default_rng(6)
    B, n_kv, H, bs, nb = 3, 2, 8, 4, 5
    pool_k = jnp.zeros((nb, bs, n_kv, H), jnp.int8)
    pool_v = jnp.zeros((nb, bs, n_kv, H), jnp.int8)
    ks = jnp.zeros((nb, bs, n_kv, 1), jnp.float32)
    vs = jnp.zeros((nb, bs, n_kv, 1), jnp.float32)
    tbl = jnp.asarray([[1, 2], [3, -1], [-1, -1]], jnp.int32)
    k_new = jnp.asarray(rng.normal(size=(B, 1, n_kv, H)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(B, 1, n_kv, H)), jnp.float32)
    pos = jnp.asarray([5, 2, 0], jnp.int32)  # rows 0/1 live, row 2 freed
    pool_k, pool_v, ks, vs = paged_cache_write(
        pool_k, pool_v, tbl, k_new, v_new, pos, bs, k_scale=ks, v_scale=vs)
    kq, kscale = quantize_kv(k_new)
    assert np.array_equal(np.asarray(pool_k[2, 1]), np.asarray(kq[0, 0]))
    assert np.array_equal(np.asarray(ks[2, 1]), np.asarray(kscale[0, 0]))
    assert np.array_equal(np.asarray(pool_k[3, 2]), np.asarray(kq[1, 0]))
    # Row 2 is freed: its write landed in the trash block, not a live one.
    assert np.array_equal(np.asarray(pool_k[0, 0]),
                          np.asarray(quantize_kv(k_new)[0][2, 0]))


@pytest.fixture(scope="module")
def olmo_int8():
    cfg = dataclasses.replace(get_reduced_config("olmo-1b"),
                              kv_cache_quant=True)
    return cfg, build_model(cfg).init(KEY)


def test_int8_paged_serving_matches_contiguous_int8(olmo_int8):
    """The lifted scheduler restriction: int8-KV requests serve from the
    paged pool (fused kernel, in-kernel dequant) greedy bit-identical to
    the contiguous int8 cache — including a mid-decode admission across
    block boundaries."""
    from repro.serving import ContinuousScheduler, Request

    cfg, params = olmo_int8
    pa = np.arange(8) % 64
    pb = (np.arange(8) + 3) % 64
    reqs = lambda: [Request(0, pa, max_new_tokens=8),
                    Request(1, pb, max_new_tokens=5)]
    contig = ContinuousScheduler(cfg, params, max_batch=2, max_ctx=48,
                                 bucket=16, paged=False)
    ref = {r.rid: r.out_tokens for r in contig.run(reqs())}

    paged = ContinuousScheduler(cfg, params, max_batch=2, max_ctx=48,
                                bucket=16, paged=True, block_size=4)
    assert paged.paged and paged.cache.kv.quantized
    got = {r.rid: r.out_tokens for r in paged.run(reqs())}
    assert got == ref
    stats = paged.pool_stats()
    assert stats["paged"] and stats["reserved_kv_bytes"] > 0

    # Mid-decode admission: join after 3 steps, crossing block boundaries.
    sched = ContinuousScheduler(cfg, params, max_batch=2, max_ctx=48,
                                bucket=16, paged=True, block_size=4)
    r0, r1 = reqs()
    sched.submit(r0)
    for _ in range(3):
        sched.step()
    sched.submit(r1)
    while sched.num_active or sched.num_waiting:
        sched.step()
    assert r0.out_tokens == ref[0]
    assert r1.out_tokens == ref[1]
