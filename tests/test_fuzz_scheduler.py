"""Randomized differential fuzz of the continuous scheduler.

Each case drives one scheduler through a few hundred seeded steps of
adversarial traffic — random admissions onto shared prefixes, cancels,
step-budget deadlines, precision tiers, self-speculation, pool-pressure
preemption, and host-tier spills (the pool is sized well below the
working set, so LRU eviction and block-to-host churn fire constantly) —
and checks two things the whole serving stack promises:

  * `assert_pool_invariants` after EVERY step (refcounts, partition,
    index/host-tier exclusivity, reservation and byte accounting);
  * every retired stream is bitwise its solo-engine oracle's: clean
    retirements match exactly, cancelled/deadline retirements match a
    prefix. Sampling is step-indexed per (seed, rid), so sampled
    streams are compared exactly too.

Runs on bf16 and int8 pools across ≥3 seeds. Uses the deterministic
hypothesis fallback so it collects (and stays reproducible) without
hypothesis installed.
"""
import dataclasses

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:  # container without hypothesis — deterministic fallback
    from hypothesis_fallback import given, settings, strategies as st  # noqa: F401,E501

from repro.configs import get_reduced_config
from repro.core.quant import QuantConfig
from repro.models import build_model
from repro.serving import ContinuousScheduler, Request, assert_pool_invariants

KEY = jax.random.PRNGKey(0)
Q8 = QuantConfig(w_bits=8, a_bits=8)
SYS = np.arange(16) % 64                     # shared system prefix
HOSTKB = 1 << 20


@pytest.fixture(scope="module")
def olmo():
    cfg = get_reduced_config("olmo-1b")
    params = build_model(cfg).init(KEY)
    return cfg, params


@pytest.fixture(scope="module")
def olmo_int8():
    cfg = dataclasses.replace(get_reduced_config("olmo-1b"),
                              kv_cache_quant=True)
    params = build_model(cfg).init(KEY)
    return cfg, params


def _sched(cfg, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_ctx", 64)
    kw.setdefault("bucket", 16)
    kw.setdefault("paged", True)
    kw.setdefault("block_size", 4)
    kw.setdefault("chunked_prefill", False)
    return ContinuousScheduler(cfg, params, **kw)


class _Oracle:
    """Memoized solo-engine reference: each distinct request is served
    alone through ONE long-lived scheduler (prefix cache off, pool far
    bigger than any request) so its stream is the uninterrupted,
    unshared ground truth. One instance per model fixture — reusing the
    scheduler keeps every oracle call on warm compiled functions."""

    def __init__(self, cfg, params, **kw):
        kw.setdefault("pool_blocks", 96)
        self.sched = _sched(cfg, params, prefix_cache=False,
                            preempt=False, **kw)
        self.memo = {}

    def stream(self, req: Request):
        key = (tuple(int(t) for t in req.prompt), req.max_new_tokens,
               req.tier, float(req.temperature), req.rid)
        if key not in self.memo:
            clone = Request(rid=req.rid, prompt=np.array(req.prompt),
                            max_new_tokens=req.max_new_tokens,
                            temperature=req.temperature, tier=req.tier)
            self.sched.run([clone])
            assert clone.error is None, f"oracle failed: {clone.error}"
            self.memo[key] = clone.out_tokens
        return self.memo[key]


def _fuzz_run(cfg, params, oracle, seed, steps, sched_kw, tiers=None):
    rng = np.random.default_rng(seed)
    sched = _sched(cfg, params, **sched_kw)
    tails = [rng.integers(0, 64, int(rng.integers(1, 8)))
             for _ in range(5)]
    tier_names = tiers.split(",") if tiers else [None]
    retired, next_rid = [], 0

    for _ in range(steps):
        u = rng.random()
        backlog = sched.num_active + len(sched.waiting)
        if u < 0.35 and backlog < 6:
            tail = tails[int(rng.integers(len(tails)))]
            extra = rng.integers(0, 64, int(rng.integers(0, 4)))
            prompt = np.concatenate([SYS[:int(rng.integers(4, 17))],
                                     tail, extra]).astype(np.int64)
            req = Request(
                rid=next_rid, prompt=prompt,
                max_new_tokens=int(rng.integers(2, 7)),
                temperature=float(rng.choice([0.0, 0.0, 0.0, 0.8])),
                tier=tier_names[int(rng.integers(len(tier_names)))],
                deadline_steps=(int(rng.integers(2, 8))
                                if rng.random() < 0.08 else None))
            next_rid += 1
            sched.submit(req)
        elif u < 0.42:
            rids = ([r.rid for r in sched._slots if r is not None]
                    + [r.rid for r in sched.waiting])
            if rids:
                sched.cancel(int(rng.choice(rids)))
        retired.extend(sched.step())
        assert_pool_invariants(sched)
    while sched.num_active or sched.waiting:
        retired.extend(sched.step())
        assert_pool_invariants(sched)

    assert retired, "fuzz run retired nothing — admission never fired?"
    clean = 0
    for req in retired:
        got = req.out_tokens or []
        ref = oracle.stream(req)
        if req.error is None:
            assert got == ref, (
                f"rid {req.rid} diverged from its solo oracle:\n"
                f"  got {got}\n  ref {ref}")
            clean += 1
        else:
            assert req.error in ("cancelled", "deadline"), req.error
            assert got == ref[:len(got)], (
                f"rid {req.rid} ({req.error}) emitted a non-prefix "
                f"stream:\n  got {got}\n  ref {ref}")
    assert clean, "every retirement was abnormal — nothing verified"
    return sched


# -- the fuzz matrix -------------------------------------------------------

_ORACLES: dict = {}
SEEDS = st.integers(0, 2**20)   # ≥3 distinct seeds per test (max_examples)


@pytest.mark.slow
@given(seed=SEEDS)
@settings(max_examples=3, deadline=None)
def test_fuzz_differential_bf16(olmo, seed):
    """Main matrix: pressure-sized pool, host tier + block-to-host
    preemption + prefix cache + chunked prefill all armed, 3 seeds."""
    cfg, params = olmo
    oracle = _ORACLES.setdefault("bf16", _Oracle(cfg, params))
    sched = _fuzz_run(cfg, params, oracle, seed, 220, dict(
        pool_blocks=16, host_pool_bytes=HOSTKB,
        victim_policy="block-to-host", chunked_prefill=True,
        prefill_budget=8))
    st_ = sched.pool_stats()
    assert st_["swap_outs"] > 0, "pool never pressured the host tier"


@pytest.mark.slow
@given(seed=SEEDS)
@settings(max_examples=3, deadline=None)
def test_fuzz_differential_int8(olmo_int8, seed):
    cfg, params = olmo_int8
    oracle = _ORACLES.setdefault("int8", _Oracle(cfg, params))
    _fuzz_run(cfg, params, oracle, seed, 160, dict(
        pool_blocks=16, host_pool_bytes=HOSTKB,
        victim_policy="block-to-host"))


@pytest.mark.slow
@given(seed=SEEDS)
@settings(max_examples=3, deadline=None)
def test_fuzz_differential_tiers_speculative(olmo, seed):
    """Quantized matrix: per-request precision tiers and
    self-speculation active while the pool churns."""
    cfg, params = olmo
    oracle = _ORACLES.setdefault(
        "q8", _Oracle(cfg, params, quant=Q8, tiers="w8a8,w4a8"))
    _fuzz_run(cfg, params, oracle, seed, 120, dict(
        pool_blocks=16, host_pool_bytes=HOSTKB,
        victim_policy="block-to-host", quant=Q8, tiers="w8a8,w4a8",
        speculate=2, draft_policy="w4a8"), tiers="w8a8,w4a8")


def test_fuzz_differential_smoke(olmo):
    """Tier-1 (non-slow) guard: one short seeded run so the fuzz path
    itself can't rot between full (slow-marked) runs."""
    cfg, params = olmo
    oracle = _ORACLES.setdefault("bf16", _Oracle(cfg, params))
    _fuzz_run(cfg, params, oracle, 5, 60, dict(
        pool_blocks=16, host_pool_bytes=HOSTKB,
        victim_policy="block-to-host"))
