"""Per-request precision tiers: one packed weight set serving w8/w4/w2
quality–latency classes inside a single continuous batch.

The contract has three layers:

  * a tier is a *view*: ``truncate_policy_view`` shares every packed /
    scale buffer with the storage params by identity (a tier equal to the
    storage policy returns the params object itself), so N tiers cost N
    jit traces and zero extra weight bytes;
  * a tier is *isolated*: a request served at tier T inside a mixed-tier
    continuous batch is greedy bit-identical to a solo engine whose whole
    policy is T — across bf16/int8 pools, mid-decode admission, warm
    prefixes, and speculation (tier groups decode through masked block
    tables; prefix hashes are tier-scoped);
  * a tier *composes* with speculation: the draft must truncate strictly
    below a slot's tier (a w2 slot has nothing cheaper to draft with) and
    verification runs at the slot's tier, batched per tier group.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.precision import (
    parse_tier_specs,
    truncate_policy_view,
)
from repro.core.quant import QuantConfig
from repro.core.quantized_linear import PackedWeight, quantize_params_for_serving
from repro.models import build_model
from repro.serving import ContinuousScheduler, Request, assert_pool_invariants

KEY = jax.random.PRNGKey(0)
BS = 4
Q8 = QuantConfig(w_bits=8, a_bits=8)
PROMPT_A = np.zeros(8, np.int64)
PROMPT_B = (np.arange(11) * 5 + 2) % 64   # non-divisor of block/bucket
PROMPT_C = (np.arange(7) * 3 + 1) % 64
TIERS = "w8a8,w4a8,w2a8"


@pytest.fixture(scope="module")
def olmo():
    cfg = get_reduced_config("olmo-1b")
    params = build_model(cfg).init(KEY)
    return cfg, params


def _sched(cfg, params, tiers=TIERS, max_batch=3, **kw):
    kw.setdefault("max_ctx", 64)
    kw.setdefault("quant", Q8)
    return ContinuousScheduler(cfg, params, max_batch=max_batch, bucket=16,
                               paged=True, block_size=BS,
                               chunked_prefill=True, prefill_budget=8,
                               tiers=tiers, **kw)


def _drain(sched):
    out = []
    while sched.num_active or sched.num_waiting:
        out.extend(sched.step())
    assert_pool_invariants(sched)
    return out


def _streams(done):
    return {r.rid: r.out_tokens for r in done}


def _solo(cfg, params, rid, prompt, n, tier, **kw):
    sched = _sched(cfg, params, tiers=tier, **kw)
    sched.submit(Request(rid, prompt, max_new_tokens=n, tier=tier))
    return _streams(_drain(sched))[rid]


# -- the view: zero-copy, identity-shared buffers -------------------------


def test_tier_view_shares_buffers_by_identity(olmo):
    cfg, params = olmo
    qp = quantize_params_for_serving(params, Q8, min_size=1024)
    view, truncated = truncate_policy_view(qp, "w4a8")
    assert truncated > 0
    src = {
        jax.tree_util.keystr(p): l
        for p, l in jax.tree_util.tree_leaves_with_path(
            qp, is_leaf=lambda l: isinstance(l, PackedWeight))
        if isinstance(l, PackedWeight)
    }
    assert src
    for path, leaf in jax.tree_util.tree_leaves_with_path(
            view, is_leaf=lambda l: isinstance(l, PackedWeight)):
        if not isinstance(leaf, PackedWeight):
            continue
        orig = src[jax.tree_util.keystr(path)]
        assert leaf.packed is orig.packed      # zero-copy: same buffer
        assert leaf.scale is orig.scale
        assert leaf.plane_lo == 2              # w8 served at w4

    # A tier equal to the storage policy is the params object itself —
    # same pytree, same compiled trace.
    same, n = truncate_policy_view(qp, "w8a8")
    assert same is qp and n == 0


def test_scheduler_tier_views_share_storage(olmo):
    cfg, params = olmo
    sched = _sched(cfg, params)
    base_packed = [l.packed for l in jax.tree_util.tree_leaves(
        sched.params, is_leaf=lambda l: isinstance(l, PackedWeight))
        if isinstance(l, PackedWeight)]
    assert sched._tier_views["w8a8"] is sched.params
    for key in ("w4a8", "w2a8"):
        tier_packed = [l.packed for l in jax.tree_util.tree_leaves(
            sched._tier_views[key],
            is_leaf=lambda l: isinstance(l, PackedWeight))
            if isinstance(l, PackedWeight)]
        assert all(a is b for a, b in zip(base_packed, tier_packed))


# -- isolation: mixed-tier == solo, bitwise -------------------------------


@pytest.mark.parametrize("kv_int8", [False, True])
@pytest.mark.slow
def test_mixed_batch_bit_identical_to_solo(olmo, kv_int8):
    """Three requests at three tiers in one continuous batch: each token
    stream equals the solo engine pinned to that request's tier — bf16
    and int8 pools."""
    cfg, params = olmo
    if kv_int8:
        cfg = dataclasses.replace(cfg, kv_cache_quant=True)
    jobs = [(1, PROMPT_A, "w8a8"), (2, PROMPT_B, "w4a8"),
            (3, PROMPT_C, "w2a8")]
    sched = _sched(cfg, params)
    for rid, prompt, tier in jobs:
        sched.submit(Request(rid, prompt, max_new_tokens=10, tier=tier))
    mixed = _streams(_drain(sched))
    for rid, prompt, tier in jobs:
        assert mixed[rid] == _solo(cfg, params, rid, prompt, 10, tier)
    st = sched.pool_stats()
    assert st["tier_serving"]
    for tier in ("w8a8", "w4a8", "w2a8"):
        tc = st["tiers"][tier]
        assert tc["requests"] == 1
        assert tc["tokens"] == 10
        assert tc["decode_calls"] > 0


def test_bit_identity_mid_decode_admission(olmo):
    """A w2 request admitted while a w8 slot is deep into its decode:
    both streams match their solo-tier runs, and the late admission never
    perturbs the live slot."""
    cfg, params = olmo
    sched = _sched(cfg, params)
    sched.submit(Request(1, PROMPT_A, max_new_tokens=14, tier="w8a8"))
    done = []
    for _ in range(5):
        done.extend(sched.step())
    sched.submit(Request(2, PROMPT_B, max_new_tokens=8, tier="w2a8"))
    done.extend(_drain(sched))
    mixed = _streams(done)
    assert mixed[1] == _solo(cfg, params, 1, PROMPT_A, 14, "w8a8")
    assert mixed[2] == _solo(cfg, params, 2, PROMPT_B, 8, "w2a8")


@pytest.mark.slow
def test_prefix_cache_is_tier_scoped(olmo):
    """Same-tier followers reuse resident prompt blocks; a cross-tier
    follower of the same prompt must NOT (its K/V was computed at a
    different weight precision) — and still decodes bit-identically to
    its solo engine."""
    cfg, params = olmo
    prompt = np.concatenate([PROMPT_B, PROMPT_C])

    sched = _sched(cfg, params)
    sched.submit(Request(1, prompt, max_new_tokens=4, tier="w4a8"))
    _drain(sched)
    hits0 = sched.pool_stats()["prefix_hit_tokens"]

    sched.submit(Request(2, prompt, max_new_tokens=4, tier="w4a8"))
    same = _streams(_drain(sched))
    hits_same = sched.pool_stats()["prefix_hit_tokens"] - hits0
    assert hits_same > 0                   # same tier: blocks reused

    sched.submit(Request(3, prompt, max_new_tokens=4, tier="w2a8"))
    cross = _streams(_drain(sched))
    hits_cross = (sched.pool_stats()["prefix_hit_tokens"]
                  - hits0 - hits_same)
    assert hits_cross == 0                 # cross tier: no poisoning
    assert same[2] == _solo(cfg, params, 2, prompt, 4, "w4a8")
    assert cross[3] == _solo(cfg, params, 3, prompt, 4, "w2a8")


# -- composition with speculation -----------------------------------------


@pytest.mark.slow
def test_speculation_composes_with_tiers(olmo):
    """w2 draft under a mixed batch: w8/w4 slots speculate, the w2 slot
    (nothing cheaper than itself) decodes normally — and every stream is
    bitwise the non-speculative mixed run."""
    cfg, params = olmo
    jobs = [(1, PROMPT_A, "w8a8"), (2, PROMPT_B, "w4a8"),
            (3, PROMPT_C, "w2a8")]

    def serve(k):
        sched = _sched(cfg, params, speculate=k, draft_policy="w2a8")
        for rid, prompt, tier in jobs:
            sched.submit(Request(rid, prompt, max_new_tokens=12, tier=tier))
        return _streams(_drain(sched)), sched

    spec, sched = serve(3)
    plain, _ = serve(0)
    assert spec == plain
    st = sched.pool_stats()
    assert st["tiers"]["w8a8"]["spec_draft_tokens"] > 0
    assert st["tiers"]["w4a8"]["spec_draft_tokens"] > 0
    assert st["tiers"]["w2a8"]["spec_draft_tokens"] == 0   # never eligible
    assert st["spec_verify_rows"] >= st["spec_verify_calls"] > 0


def test_same_tier_verify_rows_batch_into_one_call(olmo):
    """Two co-speculating same-tier slots verify in one multi-row call
    per round: rows outnumber dispatches."""
    cfg, params = olmo
    sched = _sched(cfg, params, tiers="w8a8", max_batch=2,
                   speculate=2, draft_policy="w2a8")
    sched.submit(Request(1, PROMPT_A, max_new_tokens=20, tier="w8a8"))
    sched.submit(Request(2, PROMPT_A + 1, max_new_tokens=20, tier="w8a8"))
    _drain(sched)
    st = sched.pool_stats()
    assert st["spec_verify_rows"] > st["spec_verify_calls"] > 0


# -- validation -----------------------------------------------------------


def test_unknown_tier_fails_request_not_engine(olmo):
    cfg, params = olmo
    sched = _sched(cfg, params, tiers="w8a8,w4a8")
    sched.submit(Request(1, PROMPT_A, max_new_tokens=4, tier="w2a8"))
    sched.submit(Request(2, PROMPT_A, max_new_tokens=4, tier="w8a8"))
    done = _drain(sched)
    by_rid = {r.rid: r for r in done}
    assert "unknown precision tier" in by_rid[1].error
    assert by_rid[1].out_tokens == []
    assert by_rid[2].error is None and len(by_rid[2].out_tokens) == 4


def test_tiers_require_paged_pool(olmo):
    cfg, params = olmo
    with pytest.raises(ValueError, match="paged"):
        ContinuousScheduler(cfg, params, max_batch=2, quant=Q8,
                            max_ctx=64, paged=False, tiers=TIERS)


def test_tiers_require_packed_params(olmo):
    cfg, params = olmo
    with pytest.raises(ValueError, match="quant policy"):
        _sched(cfg, params, quant=None)


def test_tier_activation_mismatch_rejected(olmo):
    cfg, params = olmo
    with pytest.raises(ValueError, match="activation precision"):
        _sched(cfg, params, tiers="w4a4")


def test_tier_spec_parsing_errors():
    with pytest.raises(ValueError, match="mixed"):
        parse_tier_specs("w8a8,w4a8r10")    # rZZ is not a plane subset
    with pytest.raises(ValueError, match="duplicate"):
        parse_tier_specs("w4a8,w4a8")
    with pytest.raises(ValueError, match="empty"):
        parse_tier_specs(" , ")
