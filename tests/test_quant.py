"""Property tests for the quantization core (paper §V-A semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis — deterministic fallback
    from hypothesis_fallback import given, settings, strategies as st

from repro.core import quant

BITS = st.sampled_from([2, 3, 4, 5, 6, 7, 8])
W_BITS = st.sampled_from([2, 4, 8])


@st.composite
def float_arrays(draw, max_dim=24):
    rows = draw(st.integers(1, max_dim))
    cols = draw(st.integers(1, max_dim))
    seed = draw(st.integers(0, 2**31 - 1))
    scale = draw(st.floats(1e-3, 1e3))
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((rows, cols)) * scale, jnp.float32)


@settings(max_examples=30, deadline=None)
@given(float_arrays(), BITS)
def test_codes_in_range(x, bits):
    q, scale = quant.quantize_tensor(x, bits, optimal_clip=False)
    assert int(jnp.min(q)) >= quant.qmin(bits)
    assert int(jnp.max(q)) <= quant.qmax(bits)
    assert float(jnp.min(scale)) >= 0


@settings(max_examples=30, deadline=None)
@given(float_arrays(), BITS)
def test_dequant_error_bounded_by_half_step(x, bits):
    """Inside the clip range, |x - deq(q(x))| <= scale/2."""
    q, scale = quant.quantize_tensor(x, bits, optimal_clip=False)
    xq = quant.dequantize(q, scale)
    thr = scale * quant.qmax(bits)
    inside = jnp.abs(x) <= thr
    err = jnp.abs(x - xq)
    assert float(jnp.max(jnp.where(inside, err, 0.0))) <= float(scale) * 0.5 + 1e-6


@settings(max_examples=20, deadline=None)
@given(float_arrays(), W_BITS)
def test_mae_optimal_no_worse_than_absmax(x, bits):
    s_opt = quant.mae_optimal_scale(x, bits)
    s_max = jnp.max(jnp.abs(x)) / quant.qmax(bits)

    def mae(s):
        q = quant.quantize(x, s, bits)
        return float(jnp.mean(jnp.abs(x - quant.dequantize(q, s))))

    assert mae(s_opt) <= mae(s_max) + 1e-7


def test_fake_quant_ste_gradient():
    x = jnp.linspace(-2.0, 2.0, 64)

    def f(v):
        return jnp.sum(quant.fake_quant(v, 4))

    g = jax.grad(f)(x)
    # Inside the clip range the STE passes gradient 1; clipped region may
    # be zero. absmax scaling ⇒ everything is inside.
    assert float(jnp.min(g)) >= 0.0
    assert float(jnp.max(g)) == pytest.approx(1.0)


def test_fake_quant_reduces_precision_monotone():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((128, 64)), jnp.float32)
    errs = []
    for b in (2, 4, 8):
        errs.append(float(jnp.mean(jnp.abs(x - quant.fake_quant(x, b)))))
    assert errs[0] > errs[1] > errs[2]


@settings(max_examples=20, deadline=None)
@given(st.integers(8, 512), st.floats(0.0, 1.0))
def test_filter_group_split(n_out, ratio):
    n8, nl = quant.split_filter_groups(n_out, ratio)
    assert n8 + nl == n_out
    assert n8 >= 0 and nl >= 0
    if ratio == 0.0:
        assert n8 == 0


def test_quantize_weights_mixed_roundtrip():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((64, 32)) * 0.1, jnp.float32)
    cfg = quant.QuantConfig(w_bits=4, a_bits=6, mixed_ratio_8b=0.25)
    q, s, n8 = quant.quantize_weights_mixed(w, cfg)
    assert q.shape == w.shape
    assert 0 < n8 < 32
    # 8-bit group must reconstruct more accurately than the 4-bit group.
    err8 = float(jnp.mean(jnp.abs(w[:, :n8] - q[:, :n8] * s[..., :n8])))
    err4 = float(jnp.mean(jnp.abs(w[:, n8:] - q[:, n8:] * s[..., n8:])))
    assert err8 < err4


def test_quant_error_stats_sqnr_improves_with_bits():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((256,)), jnp.float32)
    s2 = quant.quant_error_stats(x, 2)
    s8 = quant.quant_error_stats(x, 8)
    assert float(s8["sqnr_db"]) > float(s2["sqnr_db"]) + 20
