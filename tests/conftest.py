import os
import sys
from pathlib import Path

# Tests must see 1 CPU device (the dry-run sets its own 512-device flag in
# subprocesses only).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))
