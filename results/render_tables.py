"""Render the §Roofline table + §Dry-run summary from results/dryrun.jsonl."""
import json
from pathlib import Path

HERE = Path(__file__).resolve().parent


def load(mesh):
    rows = {}
    for line in (HERE / "dryrun.jsonl").read_text().splitlines():
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        if r.get("mesh") == mesh:
            rows[(r["arch"], r["shape"])] = r
    return rows


def fmt_ms(s):
    return f"{s*1e3:,.0f}"


def roofline_table():
    rows = load("single")
    out = ["| arch | shape | compute | memory | collective | bound | useful | move the bound by |",
           "|---|---|---:|---:|---:|---|---:|---|"]
    hints = {
        ("memory", "train"): "fusing flash-attn/norm chains into Pallas kernels (VMEM-resident)",
        ("memory", "prefill"): "Pallas flash-attention (scores never reach HBM)",
        ("memory", "decode"): "int8 KV cache + packed weights (§Perf C)",
        ("collective", "train"): "sharding/overlap changes (§Perf B); hierarchical pod reduce",
        ("collective", "prefill"): "2D activation sharding to shrink TP all-reduces",
        ("collective", "decode"): "replicating small states instead of gathering",
        ("compute", "train"): "less remat recompute",
    }
    for (a, s), r in sorted(rows.items()):
        if r.get("status") == "skipped":
            out.append(f"| {a} | {s} | — | — | — | SKIP | — | {r.get('reason','')[:52]} |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {a} | {s} | — | — | — | {r.get('status')} | — | |")
            continue
        kind = ("train" if s.startswith("train") else
                "prefill" if s.startswith("prefill") else "decode")
        hint = hints.get((r["bottleneck"], kind), "")
        out.append(
            f"| {a} | {s} | {fmt_ms(r['compute_s'])} ms | {fmt_ms(r['memory_s'])} ms "
            f"| {fmt_ms(r['collective_s'])} ms | **{r['bottleneck']}** "
            f"| {r['useful_flops_ratio']:.2f} | {hint} |"
        )
    return "\n".join(out)


def dryrun_summary():
    single, multi = load("single"), load("multi")
    ok_s = sum(1 for r in single.values() if r.get("status") == "ok")
    sk_s = sum(1 for r in single.values() if r.get("status") == "skipped")
    ok_m = sum(1 for r in multi.values() if r.get("status") == "ok")
    sk_m = sum(1 for r in multi.values() if r.get("status") == "skipped")
    comp = [r["compile_s"] for r in single.values() if r.get("status") == "ok"]
    lines = [
        f"single-pod: {ok_s} compiled + {sk_s} documented skips = {ok_s+sk_s} cells",
        f"multi-pod : {ok_m} compiled + {sk_m} documented skips = {ok_m+sk_m} cells",
        f"compile time: median {sorted(comp)[len(comp)//2]:.1f}s, max {max(comp):.1f}s",
    ]
    return "\n".join(lines)


def collective_mix():
    rows = load("single")
    out = ["| arch × shape | AG | AR | RS | A2A | permute | wire GB/dev |",
           "|---|---:|---:|---:|---:|---:|---:|"]
    for (a, s), r in sorted(rows.items()):
        if r.get("status") != "ok" or not s.startswith("train"):
            continue
        c = r.get("collective_counts", {})
        out.append(
            f"| {a} × {s} | {c.get('all-gather',0)} | {c.get('all-reduce',0)} "
            f"| {c.get('reduce-scatter',0)} | {c.get('all-to-all',0)} "
            f"| {c.get('collective-permute',0)} | {r['collective_bytes']/1e9:,.1f} |"
        )
    return "\n".join(out)


if __name__ == "__main__":
    import sys

    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "roofline"):
        print(roofline_table())
    if which in ("all", "summary"):
        print(dryrun_summary())
    if which in ("all", "mix"):
        print(collective_mix())
