"""AdamW + schedules, from scratch (no optax in this environment).

Mixed-precision discipline: master weights and both moments are fp32
regardless of compute dtype; the update is computed in fp32 and cast back.
Moments inherit the parameter's sharding (same shape) so FSDP shards the
optimizer state for free — the ZeRO-style memory win.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamState(NamedTuple):
    step: jax.Array
    mu: dict        # first moment  (fp32, same tree as params)
    nu: dict        # second moment (fp32)


def init_state(params) -> AdamState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros,
                     nu=jax.tree_util.tree_map(jnp.copy, zeros))


def cosine_schedule(tc: TrainConfig) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
        prog = jnp.clip(
            (step - tc.warmup_steps) / jnp.maximum(tc.total_steps - tc.warmup_steps, 1),
            0.0, 1.0,
        )
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        floor = tc.lr_min_ratio
        return tc.lr * warm * (floor + (1 - floor) * cos)

    return lr


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


def _is_matrix(p) -> bool:
    return p.ndim >= 2


def apply_updates(
    params,
    grads,
    state: AdamState,
    tc: TrainConfig,
    lr_fn: Optional[Callable] = None,
):
    """One AdamW step. Weight decay only on matrices (standard practice:
    no decay on norms/biases/embedding scales)."""
    lr_fn = lr_fn or cosine_schedule(tc)
    step = state.step + 1
    lr = lr_fn(step).astype(jnp.float32)
    b1, b2, eps = tc.beta1, tc.beta2, tc.eps
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * jnp.square(gf)
        mhat = m2 / c1
        vhat = v2 / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if tc.weight_decay and _is_matrix(p):
            delta = delta + tc.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamState(step=step, mu=new_m, nu=new_v), lr
