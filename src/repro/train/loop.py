"""Training loop: step factory (used by the dry-run and the live driver),
gradient-accumulation microbatching, int8-compressed data-parallel gradients
with error feedback, and a fault-tolerant runner (checkpoint/resume,
straggler monitor, preemption-safe saves).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Iterator, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.optim import adamw
from repro.parallel import collectives


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamState
    err: Any            # error-feedback buffers (None when compression off)


def init_train_state(params, tc: TrainConfig) -> TrainState:
    err = collectives.init_error(params) if tc.grad_compress_bits else None
    return TrainState(params=params, opt=adamw.init_state(params), err=err)


def make_train_step(model, tc: TrainConfig) -> Callable:
    """Returns train_step(state, batch) → (state, metrics).

    microbatches > 1 splits the batch on axis 0 and accumulates grads with
    a lax.scan — the activation-memory knob (remat already bounds per-layer
    memory; microbatching bounds the batch dimension).
    """
    lr_fn = adamw.cosine_schedule(tc)

    def loss_fn(params, batch):
        loss, metrics = model.train_loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if tc.microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        def split(x):
            b = x.shape[0]
            mb = b // tc.microbatches
            return x.reshape(tc.microbatches, mb, *x.shape[1:])

        micro = jax.tree_util.tree_map(split, batch)
        zero_g = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

        def acc_step(carry, mb):
            g_acc, l_acc = carry
            (loss, _), g = grad_fn(params, mb)
            g_acc = jax.tree_util.tree_map(
                lambda a, b_: a + b_.astype(jnp.float32), g_acc, g
            )
            return (g_acc, l_acc + loss), None

        (g_sum, l_sum), _ = jax.lax.scan(acc_step, (zero_g, 0.0), micro)
        inv = 1.0 / tc.microbatches
        grads = jax.tree_util.tree_map(lambda g: g * inv, g_sum)
        loss = l_sum * inv
        return loss, {"loss": loss, "aux_loss": jnp.zeros((), jnp.float32)}, grads

    def train_step(state: TrainState, batch):
        loss, metrics, grads = compute_grads(state.params, batch)
        grads, gnorm = adamw.clip_by_global_norm(grads, tc.grad_clip)
        err = state.err
        if tc.grad_compress_bits:
            _, err, grads = collectives.compress_gradients(
                grads, err, bits=tc.grad_compress_bits
            )
        params, opt, lr = adamw.apply_updates(
            state.params, grads, state.opt, tc, lr_fn
        )
        metrics = dict(metrics)
        metrics.update(grad_norm=gnorm, lr=lr, loss=loss)
        return TrainState(params=params, opt=opt, err=err), metrics

    return train_step


# --------------------------------------------------------------------------
# Fault-tolerant runner
# --------------------------------------------------------------------------


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time monitor. On TPU pods stragglers manifest as step-time
    blowups on the whole SPMD program; the launcher contract is
    flag → checkpoint → evict → restart. Here we detect and log."""

    alpha: float = 0.1
    threshold: float = 2.5
    ewma: Optional[float] = None
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = dt > self.threshold * self.ewma
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        if slow:
            self.flagged += 1
        return slow


class _PreemptionFlag:
    """SIGTERM → finish the current step, checkpoint, exit cleanly."""

    def __init__(self):
        self.raised = False
        try:
            signal.signal(signal.SIGTERM, self._handle)
        except ValueError:  # non-main thread (tests)
            pass

    def _handle(self, *_):
        self.raised = True


def run_training(
    model,
    tc: TrainConfig,
    data_iter: Iterator,
    checkpoint_mgr=None,
    init_key=None,
    hooks: Optional[Callable[[int, dict], None]] = None,
    jit: bool = True,
):
    """End-to-end training with restore-if-present, periodic + preemption
    checkpoints, and straggler monitoring. Returns (state, history)."""
    init_key = init_key if init_key is not None else jax.random.PRNGKey(tc.seed)
    start_step = 0
    if checkpoint_mgr is not None and checkpoint_mgr.latest_step() is not None:
        state, data_state, start_step = checkpoint_mgr.restore(
            lambda: init_train_state(model.init(init_key), tc)
        )
        if data_state is not None and hasattr(data_iter, "set_state"):
            data_iter.set_state(data_state)
    else:
        state = init_train_state(model.init(init_key), tc)

    step_fn = make_train_step(model, tc)
    if jit:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    monitor = StragglerMonitor()
    preempt = _PreemptionFlag()
    history = []
    for step in range(start_step, tc.total_steps):
        batch = next(data_iter)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        slow = monitor.observe(dt)
        if step % tc.log_every == 0 or slow:
            rec = {k: float(v) for k, v in metrics.items()}
            rec.update(step=step, dt=dt, straggler=slow)
            history.append(rec)
            if hooks:
                hooks(step, rec)
        should_ckpt = checkpoint_mgr is not None and (
            (step + 1) % tc.checkpoint_every == 0 or preempt.raised
        )
        if should_ckpt:
            data_state = data_iter.get_state() if hasattr(data_iter, "get_state") else None
            checkpoint_mgr.save(step + 1, state, data_state)
        if preempt.raised:
            break
    return state, history
