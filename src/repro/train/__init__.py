from repro.train.loop import TrainState, init_train_state, make_train_step, run_training  # noqa: F401
