"""RWKV-6 (Finch) chunked linear-attention kernel.

The rwkv6-3b architecture in the assigned pool is attention-free: its mixer
is the data-dependent-decay recurrence

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)

A naive lax.scan is latency-bound (T sequential steps of rank-1 updates).
The TPU-native formulation processes the sequence in chunks of C tokens:
within a chunk everything is dense matmul work for the MXU, and only the
C-step-compressed state crosses chunk boundaries.

Stability: decays satisfy 0 < w ≤ 1 so all exponent differences used here
(L_{t-1}-L_s for s<t and L_last-L_s) are ≤ 0 — every exp() is ≤ 1; no
log-space overflow regardless of chunk size.

Grid: (H, T/C) with ("arbitrary", "arbitrary") semantics — the state
scratch S (K, V) persists across grid steps; it is re-initialized whenever
the chunk index wraps to 0 (new head). Per-chunk work:

    term1  = (r ⊙ e^{Lsh}) @ S                    # carry-in state
    P[t,s] = Σ_k r[t,k] k[s,k] e^{Lsh[t,k]-L[s,k]}   (s < t, intra-chunk)
    P[t,t] = Σ_k r[t,k] u[k] k[t,k]                  (current-token bonus)
    out    = term1 + P @ v
    S     ← diag(e^{L_last}) S + (k ⊙ e^{L_last - L})^T @ v

Validated in interpret mode against the sequential scan oracle
(repro/kernels/ref.py::wkv6_ref).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import compiler_params as _compiler_params


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_ref, *, chunk: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)  # (C, K)
    k = k_ref[0].astype(jnp.float32)  # (C, K)
    v = v_ref[0].astype(jnp.float32)  # (C, V)
    w = w_ref[0].astype(jnp.float32)  # (C, K) decays in (0, 1]
    u = u_ref[0].astype(jnp.float32)  # (1, K)

    lw = jnp.log(jnp.maximum(w, 1e-12))
    L = jnp.cumsum(lw, axis=0)          # inclusive log-decay prefix
    Lsh = L - lw                        # exclusive prefix (L_{t-1})

    S = state_ref[...]

    # Carry-in contribution.
    term1 = (r * jnp.exp(Lsh)) @ S      # (C, V)

    # Intra-chunk pairwise contribution (strictly lower triangular) plus
    # the diag bonus term. diff <= 0 for s < t, so exp() never overflows.
    diff = Lsh[:, None, :] - L[None, :, :]              # (C, C, K)
    t_ids = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_ids = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = (s_ids < t_ids)[:, :, None]
    gate = jnp.where(tri, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    P = jnp.sum(r[:, None, :] * k[None, :, :] * gate, axis=-1)  # (C, C)
    Pdiag = jnp.sum(r * u * k, axis=-1)                          # (C,)
    eye = (s_ids == t_ids).astype(jnp.float32)
    P = P + eye * Pdiag[:, None]

    o_ref[0] = (term1 + P @ v).astype(o_ref.dtype)

    # State update to the end of the chunk.
    L_last = L[-1:, :]                                   # (1, K)
    decayed_k = k * jnp.exp(L_last - L)                  # (C, K), exps <= 1
    state_ref[...] = jnp.exp(L_last).T * S + decayed_k.T @ v


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    *,
    chunk: int = 32,
    interpret: bool = True,
) -> jax.Array:
    """Chunked WKV6. r/k/w: (T, H, K); v: (T, H, V); u: (H, K) → (T, H, V)."""
    T, H, K = r.shape
    V = v.shape[-1]
    if T % chunk:
        pad = chunk - T % chunk
        zkv = lambda a: jnp.concatenate(
            [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], axis=0
        )
        r, kk, v = zkv(r), zkv(k), zkv(v)
        w = jnp.concatenate([w, jnp.ones((pad, H, K), w.dtype)], axis=0)
        k = kk
    Tp = r.shape[0]
    # (T, H, D) → (H, T, D) so heads are the outer grid dim.
    rt, kt, vt, wt = (jnp.swapaxes(a, 0, 1) for a in (r, k, v, w))
    out = pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk),
        grid=(H, Tp // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, K), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, V), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, 1, K), lambda h, c: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, V), lambda h, c: (h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((H, Tp, V), jnp.float32),
        scratch_shapes=[_vmem_scratch(K, V)],
        compiler_params=_compiler_params(("arbitrary", "arbitrary")),
        interpret=interpret,
    )(rt, kt, vt, wt, u[:, None, :])
    return jnp.swapaxes(out, 0, 1)[:T]


def _vmem_scratch(K: int, V: int):
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM((K, V), jnp.float32)
    except Exception:  # pragma: no cover — interpret fallback
        return pl.MemorySpace.ANY((K, V), jnp.float32)
