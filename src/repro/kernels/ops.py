"""Public jit'd wrappers for the Pallas kernels.

This module is the only kernel entry point the rest of the framework uses.
It owns:
  * interpret-vs-compiled dispatch (CPU containers run interpret=True;
    on TPU `set_interpret(False)` switches to Mosaic lowering),
  * block-shape selection per operand shape (VMEM budgeting),
  * the packed/mixed-group compositions used by QuantizedLinear.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import bitplane
from repro.kernels import bitplane_matmul as _bpm
from repro.kernels import pack_quant as _pq
from repro.kernels import wkv6 as _wkv6

_INTERPRET = True  # CPU container default; flipped on real TPU.


def set_interpret(value: bool) -> None:
    global _INTERPRET
    _INTERPRET = bool(value)


def pick_matmul_blocks(m: int, n: int, k: int) -> Tuple[int, int, int]:
    """Choose (bm, bn, bk) fitting a ~4 MiB VMEM working-set budget.

    x tile: bm*bk int8; w tile: bk*bn int8; acc: bm*bn int32 (+ Pallas
    double-buffers the input tiles). MXU wants M/N tiles at multiples of
    128 and the int8 K lane at multiples of 256 where possible.
    """
    bm = 128 if m >= 128 else max(8, _ru(m, 8))
    bn = 128 if n >= 128 else max(128, _ru(n, 128))
    bk = 512 if k >= 512 else max(128, _ru(k, 128))
    # Shrink bk until 2*(bm*bk + bk*bn) + 4*bm*bn <= 4 MiB
    while 2 * (bm * bk + bk * bn) + 4 * bm * bn > (4 << 20) and bk > 128:
        bk //= 2
    return bm, bn, bk


def _ru(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def bitplane_matmul(
    x_codes: jax.Array,
    w_codes: jax.Array,
    *,
    a_bits: int = 8,
    act_signed: bool = True,
    plane_bits: int = 2,
    blocks: Optional[Tuple[int, int, int]] = None,
) -> jax.Array:
    """Exact int matmul of activation codes × weight codes via bit planes."""
    m, k = x_codes.shape
    n = w_codes.shape[1]
    bm, bn, bk = blocks or pick_matmul_blocks(m, n, k)
    return _bpm.bitplane_matmul(
        x_codes,
        w_codes,
        a_bits=a_bits,
        act_signed=act_signed,
        plane_bits=plane_bits,
        bm=bm,
        bn=bn,
        bk=bk,
        interpret=_INTERPRET,
    )


def quantize_rows(x: jax.Array, *, bits: int = 8, signed: bool = True):
    """Fused per-row (per-token) quantization: (M, K) float → int8 codes + scales."""
    return _pq.quantize_rows(x, bits=bits, signed=signed, interpret=_INTERPRET)


def packed_matmul(
    x: jax.Array,
    packed: jax.Array,
    scale: jax.Array,
    *,
    w_bits: int,
    a_bits: int = 8,
    act_signed: bool = True,
) -> jax.Array:
    """float x (M, K) × packed sub-byte weights ((K·bits/8), N) → float (M, N).

    The end-to-end M4BRAM serving path: quantize activations (kernel),
    unpack weights (VMEM-side layout op), bit-plane matmul (kernel),
    dequantize with per-token × per-channel scales.
    """
    xq, xs = quantize_rows(x.astype(jnp.float32), bits=a_bits, signed=act_signed)
    wq = bitplane.unpack_weights(packed, w_bits, axis=0)
    acc = bitplane_matmul(xq, wq, a_bits=a_bits, act_signed=act_signed)
    return (acc.astype(jnp.float32) * xs * scale.reshape(1, -1)).astype(x.dtype)


def mixed_group_matmul(
    x: jax.Array,
    w8_codes: jax.Array,
    wl_packed: jax.Array,
    scale8: jax.Array,
    scalel: jax.Array,
    *,
    w_bits: int,
    a_bits: int = 8,
) -> jax.Array:
    """Intra-layer mixed 8b/low-bit group matmul (paper Table III).

    The activation quantization is shared between the groups (one kernel
    pass), then each filter group runs its own bit-plane matmul — the two
    groups are the TPU analogue of the paper's BPE/DSP heterogeneous split,
    and XLA schedules them back-to-back on the MXU with no interlock.
    """
    xq, xs = quantize_rows(x.astype(jnp.float32), bits=a_bits, signed=True)
    acc8 = bitplane_matmul(xq, w8_codes.astype(jnp.int32), a_bits=a_bits)
    wl = bitplane.unpack_weights(wl_packed, w_bits, axis=0)
    accl = bitplane_matmul(xq, wl, a_bits=a_bits)
    y8 = acc8.astype(jnp.float32) * xs * scale8.reshape(1, -1)
    yl = accl.astype(jnp.float32) * xs * scalel.reshape(1, -1)
    return jnp.concatenate([y8, yl], axis=1).astype(x.dtype)


def flash_attention(
    q: jax.Array,  # (B, T, NQ, H)
    k: jax.Array,  # (B, S, NKV, H)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    bq: int = 128,
    bk: int = 128,
) -> jax.Array:
    """GQA-aware flash attention: kv heads are broadcast to the q-head
    grid, heads fold into the batch grid dim. Returns (B, T, NQ, H)."""
    from repro.kernels import flash_attention as _fa

    B, T, NQ, H = q.shape
    NKV = k.shape[2]
    G = NQ // NKV
    qf = q.transpose(0, 2, 1, 3).reshape(B * NQ, T, H)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * NQ, -1, H)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * NQ, -1, H)
    out = _fa.flash_attention(
        qf, kf, vf, causal=causal, window=window, q_offset=q_offset,
        bq=bq, bk=bk, interpret=_INTERPRET,
    )
    return out.reshape(B, NQ, T, H).transpose(0, 2, 1, 3).astype(q.dtype)


def wkv6(r, k, v, w, u, *, chunk: int = 32) -> jax.Array:
    """Chunked RWKV-6 mixer. See repro/kernels/wkv6.py."""
    return _wkv6.wkv6(r, k, v, w, u, chunk=chunk, interpret=_INTERPRET)


def wkv6_batched(r, k, v, w, u, *, chunk: int = 32) -> jax.Array:
    """vmapped-over-batch wkv6: r/k/w (B, T, H, K), v (B, T, H, V)."""
    fn = functools.partial(wkv6, chunk=chunk)
    return jax.vmap(lambda a, b, c, d: fn(a, b, c, d, u))(r, k, v, w)
