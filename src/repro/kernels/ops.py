"""Public jit'd wrappers for the Pallas kernels.

This module is the only kernel entry point the rest of the framework uses.
It owns:
  * backend dispatch through :mod:`repro.kernels.registry` — every op takes
    an optional ``backend=`` ("interpret" | "mosaic" | "reference") and
    otherwise uses the registry's active backend (platform default: Mosaic
    on TPU, interpret elsewhere),
  * block-shape selection per operand shape (VMEM budgeting, memoized in
    the registry's plan cache),
  * the packed/mixed-group compositions used by QuantizedLinear — the
    serve path runs the *fused* quantize→bit-plane kernel so activations
    never round-trip through HBM as int8 codes.
"""
from __future__ import annotations

import functools
import warnings
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import bitplane
from repro.kernels import bitplane_matmul as _bpm
from repro.kernels import fused_matmul as _fused
from repro.kernels import pack_quant as _pq
from repro.kernels import paged_attention as _paged
from repro.kernels import paged_prefill as _paged_pf
from repro.kernels import ref as _ref
from repro.kernels import wkv6 as _wkv6
from repro.kernels.registry import KernelBackend, get_registry, use_backend  # noqa: F401


def set_interpret(value: bool) -> None:
    """Deprecated shim over the kernel registry.

    Use ``get_registry().set_active("interpret"|"mosaic")`` or the scoped
    ``use_backend(...)`` context manager instead.
    """
    warnings.warn(
        "set_interpret is deprecated; select a backend through "
        "repro.kernels.registry (get_registry().set_active / use_backend)",
        DeprecationWarning,
        stacklevel=2,
    )
    get_registry().set_active("interpret" if value else "mosaic")


def pick_matmul_blocks(
    m: int, n: int, k: int, backend: Optional[str] = None
) -> Tuple[int, int, int]:
    """Memoized (bm, bn, bk) for shape (m, n, k) on the given/active backend.

    Large shapes take MXU tiles fitting the ~4 MiB VMEM working-set budget;
    small shapes round up only to the backend's alignment (interpret mode
    tiles at 8, so tiny layers no longer pad N/K up to 128).
    """
    return get_registry().matmul_plan(m, n, k, backend)


def bitplane_matmul(
    x_codes: jax.Array,
    w_codes: jax.Array,
    *,
    a_bits: int = 8,
    act_signed: bool = True,
    plane_bits: int = 2,
    w_plane_lo: int = 0,
    blocks: Optional[Tuple[int, int, int]] = None,
    backend=None,
) -> jax.Array:
    """Exact int matmul of activation codes × weight codes via bit planes.

    ``w_plane_lo`` contracts only the top weight planes (the self-
    speculative draft path): plane ``lo`` becomes the LSB plane and the
    caller re-scales dequantization by ``4**w_plane_lo``.
    """
    be = get_registry().resolve(backend)
    if be.is_reference:
        return _ref.bitplane_matmul_ref(x_codes, w_codes, a_bits, act_signed,
                                        w_plane_lo=w_plane_lo,
                                        plane_bits=plane_bits)
    m, k = x_codes.shape
    n = w_codes.shape[1]
    bm, bn, bk = blocks or get_registry().matmul_plan(m, n, k, be)
    return _bpm.bitplane_matmul(
        x_codes,
        w_codes,
        a_bits=a_bits,
        act_signed=act_signed,
        plane_bits=plane_bits,
        w_plane_lo=w_plane_lo,
        bm=bm,
        bn=bn,
        bk=bk,
        interpret=be.interpret,
    )


def quantize_rows(x: jax.Array, *, bits: int = 8, signed: bool = True,
                  backend=None):
    """Fused per-row (per-token) quantization: (M, K) float → int8 codes + scales."""
    be = get_registry().resolve(backend)
    if be.is_reference:
        return _ref.quantize_pack_ref(x.astype(jnp.float32), bits, signed=signed)
    return _pq.quantize_rows(x, bits=bits, signed=signed, interpret=be.interpret)


def fused_quantize_matmul(
    x: jax.Array,
    w_codes: jax.Array,
    *,
    a_bits: int = 8,
    act_signed: bool = True,
    plane_bits: int = 2,
    w_plane_lo: int = 0,
    blocks: Optional[Tuple[int, int, int]] = None,
    backend=None,
):
    """(M, K) float × (K, N) int codes → ((M, N) int32, (M, 1) fp32 scales).

    One kernel: per-row quantization happens in the matmul's K-loop prologue
    with the fp32 rows resident in VMEM — no intermediate int8 activation
    tensor in HBM. Bit-identical to ``quantize_rows → bitplane_matmul``.
    ``w_plane_lo`` contracts only the top weight planes (draft-policy path).
    """
    be = get_registry().resolve(backend)
    if be.is_reference:
        q, s = _ref.quantize_pack_ref(x.astype(jnp.float32), a_bits,
                                      signed=act_signed)
        return _ref.bitplane_matmul_ref(q, w_codes, a_bits, act_signed,
                                        w_plane_lo=w_plane_lo,
                                        plane_bits=plane_bits), s
    m, k = x.shape
    n = w_codes.shape[1]
    bm, bn, bk = blocks or get_registry().fused_matmul_plan(m, n, k, be)
    return _fused.fused_quantize_matmul(
        x,
        w_codes,
        a_bits=a_bits,
        act_signed=act_signed,
        plane_bits=plane_bits,
        w_plane_lo=w_plane_lo,
        bm=bm,
        bn=bn,
        bk=bk,
        interpret=be.interpret,
    )


def packed_matmul(
    x: jax.Array,
    packed: jax.Array,
    scale: jax.Array,
    *,
    w_bits: int,
    a_bits: int = 8,
    act_signed: bool = True,
    w_plane_lo: int = 0,
    backend=None,
) -> jax.Array:
    """float x (M, K) × packed sub-byte weights ((K·bits/8), N) → float (M, N).

    The end-to-end M4BRAM serving path: unpack weights (VMEM-side layout
    op), then the *fused* quantize→bit-plane kernel (activations quantized
    in the matmul prologue), then dequantize with per-token × per-channel
    scales. ``w_plane_lo`` runs the plane-truncated draft contraction on
    the same packed buffer; the dropped low planes shrink the code range
    by 4^lo, so the weight scale regains that factor here.
    """
    wq = bitplane.unpack_weights(packed, w_bits, axis=0)
    acc, xs = fused_quantize_matmul(
        x.astype(jnp.float32), wq, a_bits=a_bits, act_signed=act_signed,
        w_plane_lo=w_plane_lo, backend=backend,
    )
    ws = scale.reshape(1, -1)
    if w_plane_lo:
        ws = ws * (1 << (2 * w_plane_lo))
    return (acc.astype(jnp.float32) * xs * ws).astype(x.dtype)


def mixed_group_matmul(
    x: jax.Array,
    w8_codes: jax.Array,
    wl_packed: jax.Array,
    scale8: jax.Array,
    scalel: jax.Array,
    *,
    w_bits: int,
    a_bits: int = 8,
    backend=None,
) -> jax.Array:
    """Intra-layer mixed 8b/low-bit group matmul (paper Table III).

    The activation quantization is shared between the groups (one kernel
    pass — which is why this path stays unfused), then each filter group
    runs its own bit-plane matmul — the two groups are the TPU analogue of
    the paper's BPE/DSP heterogeneous split, and XLA schedules them
    back-to-back on the MXU with no interlock.
    """
    xq, xs = quantize_rows(x.astype(jnp.float32), bits=a_bits, signed=True,
                           backend=backend)
    acc8 = bitplane_matmul(xq, w8_codes.astype(jnp.int32), a_bits=a_bits,
                           backend=backend)
    wl = bitplane.unpack_weights(wl_packed, w_bits, axis=0)
    accl = bitplane_matmul(xq, wl, a_bits=a_bits, backend=backend)
    y8 = acc8.astype(jnp.float32) * xs * scale8.reshape(1, -1)
    yl = accl.astype(jnp.float32) * xs * scalel.reshape(1, -1)
    return jnp.concatenate([y8, yl], axis=1).astype(x.dtype)


def flash_attention(
    q: jax.Array,  # (B, T, NQ, H)
    k: jax.Array,  # (B, S, NKV, H)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    bq: int = 128,
    bk: int = 128,
    backend=None,
) -> jax.Array:
    """GQA-aware flash attention: kv heads are broadcast to the q-head
    grid, heads fold into the batch grid dim. Returns (B, T, NQ, H)."""
    be = get_registry().resolve(backend)
    B, T, NQ, H = q.shape
    NKV = k.shape[2]
    G = NQ // NKV
    qf = q.transpose(0, 2, 1, 3).reshape(B * NQ, T, H)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * NQ, -1, H)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * NQ, -1, H)
    if be.is_reference:
        out = _ref.flash_attention_ref(qf, kf, vf, causal, window, q_offset)
    else:
        from repro.kernels import flash_attention as _fa

        out = _fa.flash_attention(
            qf, kf, vf, causal=causal, window=window, q_offset=q_offset,
            bq=bq, bk=bk, interpret=be.interpret,
        )
    return out.reshape(B, NQ, T, H).transpose(0, 2, 1, 3).astype(q.dtype)


def paged_attention(
    q: jax.Array,            # (B, 1, NQ, H) — one new token per row
    pool_k: jax.Array,       # (num_blocks, block_size, NKV, H)
    pool_v: jax.Array,
    block_table: jax.Array,  # (B, max_blocks) int32, -1 = unallocated
    q_pos: jax.Array,        # (B,) per-row decode position
    *,
    k_scale: Optional[jax.Array] = None,  # (num_blocks, block_size, NKV, 1)
    v_scale: Optional[jax.Array] = None,
    softcap: float = 0.0,
    blocks: Optional[Tuple[int, int, int]] = None,
    backend=None,
) -> jax.Array:
    """Fused flash-decode attention over the paged KV pool.

    Block-table resolution happens *inside* the kernel (scalar prefetch):
    each grid step streams one live pool block into VMEM and folds it
    into the online softmax — no contiguous gather of the pool is ever
    materialized, per-row HBM traffic is the row's live blocks, and an
    int8 pool (``k_scale``/``v_scale`` planes) dequantizes in-kernel.
    The reference backend runs the gather-then-attend oracle
    (:func:`repro.kernels.ref.paged_attention_ref`), which is the
    bit-exactness specification the kernel is tested against.

    The kernel is ownership-agnostic: multiple rows' tables may map to
    the same pool block (the scheduler's cross-request prefix cache does
    exactly that), since each row only ever reads blocks through its own
    table and positions below its own ``q_pos``.
    """
    be = get_registry().resolve(backend)
    if be.is_reference:
        return _ref.paged_attention_ref(
            q, pool_k, pool_v, block_table, q_pos,
            k_scale=k_scale, v_scale=v_scale, softcap=softcap,
        )
    bs, n_kv = pool_k.shape[1], pool_k.shape[2]
    bh, _, _ = blocks or get_registry().paged_attention_plan(
        n_kv, bs, pool_k.shape[3], be
    )
    if bh <= 0 or n_kv % bh:
        bh = n_kv  # plans must divide the KV heads; fall back to all
    return _paged.paged_attention(
        q, pool_k, pool_v, block_table, q_pos, k_scale, v_scale,
        softcap=softcap, bh=bh, interpret=be.interpret,
    )


def paged_prefill(
    q: jax.Array,            # (1, Lc, NQ, H) — one row's chunk queries
    k_new: jax.Array,        # (1, Lc, NKV, H) — chunk K/V (unquantized)
    v_new: jax.Array,
    pool_k: jax.Array,       # (num_blocks, block_size, NKV, H)
    pool_v: jax.Array,
    blocks: jax.Array,       # (mb,) int32 row block table, -1 = unallocated
    start: jax.Array,        # () int32 chunk token 0's absolute position
    length: jax.Array,       # () int32 real chunk length (<= Lc)
    *,
    k_scale: Optional[jax.Array] = None,  # (num_blocks, block_size, NKV, 1)
    v_scale: Optional[jax.Array] = None,
    softcap: float = 0.0,
    blocks_plan: Optional[Tuple[int, int, int]] = None,
    backend=None,
):
    """Fused paged chunked-prefill: attend a prompt chunk against
    [pool-resident prefix ++ chunk] causally AND write the chunk's K/V
    into its destination pool blocks, in one kernel.

    The decode kernel's scalar-prefetch/block-table trick applied to the
    prefill grid: resident prefix blocks stream through the table index
    map (no per-layer HBM gather of the prefix), and destination blocks
    are written back through input/output-aliased pool refs from the
    kernel epilogue (no post-prefill scatter round trip). int8 pools
    quantize on write in-kernel with the exact `quantize_kv` math, so the
    pool bytes are bit-identical to the scatter path's.

    Returns (attn (1, Lc, NQ, H) in q's dtype, pool_k, pool_v, k_scale,
    v_scale) — scales are None passthroughs for a bf16 pool. The
    reference backend runs the scatter-then-gather-attend oracle
    (:func:`repro.kernels.ref.paged_prefill_ref`), the semantic spec the
    kernel is tested against."""
    be = get_registry().resolve(backend)
    if be.is_reference:
        return _ref.paged_prefill_ref(
            q, k_new, v_new, pool_k, pool_v, blocks, start, length,
            k_scale=k_scale, v_scale=v_scale, softcap=softcap,
        )
    bs, n_kv = pool_k.shape[1], pool_k.shape[2]
    bh, _, _ = blocks_plan or get_registry().paged_prefill_plan(
        n_kv, bs, pool_k.shape[3], be
    )
    if bh <= 0 or n_kv % bh:
        bh = n_kv  # plans must divide the KV heads; fall back to all
    return _paged_pf.paged_prefill_attention(
        q, k_new, v_new, pool_k, pool_v, blocks, start, length,
        k_scale, v_scale, softcap=softcap, bh=bh, interpret=be.interpret,
    )


def wkv6(r, k, v, w, u, *, chunk: int = 32, backend=None) -> jax.Array:
    """Chunked RWKV-6 mixer. See repro/kernels/wkv6.py."""
    be = get_registry().resolve(backend)
    if be.is_reference:
        return _ref.wkv6_ref(r, k, v, w, u)
    return _wkv6.wkv6(r, k, v, w, u, chunk=chunk, interpret=be.interpret)


def wkv6_batched(r, k, v, w, u, *, chunk: int = 32, backend=None) -> jax.Array:
    """vmapped-over-batch wkv6: r/k/w (B, T, H, K), v (B, T, H, V)."""
    fn = functools.partial(wkv6, chunk=chunk, backend=backend)
    return jax.vmap(lambda a, b, c, d: fn(a, b, c, d, u))(r, k, v, w)
