"""Fused per-token quantize kernel (activation side of the M4BRAM path).

The paper's activations arrive at the BPE already quantized (the CIM
instruction carries 2–8-bit activations). On TPU the quantization itself is
a bandwidth-bound elementwise pass, so we fuse absmax → scale → round →
clip into one VMEM-resident kernel: each grid step owns `bm` full rows so
the row reduction never leaves VMEM.

Outputs int8 codes (packing to sub-byte words is a layout transform done by
repro.core.bitplane at weight-load time; activations stay int8 because the
MXU consumes int8 lanes directly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import compiler_params as _compiler_params
from repro.kernels.common import round_up as _round_up


def _quantize_rows_kernel(x_ref, q_ref, s_ref, *, bits: int, signed: bool):
    x = x_ref[...].astype(jnp.float32)
    qhi = (1 << (bits - 1)) - 1 if signed else (1 << bits) - 1
    qlo = -(1 << (bits - 1)) if signed else 0
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = absmax / qhi
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.round(x * inv), qlo, qhi)
    # int32 hop: float→int8 saturates (255 → 127, corrupting unsigned 8-bit
    # codes) while int32→int8 wraps, storing the code's bit pattern exactly —
    # the bit-plane matmul reconstructs it mod 2^bits.
    q_ref[...] = q.astype(jnp.int32).astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("bits", "signed", "bm", "interpret"))
def quantize_rows(
    x: jax.Array,
    *,
    bits: int = 8,
    signed: bool = True,
    bm: int = 256,
    interpret: bool = True,
):
    """Per-row symmetric quantization of (M, K) float x.

    Returns (codes int8 (M, K), scales float32 (M, 1)).
    """
    if x.ndim != 2:
        raise ValueError("quantize_rows expects (M, K)")
    m, k = x.shape
    bm_ = min(bm, _round_up(m, 8))
    mp = _round_up(m, bm_)
    xp = jnp.zeros((mp, k), x.dtype).at[:m].set(x)
    kernel = functools.partial(_quantize_rows_kernel, bits=bits, signed=signed)
    q, s = pl.pallas_call(
        kernel,
        grid=(mp // bm_,),
        in_specs=[pl.BlockSpec((bm_, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm_, k), lambda i: (i, 0)),
            pl.BlockSpec((bm_, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, k), jnp.int8),
            jax.ShapeDtypeStruct((mp, 1), jnp.float32),
        ],
        compiler_params=_compiler_params(("parallel",)),
        interpret=interpret,
    )(xp)
    return q[:m], s[:m]
