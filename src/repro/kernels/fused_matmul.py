"""Fused activation-quantize → bit-plane matmul (single Pallas kernel).

The paper's central claim is that M4BRAM computes mixed-precision matmuls
*in place*: activations arrive at the BPE already quantized and no separate
quantized-activation buffer ever materializes (§IV). The unfused TPU path
violated that — ``pack_quant.quantize_rows`` wrote int8 codes back to HBM
and ``bitplane_matmul`` re-read them, an extra M×K round trip per serve-mode
matmul. This kernel fuses absmax → scale → round → plane-decompose → MXU
contraction so the fp32 activation tile is quantized in the K-loop prologue
while already resident in VMEM, and HBM only ever sees fp32 activations in
and int32 accumulators out.

Dataflow (hw-codesign notes):
  * Grid (M/bm, N/bn, K/bk), K innermost ("arbitrary") so the int32
    accumulator tile revisits VMEM across K steps, as in bitplane_matmul.
  * The activation block is (bm, K) — *full rows* resident in VMEM, because
    the per-token absmax reduction needs the whole row. bm shrinks as K
    grows (see registry.pick_fused_blocks) instead of tiling K on the
    activation side; only the weight operand tiles along K.
  * Quantization is recomputed per K step from the resident rows (VPU work,
    cheap next to the MXU contraction) rather than staged through scratch,
    keeping the kernel free of cross-step carried state beyond the
    revisited output block.
  * Per-row scales are emitted as a second output so callers dequantize
    exactly as the unfused path did.

Exactness contract (tested): for any (a_bits, signed) the int32 accumulator
and fp32 scales are bit-identical to the unfused composition
``quantize_rows(x) → bitplane_matmul(codes, w)``. Quantization uses the very
same elementwise formula, the row max is order-independent, and the integer
accumulation is exact, so block-plan differences cannot change results.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import compiler_params, round_up


def _fused_kernel(
    x_ref,  # (bm, Kp) fp32 activation rows, fully resident
    w_ref,  # (bk, bn) int8 weight codes
    o_ref,  # (bm, bn) int32 accumulator (revisited across K grid steps)
    s_ref,  # (bm, 1) fp32 per-row scales
    *,
    a_bits: int,
    act_signed: bool,
    plane_bits: int,
    w_plane_lo: int,
    bk: int,
):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)

    # --- quantize prologue (same arithmetic as pack_quant, bit-exact) ---
    qhi = (1 << (a_bits - 1)) - 1 if act_signed else (1 << a_bits) - 1
    qlo = -(1 << (a_bits - 1)) if act_signed else 0
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = absmax / qhi
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    s_ref[...] = scale

    xs = jax.lax.dynamic_slice_in_dim(x, kk * bk, bk, axis=1)
    q = jnp.clip(jnp.round(xs * inv), qlo, qhi).astype(jnp.int32)

    # --- plane decompose + contract (same algebra as bitplane_matmul) ---
    offset = (1 << (a_bits - 1)) if act_signed else 0
    u = q + offset  # offset-binary: planes are unsigned
    n_planes = -(-a_bits // plane_bits)
    mask = (1 << plane_bits) - 1
    w = w_ref[...].astype(jnp.int32)
    if w_plane_lo:
        # Top-planes-only weight view: arithmetic shift ≡ drop planes
        # [0, lo) of the offset-binary decomposition (the sign offset
        # 2^(b-1) divides by 4^lo for 2·lo < b), so the sign plane stays
        # the top plane. Must happen before the colsum correction — the
        # offset term has to see the truncated weight, not the full one.
        # See _bitplane_matmul_kernel for the full derivation.
        w = w >> (w_plane_lo * plane_bits)

    acc = jnp.zeros(o_ref.shape, jnp.int32)
    for p in range(n_planes):  # static unroll: one MXU pass per plane
        plane = ((u >> (p * plane_bits)) & mask).astype(jnp.int8)
        part = jax.lax.dot_general(
            plane,
            w.astype(jnp.int8),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        acc = acc + (part << (p * plane_bits))

    if offset:
        # INV-row analogue: subtract offset * colsum(W) for this K block.
        colsum = jnp.sum(w, axis=0, keepdims=True)
        acc = acc - offset * colsum

    o_ref[...] += acc


@functools.partial(
    jax.jit,
    static_argnames=("a_bits", "act_signed", "plane_bits", "w_plane_lo",
                     "bm", "bn", "bk", "interpret"),
)
def fused_quantize_matmul(
    x: jax.Array,
    w_codes: jax.Array,
    *,
    a_bits: int = 8,
    act_signed: bool = True,
    plane_bits: int = 2,
    w_plane_lo: int = 0,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = True,
):
    """(M, K) float × (K, N) int weight codes → ((M, N) int32, (M, 1) fp32).

    Returns the exact integer accumulator of quantized-activation codes
    against `w_codes`, plus the per-row activation scales; the caller
    dequantizes as ``acc * scales * w_scale``. Shapes need not be
    block-aligned (zero padding contributes nothing — including to the row
    absmax and to the signed-offset correction). ``w_plane_lo`` contracts
    only the top weight planes (see bitplane_matmul); the caller folds the
    ``1 << (plane_bits * w_plane_lo)`` factor into the weight scale.
    """
    if x.ndim != 2 or w_codes.ndim != 2:
        raise ValueError("fused_quantize_matmul expects 2-D operands")
    m, k = x.shape
    k2, n = w_codes.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {k} vs {k2}")

    # As in bitplane_matmul: clamping to the padded problem must preserve
    # the block plan's own alignment (128 lanes for mosaic plans).
    bm_ = min(bm, round_up(m, 8))
    bn_ = min(bn, round_up(n, 128 if bn % 128 == 0 else 8))
    bk_ = min(bk, round_up(k, 128 if bk % 128 == 0 else 8))
    mp, np_, kp = round_up(m, bm_), round_up(n, bn_), round_up(k, bk_)

    xp = jnp.zeros((mp, kp), jnp.float32).at[:m, :k].set(x.astype(jnp.float32))
    wp = jnp.zeros((kp, np_), jnp.int8).at[:k, :n].set(w_codes.astype(jnp.int8))

    grid = (mp // bm_, np_ // bn_, kp // bk_)
    kernel = functools.partial(
        _fused_kernel,
        a_bits=a_bits,
        act_signed=act_signed,
        plane_bits=plane_bits,
        w_plane_lo=w_plane_lo,
        bk=bk_,
    )
    acc, scales = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, kp), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm_, 1), lambda i, j, kk: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), jnp.int32),
            jax.ShapeDtypeStruct((mp, 1), jnp.float32),
        ],
        compiler_params=compiler_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xp, wp)
    return acc[:m, :n], scales[:m]
