"""Helpers shared by every Pallas kernel in this package.

Single home for the rounding/padding arithmetic and the TPU compiler-params
shim that ``bitplane_matmul``, ``pack_quant``, ``fused_matmul``,
``flash_attention`` and ``wkv6`` previously each re-declared.
"""
from __future__ import annotations

try:  # TPU compiler params are optional in interpret mode
    from jax.experimental.pallas import tpu as pltpu

    def compiler_params(dims):
        try:
            return pltpu.CompilerParams(dimension_semantics=dims)
        except AttributeError:  # older naming
            return pltpu.TPUCompilerParams(dimension_semantics=dims)

except ImportError:  # pragma: no cover
    pltpu = None

    def compiler_params(dims):
        return None


def round_up(x: int, mult: int) -> int:
    """Smallest multiple of `mult` that is >= x."""
    return -(-x // mult) * mult
