"""Fused paged-attention decode Pallas kernel.

One query token per batch row attends directly against the paged KV pool
(`kv_cache.PagedKVCache` layout: one layer's slice is `(num_blocks,
block_size, NKV, H)` plus a `(B, max_blocks)` block table). This is the
M4BRAM argument applied to the decode hot loop: compute happens where the
data already lives — no staging copy ("separate buffer") of the pool is
ever materialized, unlike the `paged_gather` → `decode_attention`
composition, which writes a contiguous `(B, max_blocks·bs, NKV, H)` copy
to HBM every step of every layer.

Mechanics:
  * The block table and per-row positions arrive via **scalar prefetch**
    (`pltpu.PrefetchScalarGridSpec`) so the k/v BlockSpec index maps can
    resolve virtual block `j` of row `b` to pool block `table[b, j]`
    *before* the grid step runs — the DMA streams exactly that block into
    VMEM, straight from the pool.
  * Grid is `(B, NKV/bh, max_blocks)` with the block dimension innermost
    ("arbitrary"), so the online-softmax running max / denominator /
    accumulator live in VMEM scratch across a row's blocks — the flash
    contract: per-(row, head-group, layer) HBM traffic is q + the row's
    *live* blocks + out.
  * Dead steps (unallocated table entries, blocks past the row's decode
    position) are remapped to pool block 0 — the reserved trash block —
    by the index map, so no new DMA is issued for them, and `pl.when`
    skips their compute. A row's cost scales with its actual length, not
    `max_blocks`.
  * GQA: all G query heads of a KV head are processed in one tile
    (`q` reshaped to `(B, NKV, G, H)`); `bh` KV heads share a grid step.
  * int8 pools dequantize **in-kernel**: per-(slot, head) fp32 scale
    planes stream alongside the code blocks, scores are computed on int8
    codes and rescaled per key slot, probabilities are rescaled per value
    slot — exactly `decode_attention`'s quantized math, with no bf16 copy
    of the cache anywhere.

Masking matches the gather-based reference: a slot is visible iff its
virtual block is allocated and its absolute position `kpos <= q_pos[b]`.
Rows whose table is all `-1` (freed slots) see nothing and output zeros —
their logits are discarded by the scheduler, and the trash block never
contributes to a live row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import compiler_params as _compiler_params


def _paged_kernel(tbl_ref, pos_ref, q_ref, k_ref, v_ref, *rest,
                  bs: int, n_blk: int, scale: float, softcap: float,
                  quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[b]
    visible = jnp.logical_and(tbl_ref[b, j] >= 0, j * bs <= pos)

    @pl.when(visible)
    def _step():
        q = q_ref[0].astype(jnp.float32)          # (bh, G, H)
        k = k_ref[0].astype(jnp.float32)          # (bs, bh, H)
        v = v_ref[0].astype(jnp.float32)          # (bs, bh, H)
        # Scores for all G query heads of each of the bh KV heads at once:
        # (bh, G, H) x (bh, H, bs) -> (bh, G, bs), batched over bh.
        s = jax.lax.dot_general(
            q, k.transpose(1, 2, 0), (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )
        if quantized:
            # Per-key-slot dequant of int8 codes (same order as
            # decode_attention: scores on codes, then rescale).
            s = s * ks_ref[0][..., 0].transpose(1, 0)[:, None, :]
        s = s * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
        mask = kpos <= pos
        s = jnp.where(mask, s, jnp.finfo(jnp.float32).min)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2))
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - safe_m[:, :, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=2)
        if quantized:
            # Per-value-slot dequant folded into the probabilities.
            p = p * vs_ref[0][..., 0].transpose(1, 0)[:, None, :]
        pv = jax.lax.dot_general(
            p, v.transpose(1, 0, 2), (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * alpha[:, :, None] + pv
        m_ref[...] = m_new

    @pl.when(j == n_blk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, :, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("softcap", "bh", "interpret"))
def paged_attention(
    q: jax.Array,            # (B, 1, NQ, H) — one new token per row
    pool_k: jax.Array,       # (num_blocks, block_size, NKV, H)
    pool_v: jax.Array,
    block_table: jax.Array,  # (B, max_blocks) int32, -1 = unallocated
    q_pos: jax.Array,        # (B,) per-row decode position
    k_scale: jax.Array | None = None,  # (num_blocks, block_size, NKV, 1)
    v_scale: jax.Array | None = None,
    *,
    softcap: float = 0.0,
    bh: int = 0,             # KV heads per grid step (0 = all)
    interpret: bool = True,
) -> jax.Array:
    """Returns (B, 1, NQ, H) attention output, dtype of q."""
    B, _, NQ, H = q.shape
    bs, NKV = pool_k.shape[1], pool_k.shape[2]
    G = NQ // NKV
    maxb = block_table.shape[1]
    if bh <= 0 or NKV % bh:
        bh = NKV
    quantized = k_scale is not None
    qr = q.reshape(B, NKV, G, H)
    block_table = block_table.astype(jnp.int32)
    q_pos = q_pos.astype(jnp.int32)

    def qo_map(b, h, j, tbl, qp):
        return (b, h, 0, 0)

    def blk_map(b, h, j, tbl, qp):
        # Dead steps (unallocated block / past the row's position) remap
        # to the trash block 0: the pipeline sees a repeated index and
        # issues no new DMA, keeping traffic at the row's live blocks.
        live = jnp.logical_and(tbl[b, j] >= 0, j * bs <= qp[b])
        return (jnp.where(live, jnp.maximum(tbl[b, j], 0), 0), 0, h, 0)

    in_specs = [
        pl.BlockSpec((1, bh, G, H), qo_map),
        pl.BlockSpec((1, bs, bh, H), blk_map),
        pl.BlockSpec((1, bs, bh, H), blk_map),
    ]
    operands = [qr, pool_k, pool_v]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, bs, bh, 1), blk_map),
            pl.BlockSpec((1, bs, bh, 1), blk_map),
        ]
        operands += [k_scale, v_scale]

    kernel = functools.partial(
        _paged_kernel, bs=bs, n_blk=maxb, scale=H**-0.5,
        softcap=softcap, quantized=quantized,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, NKV // bh, maxb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bh, G, H), qo_map),
        scratch_shapes=[
            pltpu.VMEM((bh, G), jnp.float32),
            pltpu.VMEM((bh, G), jnp.float32),
            pltpu.VMEM((bh, G, H), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, NKV, G, H), q.dtype),
        compiler_params=_compiler_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_table, q_pos, *operands)
    return out.reshape(B, 1, NQ, H)
