"""Fused paged chunked-prefill flash Pallas kernel.

A token *chunk* of one request's prompt attends causally against
``[pool-resident prefix ++ the chunk itself]`` and the chunk's K/V is
written into its destination pool blocks from the same kernel — the PR 4
scalar-prefetch/block-table trick applied to the prefill grid. This kills
both halves of the old admission round trip: no post-prefill
``scatter_into_paged`` (the chunk lands in the pool as a side effect of
attending), and no per-layer HBM gather of the resident prefix
(``prefill_suffix`` materialized a contiguous ``(1, P, NKV, H)`` copy of
the prefix every layer; here prefix blocks stream through the block-table
index map exactly like the decode kernel's).

Mechanics:
  * The row's block table and ``(start, length)`` arrive via **scalar
    prefetch** (``pltpu.PrefetchScalarGridSpec``) so the pool BlockSpec
    index maps can resolve virtual block ``j`` to pool block ``table[j]``
    before the grid step runs — one DMA streams exactly that block.
  * Grid is ``(NKV/bh, max_blocks)`` with the block dimension innermost;
    the online-softmax running max / denominator / accumulator for all
    ``Lc`` chunk queries live in VMEM scratch across a row's blocks.
  * Dead steps (unallocated table entries, blocks past the chunk's last
    position) are remapped to pool block 0 — the reserved trash block —
    so no new DMA is issued for them, and ``pl.when`` skips their compute.
  * Steps whose virtual block overlaps ``[start, start + length)`` are
    *destination* steps: the kernel merges the chunk's K/V rows into the
    streamed pool tile (resident slots below ``start`` keep their pool
    values) and writes the merged tile back to the pool through an
    input/output-aliased pool ref — the epilogue write. Non-destination
    steps remap the output to the trash block, so resident prefix blocks
    (possibly shared with other rows) are never rewritten.
  * int8 pools quantize on write in-kernel (``kv_cache.quantize_kv``'s
    exact per-(token, head) math, so pool bytes are bit-identical to the
    scatter path's) and dequantize in-kernel on read: scores are computed
    on int8 codes and rescaled per key slot, probabilities per value slot
    — ``decode_attention``'s quantized math, like the decode kernel.

Masking: key slot at absolute position ``kpos`` is visible to chunk query
``i`` iff its block is allocated and ``kpos <= start + i`` (causal);
padded queries (``i >= length``) see nothing and output zeros. Positions
in ``[start + length, ...)`` of a destination block are masked on read
and preserved on write, so a later chunk appending into the same partial
block finds earlier residents intact.

The write-then-read ordering within a destination step (the merged tile
is both the attention operand and the written output) is what makes the
chunk attend to itself through the *pool's* representation: for an int8
pool a chunk key is read back as ``dequantize(quantize(k))`` — exactly
the `_kv_attn_view` contract the cold prefill applies to its own K/V.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import compiler_params as _compiler_params


def _quantize_tile(x):
    """In-kernel `kv_cache.quantize_kv`: per-(slot, head) int8 symmetric
    codes + fp32 scales for a (bs, bh, H) tile. Must stay bit-identical
    to the jnp helper — pool bytes written here are shared with readers
    that assume the scatter path's exact quantization."""
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = absmax / 127.0
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    codes = jnp.clip(jnp.round(xf * inv), -128, 127).astype(jnp.int8)
    return codes, scale


def _chunk_kernel(tbl_ref, meta_ref, q_ref, kn_ref, vn_ref, k_ref, v_ref,
                  *rest, bs: int, n_blk: int, lc: int, scale: float,
                  softcap: float, quantized: bool):
    if quantized:
        (ks_ref, vs_ref, o_ref, pk_out, pv_out, ks_out, vs_out,
         m_ref, l_ref, acc_ref) = rest
    else:
        o_ref, pk_out, pv_out, m_ref, l_ref, acc_ref = rest
    j = pl.program_id(1)
    start = meta_ref[0]
    length = meta_ref[1]
    last = start + length - 1

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = jnp.logical_and(
        jnp.logical_and(tbl_ref[j] >= 0, j * bs <= last), length > 0
    )

    @pl.when(live)
    def _step():
        kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (bs,), 0)
        cidx = kpos - start
        in_chunk = jnp.logical_and(cidx >= 0, cidx < length)
        gather = jnp.clip(cidx, 0, lc - 1)
        kn = jnp.take(kn_ref[...], gather, axis=0)    # (bs, bh, H)
        vn = jnp.take(vn_ref[...], gather, axis=0)
        sel = in_chunk[:, None, None]
        if quantized:
            kq, ksc = _quantize_tile(kn)
            vq, vsc = _quantize_tile(vn)
            mk = jnp.where(sel, kq, k_ref[0])
            mv = jnp.where(sel, vq, v_ref[0])
            msk = jnp.where(sel, ksc, ks_ref[0])      # (bs, bh, 1) fp32
            msv = jnp.where(sel, vsc, vs_ref[0])
        else:
            mk = jnp.where(sel, kn.astype(k_ref.dtype), k_ref[0])
            mv = jnp.where(sel, vn.astype(v_ref.dtype), v_ref[0])

        q = q_ref[...].astype(jnp.float32)            # (bh, Lc, G, H)
        # (bh, Lc, G, H) x (bs, bh, H) -> (bh, Lc, G, bs), batched over bh.
        s = jax.lax.dot_general(
            q, mk.astype(jnp.float32), (((3,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32,
        )
        if quantized:
            # Per-key-slot dequant of int8 codes (scores on codes, then
            # rescale — decode_attention's order).
            s = s * msk[..., 0].transpose(1, 0)[:, None, None, :]
        s = s * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        qi = jax.lax.broadcasted_iota(jnp.int32, (lc,), 0)
        qpos = jnp.where(qi < length, start + qi, -1)  # padded queries: none
        mask = kpos[None, None, None, :] <= qpos[None, :, None, None]
        s = jnp.where(mask, s, jnp.finfo(jnp.float32).min)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=3))
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - safe_m[..., None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=3)
        if quantized:
            # Per-value-slot dequant folded into the probabilities.
            p = p * msv[..., 0].transpose(1, 0)[:, None, None, :]
        pv = jax.lax.dot_general(
            p, mv.astype(jnp.float32), (((3,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * alpha[..., None] + pv
        m_ref[...] = m_new

        # Epilogue: destination steps write the merged tile back to the
        # pool (aliased refs — in place). Non-destination steps map the
        # output to the trash block, so this store is simply skipped.
        @pl.when(j >= start // bs)
        def _write():
            pk_out[0] = mk
            pv_out[0] = mv
            if quantized:
                ks_out[0] = msk
                vs_out[0] = msv

    @pl.when(j == n_blk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("softcap", "bh", "interpret"))
def paged_prefill_attention(
    q: jax.Array,            # (1, Lc, NQ, H) — rope'd chunk queries
    k_new: jax.Array,        # (1, Lc, NKV, H) — chunk K/V (unquantized)
    v_new: jax.Array,
    pool_k: jax.Array,       # (num_blocks, block_size, NKV, H)
    pool_v: jax.Array,
    blocks: jax.Array,       # (mb,) int32 row block table, -1 = unallocated
    start: jax.Array,        # () int32 absolute position of chunk token 0
    length: jax.Array,       # () int32 real chunk length (<= Lc)
    k_scale: jax.Array | None = None,  # (num_blocks, block_size, NKV, 1)
    v_scale: jax.Array | None = None,
    *,
    softcap: float = 0.0,
    bh: int = 0,             # KV heads per grid step (0 = all)
    interpret: bool = True,
):
    """Returns (attn (1, Lc, NQ, H) dtype of q, pool_k, pool_v, k_scale,
    v_scale) — the pool planes updated in place (aliased) with the chunk's
    K/V at positions [start, start + length)."""
    _, Lc, NQ, H = q.shape
    bs, NKV = pool_k.shape[1], pool_k.shape[2]
    G = NQ // NKV
    mb = blocks.shape[0]
    if bh <= 0 or NKV % bh:
        bh = NKV
    quantized = k_scale is not None
    qr = q.reshape(Lc, NKV, G, H).transpose(1, 0, 2, 3)  # (NKV, Lc, G, H)
    kn = k_new.reshape(Lc, NKV, H)
    vn = v_new.reshape(Lc, NKV, H)
    blocks = blocks.astype(jnp.int32)
    meta = jnp.stack([jnp.asarray(start, jnp.int32),
                      jnp.asarray(length, jnp.int32)])

    def q_map(h, j, tbl, mt):
        return (h, 0, 0, 0)

    def new_map(h, j, tbl, mt):
        return (0, h, 0)

    def blk_map(h, j, tbl, mt):
        # Dead steps (unallocated block / past the chunk) remap to the
        # trash block 0: a repeated index issues no new DMA.
        live = jnp.logical_and(tbl[j] >= 0, j * bs <= mt[0] + mt[1] - 1)
        return (jnp.where(live, jnp.maximum(tbl[j], 0), 0), 0, h, 0)

    def dst_map(h, j, tbl, mt):
        # Destination steps write back through the aliased pool ref; all
        # other steps dump the (unwritten) output tile into the trash
        # block so resident prefix blocks are never rewritten.
        live = jnp.logical_and(tbl[j] >= 0, j * bs <= mt[0] + mt[1] - 1)
        dst = jnp.logical_and(live, j >= mt[0] // bs)
        return (jnp.where(dst, jnp.maximum(tbl[j], 0), 0), 0, h, 0)

    in_specs = [
        pl.BlockSpec((bh, Lc, G, H), q_map),
        pl.BlockSpec((Lc, bh, H), new_map),
        pl.BlockSpec((Lc, bh, H), new_map),
        pl.BlockSpec((1, bs, bh, H), blk_map),
        pl.BlockSpec((1, bs, bh, H), blk_map),
    ]
    operands = [qr, kn, vn, pool_k, pool_v]
    out_shapes = [
        jax.ShapeDtypeStruct((NKV, Lc, G, H), q.dtype),
        jax.ShapeDtypeStruct(pool_k.shape, pool_k.dtype),
        jax.ShapeDtypeStruct(pool_v.shape, pool_v.dtype),
    ]
    out_specs = [
        pl.BlockSpec((bh, Lc, G, H), q_map),
        pl.BlockSpec((1, bs, bh, H), dst_map),
        pl.BlockSpec((1, bs, bh, H), dst_map),
    ]
    # Operand indices count the scalar-prefetch args (blocks=0, meta=1).
    aliases = {5: 1, 6: 2}
    if quantized:
        in_specs += [
            pl.BlockSpec((1, bs, bh, 1), blk_map),
            pl.BlockSpec((1, bs, bh, 1), blk_map),
        ]
        operands += [k_scale, v_scale]
        out_shapes += [
            jax.ShapeDtypeStruct(k_scale.shape, k_scale.dtype),
            jax.ShapeDtypeStruct(v_scale.shape, v_scale.dtype),
        ]
        out_specs += [
            pl.BlockSpec((1, bs, bh, 1), dst_map),
            pl.BlockSpec((1, bs, bh, 1), dst_map),
        ]
        aliases.update({7: 3, 8: 4})

    kernel = functools.partial(
        _chunk_kernel, bs=bs, n_blk=mb, lc=Lc, scale=H**-0.5,
        softcap=softcap, quantized=quantized,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(NKV // bh, mb),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((bh, Lc, G), jnp.float32),
            pltpu.VMEM((bh, Lc, G), jnp.float32),
            pltpu.VMEM((bh, Lc, G, H), jnp.float32),
        ],
    )
    outs = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        compiler_params=_compiler_params(("arbitrary", "arbitrary")),
        input_output_aliases=aliases,
        interpret=interpret,
    )(blocks, meta, *operands)
    attn = outs[0].transpose(1, 0, 2, 3).reshape(1, Lc, NQ, H)
    if quantized:
        return attn, outs[1], outs[2], outs[3], outs[4]
    return attn, outs[1], outs[2], None, None
