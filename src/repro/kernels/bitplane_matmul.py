"""Bit-plane mixed-precision matmul — the M4BRAM BPE dataflow on the MXU.

The paper's BPE consumes activation bits serially and LUT-selects partial
sums ``{0, W1, W2, W1+W2}``; algebraically each cycle adds
``(I1[n]·W1 + I2[n]·W2) << n``. Vectorized over a whole tile that is::

    acc = sum_p (plane_p @ W) << (p · plane_bits)  -  2^(a_bits-1) · colsum(W)

with 2-bit planes (the TPU-efficient choice: ceil(a_bits/2) MXU passes, each
an int8×int8→int32 matmul) and the offset term playing the INV-row's role
for signed activations (see repro/core/bitplane.py).

TPU mapping decisions (hw-codesign):
  * Grid (M/bm, N/bn, K/bk) with ("parallel", "parallel", "arbitrary")
    dimension semantics — K innermost so the int32 accumulator tile stays
    resident in VMEM across K steps (revisited output block).
  * Block shapes default to (bm, bn, bk) = (128, 128, 256): MXU-aligned
    (multiples of 128 on M/N for the 128×128 systolic array; 256 on K keeps
    the x/w tiles at 32 KiB / 64 KiB int8 — well inside VMEM with Pallas'
    automatic double-buffering of BlockSpec tiles, the analogue of the
    paper's double-buffered load/compute/store pipeline).
  * The plane decomposition runs on registers in VMEM (shift+mask on the
    already-loaded int8 tile) — the duplication-shuffler analogue: HBM only
    ever sees packed data; unpacking is free bandwidth multiplication.
  * The number of planes is static (specialized per a_bits) so the P-loop
    fully unrolls into `P` MXU contractions — latency scales with ceil(a/2)
    exactly as the paper's (n/2+2)-cycle double-pumped BPE.

Validated in interpret mode on CPU against repro/kernels/ref.py (exact
integer equality) across shapes, precisions and signedness.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import compiler_params as _compiler_params
from repro.kernels.common import round_up as _round_up


def _bitplane_matmul_kernel(
    x_ref,  # (bm, bk) int8 activation codes
    w_ref,  # (bk, bn) int8 weight codes
    o_ref,  # (bm, bn) int32 accumulator (revisited across K grid steps)
    *,
    a_bits: int,
    act_signed: bool,
    plane_bits: int,
    w_plane_lo: int,
):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)

    if w_plane_lo:
        # Plane-truncated contraction: use only the top planes of the
        # (conceptually little-endian plane-decomposed) weight codes.  A
        # signed code w stores as offset-binary u = w + 2^(b-1), whose
        # plane p holds bits [p·pb, (p+1)·pb).  Dropping planes [0, lo)
        # and re-weighting plane p at 2^((p-lo)·pb) is exactly
        # floor(u / 4^lo) - 2^(b-1)/4^lo; since the sign offset 2^(b-1)
        # divides by 4^lo whenever 2·lo < b (pb = 2), that equals the
        # arithmetic shift w >> (lo·pb) — the sign plane stays the top
        # plane and the truncated code is itself a valid signed
        # (b - lo·pb)-bit code.  Crucially the shift happens BEFORE the
        # activation-offset colsum correction below: the correction term
        # offset·colsum(W) must be computed over the *truncated* weight,
        # otherwise the dropped low planes of W would leak back in
        # through the correction.
        w = w >> (w_plane_lo * plane_bits)

    offset = (1 << (a_bits - 1)) if act_signed else 0
    u = x + offset  # offset-binary: planes are unsigned
    n_planes = -(-a_bits // plane_bits)
    mask = (1 << plane_bits) - 1

    acc = jnp.zeros(o_ref.shape, jnp.int32)
    for p in range(n_planes):  # static unroll: one MXU pass per plane
        plane = ((u >> (p * plane_bits)) & mask).astype(jnp.int8)
        part = jax.lax.dot_general(
            plane,
            w.astype(jnp.int8),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        acc = acc + (part << (p * plane_bits))

    if offset:
        # INV-row analogue: subtract offset * colsum(W) for this K block.
        colsum = jnp.sum(w, axis=0, keepdims=True)
        acc = acc - offset * colsum

    o_ref[...] += acc


@functools.partial(
    jax.jit,
    static_argnames=("a_bits", "act_signed", "plane_bits", "w_plane_lo",
                     "bm", "bn", "bk", "interpret"),
)
def bitplane_matmul(
    x_codes: jax.Array,
    w_codes: jax.Array,
    *,
    a_bits: int = 8,
    act_signed: bool = True,
    plane_bits: int = 2,
    w_plane_lo: int = 0,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """(M, K) int codes × (K, N) int codes → (M, N) int32 exact product.

    Shapes need not be block-aligned; inputs are zero-padded (zero codes
    contribute nothing — including to the offset correction, since colsum
    of a zero column block is zero; likewise a zero code is shift-invariant
    so padding is safe under ``w_plane_lo`` truncation).

    ``w_plane_lo`` contracts only the top planes of the weight codes:
    plane ``lo`` becomes the new least-significant plane, realized as an
    arithmetic shift of the signed codes (see the kernel for why that is
    exactly "keep planes [lo:]"). The caller re-scales the dequantized
    output by ``(1 << (plane_bits * w_plane_lo))`` to keep the weight
    scale meaning "value of one unit of the *original* LSB".
    """
    if x_codes.ndim != 2 or w_codes.ndim != 2:
        raise ValueError("bitplane_matmul expects 2-D operands")
    m, k = x_codes.shape
    k2, n = w_codes.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {k} vs {k2}")

    # Clamp blocks to the padded problem without dropping the alignment the
    # caller's plan carries: a 128-multiple block (MXU lane contract, mosaic
    # plans) stays a 128-multiple; finer interpret-mode plans clamp to 8.
    bm_ = min(bm, _round_up(m, 8))
    bn_ = min(bn, _round_up(n, 128 if bn % 128 == 0 else 8))
    bk_ = min(bk, _round_up(k, 128 if bk % 128 == 0 else 8))
    mp, np_, kp = _round_up(m, bm_), _round_up(n, bn_), _round_up(k, bk_)

    x = jnp.zeros((mp, kp), jnp.int8).at[:m, :k].set(x_codes.astype(jnp.int8))
    w = jnp.zeros((kp, np_), jnp.int8).at[:k, :n].set(w_codes.astype(jnp.int8))

    grid = (mp // bm_, np_ // bn_, kp // bk_)
    kernel = functools.partial(
        _bitplane_matmul_kernel,
        a_bits=a_bits,
        act_signed=act_signed,
        plane_bits=plane_bits,
        w_plane_lo=w_plane_lo,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        compiler_params=_compiler_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)
    return out[:m, :n]
