"""Flash attention (fwd) Pallas kernel — the memory-term lever for the
attention-heavy cells in EXPERIMENTS.md §Roofline.

The distributed/jnp path (models/common.chunked_attention) is memory-bound
under the unfused HLO convention because every (q-block × kv-block) score
tile round-trips HBM. This kernel keeps the running max/denominator and
the output accumulator in VMEM scratch across the KV grid dimension —
per-(batch, head, q-block) HBM traffic is exactly q + k + v + out, the
flash contract.

Supports causal and sliding-window masks and a query-position offset
(decode/prefill continuation). GQA callers pass q grouped per kv head
(B, NKV, G·Tq, D) or pre-broadcast kv — see ops.flash_attention for the
dispatching wrapper.

Grid: (B·H, Tq/bq, Tk/bk) with ("parallel", "parallel", "arbitrary") —
the KV dim is innermost so scratch persists across it; fully-masked KV
blocks are skipped with pl.when (the causal/window block-level test), so
compute is sub-quadratic for windowed attention, matching the jnp path's
semantics while eliminating its HBM traffic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import compiler_params as _compiler_params
from repro.kernels.common import round_up as _round_up


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, causal: bool, window: int,
                  q_offset: int, kv_len: int, scale: float, n_kb: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = q_offset + qi * bq
    k_lo = ki * bk
    # Block-level visibility: skip blocks fully outside the mask.
    visible = True
    if causal:
        visible = jnp.asarray(k_lo <= q_lo + bq - 1)
    if window:
        visible = jnp.logical_and(visible, k_lo + bk - 1 > q_lo - window)

    @pl.when(visible)
    def _step():
        q = q_ref[0].astype(jnp.float32)          # (bq, D)
        k = k_ref[0].astype(jnp.float32)          # (bk, D)
        v = v_ref[0].astype(jnp.float32)          # (bk, D)
        s = (q @ k.T) * scale                     # (bq, bk)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, -jnp.inf)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - safe_m[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(ki == n_kb - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (BH, Tq, D)
    k: jax.Array,  # (BH, Tk, D)
    v: jax.Array,  # (BH, Tk, D)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    BH, Tq, D = q.shape
    Tk = k.shape[1]
    scale = D**-0.5
    bq_ = min(bq, _round_up(Tq, 8))
    bk_ = min(bk, _round_up(Tk, 8))
    Tqp, Tkp = _round_up(Tq, bq_), _round_up(Tk, bk_)
    if Tqp != Tq:
        q = jnp.pad(q, ((0, 0), (0, Tqp - Tq), (0, 0)))
    if Tkp != Tk:
        k = jnp.pad(k, ((0, 0), (0, Tkp - Tk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Tkp - Tk), (0, 0)))
    n_kb = Tkp // bk_
    kernel = functools.partial(
        _flash_kernel, bq=bq_, bk=bk_, causal=causal, window=window,
        q_offset=q_offset, kv_len=Tk, scale=scale, n_kb=n_kb,
    )
    try:
        from jax.experimental.pallas import tpu as pltpu

        scratch = [
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_, D), jnp.float32),
        ]
    except Exception:  # pragma: no cover
        scratch = []
    out = pl.pallas_call(
        kernel,
        grid=(BH, Tqp // bq_, n_kb),
        in_specs=[
            pl.BlockSpec((1, bq_, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk_, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk_, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tqp, D), q.dtype),
        scratch_shapes=scratch,
        compiler_params=_compiler_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :Tq]
