"""Pallas TPU kernels for the M4BRAM reproduction.

  bitplane_matmul : mixed-precision matmul via 2-bit activation planes —
                    the BPE dataflow vectorized onto the MXU
  pack_quant      : fused per-token activation quantization
  wkv6            : RWKV-6 chunked linear-attention mixer
  ops             : jit'd public wrappers + block-shape selection
  ref             : pure-jnp oracles (the test specification)

All kernels are written with pl.pallas_call + explicit BlockSpec VMEM tiling
targeting TPU, and validated on CPU in interpret mode.
"""
from repro.kernels import ops  # noqa: F401
