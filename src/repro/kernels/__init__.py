"""Pallas TPU kernels for the M4BRAM reproduction.

  bitplane_matmul : mixed-precision matmul via 2-bit activation planes —
                    the BPE dataflow vectorized onto the MXU
  fused_matmul    : fused quantize→bit-plane matmul (serve hot path; no
                    intermediate int8 activation tensor in HBM)
  pack_quant      : standalone per-token activation quantization
  wkv6            : RWKV-6 chunked linear-attention mixer
  registry        : backend dispatch (interpret/mosaic/reference) + memoized
                    per-shape block-plan/autotune cache
  ops             : jit'd public wrappers — the only entry point callers use
  ref             : pure-jnp oracles (the test specification, also the
                    "reference" backend)

All kernels are written with pl.pallas_call + explicit BlockSpec VMEM tiling
targeting TPU, and validated on CPU in interpret mode.
"""
from repro.kernels import ops  # noqa: F401
from repro.kernels.registry import get_registry, use_backend  # noqa: F401
