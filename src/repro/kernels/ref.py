"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the *semantic specification* its kernel is tested against
(tests/test_kernels_*.py sweep shapes/dtypes/precisions with
assert_allclose / exact integer equality).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import bitplane


def bitplane_matmul_ref(
    x_codes: jax.Array,
    w_codes: jax.Array,
    a_bits: int,
    act_signed: bool = True,
    w_plane_lo: int = 0,
    plane_bits: int = 2,
) -> jax.Array:
    """(M, K) int codes × (K, N) int codes → (M, N) int32, exact.

    Unsigned codes may arrive as wrapped int8 storage (255 → -1); mask to
    the a_bits range so the semantics match the kernels' offset-binary
    reconstruction mod 2^a_bits.

    ``w_plane_lo`` truncates the weight to its top planes before the
    contraction: the arithmetic shift is exactly "keep planes [lo:]" of
    the little-endian offset-binary decomposition, because the sign
    offset 2^(b-1) is divisible by 4^lo whenever 2·lo < b (see
    bitplane_matmul's kernel for the derivation).
    """
    x = x_codes.astype(jnp.int32)
    if not act_signed:
        x = x & ((1 << a_bits) - 1)
    w = w_codes.astype(jnp.int32)
    if w_plane_lo:
        w = w >> (w_plane_lo * plane_bits)
    return (x @ w).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bits", "signed"))
def quantize_pack_ref(
    x: jax.Array, bits: int, signed: bool = True
) -> tuple[jax.Array, jax.Array]:
    """Per-row absmax symmetric quantization of (M, K) float x to `bits`-bit
    codes, returned as int8 codes (unpacked; packing is layout-only) and
    per-row scales (M, 1)."""
    qhi = (1 << (bits - 1)) - 1 if signed else (1 << bits) - 1
    qlo = -(1 << (bits - 1)) if signed else 0
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = absmax / qhi
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    # int32 hop: float→int8 saturates but int32→int8 wraps, preserving the
    # bit pattern of unsigned 8-bit codes (see pack_quant).
    q = jnp.clip(jnp.round(x * inv), qlo, qhi).astype(jnp.int32).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def mixed_group_matmul_ref(
    x: jax.Array,
    w8_codes: jax.Array,
    wl_codes: jax.Array,
    scale8: jax.Array,
    scalel: jax.Array,
    a_bits: int,
) -> jax.Array:
    """Intra-layer mixed matmul (Table III): x (M, K) float; the first group
    is 8-bit codes (K, N8), the second `w_bits`-bit codes (K, NL); output is
    the float concatenation [x@deq(w8), x@deq(wl)] with activations quantized
    per-row at a_bits."""
    q, s = quantize_pack_ref(x.astype(jnp.float32), a_bits)
    acc8 = q.astype(jnp.int32) @ w8_codes.astype(jnp.int32)
    accl = q.astype(jnp.int32) @ wl_codes.astype(jnp.int32)
    y8 = acc8.astype(jnp.float32) * s * scale8.reshape(1, -1)
    yl = accl.astype(jnp.float32) * s * scalel.reshape(1, -1)
    return jnp.concatenate([y8, yl], axis=1)


def wkv6_ref(
    r: jax.Array,  # (T, H, K)   receptance
    k: jax.Array,  # (T, H, K)   key
    v: jax.Array,  # (T, H, V)   value
    w: jax.Array,  # (T, H, K)   data-dependent decay, in (0, 1)
    u: jax.Array,  # (H, K)      bonus for the current token
) -> jax.Array:
    """RWKV-6 (Finch) recurrence, sequential reference.

    State S_h ∈ R^{K×V};   out_t = r_t · (S + u ⊙ k_t v_tᵀ);
                           S ← diag(w_t) S + k_t v_tᵀ.
    Returns (T, H, V) float32.
    """
    T, H, K = r.shape
    V = v.shape[-1]

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]          # (H, K, V)
        out = jnp.einsum("hk,hkv->hv", r_t, S + u[..., :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, out

    S0 = jnp.zeros((H, K, V), jnp.float32)
    _, outs = jax.lax.scan(
        step, S0, (r.astype(jnp.float32), k.astype(jnp.float32),
                   v.astype(jnp.float32), w.astype(jnp.float32))
    )
    return outs


def paged_attention_ref(
    q: jax.Array,            # (B, 1, NQ, H)
    pool_k: jax.Array,       # (num_blocks, block_size, NKV, H)
    pool_v: jax.Array,
    block_table: jax.Array,  # (B, max_blocks) int32, -1 = unallocated
    q_pos: jax.Array,        # (B,) per-row decode position
    k_scale: jax.Array | None = None,  # (num_blocks, block_size, NKV, 1)
    v_scale: jax.Array | None = None,
    softcap: float = 0.0,
) -> jax.Array:
    """Gather-then-attend oracle for the fused paged-attention kernel.

    Materializes each row's blocks in table order (the contiguous
    slot == position layout) and runs the same one-token masked-softmax
    math as ``models.common.decode_attention`` — including the int8-pool
    per-slot rescaling. This IS the "separate buffer" the fused kernel
    eliminates; it survives as the semantic specification."""
    B, _, NQ, H = q.shape
    bs, NKV = pool_k.shape[1], pool_k.shape[2]
    G = NQ // NKV
    max_blocks = block_table.shape[1]
    tbl = jnp.maximum(block_table, 0)
    k_rows = pool_k[tbl].reshape(B, max_blocks * bs, NKV, H)
    v_rows = pool_v[tbl].reshape(B, max_blocks * bs, NKV, H)
    virt = jnp.arange(max_blocks * bs, dtype=jnp.int32)
    alloc = jnp.repeat(block_table >= 0, bs, axis=1)
    kpos = jnp.where(alloc, virt[None, :], -1)

    qr = q.reshape(B, NKV, G, H)
    s = jnp.einsum("bngh,bsnh->bngs", qr.astype(jnp.float32),
                   k_rows.astype(jnp.float32))
    if k_scale is not None:
        ks = k_scale[tbl].reshape(B, max_blocks * bs, NKV)
        s = s * jnp.moveaxis(ks, -1, 1)[:, :, None, :]
    s = s * (H**-0.5)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.broadcast_to(jnp.asarray(q_pos, jnp.int32), (B,))
    valid = (kpos >= 0) & (kpos <= q_pos[:, None])
    s = jnp.where(valid[:, None, None, :], s, jnp.finfo(jnp.float32).min)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:
        vs = v_scale[tbl].reshape(B, max_blocks * bs, NKV)
        p = p * jnp.moveaxis(vs, -1, 1)[:, :, None, :]
    out = jnp.einsum("bngs,bsnh->bngh", p, v_rows.astype(jnp.float32))
    return out.reshape(B, 1, NQ, H).astype(q.dtype)


def paged_prefill_ref(
    q: jax.Array,            # (1, Lc, NQ, H)
    k_new: jax.Array,        # (1, Lc, NKV, H) — chunk K/V, unquantized
    v_new: jax.Array,
    pool_k: jax.Array,       # (num_blocks, block_size, NKV, H)
    pool_v: jax.Array,
    blocks: jax.Array,       # (mb,) int32 row block table, -1 = unallocated
    start: jax.Array,        # () int32 chunk token 0's absolute position
    length: jax.Array,       # () int32 real chunk length (<= Lc)
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
    softcap: float = 0.0,
):
    """Scatter-then-gather-attend oracle for the chunked-prefill kernel.

    Writes the chunk into the pool with `paged_chunk_write` (the exact
    quantize-on-write math the kernel inlines), then gathers the row's
    blocks in table order and runs a full fp32 masked softmax — chunk
    query i sees allocated positions <= start + i, padded queries
    (i >= length) see nothing and output zeros. Attending *through the
    pool* is the point: an int8 pool's chunk keys come back as
    dequantize(quantize(k)), the `_kv_attn_view` contract."""
    from repro.models import kv_cache as _kvc

    _, Lc, NQ, H = q.shape
    bs, NKV = pool_k.shape[1], pool_k.shape[2]
    G = NQ // NKV
    mb = blocks.shape[0]
    pool_k, pool_v, k_scale, v_scale = _kvc.paged_chunk_write(
        pool_k, pool_v, blocks, k_new, v_new, start, length, bs,
        k_scale, v_scale)
    tbl = jnp.maximum(blocks, 0)
    k_rows = pool_k[tbl].reshape(mb * bs, NKV, H)
    v_rows = pool_v[tbl].reshape(mb * bs, NKV, H)
    virt = jnp.arange(mb * bs, dtype=jnp.int32)
    alloc = jnp.repeat(blocks >= 0, bs)
    kpos = jnp.where(alloc, virt, -1)

    qr = q.reshape(Lc, NKV, G, H)
    s = jnp.einsum("qngh,snh->nqgs", qr.astype(jnp.float32),
                   k_rows.astype(jnp.float32))
    if k_scale is not None:
        ks = k_scale[tbl].reshape(mb * bs, NKV)
        s = s * ks.T[:, None, None, :]
    s = s * (H**-0.5)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qi = jnp.arange(Lc, dtype=jnp.int32)
    qpos = jnp.where(qi < length, jnp.asarray(start, jnp.int32) + qi, -1)
    valid = (kpos[None, :] >= 0) & (kpos[None, :] <= qpos[:, None])
    s = jnp.where(valid[None, :, None, :], s, jnp.finfo(jnp.float32).min)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid[None, :, None, :], p, 0.0)
    if v_scale is not None:
        vs = v_scale[tbl].reshape(mb * bs, NKV)
        p = p * vs.T[:, None, None, :]
    out = jnp.einsum("nqgs,snh->qngh", p, v_rows.astype(jnp.float32))
    attn = out.reshape(1, Lc, NQ, H).astype(q.dtype)
    return attn, pool_k, pool_v, k_scale, v_scale


def flash_attention_ref(
    q: jax.Array,  # (BH, Tq, D)
    k: jax.Array,  # (BH, Tk, D)
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    """Naive fp32 softmax attention with causal/window masks."""
    BH, Tq, D = q.shape
    Tk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D**-0.5)
    qpos = (q_offset + jnp.arange(Tq))[:, None]
    kpos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None], p, 0.0)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
