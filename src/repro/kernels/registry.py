"""Kernel backend dispatch registry.

Replaces the old mutable module-global ``ops._INTERPRET`` flag with an
explicit, inspectable abstraction. Three backends ship by default:

  interpret : Pallas interpret mode — runs anywhere (CPU containers, tests).
              Block planning may use sub-128 tiles since no MXU lane
              constraint applies; tiny layers stop over-padding to 128.
  mosaic    : Pallas → Mosaic lowering for real TPUs. Block plans keep the
              MXU alignment contract (N/K tiles at multiples of 128).
  reference : the pure-jnp oracles in :mod:`repro.kernels.ref` — no Pallas
              at all. Useful inside distributed jit graphs and as the
              always-correct fallback for new hardware bring-up.

The registry also owns per-shape block-plan selection with a memoized
autotune cache: :meth:`KernelRegistry.matmul_plan` answers "what (bm, bn, bk)
should shape (M, N, K) use on this backend" from a heuristic VMEM model, and
:meth:`KernelRegistry.autotune` lets benchmarks measure candidate plans once
and pin the winner for every later call with the same shape.

Backend selection is scoped, not global-mutable-state:

    reg = get_registry()
    with reg.use("reference"):
        y = ops.bitplane_matmul(xq, wq, a_bits=4)

or per-call via the ``backend=`` argument every op in
:mod:`repro.kernels.ops` accepts.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from pathlib import Path
from typing import Callable, Dict, Iterable, Optional, Sequence, Tuple, Union

from repro.kernels.common import round_up

Blocks = Tuple[int, int, int]


@dataclasses.dataclass(frozen=True)
class KernelBackend:
    """One way of executing the kernel suite.

    Attributes:
      name: registry key ("interpret" | "mosaic" | "reference" | custom).
      interpret: value passed to ``pl.pallas_call(interpret=...)``.
      is_reference: route to the pure-jnp oracles instead of Pallas.
      m_align/n_align/k_align: block-shape alignment the backend requires.
        Mosaic needs 128-lane N/K tiles for the MXU; interpret/reference
        can tile at the fp32 sublane granularity (8) and avoid padding
        tiny layers up to 128.
    """

    name: str
    interpret: bool = True
    is_reference: bool = False
    m_align: int = 8
    n_align: int = 128
    k_align: int = 128


_DEFAULT_BACKENDS = (
    KernelBackend("interpret", interpret=True, n_align=8, k_align=8),
    KernelBackend("mosaic", interpret=False, n_align=128, k_align=128),
    KernelBackend("reference", interpret=True, is_reference=True,
                  n_align=8, k_align=8),
)

# VMEM working-set budgets (bytes). The int8 path double-buffers two input
# tiles; the fused path keeps full fp32 activation rows resident so it gets
# a larger slice of the ~16 MiB/core VMEM.
MATMUL_VMEM_BUDGET = 4 << 20
FUSED_VMEM_BUDGET = 8 << 20


def pick_matmul_blocks(
    m: int,
    n: int,
    k: int,
    *,
    m_align: int = 8,
    n_align: int = 128,
    k_align: int = 128,
    vmem_budget: int = MATMUL_VMEM_BUDGET,
) -> Blocks:
    """Choose (bm, bn, bk) for the int8 bit-plane matmul.

    x tile: bm*bk int8; w tile: bk*bn int8; acc: bm*bn int32 (+ Pallas
    double-buffers the input tiles). Large shapes take MXU-shaped tiles
    (128 on M/N, 512 on K); small shapes round up only to the backend's
    alignment so a (3, 100, 5) matmul no longer pads to (8, 128, 128).
    """
    bm = 128 if m >= 128 else max(m_align, round_up(m, m_align))
    bn = 128 if n >= 128 else min(128, max(n_align, round_up(n, n_align)))
    bk = 512 if k >= 512 else min(512, max(k_align, round_up(k, k_align)))
    while 2 * (bm * bk + bk * bn) + 4 * bm * bn > vmem_budget and bk > k_align:
        bk = max(k_align, bk // 2)
    return bm, bn, bk


def pick_fused_blocks(
    m: int,
    n: int,
    k: int,
    *,
    m_align: int = 8,
    n_align: int = 128,
    k_align: int = 128,
    vmem_budget: int = FUSED_VMEM_BUDGET,
) -> Blocks:
    """Blocks for the fused quantize→matmul kernel.

    The fused kernel keeps a (bm, K) fp32 activation block fully resident
    (the row absmax needs whole rows), so bm shrinks as K grows instead of
    tiling K on the activation side; bk only tiles the weight operand.
    """
    kp = max(k_align, round_up(k, k_align))
    bn = 128 if n >= 128 else min(128, max(n_align, round_up(n, n_align)))
    bk = 512 if k >= 512 else kp
    bm = 128 if m >= 128 else max(m_align, round_up(m, m_align))
    # 4B fp32 rows double-buffered + int8 w tile double-buffered + int32 acc.
    while bm > m_align and 8 * bm * kp + 2 * bk * bn + 4 * bm * bn > vmem_budget:
        bm = max(m_align, bm // 2)
    while 2 * bk * bn > vmem_budget // 4 and bk > k_align:
        bk = max(k_align, bk // 2)
    return bm, bn, bk


PAGED_ATTN_VMEM_BUDGET = 2 << 20


def pick_paged_attention_blocks(
    m: int,   # NKV — number of KV heads
    n: int,   # block_size — pool tokens per block
    k: int,   # H — head dim
    *,
    m_align: int = 8,
    n_align: int = 128,
    k_align: int = 128,
    vmem_budget: int = PAGED_ATTN_VMEM_BUDGET,
) -> Blocks:
    """Plan (bh, block_size, H) for the paged-attention decode kernel.

    The only free knob is ``bh`` — how many KV heads one grid step
    streams alongside a pool block: larger bh = fewer grid steps and
    DMAs, more VMEM per step (k + v tiles double-buffered in fp32 after
    dequant). bh must divide NKV; block_size and H are fixed by the pool
    layout and pass through so the plan cache keys on the full shape.
    """
    bh = m
    # k/v tiles double-buffered + fp32 working copies + softmax scratch.
    while bh > 1 and 8 * n * bh * k > vmem_budget:
        bh = max(d for d in range(1, bh) if m % d == 0)
    return bh, n, k


def _paged_attention_candidates(heur: Blocks, m, n, k, be) -> list:
    """Autotune candidates: every divisor of NKV as the bh knob."""
    _, bs, hd = heur
    return [(d, bs, hd) for d in range(1, m + 1) if m % d == 0]


def pick_paged_prefill_blocks(
    m: int,   # NKV — number of KV heads
    n: int,   # block_size — pool tokens per block
    k: int,   # H — head dim
    *,
    m_align: int = 8,
    n_align: int = 128,
    k_align: int = 128,
    vmem_budget: int = PAGED_ATTN_VMEM_BUDGET,
) -> Blocks:
    """Plan (bh, block_size, H) for the chunked-prefill kernel.

    Same single knob as the decode kernel — KV heads streamed per grid
    step — but a prefill step additionally holds the whole chunk's
    queries, fresh K/V rows and the (Lc-deep) softmax scratch in VMEM,
    so the head budget is charged double relative to decode."""
    bh = m
    while bh > 1 and 16 * n * bh * k > vmem_budget:
        bh = max(d for d in range(1, bh) if m % d == 0)
    return bh, n, k


_PLANNERS: Dict[str, Callable[..., Blocks]] = {
    "bitplane_matmul": pick_matmul_blocks,
    "fused_matmul": pick_fused_blocks,
    "paged_attention": pick_paged_attention_blocks,
    "paged_prefill": pick_paged_prefill_blocks,
}

# Per-op autotune candidate generators; ops without an entry fall back to
# the generic matmul-style (bm, bk) factor sweep.
_CANDIDATES: Dict[str, Callable[..., list]] = {
    "paged_attention": _paged_attention_candidates,
    "paged_prefill": _paged_attention_candidates,
}


class KernelRegistry:
    """Kernel-backend dispatch + memoized per-shape block-plan cache.

    Every op in `repro.kernels.ops` resolves its backend here: `interpret`
    (Pallas interpret mode, the CPU default), `mosaic` (TPU lowering), or
    `reference` (pure-jnp oracles). Select globally with `set_active`,
    scoped with the `use(name)` / `repro.kernels.use_backend(name)`
    context manager, or per call via `backend=` on any op; `register(
    KernelBackend(...))` adds a new backend (e.g. a GPU Triton port) that
    every call site dispatches to immediately.

    Tiled ops memoize a per-(op, shape, backend) block plan: `plan` serves
    the heuristic, `autotune` measures candidate plans once and pins the
    winner, `record_plan` injects measured plans (e.g. a TPU sweep), and
    `save_plans`/`load_plans` persist the cache as JSON keyed by
    op/shape/backend so winners survive restarts (`serve --plans FILE`)."""

    def __init__(self, backends: Iterable[KernelBackend] = _DEFAULT_BACKENDS):
        self._backends: Dict[str, KernelBackend] = {}
        for b in backends:
            self.register(b)
        self._active: Optional[str] = None
        self._plans: Dict[Tuple[str, str, Blocks], Blocks] = {}
        self._plan_hits = 0
        self._plan_misses = 0

    # -- backends ----------------------------------------------------------

    def register(self, backend: KernelBackend, overwrite: bool = False) -> None:
        if backend.name in self._backends and not overwrite:
            raise ValueError(f"backend {backend.name!r} already registered")
        self._backends[backend.name] = backend

    def get(self, name: str) -> KernelBackend:
        try:
            return self._backends[name]
        except KeyError:
            raise KeyError(
                f"unknown kernel backend {name!r}; registered: {self.names()}"
            ) from None

    def names(self) -> Tuple[str, ...]:
        return tuple(self._backends)

    def default_name(self) -> str:
        """Platform default: Mosaic on real TPUs, interpret elsewhere."""
        import jax

        return "mosaic" if jax.default_backend() == "tpu" else "interpret"

    @property
    def active(self) -> KernelBackend:
        return self.get(self._active or self.default_name())

    def set_active(self, name: str) -> None:
        self.get(name)  # validate
        self._active = name

    @contextlib.contextmanager
    def use(self, name: str):
        """Scoped backend selection (restores the previous choice on exit)."""
        prev = self._active
        self.set_active(name)
        try:
            yield self.get(name)
        finally:
            self._active = prev

    def resolve(self, backend: Union[None, str, KernelBackend]) -> KernelBackend:
        if backend is None:
            return self.active
        if isinstance(backend, KernelBackend):
            return backend
        return self.get(backend)

    # -- block plans -------------------------------------------------------

    def plan(
        self,
        op: str,
        m: int,
        n: int,
        k: int,
        backend: Union[None, str, KernelBackend] = None,
    ) -> Blocks:
        """Memoized (bm, bn, bk) for `op` at shape (m, n, k) on `backend`."""
        be = self.resolve(backend)
        key = (op, be.name, (m, n, k))
        hit = self._plans.get(key)
        if hit is not None:
            self._plan_hits += 1
            return hit
        self._plan_misses += 1
        try:
            planner = _PLANNERS[op]
        except KeyError:
            raise KeyError(f"no block planner for op {op!r}") from None
        blocks = planner(
            m, n, k, m_align=be.m_align, n_align=be.n_align, k_align=be.k_align
        )
        self._plans[key] = blocks
        return blocks

    def matmul_plan(self, m, n, k, backend=None) -> Blocks:
        return self.plan("bitplane_matmul", m, n, k, backend)

    def fused_matmul_plan(self, m, n, k, backend=None) -> Blocks:
        return self.plan("fused_matmul", m, n, k, backend)

    def paged_attention_plan(self, n_kv, block_size, head_dim,
                             backend=None) -> Blocks:
        return self.plan("paged_attention", n_kv, block_size, head_dim,
                         backend)

    def paged_prefill_plan(self, n_kv, block_size, head_dim,
                           backend=None) -> Blocks:
        return self.plan("paged_prefill", n_kv, block_size, head_dim,
                         backend)

    def record_plan(
        self, op: str, m: int, n: int, k: int, blocks: Blocks, backend=None
    ) -> None:
        """Pin an explicit plan (autotune winners land here)."""
        be = self.resolve(backend)
        self._plans[(op, be.name, (m, n, k))] = tuple(blocks)

    def autotune(
        self,
        op: str,
        m: int,
        n: int,
        k: int,
        run: Callable[[Blocks], None],
        candidates: Optional[Sequence[Blocks]] = None,
        backend=None,
        repeat: int = 2,
    ) -> Blocks:
        """Measure candidate block plans and memoize the fastest.

        `run(blocks)` must execute the kernel to completion (block_until_ready)
        for one candidate. Already-tuned shapes return the cached winner
        without re-measuring. Failing candidates are skipped; the heuristic
        plan is always included so autotune can only improve on it.
        """
        be = self.resolve(backend)
        key = (op, be.name, (m, n, k))
        cached = self._plans.get(key)
        if cached is not None:
            return cached
        heur = _PLANNERS[op](
            m, n, k, m_align=be.m_align, n_align=be.n_align, k_align=be.k_align
        )
        if candidates:
            cands = list(candidates)
        elif op in _CANDIDATES:
            cands = _CANDIDATES[op](heur, m, n, k, be)
        else:
            cands = self._default_candidates(heur, m, n, k, be)
        if heur not in cands:
            cands.insert(0, heur)
        best: Optional[Tuple[float, Blocks]] = None
        for cand in cands:
            try:
                run(cand)  # warmup / compile outside the timed region
                t = min(
                    self._time_one(run, cand) for _ in range(max(1, repeat))
                )
            except Exception:
                continue
            if best is None or t < best[0]:
                best = (t, cand)
        if best is None:
            raise RuntimeError(f"autotune: no candidate ran for {op} {m}x{n}x{k}")
        self._plans[key] = best[1]
        return best[1]

    @staticmethod
    def _time_one(run: Callable[[Blocks], None], cand: Blocks) -> float:
        t0 = time.perf_counter()
        run(cand)
        return time.perf_counter() - t0

    @staticmethod
    def _default_candidates(heur: Blocks, m, n, k, be: KernelBackend):
        bm, bn, bk = heur
        cands = []
        for fm in (1, 2):
            for fk in (1, 2, 4):
                c = (
                    max(be.m_align, min(round_up(m, be.m_align), bm * fm)),
                    bn,
                    max(be.k_align, min(round_up(k, be.k_align), bk * fk)),
                )
                if c not in cands:
                    cands.append(c)
        return cands

    def cache_info(self) -> dict:
        return {
            "plans": len(self._plans),
            "hits": self._plan_hits,
            "misses": self._plan_misses,
        }

    def clear_plans(self) -> None:
        self._plans.clear()
        self._plan_hits = self._plan_misses = 0

    # -- plan persistence --------------------------------------------------

    def save_plans(self, path) -> int:
        """Write the block-plan cache to `path` as JSON (keyed by
        op/shape/backend), so autotune winners survive process restarts.
        Returns the number of plans written."""
        entries = [
            {"op": op, "backend": be, "shape": list(shape),
             "blocks": list(blocks)}
            for (op, be, shape), blocks in sorted(self._plans.items())
        ]
        Path(path).write_text(
            json.dumps({"version": 1, "plans": entries}, indent=2) + "\n"
        )
        return len(entries)

    def load_plans(self, path) -> int:
        """Merge plans from a `save_plans` JSON file into the cache
        (loaded plans overwrite heuristic entries, like `record_plan`).
        Returns the number of plans loaded. NEVER raises on bad input —
        a missing, truncated, or corrupt file and an unsupported schema
        version each warn and load 0 plans, because a stale cache must
        not take down a process that can simply re-autotune (the same
        cold-start contract as the serving prefix index)."""
        import warnings

        try:
            obj = json.loads(Path(path).read_text())
        except (OSError, ValueError) as e:
            warnings.warn(f"plan-cache load from {path!s} failed ({e}) — "
                          "cold start")
            return 0
        if not isinstance(obj, dict) or obj.get("version") != 1:
            got = obj.get("version") if isinstance(obj, dict) else None
            warnings.warn(f"unsupported plan-cache version in {path!s}: "
                          f"{got!r} — cold start")
            return 0
        loaded = {}
        try:
            for e in obj["plans"]:
                key = (e["op"], e["backend"],
                       tuple(int(x) for x in e["shape"]))
                loaded[key] = tuple(int(x) for x in e["blocks"])
        except (KeyError, TypeError, ValueError) as e:
            warnings.warn(f"corrupt plan-cache entry in {path!s} ({e}) — "
                          "cold start")
            return 0
        self._plans.update(loaded)
        return len(loaded)


_REGISTRY = KernelRegistry()


def get_registry() -> KernelRegistry:
    """The process-wide registry every public op dispatches through."""
    return _REGISTRY


def use_backend(name: str):
    """Convenience: ``with use_backend("reference"): ...``"""
    return _REGISTRY.use(name)
