"""repro — M4BRAM (mixed-precision matmul in FPGA BRAMs) reproduced and
adapted as a production JAX/TPU training + serving framework.

Subpackages:
  core      — the paper's technique (quantization, bit-serial MAC2, block
              model, hetero partitioner, cycle-accurate simulator, DSE)
  kernels   — Pallas TPU kernels (bit-plane matmul, pack/quant, wkv6, ...)
  models    — 10-arch model zoo (dense GQA, MoE, RWKV6, griffin, encoder, VLM)
  parallel  — sharding rules (DP/TP/FSDP/EP/SP) + compressed collectives
  data      — deterministic, checkpointable synthetic LM pipeline
  optim     — AdamW + schedules (from scratch)
  checkpoint— atomic, elastic checkpoint manager
  train     — fault-tolerant training loop
  serving   — continuous-batching scheduler + engine, on-device sampling
  configs   — assigned architecture configs + shape sets
  launch    — production mesh, multi-pod dry-run, train/serve drivers
  roofline  — TPU v5e roofline term extraction from compiled artifacts
"""

__version__ = "1.0.0"
