"""Heterogeneous BPE/DSP workload partitioning (paper §IV-H).

In Hetero-DLA the output-pixel tile dimension ``Q_VEC`` is split between the
bit-serial engine (all M4BRAM BPEs; latency ∝ activation bits) and the
bit-parallel engine (all DSPs; 1 MAC2/cycle/DSP with packing). The optimal
split equalizes the two engines' tile latencies — the tile completes at
``max(t_bpe, t_dsp)`` (§IV-H), so imbalance directly wastes cycles.

This module provides the static partitioner used by both the performance
simulator (faithful reproduction) and the TPU mixed-precision group split
(Table III analogue): given per-unit throughputs it returns the split and
the resulting latency, plus utilities to balance intra-layer 4b/8b filter
groups across two compute paths.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class EngineRate:
    """Effective MACs/cycle of one engine for a given precision config."""

    name: str
    macs_per_cycle: float
    fixed_overhead_cycles: float = 0.0


def split_q(q_total: int, bpe: EngineRate, dsp: EngineRate) -> Tuple[int, int]:
    """Split Q_VEC units between BPE and DSP proportionally to throughput.

    Returns (q_bpe, q_dsp) with q_bpe + q_dsp == q_total. Degenerate rates
    (a disabled engine) route everything to the other engine.
    """
    if q_total <= 0:
        return 0, 0
    tot = bpe.macs_per_cycle + dsp.macs_per_cycle
    if tot <= 0:
        raise ValueError("both engines have zero throughput")
    if bpe.macs_per_cycle <= 0:
        return 0, q_total
    if dsp.macs_per_cycle <= 0:
        return q_total, 0
    q_bpe = int(round(q_total * bpe.macs_per_cycle / tot))
    q_bpe = max(0, min(q_total, q_bpe))
    return q_bpe, q_total - q_bpe


def tile_latency(
    work_macs: float, q_total: int, bpe: EngineRate, dsp: EngineRate
) -> Tuple[float, int, int]:
    """Latency (cycles) of a tile split along Q_VEC; returns (t, q_bpe, q_dsp).

    `work_macs` is the MAC count of the whole tile; each engine gets the
    fraction of MACs proportional to its share of Q, and the tile latency is
    the max of the two (plus each engine's fixed overhead) — Fig. 8(c).
    """
    q_bpe, q_dsp = split_q(q_total, bpe, dsp)
    t_bpe = (
        (work_macs * q_bpe / max(q_total, 1)) / bpe.macs_per_cycle + bpe.fixed_overhead_cycles
        if q_bpe
        else 0.0
    )
    t_dsp = (
        (work_macs * q_dsp / max(q_total, 1)) / dsp.macs_per_cycle + dsp.fixed_overhead_cycles
        if q_dsp
        else 0.0
    )
    return max(t_bpe, t_dsp), q_bpe, q_dsp


def balanced_group_ratio(rate_8b: float, rate_lowb: float) -> float:
    """TPU analogue: fraction of output channels to place in the 8-bit group
    so that both precision paths finish together when run as two matmuls.

    With per-channel cost 1/rate, equal finish time ⇒
    R / rate_8b = (1-R) / rate_lowb ⇒ R = rate_8b / (rate_8b + rate_lowb).
    """
    if rate_8b <= 0:
        return 0.0
    if rate_lowb <= 0:
        return 1.0
    return rate_8b / (rate_8b + rate_lowb)


def utilization(q_total: int, n_units: int, unit_q: int) -> float:
    """Spatial utilization of `n_units` engines each covering `unit_q`
    outputs when `q_total` outputs exist — the quantity M4BRAM's (N_W, N_I)
    flexibility optimizes (Fig. 4 / §IV-C, Intel study [28])."""
    if q_total <= 0 or n_units <= 0 or unit_q <= 0:
        return 0.0
    per_pass = n_units * unit_q
    passes = -(-q_total // per_pass)
    return q_total / (passes * per_pass)
