"""Design-space exploration for tiling configurations (paper §V-A).

Searches (C_VEC, K_VEC, Q_VEC) power-of-two tiles plus the CIM lane config
(N_W, N_I) per network, subject to the FPGA's DSP/BRAM budgets, maximizing
the paper's objective perf × (perf/area). One tiling per network (DLA is a
static overlay; the tile shape is fixed at compile time, the lane config is
a per-layer runtime knob — we pick the best per layer, matching M4BRAM's
runtime-configurable duplication factor).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

from repro.core import simulate as sim
from repro.core.workloads import Layer

_POW2 = (1, 2, 4, 8, 16, 32, 64, 128, 256)


@dataclasses.dataclass
class DseResult:
    tile: sim.TileConfig
    cycles: float
    perf: float
    objective: float
    per_layer_ni: List[int]
    resources: Tuple[int, int]  # (dsp_used, bram_used)


def _candidate_tiles(fpga: sim.Fpga, pw: int, pa: int):
    packing = sim.dsp_packing(pw, pa)
    for c in _POW2:
        if c < 4:
            continue
        for k in _POW2:
            if k < 4:
                continue
            for q in (1, 2, 4, 8, 16, 32, 64):
                if fpga.n_dsp > 0 and c * k * q / packing > fpga.n_dsp * 1.05:
                    continue
                yield c, k, q


def search(
    layers: List[Layer],
    pw: int,
    pa: int,
    fpga: sim.Fpga,
    cim: Optional[sim.CimArch],
    pw8_fraction: float = 0.0,
    ni_restrict: Optional[Tuple[int, ...]] = None,
) -> DseResult:
    """Find the best tile config; per-layer N_I chosen greedily (runtime
    configurable in M4BRAM via DP-sram; BRAMAC archs have it fixed)."""
    best: Optional[DseResult] = None
    area = sim.area_cost(fpga, cim)
    lane_cfgs = [(1, 1)]
    if cim is not None:
        opts = cim.nw_options(pw)
        if ni_restrict is not None:
            opts = tuple((nw, ni) for nw, ni in opts if ni in ni_restrict)
        lane_cfgs = list(opts) or [(cim.lanes(pw), 1)]

    for c, k, q in _candidate_tiles(fpga, pw, pa):
        tile0 = sim.TileConfig(c, k, q)
        if not sim.fits(tile0, layers[0], pw, pa, fpga, cim):
            continue
        # Static Q_VEC split (baked into the compiled overlay): search it.
        q_bpe_options = [0] if cim is None else sorted(
            {0, q // 4, q // 2, (3 * q) // 4, q - 1, q}
        )
        for q_bpe in q_bpe_options:
            if q_bpe < 0:
                continue
            total = 0.0
            per_layer_ni = []
            feasible = True
            for layer in layers:
                best_layer = None
                for nw, ni in lane_cfgs:
                    tile = sim.TileConfig(c, k, q, nw, ni, q_bpe)
                    if not sim.fits(tile, layer, pw, pa, fpga, cim):
                        feasible = False
                        break
                    r = sim.simulate_layer(layer, tile, pw, pa, fpga, cim,
                                           pw8_fraction)
                    if best_layer is None or r.cycles < best_layer[0]:
                        best_layer = (r.cycles, ni)
                if not feasible or best_layer is None:
                    feasible = False
                    break
                total += best_layer[0]
                per_layer_ni.append(best_layer[1])
            if not feasible or total <= 0:
                continue
            perf = 1.0 / total
            obj = perf * (perf / area)
            if best is None or obj > best.objective:
                tile = sim.TileConfig(c, k, q, q_bpe=q_bpe)
                packing = sim.dsp_packing(pw, pa)
                max_layer = max(layers, key=lambda l: l.C * l.K * l.R * l.S)
                n_bram, _ = sim.resource_usage(tile, max_layer, pw, cim, fpga)
                best = DseResult(
                    tile=tile, cycles=total, perf=perf, objective=obj,
                    per_layer_ni=per_layer_ni,
                    resources=(sim.dsp_needed(tile, packing), n_bram),
                )
    if best is None:
        raise RuntimeError("DSE found no feasible tiling")
    return best


def speedup(
    layers: List[Layer],
    pw: int,
    pa: int,
    fpga: sim.Fpga,
    cim: sim.CimArch,
    baseline_pw: Optional[int] = None,
    baseline_pa: Optional[int] = None,
    pw8_fraction: float = 0.0,
    ni_restrict: Optional[Tuple[int, ...]] = None,
) -> float:
    """Hetero-DLA(cim) speedup over plain DLA at (baseline_pw, baseline_pa)
    (defaults: same precision — the paper's Fig 9/10 setting)."""
    base = search(layers, baseline_pw or pw, baseline_pa or pa, fpga, None)
    het = search(layers, pw, pa, fpga, cim, pw8_fraction, ni_restrict)
    return base.cycles / het.cycles
