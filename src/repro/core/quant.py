"""Uniform symmetric quantization (paper §V-A) with MAE-optimal clipping.

The paper quantizes FP32 models to fixed point with *uniform symmetric*
quantization, choosing clipping thresholds that minimize the mean absolute
error (MAE) between the original and quantized tensors, with activation
statistics estimated from a large random batch. We implement exactly that,
plus:

  * straight-through-estimator (STE) fake-quant for fine-tuning (the paper
    fine-tunes with Adam, lr 1e-5, cosine decay),
  * per-tensor and per-channel granularity,
  * the intra-layer weight quantization of Table III: output channels are
    partitioned into two filter groups quantized at 4-bit and 8-bit with a
    configurable ratio R of 8-bit filters, each group quantized individually.

All functions are pure and jit-friendly; nothing here touches device state.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# Supported precisions (paper: weights 2/4/8-bit; activations 2..8-bit).
WEIGHT_BITS = (2, 4, 8)
ACT_BITS = tuple(range(2, 9))


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Quantization configuration for one linear layer (or a whole model).

    Attributes:
      w_bits: weight precision; one of (2, 4, 8). The paper stores this in
        configuration SRAM — static per layer.
      a_bits: activation precision in [2, 8]. Run-time configurable in the
        paper (CIM instruction `inClr` path); a traced argument here.
      per_channel: quantize weights per output channel (axis=-1 scale vector)
        instead of per tensor.
      mixed_ratio_8b: Table III intra-layer mixing — fraction R of output
        channels kept at 8-bit while the rest use `w_bits`. 0.0 disables.
      symmetric: always True in the paper; kept for interface clarity.
      act_signed: whether activations are signed (paper: the INV row handles
        signed activations; post-ReLU CNN activations are unsigned, attention
        activations are signed).
    """

    w_bits: int = 8
    a_bits: int = 8
    per_channel: bool = True
    mixed_ratio_8b: float = 0.0
    symmetric: bool = True
    act_signed: bool = True

    def __post_init__(self):
        if self.w_bits not in WEIGHT_BITS:
            raise ValueError(f"w_bits must be one of {WEIGHT_BITS}, got {self.w_bits}")
        if self.a_bits not in ACT_BITS:
            raise ValueError(f"a_bits must be in {ACT_BITS}, got {self.a_bits}")
        if not (0.0 <= self.mixed_ratio_8b <= 1.0):
            raise ValueError("mixed_ratio_8b must be in [0, 1]")


def qmax(bits: int, signed: bool = True) -> int:
    """Largest representable magnitude for a `bits`-bit integer code."""
    return (1 << (bits - 1)) - 1 if signed else (1 << bits) - 1


def qmin(bits: int, signed: bool = True) -> int:
    return -(1 << (bits - 1)) if signed else 0


def quantize(
    x: jax.Array,
    scale: jax.Array,
    bits: int,
    signed: bool = True,
) -> jax.Array:
    """Quantize to integer codes: round(x / scale) clipped to the code range.

    Symmetric: zero-point is always 0 (paper uses uniform symmetric).
    Returns int32 codes (callers pack to narrower storage as needed).
    """
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    q = jnp.round(x * inv)
    return jnp.clip(q, qmin(bits, signed), qmax(bits, signed)).astype(jnp.int32)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(scale.dtype) * scale


def _mae(x: jax.Array, xq: jax.Array, axis=None) -> jax.Array:
    return jnp.mean(jnp.abs(x - xq), axis=axis)


def mae_optimal_scale(
    x: jax.Array,
    bits: int,
    signed: bool = True,
    axis: Optional[int] = None,
    num_candidates: int = 32,
) -> jax.Array:
    """Clipping-threshold search minimizing MAE (paper §V-A).

    Candidate thresholds are a geometric sweep of fractions of |x|max
    (the standard minimum-error clipping search, cf. Banner et al. [4]).
    `axis=None` → per-tensor scalar scale; `axis=k` → per-channel scales
    along axis k (reduced over all other axes).

    Pure-jnp and differentiable-free (used under lax.stop_gradient in QAT).
    """
    if axis is None:
        absmax = jnp.max(jnp.abs(x))
        reduce_axes = None
    else:
        reduce_axes = tuple(i for i in range(x.ndim) if i != axis)
        absmax = jnp.max(jnp.abs(x), axis=reduce_axes, keepdims=True)

    q_hi = qmax(bits, signed)
    # Fractions from 0.35 to 1.0 of absmax — low-bit benefits from aggressive
    # clipping, 8-bit usually picks ~1.0.
    fracs = jnp.linspace(0.35, 1.0, num_candidates)

    def err_for(frac):
        scale = absmax * frac / q_hi
        xq = dequantize(quantize(x, scale, bits, signed), scale)
        return _mae(x, xq, axis=reduce_axes)

    errs = jax.vmap(err_for)(fracs)  # (num_candidates, ...) per-channel errs
    best = jnp.argmin(errs, axis=0)
    best_frac = fracs[best]
    scale = absmax * best_frac / q_hi
    if axis is None:
        return scale
    return scale  # keepdims shape broadcastable against x


def quantize_tensor(
    x: jax.Array,
    bits: int,
    signed: bool = True,
    axis: Optional[int] = None,
    optimal_clip: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """One-shot (codes, scale) quantization of a tensor.

    optimal_clip=False uses plain absmax scaling (cheaper; used for
    activations on the hot path where the paper estimates statistics offline).
    """
    if optimal_clip:
        scale = mae_optimal_scale(x, bits, signed, axis=axis)
    else:
        if axis is None:
            absmax = jnp.max(jnp.abs(x))
        else:
            reduce_axes = tuple(i for i in range(x.ndim) if i != axis)
            absmax = jnp.max(jnp.abs(x), axis=reduce_axes, keepdims=True)
        scale = absmax / qmax(bits, signed)
    return quantize(x, scale, bits, signed), scale


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def fake_quant(x: jax.Array, bits: int, signed: bool = True, axis: Optional[int] = None):
    """Quantize-dequantize with a straight-through estimator.

    Forward: absmax symmetric quant-dequant (statistics computed on the fly,
    matching the paper's fine-tuning where thresholds are fixed offline but
    the STE passes gradients through the rounding).
    Backward: identity inside the clip range, zero outside.
    """
    q, scale = quantize_tensor(x, bits, signed, axis=axis, optimal_clip=False)
    return dequantize(q, scale).astype(x.dtype)


def _fake_quant_fwd(x, bits, signed, axis):
    q, scale = quantize_tensor(x, bits, signed, axis=axis, optimal_clip=False)
    y = dequantize(q, scale).astype(x.dtype)
    # Save the clip mask: gradient flows only where |x| <= clip threshold.
    thr = scale * qmax(bits, signed)
    mask = (jnp.abs(x) <= thr).astype(x.dtype)
    return y, mask


def _fake_quant_bwd(bits, signed, axis, mask, g):
    return (g * mask,)


fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)


def split_filter_groups(n_out: int, ratio_8b: float) -> Tuple[int, int]:
    """Table III intra-layer split: (n_8bit, n_lowbit) output channels.

    The paper partitions weights into two slices along the output dimension
    and quantizes each individually. We round the 8-bit group up to the
    nearest multiple of 8 lanes so the packed layouts stay aligned (the
    hardware analogue: filter groups map to whole M4BRAM columns).
    """
    n8 = int(round(n_out * ratio_8b))
    if 0 < ratio_8b:
        n8 = max(8, n8)
        n8 = min(n_out, ((n8 + 7) // 8) * 8)
    return n8, n_out - n8


def quantize_weights_mixed(
    w: jax.Array, cfg: QuantConfig
) -> Tuple[jax.Array, jax.Array, int]:
    """Intra-layer mixed quantization of a (..., n_out) weight matrix.

    Returns (codes int32, scale, n8) where the first n8 output channels are
    8-bit codes and the remainder are cfg.w_bits codes. Channel order is
    preserved (the caller may pre-permute by sensitivity; the paper selects
    groups during mixed-precision training).
    """
    n_out = w.shape[-1]
    n8, _ = split_filter_groups(n_out, cfg.mixed_ratio_8b)
    axis = w.ndim - 1 if cfg.per_channel else None
    if n8 == 0:
        q, s = quantize_tensor(w, cfg.w_bits, True, axis=axis)
        return q, s, 0
    if n8 == n_out:
        q, s = quantize_tensor(w, 8, True, axis=axis)
        return q, s, n8
    w8, wl = w[..., :n8], w[..., n8:]
    q8, s8 = quantize_tensor(w8, 8, True, axis=axis)
    ql, sl = quantize_tensor(wl, cfg.w_bits, True, axis=axis)
    q = jnp.concatenate([q8, ql], axis=-1)
    if axis is None:
        s8 = jnp.broadcast_to(s8, (1,) * (w.ndim - 1) + (n8,))
        sl = jnp.broadcast_to(sl, (1,) * (w.ndim - 1) + (n_out - n8,))
    s = jnp.concatenate([s8, sl], axis=-1)
    return q, s, n8


def quant_error_stats(x: jax.Array, bits: int, signed: bool = True) -> dict:
    """Diagnostics: MAE / RMSE / SQNR of quantizing `x` at `bits` bits."""
    q, scale = quantize_tensor(x, bits, signed)
    xq = dequantize(q, scale)
    err = x - xq
    mae = jnp.mean(jnp.abs(err))
    rmse = jnp.sqrt(jnp.mean(err**2))
    sig = jnp.sqrt(jnp.mean(x**2))
    sqnr_db = 20.0 * jnp.log10(jnp.where(rmse > 0, sig / rmse, jnp.inf))
    return {"mae": mae, "rmse": rmse, "sqnr_db": sqnr_db}
