"""Functional model of one M4BRAM block (paper §IV).

This is a *behavioural* model — numpy/jnp state, no timing — used to
property-test the architecture's dataflow end-to-end:

  memory mode : plain 512×32b simple dual-port RAM (M20K compute-mode
                geometry, §IV-B) with byte enables.
  compute mode: port-A writes double as CIM instructions when `wenB` is
                asserted; the duplication shuffler (Fig. 5) slices/replicates
                the 32-bit weight vector across the 4 BPEs; each BPE runs the
                bit-serial MAC2 of :mod:`repro.core.bitserial` and
                accumulates into its ACC row; port-B reads results out while
                remaining available for "DSP" reads of the main array —
                the one-port property that distinguishes M4BRAM from BRAMAC.

Timing (cycles, stalls, double-pumping) lives in :mod:`repro.core.simulate`;
geometry and precision legality live here.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import bitserial

MAIN_ROWS = 512          # compute-mode depth (§IV-B)
MAIN_WIDTH_BITS = 32     # compute-mode data width (§IV-B)
NUM_BPE = 4              # §IV-A
DUMMY_ROWS = 7           # §IV-C
SLICE_BITS = 8           # 32-bit vector → 4 slices A,B,C,D (Fig. 5)


@dataclasses.dataclass(frozen=True)
class M4BramGeometry:
    """M4BRAM-S vs M4BRAM-L (§IV-G, Table II)."""

    name: str
    dummy_cols: int            # 32 (S) or 64 (L)
    area_overhead: float       # vs M20K (§V-B)
    critical_path_ps: float    # §V-B

    @property
    def large(self) -> bool:
        return self.dummy_cols == 64

    def lanes(self, pw: int) -> int:
        return bitserial.lanes_per_block(pw, self.large)

    def weight_vectors_per_read(self) -> int:
        # M4BRAM-L banks the main array 2× to fetch two 32-bit vectors.
        return 2 if self.large else 1

    def readout_stall_cycles(self) -> int:
        """DSP stall when a dot product is read out (§IV-H): 4 (S) / 8 (L)."""
        return 8 if self.large else 4


M4BRAM_S = M4BramGeometry("M4BRAM-S", 32, 0.196, 903.0)
M4BRAM_L = M4BramGeometry("M4BRAM-L", 64, 0.334, 925.0)


@dataclasses.dataclass(frozen=True)
class CimInstruction:
    """One CIM instruction (Fig. 6). Two are issued per MAC2 (2 eFSM cycles).

    addr_row/addr_col : location of the weight vector in the main array.
    addr_dp           : 2-bit slice select for the duplication shuffler.
    activations       : the 4 input activations carried in port-A data.
    in_clr            : precision/sign reconfiguration flag (byte-enable
                        encoding); when set, `a_bits`/`act_signed` update
                        the eFSM state for subsequent MAC2s.
    accumulate        : keep accumulating into the ACC row vs clear first.
    """

    addr_row: int
    addr_col: int = 0
    addr_dp: int = 0
    activations: Tuple[int, int, int, int] = (0, 0, 0, 0)
    in_clr: bool = False
    a_bits: Optional[int] = None
    act_signed: Optional[bool] = None
    accumulate: bool = True


@dataclasses.dataclass
class M4BramConfig:
    """Configuration-SRAM state (static per compute phase)."""

    geometry: M4BramGeometry = M4BRAM_S
    w_bits: int = 8          # config SRAM (§IV-B) — static
    dp_factor: int = 1       # DP-sram: N_I ∈ {1, 2, 4} (Fig. 5)
    double_pumped: bool = False

    def __post_init__(self):
        if self.w_bits not in (2, 4, 8):
            raise ValueError("w_bits must be 2/4/8")
        if self.dp_factor not in (1, 2, 4):
            raise ValueError("dp_factor (N_I) must be 1/2/4")


def _signext(v: int, bits: int) -> int:
    v &= (1 << bits) - 1
    return v - (1 << bits) if v & (1 << (bits - 1)) else v


class M4BramBlock:
    """One M4BRAM block with a numpy main array and 4 BPE accumulators."""

    def __init__(self, config: M4BramConfig):
        self.cfg = config
        self.mem = np.zeros(MAIN_ROWS, dtype=np.uint32)  # 512 × 32b
        self.mode = "memory"
        # eFSM dynamic state (set via in_clr instructions)
        self.a_bits = 8
        self.act_signed = True
        # Per-BPE, per-lane accumulators (the last dummy row).
        lanes_per_bpe = self.cfg.geometry.lanes(self.cfg.w_bits) // NUM_BPE
        self.acc = np.zeros((NUM_BPE, lanes_per_bpe), dtype=np.int64)
        self._pending: Optional[CimInstruction] = None

    # ------------------------------------------------------------------ #
    # Memory mode (also fully available in compute mode through port-B /
    # the free write port — asserted by tests).
    # ------------------------------------------------------------------ #
    def write(self, addr: int, data: int, byte_enable: int = 0xF) -> None:
        old = int(self.mem[addr])
        new = int(data) & 0xFFFFFFFF
        out = 0
        for b in range(4):
            sel = new if (byte_enable >> b) & 1 else old
            out |= sel & (0xFF << (8 * b))
        self.mem[addr] = out

    def read(self, addr: int) -> int:
        return int(self.mem[addr])

    def write_weight_vector(self, addr: int, codes: Sequence[int]) -> None:
        """Pack `w_bits`-bit signed codes little-endian into one 32b word."""
        pw = self.cfg.w_bits
        assert len(codes) == MAIN_WIDTH_BITS // pw
        word = 0
        for j, c in enumerate(codes):
            word |= (int(c) & ((1 << pw) - 1)) << (j * pw)
        self.write(addr, word)

    def _read_weight_codes(self, addr: int) -> List[int]:
        pw = self.cfg.w_bits
        word = self.read(addr)
        return [_signext(word >> (j * pw), pw) for j in range(MAIN_WIDTH_BITS // pw)]

    # ------------------------------------------------------------------ #
    # Compute mode
    # ------------------------------------------------------------------ #
    def set_mode(self, mode: str) -> None:
        assert mode in ("memory", "compute")
        self.mode = mode

    def clear_acc(self) -> None:
        self.acc[:] = 0

    def _shuffle(self, vec_codes: List[int]) -> List[List[int]]:
        """Duplication shuffler (Fig. 5): 32b → 4 slices; replicate by N_I.

        Returns per-BPE weight-code lists. With dp=1 BPE b gets slice b;
        with dp=2 slices are duplicated pairwise; with dp=4 one slice is
        broadcast to all BPEs (addr_dp selects which).
        """
        pw = self.cfg.w_bits
        per_slice = SLICE_BITS // pw if pw <= SLICE_BITS else 1
        codes_per_vec = len(vec_codes)
        slices = [
            vec_codes[s * per_slice : (s + 1) * per_slice]
            for s in range(codes_per_vec // per_slice)
        ]
        dp = self.cfg.dp_factor
        adp = self._addr_dp
        if dp == 1:
            sel = [slices[b % len(slices)] for b in range(NUM_BPE)]
        elif dp == 2:
            base = (adp // 2) * 2
            sel = [slices[(base + (b // 2)) % len(slices)] for b in range(NUM_BPE)]
        else:  # dp == 4: broadcast addr_dp's slice
            sel = [slices[adp % len(slices)] for _ in range(NUM_BPE)]
        return sel

    def issue_mac2(self, inst1: CimInstruction, inst2: CimInstruction) -> np.ndarray:
        """Two CIM instructions → one MAC2 across all BPE lanes (§IV-E).

        inst1 carries (W-vector-1 address, I1 activations);
        inst2 carries (W-vector-2 address, I2 activations).
        Returns the (NUM_BPE, lanes_per_bpe) int64 accumulator snapshot.
        """
        assert self.mode == "compute", "MAC2 requires compute mode"
        for inst in (inst1, inst2):
            if inst.in_clr:
                if inst.a_bits is not None:
                    if not 2 <= inst.a_bits <= 8:
                        raise ValueError("a_bits must be 2..8")
                    self.a_bits = inst.a_bits
                if inst.act_signed is not None:
                    self.act_signed = inst.act_signed
        self._addr_dp = inst1.addr_dp
        w1 = self._read_weight_codes(inst1.addr_row)
        w2 = self._read_weight_codes(inst2.addr_row)
        per_bpe_w1 = self._shuffle(w1)
        per_bpe_w2 = self._shuffle(w2)
        if not inst1.accumulate:
            self.clear_acc()
        import jax.numpy as jnp

        for b in range(NUM_BPE):
            i1 = int(inst1.activations[b])
            i2 = int(inst2.activations[b])
            lw1 = per_bpe_w1[b][: self.acc.shape[1]]
            lw2 = per_bpe_w2[b][: self.acc.shape[1]]
            res = bitserial.mac2_bitserial(
                jnp.array(lw1, jnp.int32),
                jnp.array(lw2, jnp.int32),
                jnp.int32(i1),
                jnp.int32(i2),
                self.a_bits,
                self.act_signed,
            )
            self.acc[b, : len(lw1)] += np.asarray(res, np.int64)
        return self.acc.copy()

    def read_result(self) -> np.ndarray:
        """Port-B result readout (stalls the DSP per geometry; timing in
        simulate.py). Returns and clears the accumulators."""
        out = self.acc.copy()
        self.clear_acc()
        return out
