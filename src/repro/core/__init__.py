"""repro.core — M4BRAM's contribution as composable JAX modules.

Layers:
  quant            : uniform symmetric quantization + MAE-optimal clipping
  bitplane         : bit-plane decomposition, sub-byte packing
  bitserial        : cycle-exact MAC2 / bit-serial dot semantics (the oracle)
  m4bram           : functional block model (modes, shuffler, instructions)
  quantized_linear : the technique as a drop-in matmul for the model zoo
  precision        : per-layer PrecisionPolicy (policy → packed leaves)
  hetero           : BPE/DSP workload partitioning (Q_VEC split)
  simulate         : cycle-accurate DLA / Hetero-DLA / BRAMAC simulator
  dse              : tiling design-space exploration (perf × perf/area)
  workloads        : the paper's DNN benchmark layer tables
"""
from repro.core.precision import (  # noqa: F401
    LayerRule,
    PrecisionPolicy,
    parse_policy_spec,
    parse_quant_token,
    policy_from_dse,
)
from repro.core.quant import QuantConfig, fake_quant, quantize_tensor  # noqa: F401
from repro.core.quantized_linear import (  # noqa: F401
    PackedWeight,
    pack_weight,
    qmatmul,
    quantize_params_for_serving,
)
