"""DNN benchmark layer tables (paper §V-A): AlexNet, VGG-16, ResNet-18/34,
and one ViT-Base self-attention module (matmuls as 1×1 convs, per [28]).

Each layer is (C_in, K_out, R, S, P, Q): filter R×S, output P×Q. FC and
matmul layers use R=S=1 with the GEMM M dimension as P·Q. Batch = 1
(DLA-style latency evaluation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List


@dataclasses.dataclass(frozen=True)
class Layer:
    name: str
    C: int   # input channels
    K: int   # output channels
    R: int   # filter height
    S: int   # filter width
    P: int   # output height
    Q: int   # output width

    @property
    def macs(self) -> int:
        return self.C * self.K * self.R * self.S * self.P * self.Q

    @property
    def dot_len(self) -> int:
        return self.C * self.R * self.S

    @property
    def out_pixels(self) -> int:
        return self.P * self.Q


def _conv(name, c, k, r, p) -> Layer:
    return Layer(name, c, k, r, r, p, p)


def _fc(name, c, k) -> Layer:
    return Layer(name, c, k, 1, 1, 1, 1)


def _mm(name, m, kdim, n) -> Layer:
    """GEMM M×K×N as 1D conv: C=K-dim, K=N, pixels=M."""
    return Layer(name, kdim, n, 1, 1, 1, m)


ALEXNET: List[Layer] = [
    _conv("conv1", 3, 64, 11, 55),
    _conv("conv2", 64, 192, 5, 27),
    _conv("conv3", 192, 384, 3, 13),
    _conv("conv4", 384, 256, 3, 13),
    _conv("conv5", 256, 256, 3, 13),
    _fc("fc6", 9216, 4096),
    _fc("fc7", 4096, 4096),
    _fc("fc8", 4096, 1000),
]

VGG16: List[Layer] = [
    _conv("conv1_1", 3, 64, 3, 224), _conv("conv1_2", 64, 64, 3, 224),
    _conv("conv2_1", 64, 128, 3, 112), _conv("conv2_2", 128, 128, 3, 112),
    _conv("conv3_1", 128, 256, 3, 56), _conv("conv3_2", 256, 256, 3, 56),
    _conv("conv3_3", 256, 256, 3, 56),
    _conv("conv4_1", 256, 512, 3, 28), _conv("conv4_2", 512, 512, 3, 28),
    _conv("conv4_3", 512, 512, 3, 28),
    _conv("conv5_1", 512, 512, 3, 14), _conv("conv5_2", 512, 512, 3, 14),
    _conv("conv5_3", 512, 512, 3, 14),
    _fc("fc6", 25088, 4096), _fc("fc7", 4096, 4096), _fc("fc8", 4096, 1000),
]


def _resnet_basic(stages: List[int]) -> List[Layer]:
    layers = [_conv("conv1", 3, 64, 7, 112)]
    c = 64
    sizes = [56, 28, 14, 7]
    chans = [64, 128, 256, 512]
    for si, (n_blocks, k, hw) in enumerate(zip(stages, chans, sizes)):
        for b in range(n_blocks):
            cin = c if b == 0 else k
            layers.append(_conv(f"s{si}b{b}_conv1", cin, k, 3, hw))
            layers.append(_conv(f"s{si}b{b}_conv2", k, k, 3, hw))
            if b == 0 and cin != k:
                layers.append(Layer(f"s{si}b{b}_down", cin, k, 1, 1, hw, hw))
        c = k
    layers.append(_fc("fc", 512, 1000))
    return layers


RESNET18 = _resnet_basic([2, 2, 2, 2])
RESNET34 = _resnet_basic([3, 4, 6, 3])

# ViT-Base self-attention: d=768, 12 heads, 197 tokens.
VIT_ATTENTION: List[Layer] = [
    _mm("qkv_proj", 197, 768, 2304),
    *[_mm(f"qk_h{h}", 197, 64, 197) for h in range(12)],
    *[_mm(f"av_h{h}", 197, 197, 64) for h in range(12)],
    _mm("out_proj", 197, 768, 768),
]

NETWORKS: Dict[str, List[Layer]] = {
    "alexnet": ALEXNET,
    "vgg16": VGG16,
    "resnet18": RESNET18,
    "resnet34": RESNET34,
    "vit-attn": VIT_ATTENTION,
}


def network_macs(name: str) -> int:
    return sum(l.macs for l in NETWORKS[name])
