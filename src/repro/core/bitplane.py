"""Bit-plane decomposition and sub-byte weight packing.

This is the data-layout half of the M4BRAM adaptation:

* The BPE consumes **two activation bits per cycle** ({I2[n], I1[n]}, §IV-F).
  `to_bitplanes(x, bits, plane_bits=2)` produces exactly those 2-bit planes;
  the bit-plane matmul kernel then reconstructs
      x = sum_p plane_p << (2p)   (with a sign correction for signed x)
  which mirrors the BPE's shift-accumulate over cycles.

* The 32-bit weight vector read from the main BRAM array holds 4×8b / 8×4b /
  16×2b weight elements (§IV-B, Fig. 7b). `pack_int{2,4}` reproduces that
  layout: little-endian within the storage word, sign-extended on unpack —
  matching the BPE's sign-extended weight rows.

Signed handling: for an n-bit two's-complement value the top plane carries
the sign. We decompose the *offset* representation instead: for signed x in
[-2^(n-1), 2^(n-1)-1], x + 2^(n-1) is unsigned in [0, 2^n - 1]; the kernel
subtracts (2^(n-1) · sum(W)) once per output — the same trick as the INV-row
temporary in the paper's BPE, which stores an inverted partial sum to handle
the sign bit without a separate signed datapath.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def num_planes(bits: int, plane_bits: int = 2) -> int:
    return (bits + plane_bits - 1) // plane_bits


def to_bitplanes(
    q: jax.Array, bits: int, plane_bits: int = 2, signed: bool = True
) -> Tuple[jax.Array, jax.Array]:
    """Decompose integer codes into unsigned bit-planes.

    Args:
      q: int32 codes in the `bits`-bit range (signed two's complement range
        if signed).
      bits: code precision (2..8).
      plane_bits: bits consumed per "cycle" (2 for M4BRAM's dual-bit BPE).
      signed: if True, uses the offset-binary trick: planes decompose
        (q + 2^(bits-1)) and the caller must subtract the offset
        2^(bits-1) * sum(other operand) from the final accumulation.

    Returns:
      planes: (P, *q.shape) uint8 array, planes[p] in [0, 2^plane_bits).
              plane p has weight 2^(p*plane_bits); planes are little-endian.
      offset: scalar int32 offset that was added (0 if unsigned).
    """
    p = num_planes(bits, plane_bits)
    offset = jnp.int32(1 << (bits - 1)) if signed else jnp.int32(0)
    u = (q + offset).astype(jnp.uint32)
    mask = jnp.uint32((1 << plane_bits) - 1)
    planes = jnp.stack(
        [((u >> jnp.uint32(i * plane_bits)) & mask).astype(jnp.uint8) for i in range(p)],
        axis=0,
    )
    return planes, offset


def from_bitplanes(
    planes: jax.Array, offset: jax.Array, plane_bits: int = 2
) -> jax.Array:
    """Inverse of to_bitplanes (for testing)."""
    p = planes.shape[0]
    acc = jnp.zeros(planes.shape[1:], jnp.int32)
    for i in range(p):
        acc = acc + (planes[i].astype(jnp.int32) << (i * plane_bits))
    return acc - offset


# ---------------------------------------------------------------------------
# Sub-byte packing: 2-/4-bit signed codes packed into int8 storage.
# Layout matches the paper's 32-bit weight vector: element j of a packed
# byte occupies bits [j*b, (j+1)*b) (little-endian), sign-extended on unpack.
# ---------------------------------------------------------------------------


def pack_int4(q: jax.Array, axis: int = -1) -> jax.Array:
    """Pack int32 codes in [-8, 7] into int8, two per byte, along `axis`."""
    q = jnp.moveaxis(q, axis, -1)
    if q.shape[-1] % 2:
        raise ValueError("pack_int4 needs an even packing dimension")
    lo = (q[..., 0::2] & 0xF).astype(jnp.uint8)
    hi = (q[..., 1::2] & 0xF).astype(jnp.uint8)
    packed = (lo | (hi << 4)).astype(jnp.uint8).view(jnp.int8)
    return jnp.moveaxis(packed, -1, axis)


def unpack_int4(packed: jax.Array, axis: int = -1) -> jax.Array:
    """Inverse of pack_int4: int8 storage → int32 sign-extended codes."""
    p = jnp.moveaxis(packed, axis, -1).view(jnp.uint8)
    lo = (p & 0xF).astype(jnp.int32)
    hi = ((p >> 4) & 0xF).astype(jnp.int32)
    # sign extend 4-bit
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    out = jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1], p.shape[-1] * 2)
    return jnp.moveaxis(out, -1, axis)


def pack_int2(q: jax.Array, axis: int = -1) -> jax.Array:
    """Pack int32 codes in [-2, 1] into int8, four per byte, along `axis`."""
    q = jnp.moveaxis(q, axis, -1)
    if q.shape[-1] % 4:
        raise ValueError("pack_int2 needs a packing dimension divisible by 4")
    b = [(q[..., i::4] & 0x3).astype(jnp.uint8) for i in range(4)]
    packed = (b[0] | (b[1] << 2) | (b[2] << 4) | (b[3] << 6)).astype(jnp.uint8)
    return jnp.moveaxis(packed.view(jnp.int8), -1, axis)


def unpack_int2(packed: jax.Array, axis: int = -1) -> jax.Array:
    p = jnp.moveaxis(packed, axis, -1).view(jnp.uint8)
    outs = []
    for i in range(4):
        v = ((p >> (2 * i)) & 0x3).astype(jnp.int32)
        v = jnp.where(v >= 2, v - 4, v)  # sign extend 2-bit
        outs.append(v)
    out = jnp.stack(outs, axis=-1).reshape(*p.shape[:-1], p.shape[-1] * 4)
    return jnp.moveaxis(out, -1, axis)


def pack_weights(q: jax.Array, bits: int, axis: int = 0) -> jax.Array:
    """Pack `bits`-bit weight codes for storage; int8 passthrough for 8-bit.

    axis defaults to 0 (the reduction/K dimension of a (K, N) weight matrix):
    packing along K mirrors the paper's 32-bit weight vector that holds
    multiple K-elements of the same output channel.
    """
    if bits == 8:
        return q.astype(jnp.int8)
    if bits == 4:
        return pack_int4(q, axis=axis)
    if bits == 2:
        return pack_int2(q, axis=axis)
    raise ValueError(f"unsupported weight bits {bits}")


def unpack_weights(packed: jax.Array, bits: int, axis: int = 0) -> jax.Array:
    if bits == 8:
        return packed.astype(jnp.int32)
    if bits == 4:
        return unpack_int4(packed, axis=axis)
    if bits == 2:
        return unpack_int2(packed, axis=axis)
    raise ValueError(f"unsupported weight bits {bits}")


def packed_bytes(shape: Tuple[int, ...], bits: int, axis: int = 0) -> int:
    """HBM bytes of a packed weight tensor — the quantity the TPU adaptation
    optimizes (the paper's throughput gain becomes a bandwidth gain here)."""
    n = 1
    for s in shape:
        n *= s
    return n * bits // 8
