"""Cycle-accurate performance simulator for DLA / Hetero-DLA (paper §V-A).

Reproduces the paper's evaluation stack: a tiled DLA-style accelerator
(DSP engine with precision-dependent packing, Fig 1) optionally augmented
with a compute-in-BRAM engine (M4BRAM-S/L, BRAMAC-1DA/2SA, Table II), a
double-buffered load/compute/store pipeline (Fig 8c), the Q_VEC workload
split between the engines (§IV-H), BPE readout stalls (4/8 cycles), and
the one-port (M4BRAM) vs two-port (BRAMAC) interoperability difference —
modelled as BRAMAC requiring a *duplicate* filter copy for the DSPs (its
CIM blocks are unreadable during compute, §III-B), which costs BRAM budget
and therefore CIM parallelism.

Model per layer (conv C,K,R,S,P,Q; weight/act precision Pw/Pa):

  DLA (DSP engine)
    rate_dsp = n_dsp_used × packing(Pw, Pa) MACs/cycle,
    padded MACs from (C_VEC, K_VEC, Q_VEC) ceil effects.
  Filter cache: DLA keeps the layer's filters resident across output
    tiles — cache bytes = C_VEC · K · R·S · Pw/8, double-buffered. For
    Hetero-DLA those cache blocks ARE the CIM blocks: every block holding
    filters contributes `lanes(Pw)` MAC2 lanes (Fig 7b).
  BPE engine
    A block completes `lanes` dot products per round:
      round = ceil(dot_len/2) MAC2 ops × mac2_cycles(Pa) + readout_stall
    lane utilization: U_K (N_W distinct channels needed), U_Q (N_I distinct
    pixels needed) — the Fig 4 / Fig 11 trade-off.
  Split: the layer's output pixels divide between engines ∝ throughput;
    tile latency = max(t_dsp + stalls, t_bpe, t_ddr_load).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

from repro.core import bitserial
from repro.core.workloads import Layer

# --------------------------------------------------------------------------
# Hardware building blocks
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Fpga:
    name: str
    n_dsp: int
    n_bram: int
    dsp_area: float = 1.0
    bram_area: float = 0.77  # M20K vs DSP normalized area (from [32])


GX400 = Fpga("GX400", 648, 1537)
GX650 = Fpga("GX650", 1152, 2489)


def dsp_packing(pw: int, pa: int, mult_w: int = 18) -> int:
    """MACs per DSP per cycle (Fig 1): Stratix-10 DSP = 2 × 18-bit mults;
    pack k copies of the narrower operand: k = 1 + floor((18 − min)/(pw+pa)),
    capped at 4. Reproduces the paper's breakpoints: at Pw=8 the factor
    doubles when Pa drops to 5 bits (Fig 9's speedup dip)."""
    k = 1 + (mult_w - min(pw, pa)) // (pw + pa)
    return 2 * min(4, max(1, k))


@dataclasses.dataclass(frozen=True)
class CimArch:
    """A compute-in-BRAM architecture (Table II)."""

    name: str
    dummy_cols_total: int        # 128 (M4-S), 256 (M4-L), 160/320 (BRAMAC)
    double_pumped: bool
    ni_options: Tuple[int, ...]  # weight-sharing factors supported
    one_port: bool               # True: DSP reads CIM blocks during compute
    readout_stall: int           # DSP stall cycles per block readout
    area_overhead: float         # vs M20K (Table II)
    mixed_precision: bool        # supports Pa != Pw

    def lanes(self, pw: int) -> int:
        return self.dummy_cols_total // 32 * (8 // pw)

    def mac2_cycles(self, pa: int) -> int:
        return bitserial.mac2_cycles(pa, self.double_pumped)

    def nw_options(self, pw: int) -> Tuple[Tuple[int, int], ...]:
        lanes = self.lanes(pw)
        return tuple((lanes // ni, ni) for ni in self.ni_options if lanes % ni == 0)


M4BRAM_S_SY = CimArch("SY-M4S", 128, False, (1, 2, 4), True, 4, 0.196, True)
M4BRAM_S_DP = CimArch("DP-M4S", 128, True, (1, 2, 4), True, 4, 0.196, True)
M4BRAM_L_SY = CimArch("SY-M4L", 256, False, (1, 2, 4), True, 8, 0.334, True)
M4BRAM_L_DP = CimArch("DP-M4L", 256, True, (1, 2, 4), True, 8, 0.334, True)
BRAMAC_1DA = CimArch("BRAMAC-1DA", 160, True, (1,), False, 4, 0.169, False)
BRAMAC_2SA = CimArch("BRAMAC-2SA", 320, False, (2,), False, 8, 0.338, False)

CIM_ARCHS = {
    a.name: a
    for a in (M4BRAM_S_SY, M4BRAM_S_DP, M4BRAM_L_SY, M4BRAM_L_DP,
              BRAMAC_1DA, BRAMAC_2SA)
}

_M20K_MEM_BYTES = 2560      # 20 Kb memory mode
_M20K_CIM_BYTES = 2048      # 512 × 32b compute-mode geometry
_DDR_BYTES_PER_CYCLE = 256  # 4 DDR4 banks × 512-bit @ fabric clock
# BPE feed/copy efficiency: weight-vector copy + activation distribution
# overhead on top of the (n+2)-cycle MAC2. Calibrated ONCE against the
# paper's own absolute BPE-vs-DSP measurement (Fig 12: GX-M4 = 1.98×/2.95×
# GX-DSP); Figs 9/10/11 are then *predictions* (tests/test_simulator.py).
_BPE_EFFICIENCY = 0.65


@dataclasses.dataclass(frozen=True)
class TileConfig:
    c_vec: int
    k_vec: int
    q_vec: int
    n_w: int = 1
    n_i: int = 1
    q_bpe: int = -1   # pixels of each q_vec tile assigned to the BPE engine
                      # (static per network, baked into the overlay by DSE;
                      #  -1 = auto-balance per layer)


@dataclasses.dataclass
class LayerResult:
    cycles: float
    dsp_cycles: float
    bpe_cycles: float
    load_cycles: float
    stall_cycles: float
    macs_bpe_frac: float
    n_cim: int


def _util(dim: int, vec: int) -> float:
    return dim / (math.ceil(dim / vec) * vec)


def _io_blocks(tile: TileConfig, layer: Layer) -> int:
    """Input/output double-buffered BRAM blocks for the DSP datapath."""
    in_bytes = tile.c_vec * (tile.q_vec + layer.R - 1) * (layer.S + 7) * 1
    out_bytes = tile.k_vec * tile.q_vec * 4
    return (
        math.ceil(2 * in_bytes / _M20K_MEM_BYTES)
        + math.ceil(2 * out_bytes / _M20K_MEM_BYTES)
    )


def resource_usage(
    tile: TileConfig, layer: Layer, pw: int, cim: Optional[CimArch],
    fpga: Optional[Fpga] = None,
) -> Tuple[int, int]:
    """(n_bram_used, n_cim_blocks).

    DLA keeps the layer's *entire* filter set resident (double-buffered
    against the next layer's load) and spreads it across the BRAM budget;
    in Hetero-DLA those resident blocks are the CIM engine, so BPE
    parallelism = resident filter blocks (paper §IV-H: "filter data stored
    in M4BRAM can be randomly accessed by both the BPE and DSP"). BRAMAC's
    CIM blocks are unreadable during compute → the DSP needs a duplicate
    memory-mode copy, costing ~2× budget per filter byte (§III-B).
    """
    io = _io_blocks(tile, layer)
    budget = max((fpga.n_bram if fpga else 10**9) - io, 0)
    filter_bytes = layer.C * layer.K * layer.R * layer.S * pw / 8
    if cim is None:
        n_filter = min(math.ceil(2 * filter_bytes / _M20K_MEM_BYTES), budget)
        return io + n_filter, 0
    if cim.one_port:
        # M4BRAM: filters fill the budget (replicated across blocks when the
        # set is small — replicas serve different output pixels); every
        # filter-holding block computes AND feeds the DSPs via its free port.
        n_cim = budget
        return io + n_cim, n_cim
    # BRAMAC: CIM blocks are unreadable during compute → every resident
    # filter byte needs a CIM copy + a memory-mode copy for the DSPs, so
    # only ~55% of the budget computes.
    per_byte = 2 / _M20K_CIM_BYTES + 2 / _M20K_MEM_BYTES
    cim_share = (2 / _M20K_CIM_BYTES) / per_byte
    n_cim = int(budget * cim_share)
    return io + budget, n_cim


def dsp_needed(tile: TileConfig, packing: int) -> int:
    return math.ceil(tile.c_vec * tile.k_vec * tile.q_vec / packing)


def fits(tile: TileConfig, layer: Layer, pw: int, pa: int,
         fpga: Fpga, cim: Optional[CimArch]) -> bool:
    packing = dsp_packing(pw, pa)
    if fpga.n_dsp > 0 and dsp_needed(tile, packing) > fpga.n_dsp:
        return False
    if fpga.n_dsp == 0 and (cim is None or tile.q_bpe not in (-1, tile.q_vec)):
        return False  # DSP-less FPGA: all pixels must go to the BPE
    return _io_blocks(tile, layer) <= fpga.n_bram // 4  # leave room for filters


def simulate_layer(
    layer: Layer,
    tile: TileConfig,
    pw: int,
    pa: int,
    fpga: Fpga,
    cim: Optional[CimArch],
    pw8_fraction: float = 0.0,
) -> LayerResult:
    packing = dsp_packing(pw, pa)
    n_dsp = min(dsp_needed(tile, packing), fpga.n_dsp)
    _, n_cim = resource_usage(tile, layer, pw, cim, fpga)
    if fpga.n_dsp == 0 and cim is not None:
        tile = dataclasses.replace(tile, q_bpe=tile.q_vec)

    # Padded work from tiling granularity (utilization loss from ceils).
    padded_macs = (
        math.ceil(layer.C / tile.c_vec) * tile.c_vec
        * math.ceil(layer.K / tile.k_vec) * tile.k_vec
        * math.ceil(layer.out_pixels / tile.q_vec) * tile.q_vec
        * layer.R * layer.S
    )
    rate_dsp = n_dsp * packing  # MACs / cycle

    # DDR: inputs + filters once per layer + outputs (double-buffered).
    load_bytes = (
        layer.C * (layer.P + layer.R - 1) * (layer.Q + layer.S - 1) * 1
        + layer.C * layer.K * layer.R * layer.S * pw / 8
        + layer.K * layer.out_pixels * 1
    )
    t_load = load_bytes / _DDR_BYTES_PER_CYCLE

    if cim is None or n_cim == 0:
        t_dsp = padded_macs / rate_dsp
        cycles = max(t_dsp, t_load)
        return LayerResult(cycles, t_dsp, 0.0, t_load, 0.0, 0.0, 0)

    # ---------------- Hetero: BPE engine out of the filter cache ---------
    n_w, n_i = tile.n_w, tile.n_i
    lanes = cim.lanes(pw)
    m2c = cim.mac2_cycles(pa)
    dot_len = layer.dot_len
    # One round: a block finishes `lanes` dot products then reads out.
    round_cycles = math.ceil(dot_len / 2) * m2c + cim.readout_stall
    # Lane utilization: N_W distinct output channels, N_I distinct pixels.
    u_k = _util(layer.K, n_w) if layer.K >= 1 else 1.0
    u_q = _util(layer.out_pixels, n_i)
    eff = u_k * u_q
    if pw8_fraction > 0 and pw < 8:
        # Table III: fraction of channels at 8-bit → fewer lanes per block.
        lanes8 = cim.lanes(8)
        eff = eff / ((1 - pw8_fraction) + pw8_fraction * (lanes / lanes8))
    # MACs/cycle: lanes dot products × dot_len MACs each, per round.
    rate_bpe = n_cim * lanes * dot_len / round_cycles * eff * _BPE_EFFICIENCY

    # Split along Q_VEC at *tile granularity* (§IV-H): each output tile's
    # q_vec pixels divide integrally between the engines, so when the BPE
    # far outruns the DSPs the tile saturates on the DSP share (the paper's
    # DP-M4L ≈ SY-M4L observation).
    if tile.q_bpe >= 0:
        q_bpe_tile = min(tile.q_bpe, tile.q_vec)
    else:
        rho = rate_bpe / (rate_bpe + rate_dsp)
        q_bpe_tile = min(tile.q_vec, max(0, round(tile.q_vec * rho)))
    frac_bpe = q_bpe_tile / tile.q_vec
    pq_bpe = int(layer.out_pixels * frac_bpe)
    if pq_bpe and n_i > 1:
        pq_bpe = max((pq_bpe // n_i) * n_i, min(n_i, layer.out_pixels))
    if rate_dsp == 0:
        pq_bpe = layer.out_pixels
    pq_dsp = layer.out_pixels - pq_bpe

    macs_dsp = padded_macs * pq_dsp / layer.out_pixels
    t_dsp = macs_dsp / rate_dsp if pq_dsp else 0.0

    outputs_bpe = pq_bpe * layer.K
    rounds_total = math.ceil(outputs_bpe / (n_cim * lanes * u_k * max(u_q, 1e-9))) \
        if pq_bpe else 0
    # Feed/copy efficiency stretches the effective round time (weight-vector
    # copies + activation distribution on top of the (n+2)-cycle MAC2).
    t_bpe = rounds_total * round_cycles / _BPE_EFFICIENCY
    # Readout stalls block concurrent DSP filter reads (one-port M4BRAM
    # keeps the *other* port free; the stall is only the result drain).
    stall = rounds_total * cim.readout_stall if pq_bpe else 0.0

    if cim.one_port:
        # M4BRAM: the write port is free between CIM instructions → the
        # next tile's filter load overlaps compute (double-buffering, §IV-H).
        cycles = max(t_dsp + stall, t_bpe, t_load)
    else:
        # BRAMAC: both ports busy during CIM → filter (re)loads into CIM
        # blocks serialize with compute (Table II: "occupied ports: two").
        filter_load = (layer.C * layer.K * layer.R * layer.S * pw / 8) \
            / _DDR_BYTES_PER_CYCLE
        cycles = max(t_dsp + stall, t_bpe, t_load - filter_load) + filter_load
    return LayerResult(
        cycles, t_dsp, t_bpe, t_load, stall,
        pq_bpe / max(layer.out_pixels, 1), n_cim,
    )


def simulate_network(
    layers: List[Layer],
    tile: TileConfig,
    pw: int,
    pa: int,
    fpga: Fpga,
    cim: Optional[CimArch],
    pw8_fraction: float = 0.0,
) -> float:
    return sum(
        simulate_layer(l, tile, pw, pa, fpga, cim, pw8_fraction).cycles
        for l in layers
    )


def area_cost(fpga: Fpga, cim: Optional[CimArch]) -> float:
    bram = fpga.n_bram * fpga.bram_area
    if cim is not None:
        bram *= 1.0 + cim.area_overhead
    return fpga.n_dsp * fpga.dsp_area + bram
