"""QuantizedLinear — the paper's technique as a composable JAX module.

Every linear layer in the model zoo routes through :func:`qmatmul`, which
dispatches on the weight leaf type and a :class:`~repro.core.quant.QuantConfig`:

  * ``mode='none'``  — plain bf16/fp32 matmul (the FP32 baseline).
  * ``mode='fake'``  — QAT: STE fake-quant of weights and activations, then a
    dense matmul. Matches the paper's fine-tuning (§V-A).
  * ``mode='serve'`` — the M4BRAM path: weights are *stored packed*
    (2/4/8-bit codes in int8 words, :mod:`repro.core.bitplane`), activations
    are quantized on the fly, and the product is computed by the bit-plane
    matmul kernel (:mod:`repro.kernels`). On TPU this is where the paper's
    throughput-scales-with-precision property becomes
    HBM-bytes-scale-with-precision.

Intra-layer mixed precision (Table III): a ``PackedWeight`` may carry two
filter groups — the first ``n8`` output channels at 8-bit and the rest at
``w_bits`` — mirroring the paper's 4b/8b filter groups computed by the two
heterogeneous engines.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core import bitplane
from repro.core.quant import QuantConfig, fake_quant, quantize_tensor, quantize_weights_mixed


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedWeight:
    """A packed sub-byte weight matrix + dequant scales.

    packed : int8 storage, shape (K * bits // 8, N) — packed along K.
    scale  : (1, N) per-output-channel dequant scale (float32).
    bits   : 2/4/8 (static aux data).
    n8     : Table III mixing — leading n8 output channels are 8-bit packed
             in `packed8` with scales in `scale` too. 0 disables mixing.
    packed8: optional int8 (K, n8) storage for the 8-bit group.
    a_bits / act_signed : the activation precision this layer was packed
             for — the leaf carries its own per-layer PrecisionPolicy
             decision, so serve-time matmuls need no global QuantConfig.
    plane_lo : contract only planes [plane_lo:] of the packed codes — a
             *view-level* precision drop (w8 storage served as w4/w2 by
             plane truncation, the self-speculative draft path). Aux
             data, not a leaf: truncating a policy never copies weight
             bytes, it only re-traces the matmul.
    """

    packed: jax.Array
    scale: jax.Array
    bits: int
    k: int
    n8: int = 0
    packed8: Optional[jax.Array] = None
    a_bits: int = 8
    act_signed: bool = True
    plane_lo: int = 0

    def tree_flatten(self):
        leaves = (self.packed, self.scale, self.packed8)
        aux = (self.bits, self.k, self.n8, self.a_bits, self.act_signed,
               self.plane_lo)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        packed, scale, packed8 = leaves
        bits, k, n8, a_bits, act_signed, plane_lo = aux
        return cls(packed=packed, scale=scale, bits=bits, k=k, n8=n8,
                   packed8=packed8, a_bits=a_bits, act_signed=act_signed,
                   plane_lo=plane_lo)

    @property
    def shape(self):
        n = self.scale.shape[-1]
        return (self.k, n)

    def hbm_bytes(self) -> int:
        n_low = self.shape[1] - self.n8
        b = self.k * n_low * self.bits // 8 + self.k * self.n8
        return b + self.scale.size * 4


def pack_weight(w: jax.Array, cfg: QuantConfig) -> PackedWeight:
    """Quantize + pack a dense (K, N) weight matrix for serving."""
    if w.ndim != 2:
        raise ValueError(f"pack_weight expects (K, N), got {w.shape}")
    k, n = w.shape
    w32 = w.astype(jnp.float32)
    ab, asg = cfg.a_bits, cfg.act_signed
    if cfg.mixed_ratio_8b > 0.0 and cfg.w_bits != 8:
        q, s, n8 = quantize_weights_mixed(w32, cfg)
        if n8 == n:
            return PackedWeight(q.astype(jnp.int8), s.reshape(1, n), 8, k, 0,
                                None, ab, asg)
        q8, ql = q[:, :n8], q[:, n8:]
        pk = bitplane.pack_weights(ql, cfg.w_bits, axis=0)
        return PackedWeight(pk, s.reshape(1, n), cfg.w_bits, k, n8,
                            q8.astype(jnp.int8), ab, asg)
    q, s = quantize_tensor(w32, cfg.w_bits, True, axis=1 if cfg.per_channel else None)
    pk = bitplane.pack_weights(q, cfg.w_bits, axis=0)
    s = jnp.broadcast_to(jnp.asarray(s, jnp.float32).reshape(1, -1), (1, n))
    return PackedWeight(pk, s, cfg.w_bits, k, 0, None, ab, asg)


def unpack_weight(pw: PackedWeight, *, apply_plane_lo: bool = True) -> jax.Array:
    """Dense int32 codes (K, N) for the reference path / tests.

    A ``plane_lo`` view is applied by arithmetic shift (≡ keep planes
    [lo:], see kernels/bitplane_matmul.py); pass ``apply_plane_lo=False``
    to get the raw resident codes when the downstream kernel performs the
    truncation itself (``w_plane_lo=``).
    """
    ql = bitplane.unpack_weights(pw.packed, pw.bits, axis=0)
    if pw.n8:
        q8 = pw.packed8.astype(jnp.int32)
        ql = jnp.concatenate([q8, ql], axis=1)
    if apply_plane_lo and pw.plane_lo:
        ql = ql >> (2 * pw.plane_lo)
    return ql


def dequantize_weight(pw: PackedWeight, dtype=jnp.float32) -> jax.Array:
    # Truncated codes lose 2·plane_lo low bits, so one code unit is worth
    # 4^plane_lo original LSBs — the scale regains that factor.
    scale = pw.scale * (1 << (2 * pw.plane_lo)) if pw.plane_lo else pw.scale
    return (unpack_weight(pw).astype(jnp.float32) * scale).astype(dtype)


def qmatmul(
    x: jax.Array,
    w: Union[jax.Array, PackedWeight],
    cfg: Optional[QuantConfig] = None,
    mode: str = "none",
    use_kernel: bool = False,
) -> jax.Array:
    """Quantization-aware matmul. x: (..., K); w: (K, N) or PackedWeight."""
    if isinstance(w, PackedWeight):
        return _serve_matmul(x, w, cfg, use_kernel=use_kernel)
    if mode == "none" or cfg is None:
        return x @ w.astype(x.dtype)
    if mode == "fake":
        xq = fake_quant(x, cfg.a_bits, cfg.act_signed)
        wq = fake_quant(w, cfg.w_bits, True, axis=w.ndim - 1 if cfg.per_channel else None)
        return xq @ wq.astype(xq.dtype)
    if mode == "serve":
        return _serve_matmul(x, pack_weight(w, cfg), cfg, use_kernel=use_kernel)
    raise ValueError(f"unknown qmatmul mode {mode!r}")


def _serve_matmul(
    x: jax.Array, pw: PackedWeight, cfg: Optional[QuantConfig], use_kernel: bool
) -> jax.Array:
    """Packed-weight matmul.

    Activation precision comes from `cfg` when given, else from the
    PackedWeight leaf itself — which is how a per-layer PrecisionPolicy
    reaches the kernel without the model threading configs around.

    use_kernel=True — the fused quantize→bit-plane Pallas kernel (exact int
    path; the real TPU implementation, validated in tests; interpret-mode
    on CPU so only used outside distributed graphs). Activations are
    quantized in the matmul's K-loop prologue; no int8 activation tensor
    ever reaches HBM.

    use_kernel=False — the algebraically *identical* dequant formulation
    for jit/pjit graphs: (codes_x · s_x) @ (codes_w · s_w). XLA fuses the
    unpack+scale chain into the matmul on TPU, so HBM sees only packed
    bytes — the kernel contract the §Perf analysis accounts with.
    """
    a_bits = cfg.a_bits if cfg is not None else pw.a_bits
    act_signed = cfg.act_signed if cfg is not None else pw.act_signed
    lead = x.shape[:-1]
    k = x.shape[-1]
    if k != pw.k:
        raise ValueError(f"K mismatch: x has {k}, weight has {pw.k}")
    x2 = x.reshape(-1, k)
    if use_kernel:
        from repro.kernels import ops as kops

        # Hand the kernel the *resident* codes and let it truncate in
        # VMEM (w_plane_lo): HBM only ever sees the one packed buffer,
        # whichever precision tier this call contracts at.
        wq = unpack_weight(pw, apply_plane_lo=False)
        acc, xscale = kops.fused_quantize_matmul(
            x2.astype(jnp.float32), wq, a_bits=a_bits, act_signed=act_signed,
            w_plane_lo=pw.plane_lo,
        )  # per-row (per-token) scale
        ws = pw.scale * (1 << (2 * pw.plane_lo)) if pw.plane_lo else pw.scale
        y = acc.astype(jnp.float32) * xscale * ws
        return y.reshape(*lead, -1).astype(x.dtype)
    # Per-token (row) activation scales, matching the kernel path's K-loop
    # prologue. Per-tensor scaling would make a token's quantized
    # activation depend on every other token in the call — decode batches,
    # prefill chunks, and speculative verify windows would each see
    # different bytes for the same token, breaking the serving stack's
    # batch-composition-independence contract.
    xq = fake_quant(x2, a_bits, act_signed, axis=0)
    w = dequantize_weight(pw, dtype=xq.dtype)
    y = xq @ w
    return y.reshape(*lead, -1).astype(x.dtype)


_NO_PACK = ("embed", "head", "patch_proj", "frame_proj", "router", "u",
            "decay_base", "gn_scale", "gn_bias", "conv_w", "lambda_p")


def quantize_params_for_serving(params, cfg, min_size: int = 1 << 16):
    """Walk a parameter pytree and replace 2-D linear weights with
    PackedWeight leaves (the serving transformation).

    `cfg` is a single :class:`QuantConfig` (uniform precision, the paper's
    per-network setting) or a :class:`~repro.core.precision.PrecisionPolicy`
    mapping parameter paths to per-layer configs — each packed leaf records
    the (w_bits, a_bits) its path matched, so a served model runs mixed
    per-layer precision end-to-end.

    Exclusions (kept full-precision, matching the paper's treatment of
    non-GEMM layers): embeddings/heads (consumed by take/transpose paths),
    frontend projections, routers, and all small vectors/norm scales —
    plus anything below `min_size` elements.
    """
    import re

    from repro.core.precision import as_policy
    from repro.parallel.sharding import tree_path_str

    policy = as_policy(cfg)

    def maybe_pack(path, leaf):
        pstr = tree_path_str(path)
        if any(re.search(rf"(^|/){re.escape(n)}$", pstr) for n in _NO_PACK):
            return leaf
        if (
            not isinstance(leaf, jax.Array)
            or not jnp.issubdtype(leaf.dtype, jnp.floating)
            or leaf.size < min_size
        ):
            return leaf
        leaf_cfg = policy.for_path(pstr)
        if leaf.ndim == 2 and leaf.shape[0] % 16 == 0 and min(leaf.shape) >= 128:
            # min-dim guard: stacked norm scales (L, d) are 2-D but not GEMMs.
            return pack_weight(leaf, leaf_cfg)
        if leaf.ndim == 3 and leaf.shape[1] % 16 == 0 and leaf.shape[2] >= 16:
            # Stacked scan-over-layers weights (L, K, N): pack per layer.
            return jax.vmap(lambda w: pack_weight(w, leaf_cfg))(leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(maybe_pack, params)


def packed_weight_bytes(params, w_bits: Optional[int] = None) -> int:
    """Total packed GEMM weight bytes resident in `params`; with `w_bits`,
    the bytes a plane-truncated view served at that width actually
    streams per forward pass (top planes only — a w8 leaf read at w4
    streams half its bytes, at w2 a quarter; leaves already at or below
    `w_bits` stream whole). The modeled-traffic denominator for both the
    speculative-decoding and precision-tier benchmarks."""
    from repro.core.precision import PLANE_BITS, plane_offset

    total = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda l: isinstance(l, PackedWeight)):
        if not isinstance(leaf, PackedWeight):
            continue
        nbytes = int(leaf.packed.nbytes)
        if leaf.packed8 is not None:
            nbytes += int(leaf.packed8.nbytes)
        if w_bits is not None:
            lo = plane_offset(leaf.bits, w_bits)
            nbytes = nbytes * (leaf.bits - PLANE_BITS * lo) // leaf.bits
        total += nbytes
    return total
