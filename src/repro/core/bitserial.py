"""Exact bit-serial MAC2 semantics of the M4BRAM BPE (paper §IV-F).

The BPE computes ``P = W1*I1 + W2*I2`` bit-serially over the *activation*
bits. Per cycle ``n`` it consumes the bit-pair ``{I2[n], I1[n]}`` — bit ``n``
of each of the two activations — and selects a partial sum from a 4-entry
lookup table held in the first four dummy-BRAM rows::

    LUT = [0, W1, W2, W1 + W2]          # indexed by (I2[n] << 1) | I1[n]
    P  += LUT[{I2[n], I1[n]}] << n

Signed activations use the INV row: the most-significant (sign) bit of a
two's-complement activation has weight ``-2^(n-1)``, so on the final cycle
the selected partial sum is *inverted* (the INV row stores the negated
partial sum) before accumulation.

Weights are sign-extended in the dummy array (§IV-F), i.e. the weight side
is natively signed and needs no correction.

MAC2 latency: ``a_bits + 2`` cycles synchronous, ``ceil(a_bits/2) + 2``
double-pumped (§IV-F) — modelled in :mod:`repro.core.simulate`; this module
is the *numerics* oracle used by property tests and by the Pallas kernel's
reference implementation.

Everything is pure jnp and shape-polymorphic: scalars broadcast, so the same
function vectorizes a whole matmul tile.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _bit(x: jax.Array, n) -> jax.Array:
    """Bit n of x interpreted in two's complement (int32 arithmetic shift)."""
    return (x >> n) & 1


def mac2_bitserial(
    w1: jax.Array,
    w2: jax.Array,
    i1: jax.Array,
    i2: jax.Array,
    a_bits: int,
    act_signed: bool = True,
) -> jax.Array:
    """Cycle-exact MAC2: returns W1*I1 + W2*I2 via the LUT dataflow.

    Args:
      w1, w2: signed integer weight codes (any broadcastable shape, int32).
      i1, i2: signed (or unsigned) integer activation codes, int32, assumed
        in range for `a_bits`.
      a_bits: activation precision, 2..8.
      act_signed: activations are two's complement if True.
    """
    w1 = w1.astype(jnp.int32)
    w2 = w2.astype(jnp.int32)
    i1 = i1.astype(jnp.int32)
    i2 = i2.astype(jnp.int32)
    p = jnp.zeros(jnp.broadcast_shapes(w1.shape, w2.shape, i1.shape, i2.shape), jnp.int32)
    for n in range(a_bits):
        b1 = _bit(i1, n)
        b2 = _bit(i2, n)
        # LUT select {0, W1, W2, W1+W2} — algebraically b1*W1 + b2*W2.
        partial = b1 * w1 + b2 * w2
        if act_signed and n == a_bits - 1:
            partial = -partial  # INV row: sign bit has weight -2^(n).
        p = p + (partial << n)
    return p


def dot_bitserial(
    w: jax.Array,
    x: jax.Array,
    a_bits: int,
    act_signed: bool = True,
) -> jax.Array:
    """Bit-serial dot product over K as a chain of MAC2 ops (paper §IV-B).

    The BPE accumulates successive MAC2 results in its last dummy-BRAM row;
    a dot product of length K takes K/2 MAC2 operations, consuming the K
    dimension in pairs (W1, W2)/(I1, I2).

    Args:
      w: (K,) or (K, N) signed weight codes.
      x: (K,) or (M, K) signed activation codes.
    Returns:
      int32 result with standard matmul broadcasting, exactly equal to
      ``x @ w`` in integer arithmetic.
    """
    w = jnp.asarray(w, jnp.int32)
    x = jnp.asarray(x, jnp.int32)
    squeeze_w = w.ndim == 1
    squeeze_x = x.ndim == 1
    if squeeze_w:
        w = w[:, None]
    if squeeze_x:
        x = x[None, :]
    K = w.shape[0]
    if K % 2:
        # Pad with a zero pair element — the hardware pads the last vector.
        w = jnp.concatenate([w, jnp.zeros((1, w.shape[1]), w.dtype)], axis=0)
        x = jnp.concatenate([x, jnp.zeros((x.shape[0], 1), x.dtype)], axis=1)
        K += 1
    acc = jnp.zeros((x.shape[0], w.shape[1]), jnp.int32)
    for k in range(0, K, 2):
        acc = acc + mac2_bitserial(
            w[k][None, :], w[k + 1][None, :],
            x[:, k][:, None], x[:, k + 1][:, None],
            a_bits, act_signed,
        )
    if squeeze_w:
        acc = acc[:, 0]
    if squeeze_x:
        acc = acc[0]
    return acc


def matmul_bitplane_reference(
    x_codes: jax.Array,
    w_codes: jax.Array,
    a_bits: int,
    act_signed: bool = True,
    plane_bits: int = 2,
) -> jax.Array:
    """Bit-*plane* matmul — the TPU-native restatement of the BPE dataflow.

    Decomposes activations into `plane_bits`-bit unsigned planes (offset
    binary for signed inputs) and accumulates per-plane integer matmuls with
    shifts::

        x = sum_p plane_p << (p * plane_bits) - offset
        x @ w = sum_p (plane_p @ w) << (p * plane_bits) - offset * colsum(w)

    With plane_bits=1 and the sign handled by the final-plane inversion this
    is *identical* per-cycle math to :func:`mac2_bitserial`; with
    plane_bits=2 it is the vectorized form our Pallas kernel implements.

    Args:
      x_codes: (M, K) int32 activation codes.
      w_codes: (K, N) int32 weight codes.
    Returns:
      (M, N) int32, exactly equal to x_codes @ w_codes.
    """
    from repro.core import bitplane

    planes, offset = bitplane.to_bitplanes(x_codes, a_bits, plane_bits, act_signed)
    acc = jnp.zeros((x_codes.shape[0], w_codes.shape[1]), jnp.int32)
    for p in range(planes.shape[0]):
        acc = acc + ((planes[p].astype(jnp.int32) @ w_codes) << (p * plane_bits))
    if act_signed:
        colsum = jnp.sum(w_codes, axis=0, dtype=jnp.int32)
        acc = acc - offset * colsum[None, :]
    return acc


def mac2_cycles(a_bits: int, double_pumped: bool) -> int:
    """MAC2 latency in main-BRAM cycles (paper §IV-F)."""
    if double_pumped:
        return -(-a_bits // 2) + 2
    return a_bits + 2


def lanes_per_block(pw: int, large: bool) -> int:
    """Independent MAC2 lanes per M4BRAM block (Fig. 7b).

    4 BPEs; each BPE's dummy array holds 32 (S) or 64 (L) columns and can
    serve one 8-bit, two 4-bit, or four 2-bit weight lanes per 32 columns.
    """
    per_bpe = (8 // pw) * (2 if large else 1)
    return 4 * per_bpe


def parallelism_configs(pw: int, large: bool) -> Tuple[Tuple[int, int], ...]:
    """Supported (N_W, N_I) pairs (Fig. 7b): N_W · N_I = lanes, N_I ≤ 4."""
    lanes = lanes_per_block(pw, large)
    out = []
    for ni in (1, 2, 4):
        if lanes % ni == 0 and lanes // ni >= 1:
            out.append((lanes // ni, ni))
    return tuple(out)
