"""Per-layer precision policies (the compile-time half of M4BRAM's
mixed-precision configurability).

The paper stores weight precision in per-layer configuration SRAM and takes
activation precision from the CIM instruction — precision is a *per-layer*
decision, not a global one (§IV; DeepBurning-MixQ and ILMPQ treat the same
choice as a first-class compile-time knob). A :class:`PrecisionPolicy` is
the software analogue: an ordered rule list mapping parameter-tree paths to
:class:`~repro.core.quant.QuantConfig`, with a default for everything else.

Policies flow end-to-end:

  * ``quantize_params_for_serving(params, policy)`` packs each 2-D weight
    with the config its path matches — the PackedWeight leaf records its
    own ``(w_bits, a_bits, act_signed)``;
  * ``QuantizedLinear.qmatmul`` reads the leaf-carried activation precision,
    so a served model runs different ``(w_bits, a_bits)`` per layer with no
    model-code changes;
  * ``ServingEngine`` / ``launch/serve.py`` accept either a single
    QuantConfig (uniform, the old behavior) or a policy spec string.

Policies can be written by hand (:func:`parse_policy_spec`) or derived from
the design-space exploration in :mod:`repro.core.dse` /
:mod:`repro.core.hetero` (:func:`policy_from_dse`): per layer, pick the
precision with the best simulated cycle count, protecting the boundary
layers at 8-bit — the standard sensitivity guard the paper's fine-tuning
setup also applies.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.quant import QuantConfig


@dataclasses.dataclass(frozen=True)
class LayerRule:
    """First-match-wins rule: `pattern` is re.search'd against the
    '/'-joined parameter path (e.g. "blocks/wq", "moe/w_up")."""

    pattern: str
    cfg: QuantConfig

    def matches(self, path: str) -> bool:
        return re.search(self.pattern, path) is not None


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Ordered per-layer quantization rules + a default config."""

    default: QuantConfig
    rules: Tuple[LayerRule, ...] = ()

    @classmethod
    def uniform(cls, cfg: QuantConfig) -> "PrecisionPolicy":
        """A policy equivalent to the old single global QuantConfig."""
        return cls(default=cfg)

    def for_path(self, path: str) -> QuantConfig:
        """Config for one parameter path (first matching rule, else default)."""
        for rule in self.rules:
            if rule.matches(path):
                return rule.cfg
        return self.default

    def with_rule(self, pattern: str, cfg: QuantConfig) -> "PrecisionPolicy":
        """A new policy with `pattern → cfg` appended (lowest priority)."""
        return dataclasses.replace(self, rules=self.rules + (LayerRule(pattern, cfg),))

    def describe(self) -> str:
        parts = [f"default={_fmt_cfg(self.default)}"]
        parts += [f"{r.pattern}={_fmt_cfg(r.cfg)}" for r in self.rules]
        return "; ".join(parts)


def quant_token(cfg: QuantConfig) -> str:
    """Canonical "wXaY[rZZ]" token for a config — the inverse of
    :func:`parse_quant_token`, used as the stable key for precision tiers
    (`pool_stats()["tiers"]`, per-request `Request.tier` strings)."""
    s = f"w{cfg.w_bits}a{cfg.a_bits}"
    if cfg.mixed_ratio_8b:
        s += f"r{int(round(cfg.mixed_ratio_8b * 100))}"
    return s


_fmt_cfg = quant_token


def as_policy(
    quant: Union[None, QuantConfig, PrecisionPolicy]
) -> Optional[PrecisionPolicy]:
    """Normalize the user-facing `quant` argument (None passes through)."""
    if quant is None or isinstance(quant, PrecisionPolicy):
        return quant
    if isinstance(quant, QuantConfig):
        return PrecisionPolicy.uniform(quant)
    raise TypeError(f"expected QuantConfig or PrecisionPolicy, got {type(quant)!r}")


_SPEC_RE = re.compile(r"w(\d)a(\d)(?:r(\d+))?")


def parse_quant_token(token: str) -> QuantConfig:
    """Parse one "wXaY[rZZ]" token (rZZ = ZZ% 8-bit filter group) — the
    single grammar shared by --quant flags and policy specs."""
    m = _SPEC_RE.fullmatch(token)
    if not m:
        raise ValueError(f"bad quant spec {token!r} (expected e.g. w4a8, w4a8r10)")
    return QuantConfig(
        w_bits=int(m.group(1)),
        a_bits=int(m.group(2)),
        mixed_ratio_8b=int(m.group(3)) / 100.0 if m.group(3) else 0.0,
    )


def parse_policy_spec(spec: str) -> PrecisionPolicy:
    """Parse "w4a8;wo=w8a8;moe/w_up=w2a4r10" into a policy.

    The first (or only) ';'-separated token without '=' is the default;
    each `pattern=wXaY[rZZ]` token appends a rule in order.
    """
    default: Optional[QuantConfig] = None
    rules: List[LayerRule] = []
    for token in filter(None, (t.strip() for t in spec.split(";"))):
        if "=" in token:
            pattern, _, cfg_s = token.rpartition("=")
            rules.append(LayerRule(pattern.strip(), parse_quant_token(cfg_s.strip())))
        else:
            if default is not None:
                raise ValueError(f"duplicate default in policy spec {spec!r}")
            default = parse_quant_token(token)
    if default is None:
        raise ValueError(f"policy spec {spec!r} has no default wXaY token")
    return PrecisionPolicy(default=default, rules=tuple(rules))


def policy_from_dse(
    layers: Sequence,
    fpga,
    cim,
    a_bits: int = 8,
    w_candidates: Sequence[int] = (2, 4, 8),
    protect_boundary: bool = True,
    mixed_from_hetero: bool = False,
) -> PrecisionPolicy:
    """Derive a per-layer policy from the performance-model DSE.

    For each candidate weight precision, run :func:`repro.core.dse.search`
    to get that precision's best tiling, then pick per layer the precision
    whose simulated cycle count is lowest. The first and last layers are
    pinned to 8-bit when `protect_boundary` (the standard sensitivity
    guard). With `mixed_from_hetero`, non-8-bit layers additionally carry a
    Table-III 8-bit filter-group ratio balancing the two engine rates
    (:func:`repro.core.hetero.balanced_group_ratio` on the BPE/DSP
    throughputs implied by the chosen tile).

    `layers` are :class:`repro.core.workloads.Layer`; rule patterns anchor
    on each layer's name, so callers map workload layer names onto their
    parameter-tree paths (the benchmark tables use matching names).
    """
    from repro.core import dse, hetero
    from repro.core import simulate as sim

    per_bits: Dict[int, Tuple[object, List[float]]] = {}
    for pw in w_candidates:
        try:
            result = dse.search(list(layers), pw, a_bits, fpga, cim)
        except RuntimeError:
            continue  # no feasible tiling at this precision
        cycles = []
        for layer, ni in zip(layers, result.per_layer_ni):
            tile = dataclasses.replace(result.tile, n_i=ni)
            r = sim.simulate_layer(layer, tile, pw, a_bits, fpga, cim)
            cycles.append(r.cycles)
        per_bits[pw] = (result, cycles)
    if not per_bits:
        raise RuntimeError("policy_from_dse: no feasible precision candidate")

    rules: List[LayerRule] = []
    n_layers = len(layers)
    for i, layer in enumerate(layers):
        if protect_boundary and i in (0, n_layers - 1) and 8 in per_bits:
            best_pw = 8
        else:
            best_pw = min(per_bits, key=lambda pw: per_bits[pw][1][i])
        ratio = 0.0
        if mixed_from_hetero and best_pw != 8 and cim is not None:
            result, _ = per_bits[best_pw]
            tile = result.tile
            if tile.q_bpe > 0:
                # BPE rate scales with lanes/latency; DSP side is bit-parallel.
                bpe_rate = tile.q_bpe * cim.lanes(best_pw) / max(
                    cim.mac2_cycles(a_bits), 1)
                dsp_rate = float(max(tile.q_vec - tile.q_bpe, 0))
                ratio = hetero.balanced_group_ratio(dsp_rate, bpe_rate)
        cfg = QuantConfig(
            w_bits=best_pw,
            a_bits=a_bits,
            mixed_ratio_8b=ratio if 0.0 < ratio < 1.0 else 0.0,
        )
        rules.append(LayerRule(rf"(^|/){re.escape(layer.name)}$", cfg))

    default = QuantConfig(w_bits=max(w_candidates), a_bits=a_bits)
    return PrecisionPolicy(default=default, rules=tuple(rules))


# -- precision tiers: plane-truncated views of one packed weight set -------
#
# M4BRAM's headline property is that one resident copy of the data serves
# many precisions. The serving analogue: weights are stored once as
# little-endian 2-bit planes (``repro.core.bitplane``), and any precision
# at or below the storage width is a *view* — contract only the top
# planes (``PackedWeight.plane_lo``), never copy a byte. Speculative
# drafts (PR 7) and per-request serving tiers are the same mechanism, so
# both route through :func:`truncate_policy_view` here.

PLANE_BITS = 2


def parse_tier_token(spec: Union[str, QuantConfig]) -> QuantConfig:
    """Normalize one tier/draft token ("w4a8" or an already-built
    QuantConfig). Tiers are pure plane truncations of the stored planes,
    so the Table-III mixed 8-bit filter-group ratio ("rZZ") is rejected:
    a filter-group split changes *which channels* are 8-bit, which cannot
    be expressed as a plane subset of the resident codes."""
    cfg = spec if isinstance(spec, QuantConfig) else parse_quant_token(str(spec))
    if cfg.mixed_ratio_8b:
        raise ValueError(
            "a precision tier is a plane truncation of the resident "
            f"weights; a mixed 8-bit filter group ({quant_token(cfg)!r}) "
            "cannot be expressed as a plane subset"
        )
    return cfg


def parse_tier_specs(
    spec: Union[str, Sequence[Union[str, QuantConfig]]]
) -> Tuple[QuantConfig, ...]:
    """Parse a ``--tiers`` value ("w8a8,w4a8,w2a8", or a sequence of
    tokens/QuantConfigs) into an ordered tuple of tier configs. Each
    token goes through :func:`parse_tier_token` (no "rZZ"); duplicates
    are rejected because tier keys name counter buckets and jit traces."""
    if isinstance(spec, str):
        tokens: Sequence = [t.strip() for t in spec.split(",") if t.strip()]
    else:
        tokens = list(spec)
    if not tokens:
        raise ValueError(f"empty tier spec {spec!r}")
    out: List[QuantConfig] = []
    seen = set()
    for tok in tokens:
        cfg = parse_tier_token(tok)
        key = quant_token(cfg)
        if key in seen:
            raise ValueError(f"duplicate precision tier {key!r} in {spec!r}")
        seen.add(key)
        out.append(cfg)
    return tuple(out)


def degrade_order(
    tiers: Union[Sequence[QuantConfig], Sequence[str]]
) -> Tuple[QuantConfig, ...]:
    """Tiers sorted quality-descending — the order graceful degradation
    walks when pool pressure persists (``--degrade``): widest weight
    planes first, activations as tiebreak. The LAST entry is the floor
    every degraded admission lands on; the scheduler serves it through
    the same :func:`truncate_policy_view` plane truncation as any
    explicitly requested tier, so shedding quality never costs a second
    weight copy."""
    cfgs = [parse_tier_token(t) for t in tiers]
    if not cfgs:
        raise ValueError("degrade_order needs at least one tier")
    return tuple(sorted(cfgs, key=lambda c: (-c.w_bits, -c.a_bits)))


def plane_offset(target_bits: int, view_bits: int) -> int:
    """Number of low 2-bit planes to drop so `target_bits` storage serves
    a `view_bits` contraction. 0 when the leaf is already at or below the
    view precision (nothing to truncate — the view runs it as-is)."""
    if view_bits >= target_bits:
        return 0
    drop = target_bits - view_bits
    if drop % PLANE_BITS:
        raise ValueError(
            f"cannot serve w{target_bits} storage at w{view_bits}: the "
            f"precision gap must be a whole number of {PLANE_BITS}-bit "
            "planes"
        )
    lo = drop // PLANE_BITS
    if PLANE_BITS * lo >= target_bits:
        raise ValueError(
            f"plane_lo={lo} leaves no planes of a w{target_bits} weight"
        )
    return lo


def truncate_policy_view(
    params, tier: Union[str, QuantConfig], *, require_truncation: bool = False
) -> Tuple[object, int]:
    """`tier`-precision view of packed serving params: every PackedWeight
    leaf stored above the tier's weight width gets ``plane_lo`` set so its
    matmuls contract only the top planes. Returns ``(view, truncated)``.

    The view is *zero-copy*: every array leaf (packed bytes, scales) is
    identity-shared with the source params (``id(view.packed) ==
    id(params.packed)``) — ``plane_lo`` is pytree aux data, so a view
    costs one extra jit trace per tier, never a second weight copy. A
    tier equal to the storage policy truncates nothing and returns
    ``params`` itself (same object → the existing compiled trace is
    reused). A tier is therefore a per-leaf *cap*: leaves already stored
    at or below the tier width serve as stored.

    Validation (a tier must be a pure plane-truncation of the served
    storage policy): raises when the params carry no packed leaves (serve
    with a quant policy first), when the precision gap of some leaf is
    not a whole number of planes, or when the tier's activation precision
    disagrees with a truncating leaf's — plane truncation only lowers
    weight bits. With ``require_truncation`` (the speculative-draft
    contract) a view that truncates no leaf is also an error."""
    import jax

    from repro.core.quantized_linear import PackedWeight

    cfg = parse_tier_token(tier)
    counts = {"packed": 0, "truncated": 0}

    def view(leaf):
        if not isinstance(leaf, PackedWeight):
            return leaf
        counts["packed"] += 1
        lo = plane_offset(leaf.bits, cfg.w_bits)
        if lo == 0:
            return leaf
        if leaf.a_bits != cfg.a_bits:
            raise ValueError(
                f"tier w{cfg.w_bits}a{cfg.a_bits} changes the "
                f"activation precision of a w{leaf.bits}a{leaf.a_bits} "
                "leaf; plane truncation only lowers weight bits — use "
                f"a{leaf.a_bits} in the tier spec"
            )
        counts["truncated"] += 1
        return dataclasses.replace(leaf, plane_lo=lo)

    view_params = jax.tree_util.tree_map(
        view, params, is_leaf=lambda l: isinstance(l, PackedWeight)
    )
    if not counts["packed"]:
        raise ValueError(
            "precision-tier views need bit-plane-packed weights: "
            "serve with a quant policy (e.g. --quant w8a8) so the view "
            "can truncate the resident planes"
        )
    if not counts["truncated"]:
        if require_truncation:
            raise ValueError(
                f"draft policy w{cfg.w_bits} truncates no leaf: every "
                "packed weight is already at or below the draft precision"
            )
        return params, 0
    return view_params, counts["truncated"]
