"""Batched serving engine: continuous-batching prefill/decode with the
M4BRAM quantized-weight path.

The engine owns:
  * a request queue with admission up to `max_batch` concurrent sequences,
  * one jitted prefill per bucketed prompt length + one jitted decode step,
  * optional serving-time weight quantization (PackedWeight params) — the
    paper's technique as deployed: weights live packed in HBM and every
    matmul runs the bit-plane path, cutting weight bytes by 8/w_bits×.
    `quant` takes either a single QuantConfig (uniform precision) or a
    per-layer PrecisionPolicy (repro.core.precision) so different layers
    serve at different (w_bits, a_bits),
  * simple greedy / temperature sampling.

Decode batches one token across all live sequences per step (static batch,
finished slots masked) — the standard TPU-serving shape discipline: every
step has one compiled signature.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.precision import PrecisionPolicy, as_policy
from repro.core.quant import QuantConfig
from repro.core.quantized_linear import quantize_params_for_serving
from repro.models import build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (T,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: Optional[List[int]] = None


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        max_batch: int = 8,
        quant: Union[None, QuantConfig, PrecisionPolicy] = None,
        bucket: int = 64,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.policy = as_policy(quant)
        if self.policy is not None:
            params = quantize_params_for_serving(params, self.policy,
                                                 min_size=1024)
        self.params = params
        self.max_batch = max_batch
        self.bucket = bucket
        self.rng = np.random.default_rng(seed)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        self._prefill_cache = {}

    def _prefill_fn(self, length: int):
        if length not in self._prefill_cache:
            self._prefill_cache[length] = jax.jit(self.model.prefill)
        return self._prefill_cache[length]

    def _bucketed(self, n: int) -> int:
        return max(self.bucket, -(-n // self.bucket) * self.bucket)

    def generate(self, requests: List[Request]) -> List[Request]:
        """Synchronous batch generation (prefill batch → decode loop)."""
        out: List[Request] = []
        for i in range(0, len(requests), self.max_batch):
            out.extend(self._generate_batch(requests[i : i + self.max_batch]))
        return out

    def _generate_batch(self, reqs: List[Request]) -> List[Request]:
        B = len(reqs)
        L = self._bucketed(max(len(r.prompt) for r in reqs))
        tokens = np.zeros((B, L), np.int32)
        for i, r in enumerate(reqs):
            tokens[i, L - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(tokens)}
        cache, logits = self._prefill_fn(L)(self.params, batch)
        max_new = max(r.max_new_tokens for r in reqs)
        cur = self._sample(logits, reqs)
        outs = [[int(cur[i, 0])] for i in range(B)]
        for _ in range(max_new - 1):
            cache, logits = self._decode(self.params, cache, jnp.asarray(cur))
            cur = self._sample(logits, reqs)
            for i in range(B):
                if len(outs[i]) < reqs[i].max_new_tokens:
                    outs[i].append(int(cur[i, 0]))
        for r, o in zip(reqs, outs):
            r.out_tokens = o
        return reqs

    def _sample(self, logits, reqs) -> np.ndarray:
        lg = np.asarray(logits[:, -1, :], np.float32)
        toks = np.empty((len(reqs), 1), np.int32)
        for i, r in enumerate(reqs):
            if r.temperature <= 0:
                toks[i, 0] = int(np.argmax(lg[i]))
            else:
                p = np.exp((lg[i] - lg[i].max()) / r.temperature)
                p /= p.sum()
                toks[i, 0] = int(self.rng.choice(len(p), p=p))
        return toks
