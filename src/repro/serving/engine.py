"""Batched serving engine over the M4BRAM quantized-weight path.

The engine owns serving-time weight quantization (PackedWeight params —
the paper's technique as deployed: weights live packed in HBM and every
matmul runs the bit-plane path, cutting weight bytes by 8/w_bits×; `quant`
takes either a single QuantConfig or a per-layer PrecisionPolicy) and two
execution modes:

  * `generate`     — continuous batching via `ContinuousScheduler`: one
    fixed compiled decode signature, solo prefill scattered into freed
    slots mid-decode, per-slot EOS/max_new retirement, on-device sampling,
    and (for full-attention archs, by default) the paged block-pool KV
    cache — admission is bounded by actual resident tokens, not a per-slot
    `max_ctx` reservation — with cross-request prefix caching on top
    (shared refcounted prompt-prefix blocks, suffix-only prefill). With
    `speculate=k` greedy slots self-speculate: a truncated-plane view of
    the resident packed weights drafts k tokens per step and one
    chunk-shaped full-policy call verifies them (`repro.serving
    .speculative`), emitting the longest matching prefix — bitwise the
    non-speculative greedy stream. With `tiers="w8a8,w4a8,w2a8"` each
    request may name a precision tier (`Request.tier`) and is served
    through a plane-truncated view of the same packed weights inside the
    same continuous batch — greedy bit-identical to a solo engine whose
    whole policy is that tier (`repro.serving.scheduler`). Requests carry
    a lifecycle: `cancel(rid)` and per-request deadlines retire early
    with an `error`, pool pressure may preempt a victim and later resume
    it warm from prefix-cached blocks (bitwise the uninterrupted stream),
    and a seeded `FaultInjector` (`chaos=`) exercises the failure seams.
  * `generate_static` — the classic static batch (batched prefill → decode
    loop, finished slots masked), kept as the baseline the serving
    benchmark measures continuous batching against. The decode loop exits
    as soon as every sequence in the batch has finished, and the cache is
    grown past the prefill headroom when `max_new_tokens` needs it (an
    overflowing decode used to silently rewrite the last cache slot via
    `write_slot`'s clamp; now it either fits or raises when `max_ctx`
    caps it).

Prompts are right-padded to the bucket with the real length passed to
prefill, so pad tokens never occupy cache slots or shift rope positions:
a request's greedy output is identical between the two modes and across
bucket sizes. Sampled outputs are too, because both paths draw from the
same per-request (seed, rid, step) PRNG streams (`repro.serving.sampling`).
"""
from __future__ import annotations

from typing import List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.precision import PrecisionPolicy, as_policy
from repro.core.quant import QuantConfig
from repro.core.quantized_linear import quantize_params_for_serving
from repro.models import build_model
from repro.models.kv_cache import KVCache, grow_cache
from repro.serving import sampling
from repro.serving.scheduler import ContinuousScheduler, Request  # noqa: F401


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        max_batch: int = 8,
        quant: Union[None, QuantConfig, PrecisionPolicy] = None,
        bucket: int = 64,
        seed: int = 0,
        max_ctx: Optional[int] = None,
        on_token=None,
        paged: Optional[bool] = None,
        block_size: int = 16,
        pool_blocks: Optional[int] = None,
        prefix_cache: Optional[bool] = None,
        chunked_prefill: Optional[bool] = None,
        prefill_budget: int = 32,
        speculate: int = 0,
        draft_policy: Union[str, QuantConfig] = "w4a8",
        tiers=None,
        preempt: Optional[bool] = None,
        victim_policy: str = "most-blocks",
        max_head_bypass: int = 4,
        degrade: bool = False,
        degrade_after: int = 2,
        chaos=None,
        host_pool_bytes: int = 0,
    ):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.policy = as_policy(quant)
        if self.policy is not None:
            params = quantize_params_for_serving(params, self.policy,
                                                 min_size=1024)
        self.params = params
        self.max_batch = max_batch
        self.bucket = bucket
        self.seed = seed
        self.max_ctx = max_ctx
        self.on_token = on_token            # streamed-token callback
        self.paged = paged                  # None = auto (paged if eligible)
        self.block_size = block_size
        self.pool_blocks = pool_blocks
        self.prefix_cache = prefix_cache    # None = auto (on if paged-able)
        self.chunked_prefill = chunked_prefill  # None = auto (on if eligible)
        self.prefill_budget = prefill_budget
        self.speculate = speculate          # draft tokens/step (0 = off)
        self.draft_policy = draft_policy    # plane-truncation draft spec
        self.tiers = tiers                  # per-request precision tiers
        self.preempt = preempt              # None = auto (on when paged)
        self.victim_policy = victim_policy
        self.max_head_bypass = max_head_bypass
        self.degrade = degrade              # admit at floor tier under pressure
        self.degrade_after = degrade_after
        self.chaos = chaos                  # FaultInjector (tests/chaos runs)
        self.host_pool_bytes = host_pool_bytes  # host-RAM spill tier budget
        self._index_data = None             # deferred load_index payload
        self._sched: Optional[ContinuousScheduler] = None
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        self._prefill_cache = {}

    def _prefill_fn(self, length: int):
        # Key by *bucketed* length — callers pad to the bucket anyway, so
        # a raw-length key would compile one executable per distinct
        # long-tail prompt length.
        length = self._bucketed(length)
        if length not in self._prefill_cache:
            self._prefill_cache[length] = jax.jit(self.model.prefill)
        return self._prefill_cache[length]

    def _bucketed(self, n: int) -> int:
        return max(self.bucket, -(-n // self.bucket) * self.bucket)

    # -- continuous path ----------------------------------------------------

    def scheduler(self, max_ctx: Optional[int] = None) -> ContinuousScheduler:
        """The engine's (lazily built) continuous scheduler. Rebuilt only
        if a larger context bound is requested. An explicit engine
        `max_ctx` is a hard cap in both modes: the scheduler never grows
        past it (requests beyond it come back failed, mirroring the
        static path's ValueError guard)."""
        if self.max_ctx is not None:
            need = self.max_ctx
        else:
            need = max_ctx or 128
        if self._sched is None or need > self._sched.max_ctx:
            # Carry the prefix index across the rebuild: a deferred
            # `load_index` payload seeds the first scheduler; on a
            # max_ctx-growth rebuild the OLD scheduler's live index (its
            # snapshot covers hashed device blocks and the host store) is
            # fresher and wins. Block geometry is max_ctx-independent, so
            # the snapshot imports cleanly into the grown pool.
            carry = self._index_data
            self._index_data = None
            if self._sched is not None and self._sched.host_tier:
                carry = self._sched.export_index()
            self._sched = ContinuousScheduler(
                self.cfg, self.params, max_batch=self.max_batch,
                max_ctx=need, quant=None, bucket=self.bucket, seed=self.seed,
                on_token=self.on_token, paged=self.paged,
                block_size=self.block_size, pool_blocks=self.pool_blocks,
                prefix_cache=self.prefix_cache,
                chunked_prefill=self.chunked_prefill,
                prefill_budget=self.prefill_budget,
                speculate=self.speculate,
                draft_policy=self.draft_policy,
                tiers=self.tiers,
                preempt=self.preempt,
                victim_policy=self.victim_policy,
                max_head_bypass=self.max_head_bypass,
                degrade=self.degrade,
                degrade_after=self.degrade_after,
                chaos=self.chaos,
                host_pool_bytes=self.host_pool_bytes,
            )
            if carry:
                self._sched.import_index(carry)
        self._sched.on_token = self.on_token  # pick up late reassignment
        return self._sched

    def pool_stats(self) -> Optional[dict]:
        """KV-pool utilization of the continuous scheduler (None before
        the first `generate`)."""
        return self._sched.pool_stats() if self._sched is not None else None

    def cancel(self, rid: int) -> bool:
        """Cancel a queued or live request on the continuous scheduler.
        False if no scheduler exists yet or `rid` is unknown / already
        retired; True means the request will come back with
        ``error="cancelled"`` at the next step boundary."""
        return self._sched.cancel(rid) if self._sched is not None else False

    # -- durable prefix index (host-tier persistence) ------------------------

    def save_index(self, path) -> int:
        """Persist the scheduler's prefix index (device + host tiers) to
        `path` as JSON. Returns the number of digests written; 0 when no
        scheduler has been built yet and nothing was loaded."""
        if self._sched is not None:
            return self._sched.save_index(path)
        if self._index_data:
            import json
            with open(path, "w") as f:
                json.dump(self._index_data, f)
                f.write("\n")
            return len(self._index_data.get("digests", {}))
        return 0

    def load_index(self, path) -> int:
        """Load a `save_index` file. With a live scheduler the snapshot
        is imported into its host tier immediately; before the first
        `generate` the parsed payload is stashed and imported when the
        scheduler is built (returning the digest count found in the
        file). Missing/corrupt files warn and cold-start with 0 — the
        same never-crash contract as `--plans`."""
        if self._sched is not None:
            return self._sched.load_index(path)
        import json
        import warnings
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError) as e:
            warnings.warn(f"prefix-index load from {path!s} failed ({e}) "
                          "— cold start")
            return 0
        if not isinstance(data, dict):
            warnings.warn("prefix-index load: unrecognized payload — "
                          "cold start")
            return 0
        self._index_data = data
        digests = data.get("digests")
        return len(digests) if isinstance(digests, dict) else 0

    def _ctx_needed(self, requests: List[Request]) -> int:
        return max(self._bucketed(len(r.prompt)) + max(r.max_new_tokens, 1)
                   for r in requests)

    def generate(self, requests: List[Request]) -> List[Request]:
        """Continuous-batching generation: requests are admitted into free
        slots as they open (honouring `arrival_time`), retired on EOS or
        max_new_tokens. Returns the input requests (out_tokens filled),
        in input order."""
        if not requests:
            return []
        self.scheduler(self._ctx_needed(requests)).run(requests)
        return list(requests)

    # -- static baseline ----------------------------------------------------

    def generate_static(self, requests: List[Request]) -> List[Request]:
        """Static batch generation (prefill batch → decode loop). The
        baseline continuous batching is benchmarked against."""
        out: List[Request] = []
        for i in range(0, len(requests), self.max_batch):
            out.extend(self._generate_batch(requests[i : i + self.max_batch]))
        return out

    def _grown(self, cache, needed: int):
        """Capacity guard + growth for the static full-attention cache:
        refuse (don't silently ring-overwrite) when `max_ctx` caps the
        batch, otherwise extend the cache to cover every decode write.
        Growth is rounded to the bucket so the decode signature count
        stays bounded."""
        kv = cache.kv
        if kv is None or not isinstance(kv, KVCache) or kv.window:
            return cache
        if self.max_ctx is not None and needed > self.max_ctx:
            raise ValueError(
                f"static batch writes {needed} cache slots but max_ctx is "
                f"{self.max_ctx}; raise max_ctx or lower max_new_tokens"
            )
        if needed > kv.k.shape[2]:
            cache = grow_cache(cache, -(-needed // self.bucket) * self.bucket)
        return cache

    def _generate_batch(self, reqs: List[Request]) -> List[Request]:
        B = len(reqs)
        lens = [len(r.prompt) for r in reqs]
        L = self._bucketed(max(lens))
        tokens = np.zeros((B, L), np.int32)
        for i, r in enumerate(reqs):
            tokens[i, : lens[i]] = r.prompt  # right-pad; real len in lengths
        batch = {"tokens": jnp.asarray(tokens),
                 "lengths": jnp.asarray(lens, jnp.int32)}
        cache, logits = self._prefill_fn(L)(self.params, batch)
        # Highest decode write is at position len + max_new - 2 (the first
        # sampled token comes from the prefill logits and writes nothing;
        # max_new <= 0 still emits it, hence the clamp).
        needed = max(n + max(r.max_new_tokens, 1) - 1
                     for n, r in zip(lens, reqs))
        cache = self._grown(cache, needed)

        temps = np.asarray([r.temperature for r in reqs], np.float32)
        top_ks = np.asarray([r.top_k for r in reqs], np.int32)
        keys = np.stack([sampling.request_key(self.seed, r.rid) for r in reqs])
        steps = np.zeros((B,), np.int32)

        def sample(lg):
            return np.asarray(sampling.sample_tokens(
                lg[:, -1, :], temps, top_ks, keys, steps))

        cur = sample(logits)
        steps += 1
        outs = [[int(cur[i])] for i in range(B)]
        done = [len(o) >= r.max_new_tokens
                or (r.eos_id is not None and o[-1] == r.eos_id)
                for o, r in zip(outs, reqs)]
        max_new = max(r.max_new_tokens for r in reqs)
        for _ in range(max_new - 1):
            if all(done):
                break  # every sequence hit max_new/EOS — no wasted steps
            cache, logits = self._decode(self.params, cache,
                                         jnp.asarray(cur[:, None]))
            cur = sample(logits)
            steps += 1
            for i, r in enumerate(reqs):
                if not done[i]:
                    outs[i].append(int(cur[i]))
                    done[i] = (len(outs[i]) >= r.max_new_tokens
                               or (r.eos_id is not None
                                   and outs[i][-1] == r.eos_id))
        for r, o in zip(reqs, outs):
            r.out_tokens = o
        return reqs
