"""Structural invariants of the paged KV block pool.

:func:`assert_pool_invariants` is the one shared checker the chaos suite,
the prefix-cache / tier / speculative tests, and the lifecycle tests all
call. It is valid at ANY step boundary — mid-serve with live rows, after a
preemption, or fully drained — because every property below is maintained
by the allocator at all times:

  * refcount conservation: ``_refcnt[blk]`` equals the number of block-
    table cells referencing ``blk`` across all rows;
  * partition: every pool block is in exactly one of {free list, LRU,
    referenced-by-a-table}; the trash block 0 is in none of them;
  * free-list integrity: no duplicates, disjoint from tables and LRU;
  * LRU membership: only refcount-0 *hashed* blocks are retained;
  * index consistency: ``_prefix_index`` (hash -> block) and
    ``_block_hash`` (block -> digest set) are exact inverses — every
    index entry appears in its block's digest set and vice versa (one
    block may carry several digests, e.g. a retired straddle block) —
    and every hashed block is resident (live or LRU); an evicted block
    leaves both maps;
  * reservation accounting: ``_avail`` (what admission may still promise)
    equals free + LRU-reclaimable minus outstanding reservations, is
    never negative, and empty rows hold no reservation and no blocks;
  * host tier: ``_host_index`` (hash -> host entry) and each host
    entry's digest set are exact inverses, no digest resolves to BOTH a
    device block and a host copy (exclusivity — a hit must have exactly
    one source of truth), ``host_bytes`` equals the sum of resident
    entry sizes and never exceeds ``host_pool_bytes``, and a disabled
    tier holds nothing.
"""
from __future__ import annotations

import collections


def assert_pool_invariants(sched) -> None:
    """Assert the paged-pool invariants on a ContinuousScheduler (no-op
    for contiguous-cache schedulers). Raises AssertionError with a
    pointed message on the first violated property."""
    if not getattr(sched, "paged", False):
        return
    tab = sched._block_tab
    refs = collections.Counter(int(blk) for blk in tab[tab >= 0])

    assert 0 not in refs, "trash block 0 mapped into a live block table"
    for blk in range(1, sched.pool_blocks + 1):
        assert int(sched._refcnt[blk]) == refs.get(blk, 0), (
            f"refcount drift on block {blk}: refcnt="
            f"{int(sched._refcnt[blk])} but {refs.get(blk, 0)} table refs")
    assert int(sched._refcnt[0]) == 0, "trash block 0 has a refcount"

    free = list(sched._free)
    fs, lru, live = set(free), set(sched._lru), set(refs)
    assert len(fs) == len(free), "free list holds duplicate blocks"
    assert 0 not in fs and 0 not in lru, "trash block 0 in free list / LRU"
    assert not fs & live, f"free blocks still referenced: {sorted(fs & live)}"
    assert not fs & lru, f"blocks both free and LRU-retained: {sorted(fs & lru)}"
    assert not lru & live, f"LRU blocks still referenced: {sorted(lru & live)}"
    every = set(range(1, sched.pool_blocks + 1))
    assert fs | lru | live == every, (
        f"pool partition leak: lost blocks {sorted(every - fs - lru - live)}")

    for blk in lru:
        assert blk in sched._block_hash, (
            f"LRU retains unhashed block {blk} (nothing could ever hit it)")

    assert len(sched._prefix_index) == sum(
        len(hs) for hs in sched._block_hash.values()), (
        "prefix index / block-hash map size mismatch")
    for h, blk in sched._prefix_index.items():
        assert h in sched._block_hash.get(blk, ()), (
            f"prefix index entry missing from block {blk}'s digest set")
    for blk, hs in sched._block_hash.items():
        assert hs, f"block {blk} hashed with an empty digest set"
        for h in hs:
            assert sched._prefix_index.get(h) == blk, (
                f"digest on block {blk} not indexed back to it")
        assert blk in live or blk in lru, (
            f"hashed block {blk} is neither live nor LRU-retained")

    assert (sched._reserved >= 0).all(), "negative per-row reservation"
    for b, req in enumerate(sched._slots):
        if req is None:
            assert int(sched._reserved[b]) == 0, (
                f"empty row {b} holds a reservation")
            assert (tab[b] == -1).all(), f"empty row {b} still maps blocks"
    assert sched._avail == len(free) + len(lru) - int(sched._reserved.sum()), (
        f"_avail drift: {sched._avail} != {len(free)} free + {len(lru)} LRU "
        f"- {int(sched._reserved.sum())} reserved")
    assert sched._avail >= 0, "negative available-capacity accounting"

    # -- host-RAM spill tier -------------------------------------------------
    store = getattr(sched, "_host_store", None)
    if store is None:
        return
    if not getattr(sched, "host_tier", False):
        assert not store and not sched._host_index and not sched.host_bytes, (
            "host tier disabled but host state is non-empty")
        return
    for hid, entry in store.items():
        assert entry.digests, f"host entry {hid} holds an empty digest set"
        for h in entry.digests:
            assert sched._host_index.get(h) == hid, (
                f"digest on host entry {hid} not indexed back to it")
            assert h not in sched._prefix_index, (
                f"digest resolves to both device block "
                f"{sched._prefix_index.get(h)} and host entry {hid}")
    assert len(sched._host_index) == sum(
        len(e.digests) for e in store.values()), (
        "host index / host store digest-count mismatch")
    for h, hid in sched._host_index.items():
        assert hid in store, f"host index points at evicted entry {hid}"
    got = sum(e.nbytes for e in store.values())
    assert sched.host_bytes == got, (
        f"host_bytes drift: tracked {sched.host_bytes} != resident {got}")
    assert sched.host_bytes <= sched.host_pool_bytes, (
        f"host tier over budget: {sched.host_bytes} > "
        f"{sched.host_pool_bytes}")
