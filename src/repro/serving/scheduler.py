"""Continuous-batching scheduler: request queue, slot table, mid-decode
admission, per-slot decode state, paged KV allocation, on-device sampling.

The serving analogue of the paper's headline property (the M4BRAM computes
while remaining fully usable as memory): the decode batch keeps computing
while individual slots are drained and refilled — no global barrier
between "batches" ever exists — and, with the paged cache, KV memory is
committed per *actual* request footprint instead of a worst-case `max_ctx`
reservation per slot.

Design:
  * ``max_batch`` decode slots. The jitted decode step always runs the
    full ``(max_batch, 1)`` token batch — ONE compiled decode signature
    for the scheduler's whole lifetime; slot occupancy changes, shapes
    never do. Free slots decode a dummy token whose output is discarded.
  * Admission: a waiting request is prefilled solo (B=1, prompt
    right-padded to a bucket, real length passed as ``lengths`` so pad
    slots never enter the cache or shift rope positions), and its KV /
    recurrent / RWKV state is scattered into the freed batch row
    (``kv_cache.scatter_into_slot`` / ``scatter_into_paged``). Only that
    row changes, so requests join mid-decode without perturbing live
    slots — a request's greedy output is bit-identical whether it is
    served solo, in a static batch, or admitted while other slots are
    deep into their decodes, and whether the cache is contiguous or paged.
  * Paged KV cache (full-attention archs, default): a shared block pool
    ``(L, num_blocks, block_size, NKV, H)`` plus per-slot block tables.
    Admission reserves the request's actual worst-case block count
    (``ceil((len + max_new - 1) / block_size)``) — when the pool can't
    cover it the request *queues* (no crash, no partial admission, no
    mid-decode deadlock). Blocks are allocated lazily: prompt blocks at
    admission, one more each time a decode step crosses a block boundary.
    Retirement frees a slot's blocks (and its unclaimed reservation)
    immediately.
  * Failure isolation: a request that can never fit (bucketed prompt or
    prompt + max_new beyond capacity) is marked failed (``Request.error``)
    and returned — it does not raise out of ``run()`` and live slots keep
    decoding.
  * Per-slot decode state: ``DecodeCache.pos``/``KVCache.slot_pos``/
    ``length`` all carry a batch axis; each slot's position advances
    independently of its neighbours.
  * Sampling: vectorized on-device greedy / temperature / top-k with
    per-slot parameters and per-request ``(seed, rid)``-derived PRNG
    streams (``repro.serving.sampling``).

Driving it: ``submit()`` + ``step()`` give deterministic single-step
control (tests, custom loops); ``run()`` drains a workload, honouring
each request's ``arrival_time`` against the wall clock (staggered /
Poisson arrivals for the continuous-serving benchmark).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.precision import PrecisionPolicy, as_policy
from repro.core.quant import QuantConfig
from repro.core.quantized_linear import quantize_params_for_serving
from repro.models import build_model
from repro.models.kv_cache import (
    KVCache,
    PagedKVCache,
    scatter_into_paged,
    scatter_into_slot,
)
from repro.serving import sampling


def _contig_headroom() -> int:
    from repro.models.transformer import DECODE_HEADROOM

    return DECODE_HEADROOM


@dataclasses.dataclass
class Request:
    """One generation request.

    ``arrival_time`` is seconds relative to the start of ``run()`` (0 =
    already queued). ``on_token`` streams tokens as they are sampled.
    ``t_first`` / ``t_done`` are filled by the scheduler (seconds since the
    run started) for latency accounting. ``error`` is set (and the request
    returned with no tokens) when it can never fit the cache — oversized
    requests are rejected individually instead of aborting the serve
    loop."""

    rid: int
    prompt: np.ndarray            # (T,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0                # 0 = no top-k filtering
    eos_id: Optional[int] = None
    arrival_time: float = 0.0
    on_token: Optional[Callable[["Request", int], None]] = None
    out_tokens: Optional[List[int]] = None
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    error: Optional[str] = None

    @property
    def failed(self) -> bool:
        return self.error is not None


class ContinuousScheduler:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_batch: int = 8,
        max_ctx: int = 128,
        quant: Union[None, QuantConfig, PrecisionPolicy] = None,
        bucket: int = 64,
        seed: int = 0,
        on_token: Optional[Callable[[Request, int], None]] = None,
        paged: Optional[bool] = None,
        block_size: int = 16,
        pool_blocks: Optional[int] = None,
    ):
        self.cfg = cfg
        self.model = build_model(cfg)
        policy = as_policy(quant)
        if policy is not None:
            params = quantize_params_for_serving(params, policy,
                                                 min_size=1024)
        self.params = params
        self.max_batch = max_batch
        self.max_ctx = max_ctx
        self.bucket = bucket
        self.seed = seed
        self.on_token = on_token

        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        self._scatter = jax.jit(scatter_into_slot, donate_argnums=(0,))
        self._scatter_paged = jax.jit(scatter_into_paged, donate_argnums=(0,))
        self._prefill_cache = {}

        # Cache flavour. Paged needs a full-attention KV cache (ring
        # buffers are already window-bounded) — eligible archs default to
        # paged. An int8 cache (cfg.kv_cache_quant) pages too: the pool
        # carries scale-plane blocks and the fused paged-attention kernel
        # dequantizes in-kernel.
        init_paged = getattr(self.model, "init_paged_cache", None)
        can_page = init_paged is not None and not cfg.attn_window
        if paged is None:
            paged = can_page
        elif paged and not can_page:
            raise ValueError(
                f"{cfg.name}: paged KV cache requires a full-attention "
                "cache (ring buffers and recurrent states are already "
                "footprint-bounded)"
            )
        self.paged = paged
        self.block_size = block_size

        B = max_batch
        if paged:
            # Per-row virtual capacity = max_ctx rounded up to blocks; the
            # pool defaults to the contiguous worst case (every slot full)
            # — pass a smaller pool_blocks to overcommit.
            self._max_blocks = -(-max_ctx // block_size)
            usable = (pool_blocks if pool_blocks is not None
                      else max_batch * self._max_blocks)
            if usable < 1:
                raise ValueError("pool_blocks must be >= 1")
            self.pool_blocks = usable
            self.cache = init_paged(B, usable + 1, block_size,
                                    self._max_blocks)  # +1: trash block 0
            # Admission bound: max_ctx in every mode (the block-rounded
            # physical row is >= this), so static / contiguous / paged
            # agree on which requests fit.
            self._capacity = max_ctx
            self._free: List[int] = list(range(usable, 0, -1))
            self._avail = usable          # free minus outstanding reservations
            self._reserved = np.zeros((B,), np.int64)
            self._block_tab = np.full((B, self._max_blocks), -1, np.int32)
            self._table_dirty = False
            self._peak_blocks = 0
        else:
            # Fixed-shape contiguous state: every slot reserves a full
            # max_ctx(+headroom) row for its whole lifetime.
            self.cache = self.model.init_cache(max_batch, max_ctx)
            kv = self.cache.kv
            # Full-attention caches bound the absolute positions a slot
            # can reach (admission bound = max_ctx in every mode; the
            # physical row carries headroom beyond it); ring buffers and
            # recurrent states are position-unbounded.
            self._capacity = (
                max_ctx if isinstance(kv, KVCache) and kv.window == 0
                else None
            )

        self._pos_host = np.zeros((B,), np.int64)    # next write position
        self._cur = np.zeros((B, 1), np.int32)       # next input token/slot
        self._temps = np.zeros((B,), np.float32)
        self._top_ks = np.zeros((B,), np.int32)
        self._keys = np.zeros((B, 2), np.uint32)
        self._steps = np.zeros((B,), np.int32)       # per-request token ctr
        self._slots: List[Optional[Request]] = [None] * B
        self.waiting: Deque[Request] = collections.deque()
        self.steps_run = 0
        self.tokens_emitted = 0
        self._t0: Optional[float] = None             # set by run()

    # -- queue/slot accounting ---------------------------------------------

    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self._slots)

    @property
    def num_waiting(self) -> int:
        return len(self.waiting)

    def submit(self, req: Request) -> None:
        """Queue a request for admission into the next free slot."""
        self.waiting.append(req)

    def _bucketed(self, n: int) -> int:
        return max(self.bucket, -(-n // self.bucket) * self.bucket)

    def _prefill_fn(self, length: int):
        if length not in self._prefill_cache:
            self._prefill_cache[length] = jax.jit(self.model.prefill)
        return self._prefill_cache[length]

    def _now(self) -> Optional[float]:
        return None if self._t0 is None else time.perf_counter() - self._t0

    # -- paged-pool accounting ---------------------------------------------

    def _need_tokens(self, req: Request) -> int:
        # The first sampled token comes from the prefill logits and writes
        # no cache slot; only the remaining max_new - 1 decode inputs do.
        # max_new <= 0 still emits that prefill token, so it reserves like
        # max_new = 1 (anything less would under-reserve the prompt).
        return len(req.prompt) + max(req.max_new_tokens, 1) - 1

    def _need_blocks(self, req: Request) -> int:
        return -(-self._need_tokens(req) // self.block_size)

    def _reject_reason(self, req: Request) -> Optional[str]:
        """Non-None iff the request can never be served by this scheduler
        (vs. transiently waiting for pool blocks)."""
        if self._capacity is None:
            return None
        need = self._need_tokens(req)
        if self.paged:
            if need > self._capacity or self._need_blocks(req) > self.pool_blocks:
                return (f"request {req.rid}: prompt ({len(req.prompt)}) + "
                        f"max_new_tokens ({req.max_new_tokens}) needs {need} "
                        f"cache slots, beyond capacity ({self._capacity} "
                        f"per slot, {self.pool_blocks * self.block_size} "
                        "pooled); raise max_ctx / pool_blocks")
            return None
        L = self._bucketed(len(req.prompt))
        # The solo prefill array carries L + headroom slots and must fit
        # the max_ctx + headroom row, hence the L > max_ctx bound.
        if L > self.max_ctx or need > self._capacity:
            return (f"request {req.rid}: bucketed prompt ({L}) or prompt + "
                    f"max_new_tokens ({need} slots) exceeds cache capacity "
                    f"(max_ctx {self.max_ctx}, {self._capacity} slots); "
                    "raise max_ctx")
        return None

    def _alloc_block(self, slot: int, j: int) -> None:
        if not self._free:
            raise RuntimeError(
                "paged pool invariant violated: reservation accounting "
                "should guarantee a free block"
            )
        self._block_tab[slot, j] = self._free.pop()
        self._reserved[slot] -= 1
        self._table_dirty = True
        self._peak_blocks = max(self._peak_blocks,
                                self.pool_blocks - len(self._free))

    def _alloc_boundary_blocks(self) -> None:
        """Allocate the block backing the position each live slot writes
        this step (a no-op except on block-boundary crossings)."""
        for b, req in enumerate(self._slots):
            if req is None:
                continue
            j = int(self._pos_host[b]) // self.block_size
            if j < self._max_blocks and self._block_tab[b, j] < 0:
                self._alloc_block(b, j)

    def _sync_table(self) -> None:
        if self._table_dirty:
            self.cache = dataclasses.replace(
                self.cache,
                kv=dataclasses.replace(
                    self.cache.kv, block_table=jnp.asarray(self._block_tab)
                ),
            )
            self._table_dirty = False

    def _release_slot(self, b: int) -> None:
        self._slots[b] = None
        if not self.paged:
            return
        row = self._block_tab[b]
        used = row[row >= 0]
        self._free.extend(int(x) for x in used)
        row[:] = -1
        self._avail += len(used) + int(self._reserved[b])
        self._reserved[b] = 0
        self._table_dirty = True

    def pool_stats(self) -> dict:
        """KV-memory utilization: resident bytes actually backing live
        tokens vs. the contiguous worst-case reservation."""
        kv = self.cache.kv
        if kv is None:
            return {"paged": False, "resident_kv_bytes": 0,
                    "reserved_kv_bytes": 0}
        if not self.paged:
            # Count every cache plane (incl. int8 scale planes) — the
            # whole reservation is resident for the scheduler's lifetime.
            total = sum(int(np.prod(a.shape)) * a.dtype.itemsize
                        for a in (kv.k, kv.v, kv.k_scale, kv.v_scale)
                        if a is not None)
            return {"paged": False,
                    "resident_kv_bytes": total,
                    "reserved_kv_bytes": total}
        per_token = (kv.k.shape[0] * int(np.prod(kv.k.shape[3:]))
                     * 2 * kv.k.dtype.itemsize)
        if kv.quantized:
            # int8 pool: add the per-(slot, head) fp32 k/v scale planes.
            per_token += kv.k.shape[0] * kv.k.shape[3] * 2 * 4
        allocated = self.pool_blocks - len(self._free)
        return {
            "paged": True,
            "block_size": self.block_size,
            "pool_blocks": self.pool_blocks,
            "free_blocks": len(self._free),
            "allocated_blocks": allocated,
            "peak_allocated_blocks": self._peak_blocks,
            "capacity_tokens": self.pool_blocks * self.block_size,
            "resident_kv_bytes": allocated * self.block_size * per_token,
            "peak_resident_kv_bytes":
                self._peak_blocks * self.block_size * per_token,
            # What the contiguous scheduler would allocate for the same
            # settings: max_ctx + decode headroom per slot (matches the
            # non-paged branch, which measures the actual arrays).
            "reserved_kv_bytes":
                self.max_batch * (self.max_ctx + _contig_headroom())
                * per_token,
        }

    def reset_pool_peak(self) -> None:
        if self.paged:
            self._peak_blocks = self.pool_blocks - len(self._free)

    # -- admission / retirement --------------------------------------------

    def _fail(self, req: Request, reason: str) -> None:
        req.error = reason
        if req.out_tokens is None:
            req.out_tokens = []
        req.t_done = self._now()

    def _admit(self, req: Request, slot: int) -> Optional[Request]:
        """Prefill `req` solo and scatter its state into batch row `slot`.
        Returns the request if it finished on its very first token."""
        n = len(req.prompt)
        L = self._bucketed(n)
        tokens = np.zeros((1, L), np.int32)
        tokens[0, :n] = req.prompt  # right-pad; real length via `lengths`
        solo, logits = self._prefill_fn(L)(
            self.params,
            {"tokens": jnp.asarray(tokens),
             "lengths": jnp.asarray([n], jnp.int32)},
        )
        if self.paged:
            need = self._need_blocks(req)
            self._avail -= need
            self._reserved[slot] = need
            for j in range(-(-n // self.block_size)):
                self._alloc_block(slot, j)
            # scatter_into_paged also writes this row's table device-side;
            # _table_dirty stays set so rows freed earlier still sync.
            self.cache = self._scatter_paged(
                self.cache, solo, slot, jnp.asarray(self._block_tab[slot])
            )
        else:
            self.cache = self._scatter(self.cache, solo, slot)
        self._pos_host[slot] = n

        key = sampling.request_key(self.seed, req.rid)
        tok = int(np.asarray(sampling.sample_tokens(
            logits[:, -1, :],
            np.asarray([req.temperature], np.float32),
            np.asarray([req.top_k], np.int32),
            key[None],
            np.zeros((1,), np.int32),
        ))[0])
        self._cur[slot, 0] = tok
        self._temps[slot] = req.temperature
        self._top_ks[slot] = req.top_k
        self._keys[slot] = key
        self._steps[slot] = 1
        self._slots[slot] = req
        req.out_tokens = [tok]
        if req.t_first is None:
            req.t_first = self._now()
        self._emit(req, tok)
        if self._finished(req, tok):
            self._release_slot(slot)
            return req
        return None

    def _emit(self, req: Request, tok: int) -> None:
        self.tokens_emitted += 1
        if req.on_token is not None:
            req.on_token(req, tok)
        if self.on_token is not None:
            self.on_token(req, tok)

    @staticmethod
    def _finished(req: Request, tok: int) -> bool:
        return (len(req.out_tokens) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id))

    # -- the decode loop ----------------------------------------------------

    def step(self) -> List[Request]:
        """One scheduler step: admit waiting requests into free slots, run
        one batched decode step, sample, retire finished slots. Returns
        the requests that finished this step (including any rejected as
        oversized — those carry ``error`` and no tokens)."""
        finished: List[Request] = []
        blocked = False
        for b in range(self.max_batch):
            if self._slots[b] is not None or blocked:
                continue
            while self.waiting:
                head = self.waiting[0]
                reason = self._reject_reason(head)
                if reason is not None:
                    # Oversized: reject just this request and keep serving.
                    self.waiting.popleft()
                    self._fail(head, reason)
                    finished.append(head)
                    continue
                if self.paged and self._need_blocks(head) > self._avail:
                    blocked = True  # pool full: queue (FIFO), don't crash
                    break
                self.waiting.popleft()
                done = self._admit(head, b)
                if done is not None:
                    # Finished on its prefill token (max_new <= 1 /
                    # instant EOS) — the slot is free again, keep
                    # admitting into it this same step.
                    finished.append(done)
                    continue
                break
        if self.num_active == 0:
            return finished

        if self.paged:
            self._alloc_boundary_blocks()
            self._sync_table()
        self.cache, logits = self._decode(self.params, self.cache,
                                          jnp.asarray(self._cur))
        toks = np.asarray(sampling.sample_tokens(
            logits[:, -1, :], self._temps, self._top_ks,
            self._keys, self._steps,
        ))
        self._steps += 1
        self.steps_run += 1
        for b, req in enumerate(self._slots):
            if req is None:
                continue
            self._pos_host[b] += 1
            tok = int(toks[b])
            req.out_tokens.append(tok)
            self._emit(req, tok)
            if self._finished(req, tok):
                self._release_slot(b)
                finished.append(req)
            else:
                self._cur[b, 0] = tok
        return finished

    def run(self, requests=()) -> List[Request]:
        """Serve a workload to completion, admitting each request no
        earlier than its ``arrival_time`` (seconds from now). Returns the
        requests in completion order with ``t_first``/``t_done`` filled;
        oversized requests come back failed (``error`` set) without
        aborting the loop."""
        pending = sorted(requests, key=lambda r: r.arrival_time)
        self._t0 = time.perf_counter()
        done: List[Request] = []
        while pending or self.waiting or self.num_active:
            now = time.perf_counter() - self._t0
            while pending and pending[0].arrival_time <= now:
                self.submit(pending.pop(0))
            if not self.waiting and self.num_active == 0:
                # Idle: sleep up to the next arrival.
                time.sleep(min(max(pending[0].arrival_time - now, 0.0), 0.05))
                continue
            for req in self.step():
                req.t_done = time.perf_counter() - self._t0
                done.append(req)
        self._t0 = None
        return done
