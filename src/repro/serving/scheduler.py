"""Continuous-batching scheduler: request queue, slot table, mid-decode
admission, per-slot decode state, on-device sampling.

The serving analogue of the paper's headline property (the M4BRAM computes
while remaining fully usable as memory): the decode batch keeps computing
while individual slots are drained and refilled — no global barrier
between "batches" ever exists.

Design:
  * ``max_batch`` decode slots. The jitted decode step always runs the
    full ``(max_batch, 1)`` token batch — ONE compiled decode signature
    for the scheduler's whole lifetime; slot occupancy changes, shapes
    never do. Free slots decode a dummy token whose output is discarded.
  * Admission: a waiting request is prefilled solo (B=1, prompt bucketed),
    and its KV / recurrent / RWKV state is scattered into the freed batch
    row (``kv_cache.scatter_into_slot``). Only that row changes, so
    requests join mid-decode without perturbing live slots — a request's
    greedy output is bit-identical whether it is served solo, in a static
    batch, or admitted while other slots are deep into their decodes.
  * Per-slot decode state: ``DecodeCache.pos``/``KVCache.slot_pos``/
    ``length`` all carry a batch axis; each slot's position advances
    independently of its neighbours.
  * Retirement: per-request ``max_new_tokens`` or EOS frees the slot; the
    next waiting request is admitted on the same scheduler step.
  * Sampling: vectorized on-device greedy / temperature / top-k with
    per-slot parameters and per-request ``(seed, rid)``-derived PRNG
    streams (``repro.serving.sampling``).

Driving it: ``submit()`` + ``step()`` give deterministic single-step
control (tests, custom loops); ``run()`` drains a workload, honouring
each request's ``arrival_time`` against the wall clock (staggered /
Poisson arrivals for the continuous-serving benchmark).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.precision import PrecisionPolicy, as_policy
from repro.core.quant import QuantConfig
from repro.core.quantized_linear import quantize_params_for_serving
from repro.models import build_model
from repro.models.kv_cache import scatter_into_slot
from repro.serving import sampling


@dataclasses.dataclass
class Request:
    """One generation request.

    ``arrival_time`` is seconds relative to the start of ``run()`` (0 =
    already queued). ``on_token`` streams tokens as they are sampled.
    ``t_first`` / ``t_done`` are filled by the scheduler (seconds since the
    run started) for latency accounting."""

    rid: int
    prompt: np.ndarray            # (T,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0                # 0 = no top-k filtering
    eos_id: Optional[int] = None
    arrival_time: float = 0.0
    on_token: Optional[Callable[["Request", int], None]] = None
    out_tokens: Optional[List[int]] = None
    t_first: Optional[float] = None
    t_done: Optional[float] = None


class ContinuousScheduler:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_batch: int = 8,
        max_ctx: int = 128,
        quant: Union[None, QuantConfig, PrecisionPolicy] = None,
        bucket: int = 64,
        seed: int = 0,
        on_token: Optional[Callable[[Request, int], None]] = None,
    ):
        self.cfg = cfg
        self.model = build_model(cfg)
        policy = as_policy(quant)
        if policy is not None:
            params = quantize_params_for_serving(params, policy,
                                                 min_size=1024)
        self.params = params
        self.max_batch = max_batch
        self.max_ctx = max_ctx
        self.bucket = bucket
        self.seed = seed
        self.on_token = on_token

        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        self._scatter = jax.jit(scatter_into_slot, donate_argnums=(0,))
        self._prefill_cache = {}

        # Fixed-shape decode state: allocated once, reused for the whole
        # scheduler lifetime (the one compiled decode signature).
        self.cache = self.model.init_cache(max_batch, max_ctx)
        kv = self.cache.kv
        # Full-attention caches bound the absolute positions a slot can
        # reach; ring buffers and recurrent states are position-unbounded.
        self._capacity = (
            kv.k.shape[2] if kv is not None and kv.window == 0 else None
        )

        B = max_batch
        self._cur = np.zeros((B, 1), np.int32)       # next input token/slot
        self._temps = np.zeros((B,), np.float32)
        self._top_ks = np.zeros((B,), np.int32)
        self._keys = np.zeros((B, 2), np.uint32)
        self._steps = np.zeros((B,), np.int32)       # per-request token ctr
        self._slots: List[Optional[Request]] = [None] * B
        self.waiting: Deque[Request] = collections.deque()
        self.steps_run = 0
        self.tokens_emitted = 0
        self._t0: Optional[float] = None             # set by run()

    # -- queue/slot accounting ---------------------------------------------

    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self._slots)

    @property
    def num_waiting(self) -> int:
        return len(self.waiting)

    def submit(self, req: Request) -> None:
        """Queue a request for admission into the next free slot."""
        self.waiting.append(req)

    def _bucketed(self, n: int) -> int:
        return max(self.bucket, -(-n // self.bucket) * self.bucket)

    def _prefill_fn(self, length: int):
        if length not in self._prefill_cache:
            self._prefill_cache[length] = jax.jit(self.model.prefill)
        return self._prefill_cache[length]

    def _now(self) -> Optional[float]:
        return None if self._t0 is None else time.perf_counter() - self._t0

    # -- admission / retirement --------------------------------------------

    def _admit(self, req: Request, slot: int) -> Optional[Request]:
        """Prefill `req` solo and scatter its state into batch row `slot`.
        Returns the request if it finished on its very first token."""
        L = self._bucketed(len(req.prompt))
        if self._capacity is not None and L + req.max_new_tokens > self._capacity:
            raise ValueError(
                f"request {req.rid}: bucketed prompt ({L}) + max_new_tokens "
                f"({req.max_new_tokens}) exceeds cache capacity "
                f"({self._capacity}); raise max_ctx"
            )
        tokens = np.zeros((1, L), np.int32)
        tokens[0, L - len(req.prompt):] = req.prompt  # left-pad
        solo, logits = self._prefill_fn(L)(self.params,
                                           {"tokens": jnp.asarray(tokens)})
        self.cache = self._scatter(self.cache, solo, slot)

        key = sampling.request_key(self.seed, req.rid)
        tok = int(np.asarray(sampling.sample_tokens(
            logits[:, -1, :],
            np.asarray([req.temperature], np.float32),
            np.asarray([req.top_k], np.int32),
            key[None],
            np.zeros((1,), np.int32),
        ))[0])
        self._cur[slot, 0] = tok
        self._temps[slot] = req.temperature
        self._top_ks[slot] = req.top_k
        self._keys[slot] = key
        self._steps[slot] = 1
        self._slots[slot] = req
        req.out_tokens = [tok]
        if req.t_first is None:
            req.t_first = self._now()
        self._emit(req, tok)
        if self._finished(req, tok):
            self._slots[slot] = None
            return req
        return None

    def _emit(self, req: Request, tok: int) -> None:
        self.tokens_emitted += 1
        if req.on_token is not None:
            req.on_token(req, tok)
        if self.on_token is not None:
            self.on_token(req, tok)

    @staticmethod
    def _finished(req: Request, tok: int) -> bool:
        return (len(req.out_tokens) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id))

    # -- the decode loop ----------------------------------------------------

    def step(self) -> List[Request]:
        """One scheduler step: admit waiting requests into free slots, run
        one batched decode step, sample, retire finished slots. Returns
        the requests that finished this step."""
        finished: List[Request] = []
        for b in range(self.max_batch):
            if self._slots[b] is None and self.waiting:
                done = self._admit(self.waiting.popleft(), b)
                if done is not None:
                    finished.append(done)
        if self.num_active == 0:
            return finished

        self.cache, logits = self._decode(self.params, self.cache,
                                          jnp.asarray(self._cur))
        toks = np.asarray(sampling.sample_tokens(
            logits[:, -1, :], self._temps, self._top_ks,
            self._keys, self._steps,
        ))
        self._steps += 1
        self.steps_run += 1
        for b, req in enumerate(self._slots):
            if req is None:
                continue
            tok = int(toks[b])
            req.out_tokens.append(tok)
            self._emit(req, tok)
            if self._finished(req, tok):
                self._slots[b] = None
                finished.append(req)
            else:
                self._cur[b, 0] = tok
        return finished

    def run(self, requests=()) -> List[Request]:
        """Serve a workload to completion, admitting each request no
        earlier than its ``arrival_time`` (seconds from now). Returns the
        requests in completion order with ``t_first``/``t_done`` filled."""
        pending = sorted(requests, key=lambda r: r.arrival_time)
        self._t0 = time.perf_counter()
        done: List[Request] = []
        while pending or self.waiting or self.num_active:
            now = time.perf_counter() - self._t0
            while pending and pending[0].arrival_time <= now:
                self.submit(pending.pop(0))
            if not self.waiting and self.num_active == 0:
                # Idle: sleep up to the next arrival.
                time.sleep(min(max(pending[0].arrival_time - now, 0.0), 0.05))
                continue
            for req in self.step():
                req.t_done = time.perf_counter() - self._t0
                done.append(req)
        self._t0 = None
        return done
