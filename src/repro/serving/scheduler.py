"""Continuous-batching scheduler: request queue, slot table, mid-decode
admission, per-slot decode state, paged KV allocation, on-device sampling.

The serving analogue of the paper's headline property (the M4BRAM computes
while remaining fully usable as memory): the decode batch keeps computing
while individual slots are drained and refilled — no global barrier
between "batches" ever exists — and, with the paged cache, KV memory is
committed per *actual* request footprint instead of a worst-case `max_ctx`
reservation per slot.

Design:
  * ``max_batch`` decode slots. The jitted decode step always runs the
    full ``(max_batch, 1)`` token batch — ONE compiled decode signature
    for the scheduler's whole lifetime; slot occupancy changes, shapes
    never do. Free slots decode a dummy token whose output is discarded.
  * Admission: a waiting request is prefilled solo (B=1, prompt
    right-padded to a bucket, real length passed as ``lengths`` so pad
    slots never enter the cache or shift rope positions), and its KV /
    recurrent / RWKV state is scattered into the freed batch row
    (``kv_cache.scatter_into_slot`` / ``scatter_into_paged``). Only that
    row changes, so requests join mid-decode without perturbing live
    slots — a request's greedy output is bit-identical whether it is
    served solo, in a static batch, or admitted while other slots are
    deep into their decodes, and whether the cache is contiguous or paged.
  * Paged KV cache (full-attention archs, default): a shared block pool
    ``(L, num_blocks, block_size, NKV, H)`` plus per-slot block tables.
    Admission reserves the request's actual worst-case block count
    (``ceil((len + max_new - 1) / block_size)``) — when the pool can't
    cover it the request *queues* (no crash, no partial admission, no
    mid-decode deadlock). Blocks are allocated lazily: prompt blocks at
    admission, one more each time a decode step crosses a block boundary.
    Retirement frees a slot's blocks (and its unclaimed reservation)
    immediately.
  * Failure isolation: a request that can never fit (bucketed prompt or
    prompt + max_new beyond capacity) is marked failed (``Request.error``)
    and returned — it does not raise out of ``run()`` and live slots keep
    decoding.
  * Per-slot decode state: ``DecodeCache.pos``/``KVCache.slot_pos``/
    ``length`` all carry a batch axis; each slot's position advances
    independently of its neighbours.
  * Cross-request prefix caching (paged transformer archs, default on):
    a host-side index maps chain-hashes of block-sized token chunks to
    resident pool blocks, so a request whose prompt prefix was already
    prefilled — same system prompt, retried request — maps those blocks
    into its table instead of re-allocating and re-prefilling them.
    Ownership becomes refcounted: blocks are shared between rows,
    retirement *decrefs* instead of frees, unreferenced prefix blocks are
    retained in an LRU (freed lazily, evicted only under pool pressure),
    and a row that must append into a block it shares copies it first
    (copy-on-write). Admission prefills only the uncached suffix
    (``prefill_suffix``) and is still greedy bit-identical to a cold
    request — bf16 and int8 pools, solo / static / mid-decode admission.
  * Per-request precision tiers (paged archs, opt-in via ``tiers=``): a
    request may name a "wXaY" quality–latency class and is then served
    through a plane-truncated *view* of the one packed weight set
    (``core.precision.truncate_policy_view`` — buffers shared by
    identity, one extra jit trace per tier). ``step()`` groups live
    slots by tier and runs one decode call per group with non-group
    rows masked out of the pushed block table; a tier-T request in a
    mixed batch is greedy bit-identical to a solo engine whose whole
    policy is T. Speculation composes: the draft must truncate strictly
    below the slot's tier, verify runs at the slot's tier.
  * Sampling: vectorized on-device greedy / temperature / top-k with
    per-slot parameters and per-request ``(seed, rid)``-derived PRNG
    streams (``repro.serving.sampling``).

Driving it: ``submit()`` + ``step()`` give deterministic single-step
control (tests, custom loops); ``run()`` drains a workload, honouring
each request's ``arrival_time`` against the wall clock (staggered /
Poisson arrivals for the continuous-serving benchmark).
"""
from __future__ import annotations

import base64
import collections
import dataclasses
import hashlib
import json
import time
import warnings
from typing import Callable, Deque, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.precision import (
    PrecisionPolicy,
    as_policy,
    degrade_order,
    parse_tier_specs,
    parse_tier_token,
    quant_token,
    truncate_policy_view,
)
from repro.core.quant import QuantConfig
from repro.core.quantized_linear import quantize_params_for_serving
from repro.models import build_model
from repro.models.kv_cache import (
    KVCache,
    PagedKVCache,
    copy_pool_block,
    scatter_into_paged,
    scatter_into_slot,
    scatter_suffix_into_paged,
    set_decode_positions,
    set_paged_row,
    write_pool_block,
)
from repro.serving import sampling
from repro.serving.chaos import FaultInjector, InjectedFault
from repro.serving.speculative import (
    derive_draft_params,
    greedy_accept,
    parse_draft_spec,
)


def _contig_headroom() -> int:
    from repro.models.transformer import DECODE_HEADROOM

    return DECODE_HEADROOM


#: Preemption victim-selection policies: `most-blocks` frees the most pool
#: capacity per eviction, `lowest-tier` sheds the cheapest quality class
#: first, `latest-deadline` preempts the request with the most slack
#: (no-deadline requests first, then the latest deadline). `block-to-host`
#: selects like `most-blocks` but spills the victim's resident K/V blocks
#: to the host-RAM tier (needs ``host_pool_bytes``), so the requeued
#: victim resumes warm-from-host even when pool churn would have evicted
#: its blocks cold before re-admission.
VICTIM_POLICIES = ("most-blocks", "lowest-tier", "latest-deadline",
                   "block-to-host")

#: Versioned schema tag of the persisted prefix index (`save_index`).
INDEX_SCHEMA = "m4bram-prefix-index"
INDEX_VERSION = 1


@dataclasses.dataclass
class _HostBlock:
    """One pool block's K/V bytes parked in the host-RAM tier: plain
    numpy copies of the device planes (int8 codes + fp32 scale planes
    for a quantized pool) plus the digests that can claim it. The bytes
    are immutable — they were frozen device-side the moment a digest was
    registered — so swap-back (`write_pool_block`) reproduces the block
    verbatim and warm-from-host streams stay bitwise cold-identical."""

    k: np.ndarray                        # (L, block_size, NKV, H)
    v: np.ndarray
    k_scale: Optional[np.ndarray]        # (L, block_size, NKV, 1) fp32
    v_scale: Optional[np.ndarray]
    digests: set                         # chain digests resolving to it
    nbytes: int


@dataclasses.dataclass
class Request:
    """One generation request.

    ``arrival_time`` is seconds relative to the start of ``run()`` (0 =
    already queued). ``on_token`` streams tokens as they are sampled.
    ``t_first`` / ``t_done`` are filled by the scheduler (seconds since the
    run started) for latency accounting. ``error`` is set (and the request
    returned with no tokens) when it can never fit the cache — oversized
    requests are rejected individually instead of aborting the serve
    loop."""

    rid: int
    prompt: np.ndarray            # (T,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0                # 0 = no top-k filtering
    eos_id: Optional[int] = None
    # Per-request precision tier: a "wXaY" token (or QuantConfig) naming
    # one of the scheduler's configured `tiers`, served as a plane-
    # truncated view of the one packed weight set. None = the storage
    # policy. A request pinned to an unconfigured tier comes back failed
    # (`error` set), like any other individually-rejected request.
    tier: Union[None, str, QuantConfig] = None
    arrival_time: float = 0.0
    # Completion deadlines. `deadline_s` is wall-clock seconds after
    # `arrival_time` (evaluated only while `run()` drives the clock);
    # `deadline_steps` is a scheduler-step budget counted from `submit()`
    # (deterministic, works under manual `step()` loops too). A request
    # past either deadline — queued or mid-decode — is retired with
    # `error="deadline"`, its blocks freed exactly like a normal
    # retirement. None = no deadline.
    deadline_s: Optional[float] = None
    deadline_steps: Optional[int] = None
    on_token: Optional[Callable[["Request", int], None]] = None
    out_tokens: Optional[List[int]] = None
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    error: Optional[str] = None
    # Times this request was preempted under pool pressure (each one
    # requeued it as prompt ++ generated for a warm, bit-identical
    # resume) and, when graceful degradation kicked in, the tier it was
    # actually served at (sticky for the request's whole lifetime).
    preemptions: int = 0
    degraded_to: Optional[str] = None
    # Per-request speculative-decoding counters (filled when the
    # scheduler runs with `speculate`): draft tokens proposed for this
    # request and how many of them greedy verification accepted.
    spec_drafted: int = 0
    spec_accepted: int = 0

    @property
    def spec_acceptance_rate(self) -> float:
        return self.spec_accepted / self.spec_drafted if self.spec_drafted else 0.0

    @property
    def failed(self) -> bool:
        return self.error is not None


class ContinuousScheduler:
    """Continuous-batching scheduler (see the module docstring for the
    full design). Drive it by queueing `Request`s with `submit()` and
    advancing with `step()`, or hand a whole workload to `run()`.

    Keyword knobs: ``max_batch`` decode slots, ``max_ctx`` per-request
    position bound, ``bucket`` prefill padding granularity, ``paged``
    (None = auto: paged whenever the arch has a full-attention cache),
    ``block_size``/``pool_blocks`` pool geometry, and ``prefix_cache``
    (None = auto: on whenever the cache is paged and the arch supports
    suffix-only prefill — dense/token transformers; explicit True raises
    if unsupported)."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_batch: int = 8,
        max_ctx: int = 128,
        quant: Union[None, QuantConfig, PrecisionPolicy] = None,
        bucket: int = 64,
        seed: int = 0,
        on_token: Optional[Callable[[Request, int], None]] = None,
        paged: Optional[bool] = None,
        block_size: int = 16,
        pool_blocks: Optional[int] = None,
        prefix_cache: Optional[bool] = None,
        chunked_prefill: Optional[bool] = None,
        prefill_budget: int = 32,
        speculate: int = 0,
        draft_policy: Union[str, QuantConfig] = "w4a8",
        tiers: Union[None, str, Tuple] = None,
        preempt: Optional[bool] = None,
        victim_policy: str = "most-blocks",
        max_head_bypass: int = 4,
        degrade: bool = False,
        degrade_after: int = 2,
        chaos: Optional[FaultInjector] = None,
        host_pool_bytes: int = 0,
    ):
        self.cfg = cfg
        self.model = build_model(cfg)
        policy = as_policy(quant)
        if policy is not None:
            params = quantize_params_for_serving(params, policy,
                                                 min_size=1024)
        self.params = params
        self.max_batch = max_batch
        self.max_ctx = max_ctx
        self.bucket = bucket
        self.seed = seed
        self.on_token = on_token

        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        # Fault-tolerance twin of `_decode`: a separate jit object whose
        # trace happens inside `use("reference")`, so an injected kernel
        # fault can re-run the SAME step on the pure-jnp reference backend
        # (bitwise the same logits/K-V) without retracing `_decode`.
        self._decode_ref = jax.jit(self.model.decode_step,
                                   donate_argnums=(1,))
        self._scatter = jax.jit(scatter_into_slot, donate_argnums=(0,))
        self._scatter_paged = jax.jit(scatter_into_paged, donate_argnums=(0,))
        self._prefill_cache = {}

        # Cache flavour. Paged needs a full-attention KV cache (ring
        # buffers are already window-bounded) — eligible archs default to
        # paged. An int8 cache (cfg.kv_cache_quant) pages too: the pool
        # carries scale-plane blocks and the fused paged-attention kernel
        # dequantizes in-kernel.
        init_paged = getattr(self.model, "init_paged_cache", None)
        can_page = init_paged is not None and not cfg.attn_window
        if paged is None:
            paged = can_page
        elif paged and not can_page:
            raise ValueError(
                f"{cfg.name}: paged KV cache requires a full-attention "
                "cache (ring buffers and recurrent states are already "
                "footprint-bounded)"
            )
        self.paged = paged
        self.block_size = block_size

        # Prefix caching rides on the paged pool (shared blocks need block
        # tables + host-side ownership) and on suffix-only prefill; archs
        # where that is bit-identical to cold prefill advertise it as
        # `prefill_suffix` (model_zoo owns the eligibility rule).
        can_prefix = (
            paged
            and getattr(self.model, "prefill_suffix", None) is not None
        )
        if prefix_cache is None:
            prefix_cache = can_prefix
        elif prefix_cache and not can_prefix:
            raise ValueError(
                f"{cfg.name}: prefix caching requires the paged KV cache "
                "and an arch with suffix-only prefill (token-input, "
                "non-MoE full-attention transformer)"
            )
        self.prefix_cache = prefix_cache

        # Sarathi-style chunked prefill rides on the paged pool and on the
        # fused chunk kernel's model method (`prefill_chunk`, same
        # eligibility gate as prefill_suffix). Admission then enqueues a
        # chunk *plan* instead of prefilling solo: each step spends at
        # most `prefill_budget` prompt tokens of chunked prefill alongside
        # the decode step, so live slots stall at most one step per
        # budget's worth of admission prefill.
        can_chunk = (
            paged
            and getattr(self.model, "prefill_chunk", None) is not None
        )
        if chunked_prefill is None:
            chunked_prefill = can_chunk
        elif chunked_prefill and not can_chunk:
            raise ValueError(
                f"{cfg.name}: chunked prefill requires the paged KV cache "
                "and an arch with the fused chunk-prefill path "
                "(token-input, non-MoE full-attention transformer)"
            )
        if prefill_budget < 1:
            raise ValueError("prefill_budget must be >= 1")
        self.chunked_prefill = chunked_prefill
        self.prefill_budget = prefill_budget
        if chunked_prefill:
            self._chunk = jax.jit(self.model.prefill_chunk,
                                  donate_argnums=(1,))
        self._chunk_plans: Dict[int, dict] = {}   # slot → in-flight plan
        # Round-robin service order across in-flight chunk plans: the
        # serviced slot rotates to the back each step, so one long prompt
        # can't starve admissions queued behind it.
        self._chunk_queue: Deque[int] = collections.deque()
        self.prefill_chunks_run = 0
        self.decode_steps_stalled = 0
        self.prefill_chunk_tokens = 0
        # Steps on which a chunk actually ran — the denominator of the
        # interleave ratio. (steps_run keeps growing after the last plan
        # retires, which made the old tokens/steps_run ratio decay toward
        # zero instead of reporting the achieved interleave.)
        self.prefill_chunk_steps = 0

        # -- self-speculative decoding (draft = plane-truncated view) ----
        # Drafting reuses the decode step with *view* params (plane_lo on
        # every packed leaf — same weight bytes, one extra jit trace) and
        # verification reuses the chunked-prefill machinery with
        # all-position logits, so speculation needs the same capability
        # gate as chunked prefill plus a packed (quantized) weight set.
        if speculate:
            if speculate < 1:
                raise ValueError("speculate must be >= 1 (0 disables)")
            can_spec = (
                paged
                and getattr(self.model, "prefill_chunk_logits_multi",
                            None) is not None
            )
            if not can_spec:
                raise ValueError(
                    f"{cfg.name}: speculative decoding requires the paged "
                    "KV cache and the chunked-prefill verify path "
                    "(token-input, non-MoE full-attention transformer)"
                )
            # Raises with guidance when params carry no packed leaves
            # (serve with --quant) or the draft truncates nothing.
            self._draft_params, _ = derive_draft_params(self.params,
                                                        draft_policy)
            self._draft_cfg = parse_draft_spec(draft_policy)
            # Verify is batched: one multi-row call per tier group per
            # round (R = max_batch rows; non-verifying rows dead).
            self._verify = jax.jit(self.model.prefill_chunk_logits_multi,
                                   donate_argnums=(1,))
        self.speculate = int(speculate)
        self.draft_policy = draft_policy
        self.spec_draft_tokens = 0
        self.spec_accepted_tokens = 0
        self.spec_rounds = 0
        self.spec_verify_calls = 0     # multi-row verify dispatches
        self.spec_verify_rows = 0      # slots verified across those calls

        # -- per-request precision tiers (plane-truncated policy views) --
        # One packed weight set serves every configured tier: a tier view
        # shares the packed/scale buffers by identity and differs only in
        # pytree aux data (plane_lo), so each tier costs one extra jit
        # trace of the decode/prefill paths — never a second weight copy.
        # The key None is the base (storage-policy) tier.
        tier_cfgs: Dict[str, QuantConfig] = {}
        tier_views: Dict[Optional[str], object] = {None: self.params}
        if tiers:
            if not paged:
                raise ValueError(
                    f"{cfg.name}: per-request precision tiers need the "
                    "paged KV cache (tier groups are isolated by masked "
                    "block tables)"
                )
            for tcfg in parse_tier_specs(tiers):
                key = quant_token(tcfg)
                # Validates the tier is a pure plane-truncation of the
                # storage policy (packed params, whole-plane gap,
                # matching activation precision).
                view, _ = truncate_policy_view(self.params, tcfg)
                tier_cfgs[key] = tcfg
                tier_views[key] = view
        self._tier_cfgs = tier_cfgs
        self._tier_views = tier_views
        self.tiers = tuple(tier_cfgs)
        self._slot_tier: List[Optional[str]] = [None] * max_batch
        self.tier_counters: Dict[Optional[str], Dict[str, int]] = {
            k: {"requests": 0, "tokens": 0, "decode_calls": 0,
                "spec_draft_tokens": 0, "spec_accepted_tokens": 0}
            for k in [None, *tier_cfgs]
        }

        # -- lifecycle, preemption, degradation, fault injection ---------
        # Preemption (auto: on whenever the pool is paged): a pool-blocked
        # admission may evict one live victim per step, registering the
        # victim's resident K/V in the prefix index and requeueing it as
        # prompt ++ generated — resume is greedy bit-identical to an
        # uninterrupted run.
        if preempt is None:
            preempt = self.paged
        elif preempt and not self.paged:
            raise ValueError(
                f"{cfg.name}: preemption needs the paged KV cache (the "
                "contiguous scheduler has no pool pressure to relieve)")
        self.preempt = bool(preempt)
        if victim_policy not in VICTIM_POLICIES:
            raise ValueError(
                f"unknown victim_policy {victim_policy!r}; choose one of "
                f"{VICTIM_POLICIES}")
        self.victim_policy = victim_policy

        # -- host-RAM block tier under the paged pool --------------------
        # With a byte budget > 0, refcount-0 cached blocks evicted from
        # the device LRU move to a pinned host store (numpy copies of the
        # K/V planes, scale planes included) instead of dying, and a
        # prefix hit on a host-resident digest swaps the block back into
        # a free device slot at admission — warm-from-host is bitwise the
        # cold stream because the bytes round-trip verbatim.
        self.host_pool_bytes = int(host_pool_bytes or 0)
        if self.host_pool_bytes < 0:
            raise ValueError("host_pool_bytes must be >= 0 (0 disables "
                             "the host-RAM tier)")
        self.host_tier = bool(self.host_pool_bytes
                              and self.paged and self.prefix_cache)
        if self.host_pool_bytes and not self.host_tier:
            raise ValueError(
                f"{cfg.name}: the host-RAM block tier rides on the paged "
                "pool + prefix cache (spilled blocks are found by their "
                "chain digests); enable both or set host_pool_bytes=0")
        if victim_policy == "block-to-host" and not self.host_tier:
            raise ValueError(
                "victim_policy='block-to-host' spills the victim's K/V "
                "to the host tier; pass host_pool_bytes > 0 (and keep the "
                "paged pool + prefix cache on)")
        self._host_store: "collections.OrderedDict[int, _HostBlock]" = (
            collections.OrderedDict())          # insertion order = LRU
        self._host_index: Dict[bytes, int] = {} # digest → host id
        self._host_next_id = 0
        self.host_bytes = 0
        self.swap_ins = 0            # host → device block copies
        self.swap_outs = 0           # device → host spills
        self.host_evictions = 0      # host-tier cold deaths (budget)
        self.host_hit_blocks = 0
        self.host_hit_tokens = 0
        if self.paged:
            self._write_block = jax.jit(write_pool_block,
                                        donate_argnums=(0,))
        if max_head_bypass < 0:
            raise ValueError("max_head_bypass must be >= 0 (0 disables "
                             "head-of-line bypass)")
        self.max_head_bypass = int(max_head_bypass)
        if degrade_after < 1:
            raise ValueError("degrade_after must be >= 1")
        self.degrade = bool(degrade)
        self.degrade_after = int(degrade_after)
        if degrade:
            if not tier_cfgs:
                raise ValueError(
                    "degrade=True serves pressure admissions at the lowest "
                    "configured precision tier — pass tiers= / --tiers")
            self._degrade_to = quant_token(
                degrade_order(tier_cfgs.values())[-1])
        self.chaos = chaos
        self._cancelled: set = set()        # rids to retire at next step()
        self._step_calls = 0                # step() invocations (lifecycle clock)
        self._head_bypass = 0               # consecutive bypasses of the blocked head
        self._pressure_streak = 0           # consecutive pool-blocked steps
        self.preemptions = 0
        self.cancellations = 0
        self.deadline_misses = 0
        self.pool_pressure_events = 0
        self.queue_wait_steps = 0
        self.head_bypasses = 0
        self.degraded_requests = 0
        self.callback_errors = 0
        self.nan_logit_events = 0
        self.kernel_fallbacks = 0

        B = max_batch
        if paged:
            # Per-row virtual capacity = max_ctx rounded up to blocks; the
            # pool defaults to the contiguous worst case (every slot full)
            # — pass a smaller pool_blocks to overcommit.
            self._max_blocks = -(-max_ctx // block_size)
            usable = (pool_blocks if pool_blocks is not None
                      else max_batch * self._max_blocks)
            if usable < 1:
                raise ValueError("pool_blocks must be >= 1")
            self.pool_blocks = usable
            self.cache = init_paged(B, usable + 1, block_size,
                                    self._max_blocks)  # +1: trash block 0
            # Admission bound: max_ctx in every mode (the block-rounded
            # physical row is >= this), so static / contiguous / paged
            # agree on which requests fit.
            self._capacity = max_ctx
            self._free: List[int] = list(range(usable, 0, -1))
            # free + LRU-retained minus outstanding reservations: what
            # admission can still promise without deadlocking a live row.
            self._avail = usable
            self._reserved = np.zeros((B,), np.int64)
            self._block_tab = np.full((B, self._max_blocks), -1, np.int32)
            self._table_dirty = False
            self._peak_blocks = 0
            # -- prefix-cache / refcount state (host-side ownership) --
            self._refcnt = np.zeros((usable + 1,), np.int64)
            self._prefix_index: Dict[bytes, int] = {}   # chunk hash → block
            # block → every digest registered against it. One block can
            # serve several chain positions — e.g. a retired row's
            # straddle block carries the prompt-partial digest AND the
            # extended (prompt ++ generated) full-chunk digest. Once any
            # digest is attached the block's bytes are frozen:
            # `_ensure_private_block` copies-on-write even at refcount 1.
            self._block_hash: Dict[int, set] = {}
            self._lru: collections.OrderedDict = collections.OrderedDict()
            self._slot_hashes: List = [None] * B        # (full, partial)/slot
            self._suffix_cache = {}
            self._scatter_suffix = jax.jit(scatter_suffix_into_paged,
                                           donate_argnums=(0,))
            self._set_row = jax.jit(set_paged_row, donate_argnums=(0,))
            self._cow = jax.jit(copy_pool_block, donate_argnums=(0,))
            # One-write pos/length restore: speculation rollback and the
            # position fix-up between per-tier decode group calls.
            self._set_positions = jax.jit(set_decode_positions,
                                          donate_argnums=(0,))
            self.prefix_hit_blocks = 0
            self.prefix_hit_tokens = 0
            self.prompt_tokens_seen = 0
            self.cow_copies = 0
            self.prefix_evictions = 0
            # Bucketed tokens actually run through prefill at admission —
            # the deterministic admission-compute metric (a prefix hit
            # prefills only its suffix bucket; wall time on the interpret
            # backend is not a perf signal, this is).
            self.prefill_tokens_computed = 0
        else:
            # Fixed-shape contiguous state: every slot reserves a full
            # max_ctx(+headroom) row for its whole lifetime.
            self.cache = self.model.init_cache(max_batch, max_ctx)
            kv = self.cache.kv
            # Full-attention caches bound the absolute positions a slot
            # can reach (admission bound = max_ctx in every mode; the
            # physical row carries headroom beyond it); ring buffers and
            # recurrent states are position-unbounded.
            self._capacity = (
                max_ctx if isinstance(kv, KVCache) and kv.window == 0
                else None
            )

        self._pos_host = np.zeros((B,), np.int64)    # next write position
        self._cur = np.zeros((B, 1), np.int32)       # next input token/slot
        self._temps = np.zeros((B,), np.float32)
        self._top_ks = np.zeros((B,), np.int32)
        self._keys = np.zeros((B, 2), np.uint32)
        self._steps = np.zeros((B,), np.int32)       # per-request token ctr
        self._slots: List[Optional[Request]] = [None] * B
        self.waiting: Deque[Request] = collections.deque()
        self.steps_run = 0
        self.tokens_emitted = 0
        self._t0: Optional[float] = None             # set by run()

    # -- queue/slot accounting ---------------------------------------------

    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self._slots)

    @property
    def num_waiting(self) -> int:
        return len(self.waiting)

    def submit(self, req: Request) -> None:
        """Queue a request for admission into the next free slot (FIFO;
        admission itself happens inside `step()` — including the prefix
        lookup, so a request submitted now can hit blocks that another
        request makes resident before a slot frees)."""
        req._submit_step = self._step_calls   # step-budget deadline epoch
        self.waiting.append(req)

    def cancel(self, rid: int) -> bool:
        """Request cancellation of `rid`. Processed at the start of the
        next `step()` — nothing mutates mid-step, so calling this from an
        `on_token` callback is safe. A queued request is dropped from the
        queue; a live one (including a mid-chunk-prefill plan) is retired
        with its blocks freed exactly like a normal retirement. Either way
        it comes back from `step()`/`run()` with ``error="cancelled"`` and
        whatever tokens it had emitted. Returns True iff `rid` is
        currently queued or in flight."""
        known = (any(r.rid == rid for r in self.waiting)
                 or any(r is not None and r.rid == rid for r in self._slots))
        if known:
            self._cancelled.add(rid)
        return known

    def _deadline_expired(self, req: Request,
                          now: Optional[float]) -> bool:
        if req.deadline_steps is not None:
            start = getattr(req, "_submit_step", None)
            if (start is not None
                    and self._step_calls - start > req.deadline_steps):
                return True
        if req.deadline_s is not None and now is not None:
            return now - req.arrival_time > req.deadline_s
        return False

    def _retire_abnormal(self, b: int, reason: str) -> Request:
        """Retire live row `b` off the normal finish path (cancellation,
        deadline, poisoned logits): mark the terminal state, free its
        blocks / reservation / chunk plan exactly like a normal
        retirement, and hand the request back with whatever tokens it
        emitted."""
        req = self._slots[b]
        req.error = reason
        if req.out_tokens is None:
            req.out_tokens = []
        req.t_done = self._now()
        self._release_slot(b)
        return req

    def _lifecycle_phase(self) -> List[Request]:
        """Process cancellations and deadline expiries — queued requests
        leave the queue, live rows are retired with their blocks freed —
        before this step admits or decodes anything."""
        out: List[Request] = []
        now = self._now()
        check_deadlines = any(
            r.deadline_s is not None or r.deadline_steps is not None
            for r in [*self.waiting,
                      *(r for r in self._slots if r is not None)])
        if not self._cancelled and not check_deadlines:
            return out
        keep: Deque[Request] = collections.deque()
        while self.waiting:
            r = self.waiting.popleft()
            if r.rid in self._cancelled:
                self.cancellations += 1
                self._fail(r, "cancelled")
                out.append(r)
            elif self._deadline_expired(r, now):
                self.deadline_misses += 1
                self._fail(r, "deadline")
                out.append(r)
            else:
                keep.append(r)
        self.waiting = keep
        for b, r in enumerate(self._slots):
            if r is None:
                continue
            if r.rid in self._cancelled:
                self.cancellations += 1
                out.append(self._retire_abnormal(b, "cancelled"))
            elif self._deadline_expired(r, now):
                self.deadline_misses += 1
                out.append(self._retire_abnormal(b, "deadline"))
        self._cancelled.clear()   # stale rids (already retired) drop here
        return out

    def _bucketed(self, n: int) -> int:
        return max(self.bucket, -(-n // self.bucket) * self.bucket)

    def _prefill_fn(self, length: int):
        # Key by *bucketed* length: callers pad to the bucket anyway, so
        # keying on the raw length would compile one identical executable
        # per distinct long-tail prompt length.
        length = self._bucketed(length)
        if length not in self._prefill_cache:
            self._prefill_cache[length] = jax.jit(self.model.prefill)
        return self._prefill_cache[length]

    def _now(self) -> Optional[float]:
        return None if self._t0 is None else time.perf_counter() - self._t0

    # -- paged-pool accounting ---------------------------------------------

    @staticmethod
    def _serve_tokens(req: Request) -> np.ndarray:
        """The token sequence admission serves for `req`: its prompt, plus
        any tokens it already generated before a preemption requeued it.
        Re-admitting `prompt ++ generated` is exactly what makes resume
        bit-identical — the resumed request prefills (or prefix-hits) the
        same positions an uninterrupted run would have resident, and its
        next sampled token is the (seed, rid, len(out))-stream token an
        uninterrupted run would draw."""
        if not req.out_tokens:
            return np.asarray(req.prompt)
        return np.concatenate([np.asarray(req.prompt, np.int64),
                               np.asarray(req.out_tokens, np.int64)])

    @staticmethod
    def _serve_len(req: Request) -> int:
        return len(req.prompt) + len(req.out_tokens or ())

    def _need_tokens(self, req: Request) -> int:
        # The first sampled token comes from the prefill logits and writes
        # no cache slot; only the remaining max_new - 1 decode inputs do.
        # max_new <= 0 still emits that prefill token, so it reserves like
        # max_new = 1 (anything less would under-reserve the prompt).
        # Invariant under preemption/resume: the served length grows by
        # exactly the tokens already emitted while the owed decode inputs
        # shrink by the same count.
        return len(req.prompt) + max(req.max_new_tokens, 1) - 1

    def _need_blocks(self, req: Request) -> int:
        return -(-self._need_tokens(req) // self.block_size)

    def _tier_error(self, req: Request) -> Optional[str]:
        """Validate + normalize `req.tier` into `req._tier_key` (the
        canonical "wXaY" counter/view key; None = storage policy).
        Non-None iff the tier can never be served here."""
        if req.tier is None:
            req._tier_key = None
        else:
            try:
                key = quant_token(parse_tier_token(req.tier))
            except ValueError as e:
                return f"request {req.rid}: bad precision tier: {e}"
            if key not in self._tier_views:
                have = sorted(self._tier_cfgs) or "none configured"
                return (f"request {req.rid}: unknown precision tier {key!r}; "
                        f"scheduler tiers: {have} — pass tiers= / --tiers to "
                        "serve this class")
            req._tier_key = key
        # A request admitted under graceful degradation stays degraded for
        # life: its emitted tokens and registered K/V are at the degraded
        # tier, so resuming (after a preemption) at the original tier
        # would splice two precisions into one stream.
        if req.degraded_to is not None:
            req._tier_key = req.degraded_to
        return None

    def _degrade_tier(self, req: Request) -> bool:
        """Point this admission attempt at the cheapest configured tier
        (graceful degradation under sustained pool pressure). Transient
        until the request actually admits — `_tier_error` recomputes
        `_tier_key` from `req.tier` on every attempt — and committed to
        `req.degraded_to` by the admission loop. Returns True iff the
        attempt was newly lowered."""
        low = self._degrade_to
        cur = req._tier_key
        cur_bits = (self._tier_cfgs[cur].w_bits if cur is not None
                    else 1 << 30)   # storage policy: above every tier
        if req.degraded_to is None and self._tier_cfgs[low].w_bits < cur_bits:
            req._tier_key = low
            return True
        return False

    # -- preemption: victim choice, warm-resume requeue ---------------------

    def _freeable(self, b: int) -> int:
        """Exact `_avail` increase releasing row `b` would produce: its
        unclaimed reservation plus every table block only it references
        (shared blocks survive under their other referencers, so evicting
        this row frees nothing there)."""
        row = self._block_tab[b]
        own = sum(1 for blk in row[row >= 0] if self._refcnt[int(blk)] == 1)
        return int(own) + int(self._reserved[b])

    @staticmethod
    def _deadline_rank(req: Request):
        """Slack ordering for the `latest-deadline` victim policy: bigger
        = more slack = preferred victim. No-deadline requests outrank any
        deadline; wall-clock deadlines rank by absolute deadline time;
        step budgets rank below wall-clock, by their budget horizon."""
        if req.deadline_s is None and req.deadline_steps is None:
            return (2, 0.0)
        if req.deadline_s is not None:
            return (1, req.arrival_time + req.deadline_s)
        return (0, float(getattr(req, "_submit_step", 0)
                         + req.deadline_steps))

    def _pick_victim(self, shortfall: int, exclude) -> Optional[int]:
        """Choose a preemption victim whose release alone covers the
        blocked admission's shortfall (a cascade of evictions for one
        admission is never worth the recompute — return None and let the
        head wait instead). Mid-chunk-plan rows are not preemptible
        (their resident blocks are partially written) and neither are
        rows admitted earlier in this same step."""
        cands = [b for b, r in enumerate(self._slots)
                 if r is not None and b not in self._chunk_plans
                 and b not in exclude and self._freeable(b) >= shortfall]
        if not cands:
            return None
        if self.victim_policy in ("most-blocks", "block-to-host"):
            # block-to-host selects like most-blocks; it differs in what
            # happens to the victim's K/V (spilled to host, not left to
            # LRU churn) — see `_preempt`.
            key = lambda b: (self._freeable(b), -b)       # noqa: E731
        elif self.victim_policy == "lowest-tier":
            def key(b):
                t = self._slot_tier[b]
                bits = (self._tier_cfgs[t].w_bits if t is not None
                        else 1 << 30)
                return (-bits, self._freeable(b), -b)
        else:  # latest-deadline
            def key(b):
                return (self._deadline_rank(self._slots[b]),
                        self._freeable(b), -b)
        return max(cands, key=key)

    def _preempt(self, b: int) -> None:
        """Preempt row `b` under pool pressure: release the slot — which
        registers its resident prompt+generated blocks in the prefix
        index (`_register_retired`) — and requeue the request at the BACK
        of the waiting queue as prompt ++ generated. Re-admission rides
        the ordinary suffix-only warm path over those registered blocks
        (or recomputes them cold if they were evicted meanwhile); either
        way the resumed stream is bitwise the uninterrupted one.

        With ``victim_policy="block-to-host"`` the victim's now
        refcount-0 resident blocks are spilled to the host tier
        immediately instead of sitting in the device LRU: pool churn
        between now and re-admission can no longer evict them cold, so
        the resume is warm-from-host at worst (same bits — the swap-back
        writes the spilled bytes verbatim)."""
        req = self._slots[b]
        self.preemptions += 1
        req.preemptions += 1
        row = self._block_tab[b]
        row_blocks = [int(blk) for blk in row[row >= 0]]
        self._release_slot(b)
        if self.victim_policy == "block-to-host":
            for blk in row_blocks:
                if blk in self._lru and blk in self._block_hash:
                    self._lru.pop(blk)
                    self._spill_block(blk)
                    self._free.append(blk)
        self.waiting.append(req)

    def _bypass_candidate(self, deg: bool):
        """Head-of-line mitigation: when the queue head is pool-blocked,
        find the first later request that is admissible and fits the
        current capacity — bounded to `max_head_bypass` consecutive
        bypasses so a large head is never starved by a stream of small
        arrivals. Returns (queue index, match, newly-degraded) or
        (None, None, False)."""
        if self._head_bypass >= self.max_head_bypass:
            return None, None, False
        for i in range(1, len(self.waiting)):
            r = self.waiting[i]
            if self._reject_reason(r) is not None:
                continue   # rejected for real when it reaches the head
            d = self._degrade_tier(r) if deg else False
            m = self._match_prefix(r)
            if m[2] + m[3] <= self._avail:
                return i, m, d
        return None, None, False

    # -- fault-tolerant decode dispatch -------------------------------------

    def _decode_call(self, params, cur) -> jnp.ndarray:
        """Jitted decode dispatch with the kernel fault seam: an injected
        chaos failure raised AT dispatch (before the donated cache enters
        the jitted call, so its buffers stay valid) is caught and the
        same step re-runs on the pure-jnp `reference` backend — bitwise
        the same logits and K/V writes, so one flaky backend call degrades
        to a slow call, never to a lost request or a broken stream."""
        try:
            if self.chaos is not None and self.chaos.fire("kernel"):
                raise InjectedFault("kernel dispatch")
            self.cache, logits = self._decode(params, self.cache,
                                              jnp.asarray(cur))
        except InjectedFault:
            self.kernel_fallbacks += 1
            from repro.kernels import get_registry
            with get_registry().use("reference"):
                self.cache, logits = self._decode_ref(params, self.cache,
                                                      jnp.asarray(cur))
        return logits

    def _reject_reason(self, req: Request) -> Optional[str]:
        """Non-None iff the request can never be served by this scheduler
        (vs. transiently waiting for pool blocks)."""
        err = self._tier_error(req)
        if err is not None:
            return err
        if self._capacity is None:
            return None
        need = self._need_tokens(req)
        if self.paged:
            if need > self._capacity or self._need_blocks(req) > self.pool_blocks:
                return (f"request {req.rid}: prompt ({len(req.prompt)}) + "
                        f"max_new_tokens ({req.max_new_tokens}) needs {need} "
                        f"cache slots, beyond capacity ({self._capacity} "
                        f"per slot, {self.pool_blocks * self.block_size} "
                        "pooled); raise max_ctx / pool_blocks")
            return None
        L = self._bucketed(len(req.prompt))
        # The solo prefill array carries L + headroom slots and must fit
        # the max_ctx + headroom row, hence the L > max_ctx bound.
        if L > self.max_ctx or need > self._capacity:
            return (f"request {req.rid}: bucketed prompt ({L}) or prompt + "
                    f"max_new_tokens ({need} slots) exceeds cache capacity "
                    f"(max_ctx {self.max_ctx}, {self._capacity} slots); "
                    "raise max_ctx")
        return None

    @property
    def _live_blocks(self) -> int:
        """Pool blocks referenced by at least one row's table (LRU-retained
        prefix blocks are resident but reclaimable, so they don't count)."""
        return self.pool_blocks - len(self._free) - len(self._lru)

    def _touch_peak(self) -> None:
        self._peak_blocks = max(self._peak_blocks, self._live_blocks)

    def _evict_lru(self) -> None:
        """Reclaim the least-recently-used retained prefix block and hand
        it back to the free list. Only refcount-0 blocks ever sit in the
        LRU, so eviction can never pull a block out from under a live row
        or an admission reservation (`_avail` already counts LRU blocks
        as reclaimable). With the host tier on, the block's bytes and
        digests move to the host store instead of dying — a later hit on
        the digest chain swaps them back; without it (or once the host
        budget is exhausted) the digests are dropped cold."""
        if not self._lru:
            raise RuntimeError(
                "paged pool invariant violated: reservation accounting "
                "should guarantee a free or evictable block"
            )
        blk, _ = self._lru.popitem(last=False)
        if self.host_tier and blk in self._block_hash:
            self._spill_block(blk)
        else:
            for h in self._block_hash.pop(blk, ()):
                self._prefix_index.pop(h, None)
            self.prefix_evictions += 1
        self._free.append(blk)

    # -- host-RAM block tier: spill, budget, swap-back -----------------------

    def _host_block_nbytes(self) -> int:
        """Host bytes one spilled block occupies (K + V planes across all
        layers, plus the fp32 scale planes of a quantized pool)."""
        kv = self.cache.kv
        per = 2 * kv.k.shape[0] * int(np.prod(kv.k.shape[2:])) \
            * kv.k.dtype.itemsize
        if kv.quantized:
            per += 2 * kv.k_scale.shape[0] \
                * int(np.prod(kv.k_scale.shape[2:])) \
                * kv.k_scale.dtype.itemsize
        return per

    def _spill_block(self, blk: int) -> None:
        """Move pool block `blk`'s bytes and digests to the host store.
        The caller owns the block's pool bookkeeping (it must already be
        out of the LRU and about to join the free list); this moves the
        digest ownership: entries leave `_prefix_index`/`_block_hash` and
        land in `_host_index`, so no digest ever resolves to both a live
        device block and a stale host copy."""
        kv = self.cache.kv
        digests = self._block_hash.pop(blk)
        for h in digests:
            self._prefix_index.pop(h, None)
        entry = _HostBlock(
            k=np.asarray(kv.k[:, blk]),
            v=np.asarray(kv.v[:, blk]),
            k_scale=(np.asarray(kv.k_scale[:, blk])
                     if kv.quantized else None),
            v_scale=(np.asarray(kv.v_scale[:, blk])
                     if kv.quantized else None),
            digests=set(digests),
            nbytes=self._host_block_nbytes(),
        )
        self._add_host_entry(entry)
        self.swap_outs += 1

    def _add_host_entry(self, entry: _HostBlock) -> None:
        """Insert a block into the host store (most-recent end) and
        enforce the byte budget by evicting the oldest entries cold."""
        hid = self._host_next_id
        self._host_next_id += 1
        self._host_store[hid] = entry
        self.host_bytes += entry.nbytes
        for h in entry.digests:
            self._host_index[h] = hid
        while self.host_bytes > self.host_pool_bytes and self._host_store:
            old_id, old = self._host_store.popitem(last=False)
            for h in old.digests:
                self._host_index.pop(h, None)
            self.host_bytes -= old.nbytes
            self.host_evictions += 1
            self.prefix_evictions += 1   # a cached chunk died for real

    def _pop_host_entry(self, hid: int) -> _HostBlock:
        """Remove a host entry (swap-back claimed it): its digests leave
        the host index FIRST, so allocator work that spills other blocks
        mid-swap-in can never budget-evict the entry being claimed."""
        entry = self._host_store.pop(hid)
        for h in entry.digests:
            self._host_index.pop(h, None)
        self.host_bytes -= entry.nbytes
        return entry

    def _drop_host_digest(self, h: bytes) -> None:
        """Device-side registration of digest `h` supersedes any host
        copy (the freshly written device block serves future hits): drop
        the digest from its host entry, and the entry once no digest can
        reach it — the exclusivity half of the host-tier invariant."""
        hid = self._host_index.pop(h, None)
        if hid is None:
            return
        entry = self._host_store[hid]
        entry.digests.discard(h)
        if not entry.digests:
            del self._host_store[hid]
            self.host_bytes -= entry.nbytes

    def _swap_in_hits(self, slot: int, host_hits, n_full: int) -> None:
        """Swap host-resident prefix blocks back into the pool for row
        `slot`: each hit allocates a device block from the row's
        reservation (the ordinary `_alloc_block` path — eviction pressure
        this causes may itself spill other LRU blocks to host) and writes
        the host bytes back verbatim (`write_pool_block`). Full-chunk
        hits re-register their digests against the new device block, so
        concurrent same-prefix admissions share it like any cached block.
        A partial-chunk hit is NOT re-registered: the claiming row will
        append decode tokens into that block in place — exactly the
        "live row's partial block is never shared" invariant of the
        device path — and retirement re-registers the partial digest over
        the final bytes as usual."""
        for j, hid in host_hits:
            entry = self._pop_host_entry(hid)
            self._alloc_block(slot, j)
            blk = int(self._block_tab[slot, j])
            self.cache = self._write_block(
                self.cache, blk, entry.k, entry.v,
                entry.k_scale, entry.v_scale)
            self.swap_ins += 1
            self.host_hit_blocks += 1
            if j < n_full:
                for h in entry.digests:
                    self._prefix_index[h] = blk
                    self._block_hash.setdefault(blk, set()).add(h)

    # -- durable prefix index: export / import / save / load -----------------

    def _pool_geometry(self) -> dict:
        kv = self.cache.kv
        shape = (kv.k.shape[0], kv.k.shape[2], kv.k.shape[3], kv.k.shape[4])
        return {"block_size": self.block_size,
                "quantized": bool(kv.quantized),
                "kv_shape": list(int(x) for x in shape),
                "kv_dtype": str(kv.k.dtype)}

    def export_index(self) -> dict:
        """Snapshot every cached chunk the scheduler could serve a hit
        from — host-tier entries AND hashed device blocks (live or
        LRU-retained) — as a JSON-able dict: a versioned schema header
        with the pool geometry, a block list of base64 K/V bytes, and a
        digest → block-index map. Feeding it to `import_index` on a
        fresh scheduler (a rebuild for `max_ctx` growth, or a process
        restart via `save_index`/`load_index`) repopulates the HOST tier,
        so the first same-prefix admission swaps the chunks back in
        instead of re-prefilling cold. Digest chains are tier-scoped at
        hash time, so mixed-tier indexes survive round trips unchanged."""
        kv = self.cache.kv

        def b64(a) -> str:
            return base64.b64encode(np.ascontiguousarray(a).tobytes()) \
                .decode("ascii")

        blocks: List[dict] = []
        digests: Dict[str, int] = {}
        if self.paged:
            for blk, hs in self._block_hash.items():
                entry = {"k": b64(np.asarray(kv.k[:, blk])),
                         "v": b64(np.asarray(kv.v[:, blk])),
                         "k_scale": (b64(np.asarray(kv.k_scale[:, blk]))
                                     if kv.quantized else None),
                         "v_scale": (b64(np.asarray(kv.v_scale[:, blk]))
                                     if kv.quantized else None)}
                idx = len(blocks)
                blocks.append(entry)
                for h in hs:
                    digests[h.hex()] = idx
            for hb in self._host_store.values():
                entry = {"k": b64(hb.k), "v": b64(hb.v),
                         "k_scale": (b64(hb.k_scale)
                                     if hb.k_scale is not None else None),
                         "v_scale": (b64(hb.v_scale)
                                     if hb.v_scale is not None else None)}
                idx = len(blocks)
                blocks.append(entry)
                for h in hb.digests:
                    digests[h.hex()] = idx
        return {"schema": INDEX_SCHEMA, "version": INDEX_VERSION,
                **self._pool_geometry(),
                "blocks": blocks, "digests": digests}

    def import_index(self, data) -> int:
        """Load an `export_index` snapshot into the HOST tier (entries
        count against ``host_pool_bytes`` like any spill; the oldest are
        budget-evicted first when the snapshot exceeds it). Returns the
        number of digests now resolvable. NEVER raises on bad input —
        truncated or garbage files, a wrong schema version, a digest
        referencing an out-of-range block, or a geometry mismatch
        (different pool dtype/shape/block size) each warn and cold-start
        with 0 loaded, because a stale index must not take down a serving
        process that can simply re-prefill."""
        if not self.host_tier:
            if data:
                warnings.warn("prefix-index import skipped: the host-RAM "
                              "tier is disabled (host_pool_bytes=0)")
            return 0
        if not isinstance(data, dict) \
                or data.get("schema") != INDEX_SCHEMA:
            warnings.warn("prefix-index import: unrecognized payload "
                          "(not an index snapshot) — cold start")
            return 0
        if data.get("version") != INDEX_VERSION:
            warnings.warn(f"prefix-index import: unsupported version "
                          f"{data.get('version')!r} (want {INDEX_VERSION})"
                          " — cold start")
            return 0
        geo = self._pool_geometry()
        theirs = {k: data.get(k) for k in geo}
        if theirs != geo:
            warnings.warn(f"prefix-index import: pool geometry mismatch "
                          f"({theirs} != {geo}) — cold start")
            return 0
        blocks = data.get("blocks")
        digests = data.get("digests")
        if not isinstance(blocks, list) or not isinstance(digests, dict):
            warnings.warn("prefix-index import: malformed blocks/digests "
                          "tables — cold start")
            return 0
        by_block: Dict[int, set] = {}
        try:
            for hx, idx in digests.items():
                idx = int(idx)
                if not 0 <= idx < len(blocks):
                    warnings.warn(
                        f"prefix-index import: digest {hx!r} references "
                        f"out-of-range block {idx} (have {len(blocks)}) "
                        "— cold start")
                    return 0
                by_block.setdefault(idx, set()).add(bytes.fromhex(hx))
        except (TypeError, ValueError) as e:
            warnings.warn(f"prefix-index import: bad digest table ({e}) "
                          "— cold start")
            return 0
        L, bs, nkv, hd = geo["kv_shape"]
        kv_dt = self.cache.kv.k.dtype
        loaded_digests = 0
        entries: List[_HostBlock] = []
        try:
            for idx, hs in by_block.items():
                e = blocks[idx]
                k = np.frombuffer(base64.b64decode(e["k"]),
                                  dtype=kv_dt).reshape(L, bs, nkv, hd)
                v = np.frombuffer(base64.b64decode(e["v"]),
                                  dtype=kv_dt).reshape(L, bs, nkv, hd)
                ks = vs = None
                if geo["quantized"]:
                    ks = np.frombuffer(base64.b64decode(e["k_scale"]),
                                       dtype=np.float32) \
                        .reshape(L, bs, nkv, 1)
                    vs = np.frombuffer(base64.b64decode(e["v_scale"]),
                                       dtype=np.float32) \
                        .reshape(L, bs, nkv, 1)
                live = {h for h in hs if h not in self._prefix_index
                        and h not in self._host_index}
                if not live:
                    continue   # fresher resident copy wins
                entries.append(_HostBlock(
                    k=k, v=v, k_scale=ks, v_scale=vs, digests=live,
                    nbytes=self._host_block_nbytes()))
                loaded_digests += len(live)
        except (KeyError, TypeError, ValueError) as e:
            warnings.warn(f"prefix-index import: corrupt block payload "
                          f"({e}) — cold start")
            return 0
        for entry in entries:
            self._add_host_entry(entry)
        return loaded_digests

    def save_index(self, path) -> int:
        """Persist `export_index` to `path` as JSON. Returns the number
        of digests written."""
        data = self.export_index()
        with open(path, "w") as f:
            json.dump(data, f)
            f.write("\n")
        return len(data["digests"])

    def load_index(self, path) -> int:
        """Load a `save_index` file into the host tier via
        `import_index`. Missing, truncated, or corrupt files warn and
        cold-start with 0 — never raise (robustness contract shared with
        the kernel registry's plan cache)."""
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError) as e:
            warnings.warn(f"prefix-index load from {path!s} failed ({e}) "
                          "— cold start")
            return 0
        return self.import_index(data)

    def _take_free_block(self) -> int:
        if not self._free:
            self._evict_lru()
        return self._free.pop()

    def _alloc_block(self, slot: int, j: int) -> None:
        blk = self._take_free_block()
        self._refcnt[blk] = 1
        self._block_tab[slot, j] = blk
        self._reserved[slot] -= 1
        self._table_dirty = True
        self._touch_peak()

    def _decref(self, blk: int) -> None:
        """Drop one table reference. At refcount 0 a prefix-cached block is
        *retained* (LRU, evicted lazily under pool pressure so a repeat of
        the same prompt still hits); an uncached block frees immediately."""
        self._refcnt[blk] -= 1
        if self._refcnt[blk] == 0:
            if blk in self._block_hash:
                self._lru[blk] = None        # most-recently-used end
            else:
                self._free.append(blk)
            self._avail += 1

    def _ensure_private_block(self, b: int, j: int) -> None:
        """Make virtual block `j` of row `b` writable: allocate it if the
        table entry is empty, and copy-on-write when it is a block the row
        shares — with other rows (refcount > 1) or with the prefix cache
        itself (a registered digest describes its bytes, so even a sole
        referencer must not append in place: a future claimant of that
        digest trusts the covered slots). The sharers/cache keep the
        pristine block, the appender gets a private copy (charged to its
        reservation like any other allocation)."""
        blk = int(self._block_tab[b, j])
        if blk < 0:
            self._alloc_block(b, j)
        elif self._refcnt[blk] > 1 or blk in self._block_hash:
            dst = self._take_free_block()
            self._refcnt[dst] = 1
            self.cache = self._cow(self.cache, blk, dst)
            self._block_tab[b, j] = dst
            self._decref(blk)
            self._reserved[b] -= 1
            self._table_dirty = True
            self.cow_copies += 1
            self._touch_peak()

    def _alloc_boundary_blocks(self) -> None:
        """Back the position each live slot writes this step."""
        for b, req in enumerate(self._slots):
            if req is None or b in self._chunk_plans:
                continue  # mid-chunk-prefill rows don't decode-append yet
            j = int(self._pos_host[b]) // self.block_size
            if j >= self._max_blocks:
                continue
            self._ensure_private_block(b, j)

    def _alloc_blocks_through(self, b: int, last_pos: int) -> None:
        """Back every position row `b` writes in a speculation round —
        [pos, last_pos] spans the draft writes and the verify chunk — with
        writable (private) blocks, before any of them runs. Positions
        backed for draft tokens that verification then rejects stay
        allocated: they sit inside the row's admission reservation and the
        row's subsequent decode steps write them next anyway."""
        first = int(self._pos_host[b]) // self.block_size
        last = min(last_pos // self.block_size, self._max_blocks - 1)
        for j in range(first, last + 1):
            self._ensure_private_block(b, j)

    def _push_spec_table(self, spec_slots) -> None:
        """Device block table for the draft phase: only speculating rows
        keep their real blocks. Every other row — live decoders, chunk
        plans, free slots — is masked to -1, so the lockstep draft decode
        steps route their writes to the trash block and attend over
        nothing (their logits are discarded and their host `_cur` is
        untouched). Without this, a draft step would append *draft-policy*
        K/V at a non-speculating row's live position — possibly into a
        block it shares with other rows. Marks the table dirty so the
        real table is re-pushed before the normal decode."""
        tab = self._block_tab.copy()
        for b in range(self.max_batch):
            if b not in spec_slots:
                tab[b, :] = -1
        self.cache = dataclasses.replace(
            self.cache,
            kv=dataclasses.replace(self.cache.kv,
                                   block_table=jnp.asarray(tab)),
        )
        self._table_dirty = True

    def _sync_table(self) -> None:
        if self._table_dirty:
            tab = self._block_tab
            if self._chunk_plans:
                # A mid-chunk-prefill row is invisible to the decode step:
                # its DEVICE table row stays all -1 (decode's cache write
                # routes to the trash block, its attention sees no keys,
                # its logits are discarded). The chunk calls receive the
                # real blocks explicitly, so the host table is untouched.
                tab = tab.copy()
                for b in self._chunk_plans:
                    tab[b, :] = -1
            self.cache = dataclasses.replace(
                self.cache,
                kv=dataclasses.replace(
                    self.cache.kv, block_table=jnp.asarray(tab)
                ),
            )
            self._table_dirty = False

    def _release_slot(self, b: int) -> None:
        """Retire row `b`: *decref* its blocks (shared prefix blocks stay
        live under their other referencers; last-reference prefix blocks
        are retained in the LRU; everything else frees) and return its
        unclaimed reservation. The row's resident content — prompt AND
        decode-generated tokens — is registered in the prefix index here,
        not at admission, because a live row appends into its tail block
        in place; once the row stops writing, every written slot is
        immutable and safe to share (`_register_retired`). A row retired
        mid-chunk-plan (cancel/deadline/preemption) drops its service-
        queue entry and registers nothing new: its unwritten tail blocks
        hold no valid bytes (blocks earlier chunks fully covered were
        already registered progressively and stay valid)."""
        req = self._slots[b]
        tier = self._slot_tier[b]
        self._slots[b] = None
        self._slot_tier[b] = None
        if not self.paged:
            return
        plan = self._chunk_plans.pop(b, None)
        if plan is not None:
            self._chunk_queue.remove(b)
            self._slot_hashes[b] = None
        if self.prefix_cache:
            self._register_retired(b, req, tier)
        self._slot_hashes[b] = None
        row = self._block_tab[b]
        for blk in row[row >= 0]:
            self._decref(int(blk))
        row[:] = -1
        self._avail += int(self._reserved[b])
        self._reserved[b] = 0
        self._table_dirty = True

    # -- prefix cache: hash index, matching, claiming, registration --------

    def _hash_chunks(
        self, prompt, tier: Optional[str] = None
    ) -> Tuple[List[bytes], Optional[bytes]]:
        """Chain-hashes of the prompt at block granularity: one digest per
        *full* block-sized token chunk (each digest covers every token up
        to and including its chunk, so a hit at chunk j implies the whole
        prefix matches) plus one for the trailing partial chunk, tagged so
        a partial run never aliases a full block. The chain is seeded with
        the request's precision tier: a tier-T prompt's hidden states —
        and therefore its pool K/V bytes — differ from tier-T', so
        cross-tier requests must never share blocks (tier-None seeds are
        unchanged from the pre-tier format)."""
        toks = np.ascontiguousarray(np.asarray(prompt, np.int32))
        bs = self.block_size
        full, h = [], b"m4bram-prefix" + (tier.encode() if tier else b"")
        for j in range(len(toks) // bs):
            h = hashlib.blake2b(h + toks[j * bs:(j + 1) * bs].tobytes(),
                                digest_size=16).digest()
            full.append(h)
        r = len(toks) % bs
        partial = (
            hashlib.blake2b(h + toks[len(toks) - r:].tobytes() + b"#partial",
                            digest_size=16).digest()
            if r else None
        )
        return full, partial

    def _req_hashes(self, req: Request) -> Tuple[List[bytes], Optional[bytes]]:
        """Chain hashes for `req`'s *served* tokens (prompt ++ generated),
        memoized on the request object — the pool-full path re-checks the
        queue head every step, and the digests depend only on (served
        length, block_size, tier); the length key invalidates the memo
        when a preemption requeues the request with more tokens."""
        tier = getattr(req, "_tier_key", None)
        key = (self.block_size, tier, self._serve_len(req))
        cached = getattr(req, "_prefix_hashes", None)
        if cached is None or cached[0] != key:
            cached = (key, self._hash_chunks(self._serve_tokens(req), tier))
            req._prefix_hashes = cached
        return cached[1]

    def _match_prefix(self, req: Request):
        """Longest resident prefix for `req` — pure lookup, no allocator
        mutation. Returns (hits [(virtual j, pool block)], resident token
        count, revive count = hits that must leave the LRU, reservation =
        blocks the row may still allocate: uncovered virtual blocks plus
        one for a potential copy-on-write of a shared partial block,
        hashes = the (full, partial) chain digests, reused at
        registration time, host_hits [(virtual j, host id)] = chain
        positions resident in the host-RAM tier rather than the pool).

        The chain walk consults the device index first and falls back to
        the host index per digest, so a chain that is part-device,
        part-host still matches end to end. Host hits are counted in
        `resident` (their bytes swap back before prefill) but NOT
        subtracted from the reservation: each one consumes a device block
        through the ordinary `_alloc_block` at swap-in."""
        need = self._need_blocks(req)
        if not self.prefix_cache:
            return [], 0, 0, need, None, []
        hashes = self._req_hashes(req)
        full, partial = hashes
        hits: List[Tuple[int, int]] = []
        host_hits: List[Tuple[int, int]] = []
        for j, h in enumerate(full):
            blk = self._prefix_index.get(h)
            if blk is not None:
                hits.append((j, blk))
                continue
            hid = self._host_index.get(h) if self.host_tier else None
            if hid is not None:
                host_hits.append((j, hid))
                continue
            break
        dev_full = len(hits)     # device full-chunk hits claim for free
        full_hits = dev_full + len(host_hits)
        resident = full_hits * self.block_size
        if full_hits == len(full) and partial is not None:
            blk = self._prefix_index.get(partial)
            if blk is not None:
                hits.append((full_hits, blk))
                resident = self._serve_len(req)
            elif self.host_tier and partial in self._host_index:
                host_hits.append((full_hits, self._host_index[partial]))
                resident = self._serve_len(req)
        revive = sum(1 for _, b in hits if self._refcnt[b] == 0)
        return hits, resident, revive, need - dev_full, hashes, host_hits

    def _claim_hits(self, slot: int, hits) -> None:
        """Map matched pool blocks into row `slot`'s table, incref'ing
        each; refcount-0 blocks are revived out of the LRU (which consumes
        one unit of reclaimable capacity — accounted against `_avail`)."""
        for j, blk in hits:
            if self._refcnt[blk] == 0:
                self._lru.pop(blk)
                self._avail -= 1
            self._refcnt[blk] += 1
            self._block_tab[slot, j] = blk
        if hits:
            self._table_dirty = True

    def _register_full(self, slot: int, limit: Optional[int] = None) -> None:
        """Index row `slot`'s full prompt blocks once their content is
        final (appends only ever land past the prompt). Solo/suffix
        admissions register everything at admission; chunked plans pass
        ``limit`` to register progressively — only blocks the landed
        chunks fully cover, since the straddled tail block is still
        rewritten by the next chunk."""
        full, _ = self._slot_hashes[slot]
        if limit is not None:
            full = full[:limit]
        for j, h in enumerate(full):
            blk = int(self._block_tab[slot, j])
            if blk < 0 or h in self._prefix_index:
                continue
            # An already-hashed block may take a second digest (the
            # straddle block of a retired row carries both the prompt-
            # partial and the extended full-chunk digest); its bytes are
            # frozen from the first registration on. A host copy of the
            # digest is superseded by the fresh device bytes.
            self._drop_host_digest(h)
            self._prefix_index[h] = blk
            self._block_hash.setdefault(blk, set()).add(h)

    def _register_partial(self, slot: int) -> None:
        """Index the trailing partial prompt block at *retirement*. While
        the row lives it appends decode tokens into this block in place;
        deferring registration means a live row's partial block is never
        shared, so in-place appends need no reservation headroom beyond
        the exact `need - full_hits` the allocator holds."""
        if self._slot_hashes[slot] is None:
            return
        full, partial = self._slot_hashes[slot]
        if partial is None:
            return
        j = len(full)
        if j >= self._max_blocks:
            return
        blk = int(self._block_tab[slot, j])
        if blk < 0 or partial in self._prefix_index:
            return
        self._drop_host_digest(partial)
        self._prefix_index[partial] = blk
        self._block_hash.setdefault(blk, set()).add(partial)

    def _register_retired(self, b: int, req: Optional[Request],
                          tier: Optional[str]) -> None:
        """Register row `b`'s resident blocks — prompt AND decode-
        generated — in the prefix index at retirement or preemption.

        Two digest chains are registered, in priority order:

        1. the admission chain — the prompt's full blocks plus its partial
           tail, now immutable. This keeps the original contract: a later
           *same-prompt* request hits the whole prompt, shares the partial
           block, and copies-on-write when it appends.
        2. the extended chain over ``serve_tokens[:pos]`` (pos = next
           write position: everything written, excluding the final
           sampled token whose K/V never lands). Blocks holding generated
           tokens get fresh digests, and the straddle block (prompt tail
           + first generated tokens) takes the extended full-chunk digest
           as a *second* hash. A later admission of ``prompt ++
           generated`` — a preempted request resuming, or a multi-turn
           conversation re-submitting its history — then claims these
           blocks and prefills only the tail.

        Shared digests between the chains (every full prompt block; the
        whole chain when nothing was generated) are deduped by the usual
        ``h in _prefix_index`` guard."""
        if self._slot_hashes[b] is None or req is None:
            return
        self._register_full(b)
        self._register_partial(b)
        pos = int(self._pos_host[b])
        toks = self._serve_tokens(req)[:pos]
        self._slot_hashes[b] = self._hash_chunks(toks, tier)
        self._register_full(b)
        self._register_partial(b)

    def _lifecycle_stats(self) -> dict:
        """Lifecycle / fault-tolerance counters — meaningful in every
        cache mode (preemption/pressure counters stay 0 off-pool)."""
        return {
            "preemptions": self.preemptions,
            "cancellations": self.cancellations,
            "deadline_misses": self.deadline_misses,
            "pool_pressure_events": self.pool_pressure_events,
            "queue_wait_steps": self.queue_wait_steps,
            "head_bypasses": self.head_bypasses,
            "degrade": self.degrade,
            "degraded_requests": self.degraded_requests,
            "preempt": self.preempt,
            "victim_policy": self.victim_policy,
            "callback_errors": self.callback_errors,
            "nan_logit_events": self.nan_logit_events,
            "kernel_fallbacks": self.kernel_fallbacks,
            "chaos": self.chaos.counts() if self.chaos else None,
        }

    def pool_stats(self) -> dict:
        """KV-memory utilization: resident bytes actually backing live
        tokens vs. the contiguous worst-case reservation — plus the
        lifecycle / preemption / fault-injection counters."""
        kv = self.cache.kv
        if kv is None:
            return {"paged": False, "resident_kv_bytes": 0,
                    "reserved_kv_bytes": 0, **self._lifecycle_stats()}
        if not self.paged:
            # Count every cache plane (incl. int8 scale planes) — the
            # whole reservation is resident for the scheduler's lifetime.
            total = sum(int(np.prod(a.shape)) * a.dtype.itemsize
                        for a in (kv.k, kv.v, kv.k_scale, kv.v_scale)
                        if a is not None)
            return {"paged": False,
                    "resident_kv_bytes": total,
                    "reserved_kv_bytes": total,
                    **self._lifecycle_stats()}
        per_token = (kv.k.shape[0] * int(np.prod(kv.k.shape[3:]))
                     * 2 * kv.k.dtype.itemsize)
        if kv.quantized:
            # int8 pool: add the per-(slot, head) fp32 k/v scale planes.
            per_token += kv.k.shape[0] * kv.k.shape[3] * 2 * 4
        allocated = self._live_blocks
        hit_rate = (self.prefix_hit_tokens / self.prompt_tokens_seen
                    if self.prompt_tokens_seen else 0.0)
        return {
            "paged": True,
            "block_size": self.block_size,
            "pool_blocks": self.pool_blocks,
            "free_blocks": len(self._free),
            # Live = referenced by a row's table. Retained = refcount-0
            # prefix blocks kept for future hits; they are reclaimable on
            # demand, so "resident" (what a right-sized pool must hold)
            # counts only live blocks.
            "allocated_blocks": allocated,
            "retained_prefix_blocks": len(self._lru),
            "peak_allocated_blocks": self._peak_blocks,
            "capacity_tokens": self.pool_blocks * self.block_size,
            "resident_kv_bytes": allocated * self.block_size * per_token,
            "peak_resident_kv_bytes":
                self._peak_blocks * self.block_size * per_token,
            # What the contiguous scheduler would allocate for the same
            # settings: max_ctx + decode headroom per slot (matches the
            # non-paged branch, which measures the actual arrays).
            "reserved_kv_bytes":
                self.max_batch * (self.max_ctx + _contig_headroom())
                * per_token,
            # -- cross-request prefix cache --
            "prefix_cache": self.prefix_cache,
            "prefix_hit_blocks": self.prefix_hit_blocks,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prompt_tokens": self.prompt_tokens_seen,
            "prefix_hit_rate": hit_rate,
            "cow_copies": self.cow_copies,
            "prefix_evictions": self.prefix_evictions,
            "cached_prefix_blocks": len(self._prefix_index),
            "prefill_tokens_computed": self.prefill_tokens_computed,
            # -- host-RAM block tier (HBM-vs-host split, FINN-style
            #    capacity modeling: device pool = BRAM/HBM working set,
            #    host store = the spill capacity behind it) --
            "host_tier": self.host_tier,
            "host_pool_bytes": self.host_pool_bytes,
            "host_blocks": len(self._host_store),
            "host_bytes": self.host_bytes,
            "swap_ins": self.swap_ins,
            "swap_outs": self.swap_outs,
            "host_evictions": self.host_evictions,
            "host_hit_blocks": self.host_hit_blocks,
            "host_hit_tokens": self.host_hit_tokens,
            "host_hit_rate": (self.host_hit_tokens / self.prompt_tokens_seen
                              if self.prompt_tokens_seen else 0.0),
            # -- Sarathi-style chunked prefill / decode interleave --
            "chunked_prefill": self.chunked_prefill,
            "prefill_budget": self.prefill_budget,
            "prefill_chunks_run": self.prefill_chunks_run,
            "decode_steps_stalled": self.decode_steps_stalled,
            # Prompt tokens prefilled per chunk-spending step — the
            # interleave ratio. Divided by the steps that actually ran a
            # chunk, not total decode steps: the old steps_run denominator
            # kept shrinking the ratio long after the last plan retired,
            # so the "same" workload read differently depending on how
            # many pure-decode steps followed it.
            "prefill_tokens_per_step":
                self.prefill_chunk_tokens / max(self.prefill_chunk_steps, 1),
            "prefill_chunk_steps": self.prefill_chunk_steps,
            # -- self-speculative decoding --
            "speculate": self.speculate,
            "spec_rounds": self.spec_rounds,
            "spec_draft_tokens": self.spec_draft_tokens,
            "spec_accepted_tokens": self.spec_accepted_tokens,
            "spec_acceptance_rate":
                (self.spec_accepted_tokens / self.spec_draft_tokens
                 if self.spec_draft_tokens else 0.0),
            "spec_verify_calls": self.spec_verify_calls,
            "spec_verify_rows": self.spec_verify_rows,
            # -- lifecycle / preemption / fault injection --
            **self._lifecycle_stats(),
            # -- per-request precision tiers --
            "tier_serving": bool(self._tier_cfgs),
            "tiers": {
                (k or "base"): {
                    **tc,
                    "spec_acceptance_rate":
                        (tc["spec_accepted_tokens"] / tc["spec_draft_tokens"]
                         if tc["spec_draft_tokens"] else 0.0),
                }
                for k, tc in self.tier_counters.items()
            },
        }

    def reset_pool_peak(self) -> None:
        if self.paged:
            self._peak_blocks = self._live_blocks

    # -- admission / retirement --------------------------------------------

    def _fail(self, req: Request, reason: str) -> None:
        req.error = reason
        if req.out_tokens is None:
            req.out_tokens = []
        req.t_done = self._now()

    def _claim_tier(self, req: Request, slot: int) -> Optional[str]:
        """Record `req`'s (already validated) precision tier on the slot
        it is being admitted into and count the admission. Every compute
        call the slot makes — prefill, chunk, decode group, verify — then
        uses the tier's plane-truncated params view."""
        tier = getattr(req, "_tier_key", None)
        self._slot_tier[slot] = tier
        self.tier_counters[tier]["requests"] += 1
        return tier

    def _admit(self, req: Request, slot: int, match=None) -> Optional[Request]:
        """Prefill `req` — solo cold, or suffix-only on a prefix-cache hit
        — and scatter its state into batch row `slot`. A preempted request
        re-admits here with its served tokens = prompt ++ generated, so
        the warm path picks up its registered blocks. Returns the request
        if it finished on its very first token."""
        toks = self._serve_tokens(req)
        n = len(toks)
        tier = self._claim_tier(req, slot)
        if self.paged:
            hits, resident, revive, reserve, hashes, host_hits = (
                match if match is not None else self._match_prefix(req)
            )
            self.prompt_tokens_seen += n
            self.prefix_hit_blocks += len(hits) + len(host_hits)
            self.prefix_hit_tokens += resident
            if host_hits:
                self.host_hit_tokens += sum(
                    min(self.block_size, n - j * self.block_size)
                    for j, _ in host_hits)
            if self.prefix_cache:
                self._slot_hashes[slot] = hashes
            self._avail -= reserve
            self._reserved[slot] = reserve
            self._claim_hits(slot, hits)   # revives pay into _avail here
            if host_hits:
                self._swap_in_hits(slot, host_hits, len(hashes[0]))
            for j in range(-(-n // self.block_size)):
                if self._block_tab[slot, j] < 0:
                    self._alloc_block(slot, j)
            self._touch_peak()
        else:
            resident = 0
        if resident:
            logits = self._prefill_suffix(req, slot, resident)
        else:
            L = self._bucketed(n)
            if self.paged:
                self.prefill_tokens_computed += L
            tokens = np.zeros((1, L), np.int32)
            tokens[0, :n] = toks        # right-pad; real length via `lengths`
            solo, logits = self._prefill_fn(L)(
                self._tier_views[tier],
                {"tokens": jnp.asarray(tokens),
                 "lengths": jnp.asarray([n], jnp.int32)},
            )
            if self.paged:
                # scatter_into_paged also writes this row's table device-
                # side; _table_dirty stays set so rows freed earlier sync.
                self.cache = self._scatter_paged(
                    self.cache, solo, slot, jnp.asarray(self._block_tab[slot])
                )
            else:
                self.cache = self._scatter(self.cache, solo, slot)
        if self.paged and self.prefix_cache:
            self._register_full(slot)
        self._pos_host[slot] = n
        return self._first_token(req, slot, logits)

    def _first_token(self, req: Request, slot: int, logits) -> Optional[Request]:
        """Sample the request's first output token from its prefill logits
        and arm the slot's decode state — the shared admission tail of the
        solo, suffix and chunked prefill paths. A resumed (previously
        preempted) request keeps its earlier tokens: its next token is
        sampled at PRNG step `len(out_tokens)`, exactly the stream index
        an uninterrupted run would use, so resume is bit-identical even at
        temperature > 0. Returns the request if it finished on that very
        first token (slot released)."""
        step0 = len(req.out_tokens or ())
        key = sampling.request_key(self.seed, req.rid)
        tok = int(np.asarray(sampling.sample_tokens(
            logits[:, -1, :],
            np.asarray([req.temperature], np.float32),
            np.asarray([req.top_k], np.int32),
            key[None],
            np.asarray([step0], np.int32),
        ))[0])
        self._cur[slot, 0] = tok
        self._temps[slot] = req.temperature
        self._top_ks[slot] = req.top_k
        self._keys[slot] = key
        self._steps[slot] = step0 + 1
        self._slots[slot] = req
        if req.out_tokens:
            req.out_tokens.append(tok)     # resumed: extend, don't reset
        else:
            req.out_tokens = [tok]
        if req.t_first is None:
            req.t_first = self._now()
        self._emit(req, tok)
        if self._finished(req, tok):
            self._release_slot(slot)
            return req
        return None

    def _suffix_fn(self, length: int):
        length = self._bucketed(length)  # see _prefill_fn
        if length not in self._suffix_cache:
            self._suffix_cache[length] = jax.jit(self.model.prefill_suffix)
        return self._suffix_cache[length]

    def _prefill_suffix(self, req: Request, slot: int, resident: int):
        """Run the suffix-only prefill for a prefix-cache hit: gather the
        resident prefix K/V from the row's (already claimed) pool blocks,
        prefill only the uncached tail, scatter the tail's K/V into the
        row's fresh blocks. At least the last prompt token is always
        prefilled — the first sampled token comes from its logits — but
        positions already resident are never re-written, so a fully
        cached prompt admits without moving any KV data."""
        toks = self._serve_tokens(req)
        n = len(toks)
        start = min(resident, n - 1)
        ls = n - start
        Ls = self._bucketed(ls)
        self.prefill_tokens_computed += Ls
        tokens = np.zeros((1, Ls), np.int32)
        tokens[0, :ls] = toks[start:]
        kv = self.cache.kv
        # Clamp the per-layer pool gather to the blocks that actually
        # cover the prefix (host-known bound, same trick as
        # paged_gather(max_blocks=...)), bucketed so the compiled
        # signature count stays bounded instead of always paying the
        # full max_blocks table width.
        gran = max(self.bucket // self.block_size, 1)
        covering = -(-start // self.block_size)     # blocks holding [0, start)
        nbp = min(self._max_blocks, max(gran, -(-covering // gran) * gran))
        batch = {
            "tokens": jnp.asarray(tokens),
            "lengths": jnp.asarray([ls], jnp.int32),
            "start": jnp.asarray(start, jnp.int32),
            "pool_k": kv.k,
            "pool_v": kv.v,
            "prefix_blocks": jnp.asarray(self._block_tab[slot, :nbp]),
        }
        if kv.quantized:
            batch["pool_k_scale"] = kv.k_scale
            batch["pool_v_scale"] = kv.v_scale
        solo, logits = self._suffix_fn(Ls)(
            self._tier_views[self._slot_tier[slot]], batch)
        if resident < n:
            # Below a full-prompt hit only whole blocks are shared, so the
            # suffix starts exactly at the block boundary `resident`.
            self.cache = self._scatter_suffix(
                self.cache, solo, slot, jnp.asarray(self._block_tab[slot]),
                resident // self.block_size,
            )
        else:
            self.cache = self._set_row(
                self.cache, solo, slot, jnp.asarray(self._block_tab[slot])
            )
        return logits

    def _admit_chunked(self, req: Request, slot: int, match) -> None:
        """Claim row `slot` for `req` and enqueue a chunk *plan* instead of
        prefilling solo: the same allocator work as `_admit` (reservation,
        prefix-hit claiming, prompt-block allocation) happens up front, but
        the prompt KV is computed `prefill_budget` tokens at a time by
        `_run_chunk`, one call per scheduler step, interleaved with the
        live batch's decode steps. Until the last chunk lands, the slot is
        masked out of decoding (device table row all -1, see `_sync_table`)
        and out of sampling, and its prompt blocks stay unregistered in
        the prefix index (their bytes don't exist yet)."""
        toks = self._serve_tokens(req)
        n = len(toks)
        self._claim_tier(req, slot)
        hits, resident, revive, reserve, hashes, host_hits = match
        self.prompt_tokens_seen += n
        self.prefix_hit_blocks += len(hits) + len(host_hits)
        self.prefix_hit_tokens += resident
        if host_hits:
            self.host_hit_tokens += sum(
                min(self.block_size, n - j * self.block_size)
                for j, _ in host_hits)
        if self.prefix_cache:
            self._slot_hashes[slot] = hashes
        self._avail -= reserve
        self._reserved[slot] = reserve
        self._claim_hits(slot, hits)   # revives pay into _avail here
        if host_hits:
            self._swap_in_hits(slot, host_hits, len(hashes[0]))
        for j in range(-(-n // self.block_size)):
            if self._block_tab[slot, j] < 0:
                self._alloc_block(slot, j)
        self._touch_peak()
        self._pos_host[slot] = 0
        self._cur[slot, 0] = 0         # dummy decode input while prefilling
        self._slots[slot] = req
        # Chunks start at the warm-prefix boundary: `resident` below a
        # full-prompt hit is whole blocks only, so chunk writes begin at a
        # block boundary and never touch a block shared with other rows.
        self._chunk_plans[slot] = {"req": req, "toks": toks,
                                   "next": resident, "n": n}
        self._chunk_queue.append(slot)
        self._table_dirty = True       # mask this row on the next sync

    def _run_chunk(self, slot: int) -> Optional[Request]:
        """Run one `prefill_budget`-token chunk of row `slot`'s plan
        through the fused paged-prefill kernel: the chunk attends over
        [pool-resident prefix ++ chunk] and its K/V lands in the row's own
        pool blocks from the kernel epilogue — no scatter round trip, no
        per-layer prefix gather. On the final chunk the prompt is fully
        resident: the row's full blocks are registered in the prefix
        index, the device table is unmasked, and the first output token is
        sampled from the chunk's last-token logits. Returns the request
        if it finished on that first token."""
        plan = self._chunk_plans[slot]
        req, n, start = plan["req"], plan["n"], plan["next"]
        Lc = self.prefill_budget
        t = min(Lc, n - start)
        tokens = np.zeros((1, Lc), np.int32)
        tokens[0, :t] = plan["toks"][start:start + t]
        # Clamp the kernel's block-table operand to the blocks covering
        # [0, start + t), bucketed like _prefill_suffix's gather clamp so
        # the compiled signature count stays bounded: one executable per
        # (budget, bucketed covering-blocks) pair.
        gran = max(self.bucket // self.block_size, 1)
        covering = -(-(start + t) // self.block_size)
        nbp = min(self._max_blocks, max(gran, -(-covering // gran) * gran))
        batch = {
            "tokens": jnp.asarray(tokens),
            "lengths": jnp.asarray([t], jnp.int32),
            "start": jnp.asarray(start, jnp.int32),
            "slot": jnp.asarray(slot, jnp.int32),
            "blocks": jnp.asarray(self._block_tab[slot, :nbp]),
        }
        self.cache, logits = self._chunk(
            self._tier_views[self._slot_tier[slot]], self.cache, batch)
        self.prefill_chunks_run += 1
        self.prefill_chunk_tokens += t
        self.prefill_tokens_computed += Lc
        plan["next"] = start + t
        if self.prefix_cache:
            # Blocks this chunk fully covered are final — index them now
            # so a same-prefix request admitted on a later step shares
            # them instead of re-prefilling.
            self._register_full(slot, limit=plan["next"] // self.block_size)
        if plan["next"] < n:
            return None
        # Prompt fully resident: the slot graduates to decoding.
        del self._chunk_plans[slot]
        self._pos_host[slot] = n
        if self.prefix_cache:
            self._register_full(slot)
        self._table_dirty = True       # unmask the row for the decode step
        return self._first_token(req, slot, logits)

    def _emit(self, req: Request, tok: int) -> None:
        """Count the token and stream it to the per-request and scheduler-
        level `on_token` callbacks. Callbacks are USER code: one raising
        must never kill the engine loop (it used to propagate out of
        `step()` and take every live slot down with it) — it marks only
        this request errored, and `_finished` retires it at the caller."""
        self.tokens_emitted += 1
        self.tier_counters[getattr(req, "_tier_key", None)]["tokens"] += 1
        callbacks = [cb for cb in (req.on_token, self.on_token)
                     if cb is not None]
        if not callbacks:
            return
        try:
            if self.chaos is not None and self.chaos.fire("callback"):
                raise InjectedFault("on_token callback")
            for cb in callbacks:
                cb(req, tok)
        except Exception as e:  # noqa: BLE001 — isolate user-code faults
            self.callback_errors += 1
            req.error = f"on_token callback raised: {e!r}"

    @staticmethod
    def _finished(req: Request, tok: int) -> bool:
        return (req.failed
                or len(req.out_tokens) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id))

    # -- self-speculative decoding -----------------------------------------

    def _spec_phase(self) -> List[Request]:
        """One speculation round: draft up to ``speculate`` tokens per
        eligible slot with the truncated-plane view params (draft K/V
        lands speculatively in the row's own pool blocks), then verify
        each slot's ``[current token, drafts]`` window in ONE full-policy
        chunk-shaped call and emit the longest matching prefix.

        Eligibility: greedy slots only (acceptance compares argmaxes; a
        sampled slot has no deterministic token to match), not mid-chunk-
        prefill, and at least 2 tokens still owed (with 1 owed the normal
        trailing decode is strictly cheaper than draft + verify).

        Rollback is a metadata write: verification recomputes all k+1
        positions at the full policy — per-token K/V overwrites the
        draft's bytes in place — so rejecting a tail only requires
        restoring ``pos``/``length`` to the accepted frontier
        (:func:`set_decode_positions`). Rejected positions' stale pool
        bytes are dead: decode attention masks ``kpos >= length`` and the
        row's next steps write those very positions before reading them.
        No copy-on-write is needed because every speculative write lands
        at position >= the prompt length, inside blocks the round made
        private up front (:meth:`_alloc_blocks_through`) — shared prefix
        blocks are never touched, so the prefix cache's
        partial-block-registers-at-retirement invariant survives."""
        spec: Dict[int, int] = {}       # slot -> draft count this round
        for b, req in enumerate(self._slots):
            if req is None or b in self._chunk_plans:
                continue
            if req.temperature > 0:
                continue
            tier = self._slot_tier[b]
            if (tier is not None
                    and self._tier_cfgs[tier].w_bits
                    <= self._draft_cfg.w_bits):
                # Speculation composes with tiers only when the draft
                # truncates strictly below the slot's tier — a w2 slot
                # has nothing cheaper than itself to draft with, so it
                # just decodes normally.
                continue
            k_eff = min(self.speculate,
                        req.max_new_tokens - len(req.out_tokens) - 1)
            if k_eff >= 1:
                spec[b] = k_eff
        if not spec:
            return []
        # Back every position the round writes — draft appends at
        # [pos, pos+k) and the verify chunk at [pos, pos+k] — before any
        # kernel runs. All writes sit inside the row's admission
        # reservation (pos + k <= prompt + max_new - 2).
        for b, k_eff in spec.items():
            self._alloc_blocks_through(b, int(self._pos_host[b]) + k_eff)
        self._push_spec_table(set(spec))

        # Lockstep draft: every speculating row advances one token per
        # iteration through the ordinary decode step, but with the view
        # params — same kernels, plane-truncated contraction. Rows that
        # hit their own draft count early are masked out (their surplus
        # writes would overrun their allocation).
        active = set(spec)
        drafts: Dict[int, List[int]] = {b: [] for b in spec}
        cur = self._cur.copy()
        for i in range(max(spec.values())):
            todo = {b for b in active if len(drafts[b]) < spec[b]}
            if todo != active:
                active = todo
                self._push_spec_table(active)
            logits = self._decode_call(self._draft_params, cur)
            toks = np.asarray(jnp.argmax(
                logits[:, -1, :].astype(jnp.float32), axis=-1))
            for b in active:
                drafts[b].append(int(toks[b]))
                cur[b, 0] = int(toks[b])

        # Verify: one chunk-shaped full-policy call per slot over
        # [current token, d_1 .. d_k]. Fixed window (speculate + 1) keeps
        # one compiled signature per bucketed block count; position i's
        # argmax is the token sequential greedy decode would emit there.
        finished: List[Request] = []
        Lc = self.speculate + 1
        R = self.max_batch
        gran = max(self.bucket // self.block_size, 1)
        vgroups: Dict[Optional[str], List[int]] = {}
        for b in spec:
            vgroups.setdefault(self._slot_tier[b], []).append(b)
        for tkey in sorted(vgroups, key=lambda k: (k is not None, k or "")):
            slots_g = vgroups[tkey]
            # One bucketed block-table width for the whole group: extra
            # -1 entries on shorter rows are dead (masked exactly), so
            # the widest row sets the compiled signature.
            covering = max(
                -(-(int(self._pos_host[b]) + spec[b] + 1) // self.block_size)
                for b in slots_g)
            nbp = min(self._max_blocks,
                      max(gran, -(-covering // gran) * gran))
            tokens = np.zeros((R, Lc), np.int32)
            lengths = np.zeros((R,), np.int32)
            starts = np.zeros((R,), np.int32)
            slot_ids = np.full((R,), -1, np.int32)
            btab = np.full((R, nbp), -1, np.int32)
            for b in slots_g:
                t = spec[b] + 1
                tokens[b, 0] = self._cur[b, 0]
                tokens[b, 1:t] = drafts[b]
                lengths[b] = t
                starts[b] = int(self._pos_host[b])
                slot_ids[b] = b
                btab[b] = self._block_tab[b, :nbp]
            batch = {
                "tokens": jnp.asarray(tokens),
                "lengths": jnp.asarray(lengths),
                "starts": jnp.asarray(starts),
                "slots": jnp.asarray(slot_ids),
                "blocks": jnp.asarray(btab),
            }
            self.cache, logits = self._verify(self._tier_views[tkey],
                                              self.cache, batch)
            self.spec_verify_calls += 1
            self.spec_verify_rows += len(slots_g)
            lg = np.asarray(jnp.argmax(logits.astype(jnp.float32), axis=-1))
            tc = self.tier_counters[tkey]
            for b in slots_g:
                k_eff = spec[b]
                req = self._slots[b]
                p = int(self._pos_host[b])
                emitted = greedy_accept(lg[b, :k_eff + 1], drafts[b])
                self.spec_draft_tokens += k_eff
                self.spec_accepted_tokens += len(emitted) - 1
                tc["spec_draft_tokens"] += k_eff
                tc["spec_accepted_tokens"] += len(emitted) - 1
                req.spec_drafted += k_eff
                req.spec_accepted += len(emitted) - 1
                m = 0
                done = False
                for tok in emitted:
                    req.out_tokens.append(tok)
                    self._emit(req, tok)
                    m += 1
                    if self._finished(req, tok):
                        done = True
                        break
                self._pos_host[b] = p + m
                self._steps[b] += m
                if done:
                    self._release_slot(b)
                    finished.append(req)
                else:
                    self._cur[b, 0] = emitted[m - 1]
        # Roll every row back to its accepted frontier in one device
        # write. Clobbering non-speculating rows is safe: chunk plans
        # drive the chunk kernel with explicit start/length operands (the
        # final chunk re-sets the device row), free rows already carry
        # stale positions behind an all--1 table, and live decoders were
        # position-synced with _pos_host before this round began.
        pos = jnp.asarray(self._pos_host, jnp.int32)
        self.cache = self._set_positions(self.cache, pos, pos)
        self._table_dirty = True       # real table re-pushed before decode
        self.spec_rounds += 1
        return finished

    def _decode_tier_groups(self, groups) -> jnp.ndarray:
        """Mixed-tier batched decode: one decode call per tier group, each
        with that group's truncated-plane view params and a block table
        masking every non-group row to -1 (writes route to the trash
        block, attention sees no keys — :meth:`_push_spec_table`, reused
        verbatim from the speculation machinery). Per-token activation
        scales make row b's logits independent of the other rows' content,
        so a group call computes exactly what a solo tier-T engine's
        decode computes for those rows — the tier bit-identity contract.

        Each jitted decode call advances EVERY row's device pos/length by
        one, so with G group calls the naive result would be +G. Between
        calls positions are reset to the pre-decode frontier and after the
        last call set to frontier+1 for all rows — precisely the state one
        single-call decode leaves behind (one metadata write each, same
        :func:`set_decode_positions` the speculation rollback uses).

        Returns the (B, V) last-position logits matrix with each row taken
        from its own group's call, ready for the shared sampling path."""
        pos0 = np.asarray(self._pos_host, np.int32).copy()
        cur = jnp.asarray(self._cur)
        out = None
        order = sorted(groups, key=lambda k: (k is not None, k or ""))
        for i, key in enumerate(order):
            if i:
                p = jnp.asarray(pos0)
                self.cache = self._set_positions(self.cache, p, p)
            self._push_spec_table(set(groups[key]))
            logits = self._decode_call(self._tier_views[key], cur)
            self.tier_counters[key]["decode_calls"] += 1
            rows = np.asarray(logits[:, -1, :])
            if out is None:
                out = np.zeros_like(rows)
            for b in groups[key]:
                out[b] = rows[b]
        p1 = jnp.asarray(pos0 + 1)
        self.cache = self._set_positions(self.cache, p1, p1)
        self._table_dirty = True       # real table re-pushed next step
        return jnp.asarray(out)

    # -- the decode loop ----------------------------------------------------

    def step(self) -> List[Request]:
        """One scheduler step: process lifecycle events (cancellations,
        deadline expiries), admit waiting requests into free slots
        (chunked-prefill plan by default; suffix-only prefill on a
        full-prompt prefix hit), spend at most one ``prefill_budget``-token
        chunk of in-flight admission prefill, run one batched decode step,
        sample, retire finished slots. Live slots always decode — a chunk
        costs them one kernel call of extra latency per step, never a
        skipped step.

        When the pool can't cover an admission's revive + reservation
        draw the request queues FIFO — but first the scheduler may (a)
        preempt one victim whose release alone covers the shortfall
        (``preempt``, warm bit-identical resume) and (b) admit a smaller
        admissible request past the blocked head, at most
        ``max_head_bypass`` consecutive times (head-of-line mitigation,
        starvation-free). With ``degrade``, admissions during sustained
        pressure are served at the cheapest configured tier. Returns the
        requests that finished this step (including any rejected as
        oversized, cancelled, past deadline, or individually failed —
        those carry ``error``)."""
        self._step_calls += 1
        finished: List[Request] = list(self._lifecycle_phase())
        pressure = False
        chunk_admitted = False
        preempted = False
        admitted_now: set = set()
        free: Deque[int] = collections.deque(
            b for b in range(self.max_batch) if self._slots[b] is None)
        deg = self.degrade and self._pressure_streak >= self.degrade_after
        while free and self.waiting and not chunk_admitted:
            slot = free[0]
            head = self.waiting[0]
            reason = self._reject_reason(head)
            if reason is not None:
                # Oversized / bad tier: reject just this request.
                self.waiting.popleft()
                self._fail(head, reason)
                finished.append(head)
                continue
            idx = 0
            was_degraded = self._degrade_tier(head) if deg else False
            match = self._match_prefix(head) if self.paged else None
            if self.paged:
                # revive + reserve is the admission's true capacity draw
                # (shared live blocks are free).
                short = match[2] + match[3] > self._avail
                if (not short and self.chaos is not None
                        and self.chaos.fire("alloc")):
                    short = True   # injected transient reservation failure
                if short:
                    pressure = True
                    self.pool_pressure_events += 1
                    shortfall = match[2] + match[3] - self._avail
                    # (1) Preempt one victim for the head — never for a
                    # head that was itself preempted (ping-pong guard),
                    # and at most once per step.
                    if (self.preempt and not preempted
                            and head.preemptions == 0):
                        victim = self._pick_victim(shortfall, admitted_now)
                        if victim is not None:
                            self._preempt(victim)
                            preempted = True
                            free.append(victim)
                            continue   # retry head against freed blocks
                    # (2) Bounded bypass: admit a smaller admissible
                    # request past the blocked head.
                    idx, match, was_degraded = self._bypass_candidate(deg)
                    if idx is None:
                        break          # head keeps FIFO priority: wait
                    self.head_bypasses += 1
                    self._head_bypass += 1
            req = self.waiting[idx]
            del self.waiting[idx]
            if idx == 0:
                self._head_bypass = 0  # the head itself is admitting
            if was_degraded and req.degraded_to is None:
                req.degraded_to = req._tier_key
                self.degraded_requests += 1
            if (self.chunked_prefill and match is not None
                    and match[1] < self._serve_len(req)):
                # Uncached prompt tail → chunk plan. (A full-prompt
                # prefix hit moves no KV and stays on the suffix
                # path: its one-token "prefill" reads shared blocks
                # the chunk kernel must never write.) One chunked
                # admission per step: a same-prefix follower admitted
                # in this same step would match against an index this
                # plan hasn't written to yet and cold-prefill blocks
                # it could share — admitted next step, it hits the
                # blocks the chunks have landed (and registered) by
                # then.
                self._admit_chunked(req, slot, match)
                admitted_now.add(slot)
                chunk_admitted = True
                free.popleft()
                continue
            done = self._admit(req, slot, match)
            if done is not None:
                # Finished on its prefill token (max_new <= 1 /
                # instant EOS) — the slot is free again, keep
                # admitting into it this same step.
                finished.append(done)
                continue
            admitted_now.add(slot)
            free.popleft()
        self._pressure_streak = self._pressure_streak + 1 if pressure else 0
        self.queue_wait_steps += len(self.waiting)

        # Spend one budgeted chunk of admission prefill alongside this
        # step's decode — round-robin across queued plans: the serviced
        # plan rotates to the back, so with several admissions in flight
        # each spends one chunk every len(queue) steps and no prompt's
        # first token waits for every earlier prompt to finish prefilling.
        chunk_ran = False
        if self._chunk_queue:
            slot = self._chunk_queue.popleft()
            chunk_ran = True
            done = self._run_chunk(slot)
            if slot in self._chunk_plans:
                self._chunk_queue.append(slot)  # unfinished: back of line
            elif done is not None:
                finished.append(done)
            self.prefill_chunk_steps += 1

        if not any(r is not None and b not in self._chunk_plans
                   for b, r in enumerate(self._slots)):
            return finished  # nothing decodes: only chunk plans in flight

        if self.speculate:
            # Speculation rounds replace several sequential decode steps
            # for greedy slots; survivors still join the trailing decode
            # below, which is exactly their next sequential step.
            finished.extend(self._spec_phase())
            if not any(r is not None and b not in self._chunk_plans
                       for b, r in enumerate(self._slots)):
                return finished  # every live slot retired mid-round

        if chunk_ran:
            self.decode_steps_stalled += 1
        if self.paged:
            self._alloc_boundary_blocks()
            self._sync_table()
        groups: Dict[Optional[str], List[int]] = {}
        for b, r in enumerate(self._slots):
            if r is not None and b not in self._chunk_plans:
                groups.setdefault(self._slot_tier[b], []).append(b)
        if len(groups) <= 1:
            # Homogeneous batch (incl. the no-tiers engine): one decode
            # with the group's view params — exactly what a solo engine
            # whose whole policy is this tier runs, so bit-identity for
            # the single-tier case holds by construction.
            key = next(iter(groups), None)
            logits = self._decode_call(self._tier_views[key], self._cur)
            self.tier_counters[key]["decode_calls"] += 1
            last = logits[:, -1, :]
        else:
            last = self._decode_tier_groups(groups)
        live = sorted(b for g in groups.values() for b in g)
        if self.chaos is not None and live and self.chaos.fire("nan"):
            # Chaos: poison one live row's logits; the detector below
            # must catch it and fail that request alone.
            bad_row = live[self.chaos.pick(len(live))]
            last = jnp.asarray(last).at[bad_row].set(jnp.nan)
        # Always-on poisoned-logits detector: a non-finite logits row
        # (numerics blow-up, corrupted weights, injected fault) cannot
        # sample a meaningful token — retire just that request with
        # error="nan-logits" before sampling; its K/V writes this step
        # were row-local, so batch neighbours are untouched.
        bad = np.asarray(jnp.any(
            ~jnp.isfinite(jnp.asarray(last).astype(jnp.float32)), axis=-1))
        for b in live:
            if bad[b]:
                self.nan_logit_events += 1
                finished.append(self._retire_abnormal(b, "nan-logits"))
        toks = np.asarray(sampling.sample_tokens(
            last, self._temps, self._top_ks,
            self._keys, self._steps,
        ))
        self._steps += 1
        self.steps_run += 1
        for b, req in enumerate(self._slots):
            if req is None or b in self._chunk_plans:
                continue  # mid-chunk-prefill slots don't sample yet
            self._pos_host[b] += 1
            tok = int(toks[b])
            req.out_tokens.append(tok)
            self._emit(req, tok)
            if self._finished(req, tok):
                self._release_slot(b)
                finished.append(req)
            else:
                self._cur[b, 0] = tok
        return finished

    def run(self, requests=()) -> List[Request]:
        """Serve a workload to completion, admitting each request no
        earlier than its ``arrival_time`` (seconds from now). Returns the
        requests in completion order with ``t_first``/``t_done`` filled;
        oversized requests come back failed (``error`` set) without
        aborting the loop."""
        pending = sorted(requests, key=lambda r: r.arrival_time)
        self._t0 = time.perf_counter()
        done: List[Request] = []
        while pending or self.waiting or self.num_active:
            now = time.perf_counter() - self._t0
            while pending and pending[0].arrival_time <= now:
                self.submit(pending.pop(0))
            if not self.waiting and self.num_active == 0:
                # Idle: sleep up to the next arrival.
                time.sleep(min(max(pending[0].arrival_time - now, 0.0), 0.05))
                continue
            for req in self.step():
                req.t_done = time.perf_counter() - self._t0
                done.append(req)
        self._t0 = None
        return done
