"""Vectorized on-device sampling for the serving stack.

One jitted call samples every batch slot at once — greedy, temperature,
and top-k — with *per-slot* parameters, replacing the per-token NumPy
loop the engine used to run on the host.

Reproducibility contract: a request's sample stream is a pure function of
``(seed, rid, step)``. The base key is ``fold_in(PRNGKey(seed), rid)`` and
each emitted token folds in the request's own token counter, so sampled
outputs never depend on batch composition, slot assignment, or admission
order — a request gets the same tokens served solo, in a static batch, or
admitted mid-decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def request_key(seed: int, rid: int) -> np.ndarray:
    """Per-request base PRNG key; the stream identity is (seed, rid) only."""
    return np.asarray(jax.random.fold_in(jax.random.PRNGKey(seed), rid))


def _sample_one(logits, temperature, top_k, base_key, step):
    """Sample one slot. logits (V,); all params scalars; vmapped over B."""
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    V = logits.shape[-1]
    # top-k: keep logits >= the k-th largest (ties keep everything equal
    # to the threshold); k <= 0 or k >= V disables the filter.
    kk = jnp.where((top_k <= 0) | (top_k >= V), V, top_k)
    thresh = jnp.sort(logits)[::-1][jnp.maximum(kk - 1, 0)]
    masked = jnp.where(logits >= thresh, logits, jnp.finfo(jnp.float32).min)
    key = jax.random.fold_in(base_key, step)
    temp = jnp.maximum(temperature, 1e-6)
    sampled = jax.random.categorical(key, masked / temp).astype(jnp.int32)
    return jnp.where(temperature > 0, sampled, greedy)


@jax.jit
def sample_tokens(logits, temperatures, top_ks, base_keys, steps):
    """logits (B, V) float; temperatures (B,); top_ks (B,) int;
    base_keys (B, 2) uint32; steps (B,) int → tokens (B,) int32.

    temperature <= 0 means greedy for that slot (keys/steps unused there).
    """
    return jax.vmap(_sample_one)(logits, temperatures, top_ks, base_keys,
                                 steps)
