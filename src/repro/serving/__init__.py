from repro.serving.chaos import FaultInjector, InjectedFault  # noqa: F401
from repro.serving.engine import ServingEngine  # noqa: F401
from repro.serving.invariants import assert_pool_invariants  # noqa: F401
from repro.serving.scheduler import (  # noqa: F401
    VICTIM_POLICIES,
    ContinuousScheduler,
    Request,
)
