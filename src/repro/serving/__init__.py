from repro.serving.engine import ServingEngine  # noqa: F401
from repro.serving.scheduler import ContinuousScheduler, Request  # noqa: F401
