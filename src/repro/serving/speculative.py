"""Self-speculative decoding from the resident bit-plane weights.

M4BRAM's thesis is that one resident copy of the data serves multiple
computational roles. Our serving stack stores weights as little-endian
2-bit planes (``repro.core.bitplane``), so a low-precision *draft* model
is already resident: contracting only the top planes of the packed w8
weights is a w4/w2 forward pass with zero extra weight memory. This
module is the policy half of that subsystem:

  * :func:`derive_draft_params` — turn the serving params into a draft
    view by setting ``plane_lo`` on every packed leaf. Since PR 8 this
    is a thin wrapper over :func:`repro.core.precision
    .truncate_policy_view` — the *same* leaf-walk that builds per-request
    serving-tier views, so draft views and tier views are provably the
    same code path. The view is *pure*: leaves (packed bytes, scales)
    are identity-shared with the target params; only pytree aux data
    changes, so the draft forward pass is one extra jit trace, never a
    second weight copy.
  * :func:`greedy_accept` — the acceptance rule. Every emitted token is
    a full-policy verify argmax (the draft only decides *how many* of
    them land per step), which is why greedy speculation is bitwise
    identical to non-speculative greedy decode.

The scheduling half lives in ``ContinuousScheduler.step()``: draft k
tokens per eligible slot with the view params (speculative K/V appended
into the row's own pool blocks), then verify each tier group's
``[current token, drafts]`` windows in one multi-row full-tier call
(``prefill_chunk_logits_multi``) whose K/V writes overwrite the draft's,
and roll back positions/lengths for the rejected tail
(:func:`repro.models.kv_cache.set_decode_positions`). When requests
carry precision tiers, the draft must truncate strictly *below* each
slot's tier and verification runs at the slot's tier, not the storage
policy — composition the scheduler enforces per slot.

Plane math (see ``kernels/bitplane_matmul.py`` for the derivation): a
w8 leaf served at w4 drops ``lo = (8-4)/2 = 2`` planes, at w2 drops 3;
a w4 leaf served at w2 drops 1. The truncated contraction reads (in the
paper's layout) ``draft_bits / target_bits`` of the weight bytes — the
latency story ``benchmarks/spec_bench.py`` models.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple, Union

from repro.core.precision import (  # noqa: F401  (re-exported: bench/tests)
    PLANE_BITS,
    parse_tier_token,
    plane_offset,
    truncate_policy_view,
)
from repro.core.quant import QuantConfig


def parse_draft_spec(spec: Union[str, QuantConfig]) -> QuantConfig:
    """Normalize a ``--draft-policy`` value ("w2a8" / "w4a8" or an
    already-built QuantConfig). Drafts are pure plane truncations, so the
    Table-III mixed-group ratio ("rZZ") has no meaning here — same rule
    as serving tiers (:func:`repro.core.precision.parse_tier_token`)."""
    return parse_tier_token(spec)


def derive_draft_params(params, draft: Union[str, QuantConfig]) -> Tuple[object, int]:
    """Draft-policy view of served params: every PackedWeight leaf whose
    precision exceeds the draft's gets ``plane_lo`` set so its matmuls
    contract only the top planes. Returns ``(draft_params, truncated)``.

    The view shares every array leaf with the target params by identity
    (``id(draft.packed) == id(target.packed)``) — asserted by tests and
    the point of the whole exercise. Raises if the params carry no packed
    leaves (serve with a quant policy first) or if the draft spec doesn't
    truncate anything (target already at or below draft precision)."""
    return truncate_policy_view(params, parse_draft_spec(draft),
                                require_truncation=True)


def greedy_accept(
    verify_tokens: Sequence[int], draft_tokens: Sequence[int]
) -> List[int]:
    """Longest-matching-prefix acceptance for greedy speculation.

    ``verify_tokens[i]`` is the full-policy argmax at chunk position i of
    the verify call over ``[current token, d_1 .. d_k]`` — i.e. the token
    greedy decode would emit after accepting the first i draft tokens.
    Accept while ``d_{i+1} == verify_tokens[i]``; the returned list is
    ``[g_0, .., g_m]`` with every element a *verify* argmax (between 1
    and k+1 tokens — the last is the free "bonus" token when all drafts
    match). The draft never contributes a token, only the count, so the
    emitted stream is bitwise the sequential greedy stream."""
    emitted = [int(verify_tokens[0])]
    for i, d in enumerate(draft_tokens):
        if int(d) != emitted[-1]:
            break
        emitted.append(int(verify_tokens[i + 1]))
    return emitted
