"""Self-speculative decoding from the resident bit-plane weights.

M4BRAM's thesis is that one resident copy of the data serves multiple
computational roles. Our serving stack stores weights as little-endian
2-bit planes (``repro.core.bitplane``), so a low-precision *draft* model
is already resident: contracting only the top planes of the packed w8
weights is a w4/w2 forward pass with zero extra weight memory. This
module is the policy half of that subsystem:

  * :func:`derive_draft_params` — turn the serving params into a draft
    view by setting ``plane_lo`` on every packed leaf. The view is
    *pure*: leaves (packed bytes, scales) are identity-shared with the
    target params; only pytree aux data changes, so the draft forward
    pass is one extra jit trace, never a second weight copy.
  * :func:`greedy_accept` — the acceptance rule. Every emitted token is
    a full-policy verify argmax (the draft only decides *how many* of
    them land per step), which is why greedy speculation is bitwise
    identical to non-speculative greedy decode.

The scheduling half lives in ``ContinuousScheduler.step()``: draft k
tokens per eligible slot with the view params (speculative K/V appended
into the row's own pool blocks), then verify all k+1 positions in one
chunk-shaped full-policy call (``prefill_chunk_logits``) whose K/V
writes overwrite the draft's, and roll back positions/lengths for the
rejected tail (:func:`repro.models.kv_cache.set_decode_positions`).

Plane math (see ``kernels/bitplane_matmul.py`` for the derivation): a
w8 leaf served at w4 drops ``lo = (8-4)/2 = 2`` planes, at w2 drops 3;
a w4 leaf served at w2 drops 1. The truncated contraction reads (in the
paper's layout) ``draft_bits / target_bits`` of the weight bytes — the
latency story ``benchmarks/spec_bench.py`` models.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple, Union

import jax

from repro.core.precision import parse_quant_token
from repro.core.quant import QuantConfig
from repro.core.quantized_linear import PackedWeight

PLANE_BITS = 2


def parse_draft_spec(spec: Union[str, QuantConfig]) -> QuantConfig:
    """Normalize a ``--draft-policy`` value ("w2a8" / "w4a8" or an
    already-built QuantConfig). Drafts are pure plane truncations, so the
    Table-III mixed-group ratio ("rZZ") has no meaning here."""
    cfg = spec if isinstance(spec, QuantConfig) else parse_quant_token(str(spec))
    if cfg.mixed_ratio_8b:
        raise ValueError(
            "draft policy is a plane truncation of the resident weights; "
            f"a mixed 8-bit filter group ({spec!r}) cannot be expressed "
            "as a plane subset"
        )
    return cfg


def plane_offset(target_bits: int, draft_bits: int) -> int:
    """Number of low 2-bit planes to drop so `target_bits` storage serves
    a `draft_bits` contraction. 0 when the leaf is already at or below the
    draft precision (nothing to truncate — the draft just runs it as-is)."""
    if draft_bits >= target_bits:
        return 0
    drop = target_bits - draft_bits
    if drop % PLANE_BITS:
        raise ValueError(
            f"cannot serve w{target_bits} storage at w{draft_bits}: the "
            f"precision gap must be a whole number of {PLANE_BITS}-bit "
            "planes"
        )
    lo = drop // PLANE_BITS
    if PLANE_BITS * lo >= target_bits:
        raise ValueError(
            f"plane_lo={lo} leaves no planes of a w{target_bits} weight"
        )
    return lo


def derive_draft_params(params, draft: Union[str, QuantConfig]) -> Tuple[object, int]:
    """Draft-policy view of served params: every PackedWeight leaf whose
    precision exceeds the draft's gets ``plane_lo`` set so its matmuls
    contract only the top planes. Returns ``(draft_params, truncated)``.

    The view shares every array leaf with the target params by identity
    (``id(draft.packed) == id(target.packed)``) — asserted by tests and
    the point of the whole exercise. Raises if the params carry no packed
    leaves (serve with a quant policy first) or if the draft spec doesn't
    truncate anything (target already at or below draft precision)."""
    cfg = parse_draft_spec(draft)
    counts = {"packed": 0, "truncated": 0}

    def view(leaf):
        if not isinstance(leaf, PackedWeight):
            return leaf
        counts["packed"] += 1
        lo = plane_offset(leaf.bits, cfg.w_bits)
        if lo == 0:
            return leaf
        if leaf.a_bits != cfg.a_bits:
            raise ValueError(
                f"draft policy w{cfg.w_bits}a{cfg.a_bits} changes the "
                f"activation precision of a w{leaf.bits}a{leaf.a_bits} "
                "leaf; plane truncation only lowers weight bits — use "
                f"a{leaf.a_bits} in the draft spec"
            )
        counts["truncated"] += 1
        return dataclasses.replace(leaf, plane_lo=lo)

    draft_params = jax.tree_util.tree_map(
        view, params, is_leaf=lambda l: isinstance(l, PackedWeight)
    )
    if not counts["packed"]:
        raise ValueError(
            "self-speculative decoding needs bit-plane-packed weights: "
            "serve with a quant policy (e.g. --quant w8a8) so the draft "
            "can truncate the resident planes"
        )
    if not counts["truncated"]:
        raise ValueError(
            f"draft policy w{cfg.w_bits} truncates no leaf: every packed "
            "weight is already at or below the draft precision"
        )
    return draft_params, counts["truncated"]


def greedy_accept(
    verify_tokens: Sequence[int], draft_tokens: Sequence[int]
) -> List[int]:
    """Longest-matching-prefix acceptance for greedy speculation.

    ``verify_tokens[i]`` is the full-policy argmax at chunk position i of
    the verify call over ``[current token, d_1 .. d_k]`` — i.e. the token
    greedy decode would emit after accepting the first i draft tokens.
    Accept while ``d_{i+1} == verify_tokens[i]``; the returned list is
    ``[g_0, .., g_m]`` with every element a *verify* argmax (between 1
    and k+1 tokens — the last is the free "bonus" token when all drafts
    match). The draft never contributes a token, only the count, so the
    emitted stream is bitwise the sequential greedy stream."""
    emitted = [int(verify_tokens[0])]
    for i, d in enumerate(draft_tokens):
        if int(d) != emitted[-1]:
            break
        emitted.append(int(verify_tokens[i + 1]))
    return emitted
