"""Seeded fault injection for the serving stack.

A :class:`FaultInjector` is handed to :class:`~repro.serving.scheduler.
ContinuousScheduler` (``chaos=``, CLI ``--chaos-seed``) and consulted at
four seams, each of which the scheduler must survive by degrading ONE
request or ONE call — never the engine loop:

  ``alloc``     admission's pool reservation "fails" (treated exactly like
                a pool-full step: the request waits, bypass and preemption
                kick in as under real pressure);
  ``kernel``    the jitted decode dispatch raises; the scheduler re-runs
                that one call on the pure-jnp ``reference`` backend (bitwise
                the same logits/K-V on every backend, so survivors keep the
                greedy bit-identity contract) and keeps serving;
  ``nan``       one live row's step logits are overwritten with NaNs; the
                always-on non-finite detector fails that request alone
                (``error="nan-logits"``) — its batch neighbours never see
                the corruption;
  ``callback``  a user ``on_token`` callback raises mid-emission; the
                scheduler catches it, marks that request errored, and the
                other slots keep decoding.

Determinism: each fault kind draws from its own ``(seed, kind)``-derived
PRNG stream, so a kind's fault schedule depends only on how many times its
own seam was visited — enabling one kind never shifts another kind's
schedule, and re-running the same workload with the same seed replays the
same faults. ``max_faults`` bounds the total number of fired faults so a
p=1.0 schedule still lets the workload finish.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

#: Seam names, in the order their PRNG streams are derived.
FAULT_KINDS = ("alloc", "kernel", "nan", "callback")


class InjectedFault(RuntimeError):
    """Raised by an armed fault seam. Never escapes the scheduler: every
    seam catches it and degrades the one request / call it covers."""


class FaultInjector:
    """Deterministic, seeded fault source (see the module docstring).

    ``p_<kind>`` is the per-visit firing probability of that seam;
    ``max_faults`` caps the total faults fired across all kinds (None =
    unbounded). ``fired``/``draws`` count per-kind activity for
    ``pool_stats()`` and the end-of-run chaos report.
    """

    def __init__(
        self,
        seed: int = 0,
        *,
        p_alloc: float = 0.0,
        p_kernel: float = 0.0,
        p_nan: float = 0.0,
        p_callback: float = 0.0,
        max_faults: Optional[int] = None,
    ):
        rates = {"alloc": float(p_alloc), "kernel": float(p_kernel),
                 "nan": float(p_nan), "callback": float(p_callback)}
        for kind, p in rates.items():
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"p_{kind} must be in [0, 1], got {p}")
        if max_faults is not None and max_faults < 0:
            raise ValueError("max_faults must be >= 0")
        self.seed = int(seed)
        self.rates = rates
        self.max_faults = max_faults
        self.fired: Dict[str, int] = {k: 0 for k in FAULT_KINDS}
        self.draws: Dict[str, int] = {k: 0 for k in FAULT_KINDS}
        # One independent stream per kind + one for victim picks, each
        # derived from (seed, stream index): a kind's schedule is a pure
        # function of (seed, visits to that seam).
        self._rngs = {k: np.random.default_rng((self.seed, i))
                      for i, k in enumerate(FAULT_KINDS)}
        self._pick_rng = np.random.default_rng((self.seed, len(FAULT_KINDS)))

    @property
    def total_fired(self) -> int:
        return sum(self.fired.values())

    def fire(self, kind: str) -> bool:
        """One visit to seam `kind`: True iff a fault fires here."""
        p = self.rates[kind]
        self.draws[kind] += 1
        if p <= 0.0:
            return False
        if self.max_faults is not None and self.total_fired >= self.max_faults:
            return False
        hit = bool(self._rngs[kind].random() < p)
        if hit:
            self.fired[kind] += 1
        return hit

    def pick(self, n: int) -> int:
        """Deterministic victim index in [0, n) (e.g. which live row's
        logits the ``nan`` fault corrupts)."""
        return int(self._pick_rng.integers(n))

    def counts(self) -> dict:
        """Counter snapshot for ``pool_stats()`` / reports."""
        return {
            "seed": self.seed,
            "rates": dict(self.rates),
            "max_faults": self.max_faults,
            "fired": dict(self.fired),
            "draws": dict(self.draws),
            "total_fired": self.total_fired,
        }
