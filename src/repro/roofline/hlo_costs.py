"""Trip-count-aware HLO cost accounting.

XLA's HloCostAnalysis (what `compiled.cost_analysis()` exposes) counts a
while-loop body ONCE, so any scanned structure — scan-over-layers, chunked
attention, wkv chunk scans, gradient-accumulation — is undercounted by its
trip count, for flops, bytes *and* collectives. This module re-derives the
three roofline numerators from the optimized HLO text with loop
multiplicities propagated through the call graph.

Mechanics (validated against the CPU backend's actual text format):
  * while ops carry ``backend_config={"known_trip_count":{"n":"L"}}`` —
    parsed directly (fallback: the max small integer constant in the loop
    condition computation);
  * operand shapes are not inline in optimized HLO — a global name→shape
    map is built in a first pass and consulted for dot/collective operands;
  * ``dot`` flops = 2 · |result| · Π lhs contracting dims;
  * HBM bytes per op = result bytes + Σ operand bytes (HloCostAnalysis'
    unfused convention), counted only outside fusion bodies (fusion
    internals are accounted at the fusion call site);
  * collective wire bytes: ×2 all-reduce (reduce-scatter + all-gather
    phases of a ring), ×1 all-gather/reduce-scatter/all-to-all/permute.

Validated in tests/test_roofline.py: a scanned N-layer model reports ≈ the
flops of the same model unrolled.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e3m4": 1,
    "s4": 0.5, "u4": 0.5, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"\bwhile\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_FUSION_RE = re.compile(r"\bfusion\(.*?calls=%?([\w.\-]+)")
_CALL_RE = re.compile(r"\bcall\(.*?to_apply=%?([\w.\-]+)")
_COND_BRANCH_RE = re.compile(r"branches=\{([^}]*)\}")
_CONST_RE = re.compile(r"\bconstant\((\d+)\)")
_DOT_RE = re.compile(r"=\s*\S+\s+dot\(([^)]*)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_COLL_RE = re.compile(
    r"=\s*\S+\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(([^)]*)\)"
)
_PAREN_OPERANDS_RE = re.compile(r"\(([^)]*)\)")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _shape_elems(dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class Computation:
    name: str
    lines: List[str]
    is_entry: bool = False
    is_fusion_body: bool = False


def _parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    depth = 0
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                cur = Computation(name=m.group(2), lines=[], is_entry=bool(m.group(1)))
                depth = 1
            continue
        depth += line.count("{") - line.count("}")
        if depth <= 0:
            comps[cur.name] = cur
            cur = None
            continue
        cur.lines.append(line)
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _build_shape_map(comps: Dict[str, Computation]) -> Dict[str, tuple]:
    """name → (dtype, dims) for every array-typed def (params included)."""
    shapes: Dict[str, tuple] = {}
    param_re = re.compile(r"^\s*%([\w.\-]+)\s*=\s*(\w+)\[([\d,]*)\]")
    for comp in comps.values():
        for line in comp.lines:
            m = _DEF_RE.match(line) or param_re.match(line)
            if m:
                shapes[m.group(1)] = (m.group(2), m.group(3))
    return shapes


def _trip_from_line(line: str, cond: Optional[Computation]) -> int:
    m = _TRIP_RE.search(line)
    if m:
        return int(m.group(1))
    if cond is not None:
        best = 1
        for cl in cond.lines:
            for cm in _CONST_RE.finditer(cl):
                v = int(cm.group(1))
                if 1 < v < 10_000_000:
                    best = max(best, v)
        return best
    return 1


@dataclasses.dataclass
class HloCosts:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_by_op: Dict[str, float]
    collective_counts: Dict[str, int]
    loop_trip_counts: Dict[str, int]


def analyze_hlo(text: str) -> HloCosts:
    comps = _parse_computations(text)
    shapes = _build_shape_map(comps)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None and comps:
        entry = max(comps.values(), key=lambda c: len(c.lines))

    for comp in comps.values():
        for line in comp.lines:
            fm = _FUSION_RE.search(line)
            if fm and fm.group(1) in comps:
                comps[fm.group(1)].is_fusion_body = True

    mult: Dict[str, float] = {}
    trips: Dict[str, int] = {}

    def visit(name: str, m: float):
        if name not in comps or m <= 0:
            return
        mult[name] = mult.get(name, 0.0) + m
        for line in comps[name].lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond_name, body_name = wm.group(1), wm.group(2)
                t = _trip_from_line(line, comps.get(cond_name))
                trips[body_name] = max(trips.get(body_name, 0), t)
                visit(body_name, m * t)
                visit(cond_name, m * (t + 1))
                continue
            fm = _FUSION_RE.search(line)
            if fm:
                visit(fm.group(1), m)
                continue
            cm = _CALL_RE.search(line)
            if cm:
                visit(cm.group(1), m)
                continue
            bm = _COND_BRANCH_RE.search(line)
            if bm and "conditional(" in line:
                for b in bm.group(1).split(","):
                    visit(b.strip().lstrip("%"), m)

    if entry is not None:
        visit(entry.name, 1.0)

    def operand_bytes(line: str) -> float:
        """Sum of operand buffer sizes via the name→shape map."""
        pm = _PAREN_OPERANDS_RE.search(line.split("=", 1)[-1])
        if not pm:
            return 0.0
        total = 0.0
        for om in _OPERAND_RE.finditer(pm.group(1)):
            s = shapes.get(om.group(1))
            if s:
                total += _shape_bytes(*s)
        return total

    def dot_flops(line: str) -> float:
        sm = _SHAPE_RE.search(line)
        if not sm:
            return 0.0
        result_elems = _shape_elems(sm.group(2))
        dm = _DOT_RE.search(line)
        if not dm:
            return 0.0
        first_op = _OPERAND_RE.search(dm.group(1))
        contract = 1
        if first_op:
            s = shapes.get(first_op.group(1))
            cm = _CONTRACT_RE.search(line)
            if s and cm and cm.group(1).strip():
                lhs_dims = [int(d) for d in s[1].split(",") if d]
                for idx in cm.group(1).split(","):
                    i = int(idx)
                    if i < len(lhs_dims):
                        contract *= lhs_dims[i]
        return 2.0 * result_elems * contract

    flops = 0.0
    hbm = 0.0
    coll_bytes: Dict[str, float] = {}
    coll_counts: Dict[str, int] = {}
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for line in comp.lines:
            if " dot(" in line:
                flops += m * dot_flops(line)
            if comp.is_fusion_body:
                continue
            sm = _DEF_RE.match(line)
            if sm:
                hbm += m * (_shape_bytes(sm.group(2), sm.group(3)) + operand_bytes(line))
            cmatch = _COLL_RE.search(line)
            if cmatch and cmatch.group(2) != "-done":
                op = cmatch.group(1)
                sm2 = _SHAPE_RE.search(line)
                result_b = _shape_bytes(*sm2.groups()) if sm2 else 0.0
                opb = operand_bytes(line) or result_b
                if op == "all-reduce":
                    wire = 2.0 * result_b
                elif op == "all-gather":
                    wire = result_b
                else:
                    wire = opb
                coll_bytes[op] = coll_bytes.get(op, 0.0) + m * wire
                coll_counts[op] = coll_counts.get(op, 0) + int(m)
    return HloCosts(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=sum(coll_bytes.values()),
        collective_by_op=coll_bytes,
        collective_counts=coll_counts,
        loop_trip_counts=trips,
    )
