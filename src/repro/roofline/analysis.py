"""Roofline-term extraction from AOT-compiled artifacts (no hardware).

  compute term    = HLO_FLOPs / (chips × peak bf16 FLOP/s)
  memory term     = HLO bytes accessed / (chips × HBM bw)
  collective term = collective wire bytes / (chips × ICI link bw)

Sources:
  * `compiled.cost_analysis()` → flops / bytes accessed. On the CPU backend
    the analysis is computed over the SPMD-partitioned *per-device* module,
    so the terms below are per-device times already (verified empirically in
    tests/test_roofline.py by comparing 1-device vs 4-device flops).
  * collective bytes are NOT in cost_analysis — we parse the optimized HLO
    (`compiled.as_text()`) and sum operand/result buffer sizes of every
    all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute, with ring-algorithm wire factors:
      all-reduce      : 2× result bytes   (reduce-scatter + all-gather phases)
      all-gather      : 1× result bytes   ((n-1)/n ≈ 1 received per device)
      reduce-scatter  : 1× operand bytes aggregated ≈ result × n → use operand
      all-to-all      : 1× operand bytes
      collective-permute : 1× operand bytes
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.roofline import hw

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3": 1, "f8e5m2": 1, "f8e4m3fn": 1,
    "s4": 0.5, "u4": 0.5,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# result_type op_name(operand_types...) — types look like bf16[128,256]{1,0}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?[\w\[\],{}()\s]*?\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\b"
)


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, float]
    count_by_op: Dict[str, int]

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    bytes_by_op: Dict[str, float] = {}
    count_by_op: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done" in line.split("=", 1)[-1][:160] and m.group(0).endswith("-done"):
            continue  # async pair: count the -start only
        op = m.group(1)
        shapes = _SHAPE_RE.findall(line)
        if not shapes:
            continue
        result_b = _shape_bytes(*shapes[0])
        operand_b = sum(_shape_bytes(*s) for s in shapes[1:]) or result_b
        if op == "all-reduce":
            wire = 2.0 * result_b
        elif op == "all-gather":
            wire = result_b
        elif op == "reduce-scatter":
            wire = operand_b
        else:
            wire = operand_b
        bytes_by_op[op] = bytes_by_op.get(op, 0.0) + wire
        count_by_op[op] = count_by_op.get(op, 0) + 1
    return CollectiveStats(bytes_by_op, count_by_op)


@dataclasses.dataclass
class RooflineReport:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    collective_bytes: float      # per-device wire bytes
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_total: float     # analytic 6·N·D (or serve equivalent)
    useful_flops_ratio: float    # model_flops_per_device / HLO flops
    collectives: Dict[str, float]
    collective_counts: Dict[str, int]
    peak_memory_bytes: Optional[float] = None
    raw_cost_analysis_flops: float = 0.0
    raw_cost_analysis_bytes: float = 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        return d


def analyze(
    compiled,
    chips: int,
    model_flops_total: float,
    hlo_text: Optional[str] = None,
) -> RooflineReport:
    """Roofline terms with *trip-count-corrected* HLO costs.

    `compiled.cost_analysis()` counts while bodies once (scan-over-layers,
    chunked attention, grad accumulation all undercounted); we therefore
    re-derive flops / bytes / collective bytes from the optimized HLO text
    with loop multiplicities (roofline/hlo_costs.py). The raw cost_analysis
    numbers are kept in the report for reference.
    """
    from repro.roofline import hlo_costs

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    text = hlo_text if hlo_text is not None else compiled.as_text()
    costs = hlo_costs.analyze_hlo(text)
    flops = costs.flops
    hbm_bytes = costs.hbm_bytes

    compute_s = flops / hw.PEAK_BF16_FLOPS
    memory_s = hbm_bytes / hw.HBM_BW
    collective_s = costs.collective_bytes / hw.ICI_LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    peak = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            peak = float(
                getattr(ma, "temp_size_in_bytes", 0)
                + getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                - getattr(ma, "alias_size_in_bytes", 0)
            )
    except Exception:
        pass

    per_dev_model = model_flops_total / max(chips, 1)
    return RooflineReport(
        flops=flops,
        hbm_bytes=hbm_bytes,
        collective_bytes=costs.collective_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops_total=model_flops_total,
        useful_flops_ratio=(per_dev_model / flops) if flops else 0.0,
        collectives=costs.collective_by_op,
        collective_counts=costs.collective_counts,
        peak_memory_bytes=peak,
        raw_cost_analysis_flops=float(ca.get("flops", 0.0)),
        raw_cost_analysis_bytes=float(ca.get("bytes accessed", 0.0)),
    )


def model_flops(cfg, shape_kind: str, seq_len: int, global_batch: int) -> float:
    """Analytic MODEL_FLOPS: 6·N·D for training, 2·N·D for single forward
    (N = active params, D = processed tokens). Attention flops excluded by
    the standard MFU convention."""
    n_active = cfg.active_param_count()
    if shape_kind == "train":
        return 6.0 * n_active * seq_len * global_batch
    if shape_kind == "prefill":
        return 2.0 * n_active * seq_len * global_batch
    return 2.0 * n_active * global_batch  # decode: one token per sequence
