from repro.roofline import hw  # noqa: F401
from repro.roofline.analysis import RooflineReport, analyze, model_flops, parse_collectives  # noqa: F401
