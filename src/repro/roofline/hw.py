"""TPU v5e hardware constants (the assignment's target part)."""

PEAK_BF16_FLOPS = 197e12      # per chip, bf16
PEAK_INT8_OPS = 394e12        # per chip, int8 (2x bf16)
HBM_BW = 819e9                # bytes/s per chip
ICI_LINK_BW = 50e9            # bytes/s per link (~ per-direction)
HBM_BYTES = 16 * 2**30        # 16 GiB per chip
VMEM_BYTES = 128 * 2**20      # ~128 MiB vector memory
MXU_DIM = 128                 # systolic array edge

CHIPS_PER_POD = 256           # 16 x 16 mesh


def compute_time_s(flops: float, chips: int = 1) -> float:
    return flops / (chips * PEAK_BF16_FLOPS)


def memory_time_s(bytes_: float, chips: int = 1) -> float:
    return bytes_ / (chips * HBM_BW)


def collective_time_s(bytes_: float, chips: int = 1) -> float:
    return bytes_ / (chips * ICI_LINK_BW)
