"""Assigned input-shape sets and the (arch × shape) applicability matrix.

LM transformer shapes are seq_len × global_batch:
  train_4k     : seq 4096,    batch 256 — training (lowers train_step)
  prefill_32k  : seq 32768,   batch 32  — inference prefill (prefill_step)
  decode_32k   : seq 32768,   batch 128 — decode: ONE new token, cache=seq
  long_500k    : seq 524288,  batch 1   — long-context decode

Skips (per assignment instructions, documented in DESIGN.md §6):
  * long_500k needs sub-quadratic attention → only ssm/hybrid/SWA archs.
  * encoder-only archs have no decode step → decode shapes skipped.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Archs with sub-quadratic sequence mixing (may run long_500k).
SUBQUADRATIC = {
    "mixtral-8x22b",        # sliding-window attention
    "recurrentgemma-9b",    # RG-LRU + local attention
    "rwkv6-3b",             # attention-free
}

ENCODER_ONLY = {"hubert-xlarge"}


def applicable(arch: str, shape: str) -> Tuple[bool, Optional[str]]:
    """Returns (runnable, skip_reason)."""
    spec = SHAPES[shape]
    if arch in ENCODER_ONLY and spec.kind == "decode":
        return False, "encoder-only arch: no autoregressive decode step"
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return False, "pure full-attention arch: 500k context needs sub-quadratic attention"
    return True, None


def all_cells():
    """Every (arch, shape) cell with its applicability — 40 total."""
    from repro.configs import ARCH_IDS

    cells = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            ok, reason = applicable(arch, shape)
            cells.append((arch, shape, ok, reason))
    return cells
