"""recurrentgemma-9b — Griffin hybrid: RG-LRU recurrence + local attention.

[arXiv:2402.19427] 38L, d_model 4096, 16 heads (kv=1, MQA), d_ff 12288
(GeGLU), vocab 256000. Block pattern 1 attention per 2 recurrent blocks
(("rglru","rglru","attn") repeated; 38 layers → 26 recurrent + 12 local-
attention blocks). Local window 2048. Sub-quadratic → runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    ffn="geglu",
    norm="rmsnorm",
    block_pattern=("rglru", "rglru", "attn"),
    rnn_width=4096,
    conv_width=4,
    local_window=2048,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke",
        family="hybrid",
        num_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab=512,
        head_dim=16,
        ffn="geglu",
        norm="rmsnorm",
        block_pattern=("rglru", "rglru", "attn"),
        rnn_width=64,
        conv_width=4,
        local_window=16,
    )
