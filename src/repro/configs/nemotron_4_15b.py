"""nemotron-4-15b — dense GQA transformer, squared-ReLU FFN.

[arXiv:2402.16819] 32L, d_model 6144, 48 Q heads, 8 KV heads (GQA),
d_ff 24576, vocab 256000. Nemotron-4 uses squared-ReLU MLPs (2 matrices),
RoPE, LayerNorm, untied embeddings, no biases.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab=256000,
    ffn="relu2",
    norm="layernorm",
    rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        ffn="relu2",
        norm="layernorm",
    )
