"""nemotron-4-340b — dense GQA transformer, squared-ReLU FFN (the largest
assigned arch; exercises FSDP+TP sharding at the memory limit).

[arXiv:2402.16819] 96L, d_model 18432, 96 Q heads, 8 KV heads,
d_ff 73728, vocab 256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab=256000,
    ffn="relu2",
    norm="layernorm",
    rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b-smoke",
        family="dense",
        num_layers=3,
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        d_ff=192,
        vocab=512,
        ffn="relu2",
        norm="layernorm",
    )
