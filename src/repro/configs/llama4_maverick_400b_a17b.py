"""llama4-maverick-400b-a17b — MoE decoder, 128 experts top-1.

[hf:meta-llama/Llama-4-*] 48L, d_model 5120, 40 Q heads, 8 KV heads,
d_ff 8192 per expert, vocab 202048, 128 experts, top-1 routing, qk-norm.
Early fusion is a frontend property — text backbone only here (assignment:
modality frontends are stubs). Experts are EP-sharded (128 % 16 == 0).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    ffn="swiglu",
    norm="rmsnorm",
    qk_norm=True,
    rope_theta=500000.0,
    moe_experts=128,
    moe_top_k=1,
    moe_shard="expert",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        ffn="swiglu",
        norm="rmsnorm",
        qk_norm=True,
        moe_experts=8,
        moe_top_k=1,
        moe_shard="expert",
    )
