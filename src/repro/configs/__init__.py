"""Architecture config registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import MeshConfig, ModelConfig, TrainConfig  # noqa: F401
from repro.configs.shapes import SHAPES, ShapeSpec, applicable  # noqa: F401

# Assigned architecture ids (public pool) → module names.
_ARCH_MODULES: Dict[str, str] = {
    "nemotron-4-15b": "nemotron_4_15b",
    "olmo-1b": "olmo_1b",
    "nemotron-4-340b": "nemotron_4_340b",
    "stablelm-12b": "stablelm_12b",
    "paligemma-3b": "paligemma_3b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "mixtral-8x22b": "mixtral_8x22b",
    "hubert-xlarge": "hubert_xlarge",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "rwkv6-3b": "rwkv6_3b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {list(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def get_reduced_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {list(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.reduced()
