"""olmo-1b — dense MHA transformer with non-parametric LayerNorm.

[arXiv:2402.00838; hf:allenai/OLMo-1B] 16L, d_model 2048, 16 heads
(kv=16 → MHA), d_ff 8192, vocab 50304. OLMo's signature: non-parametric
LayerNorm (no scale/bias), SwiGLU, tied embeddings, no biases.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=50304,
    ffn="swiglu",
    norm="nonparam_ln",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=512,
        ffn="swiglu",
        norm="nonparam_ln",
        tie_embeddings=True,
    )
