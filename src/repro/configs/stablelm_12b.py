"""stablelm-12b — dense GQA transformer with per-head QK norm.

[hf:stabilityai/stablelm-2-12b] 40L, d_model 5120, 32 Q heads, 8 KV heads,
d_ff 13824, vocab 100352. StableLM-2 uses LayerNorm, SwiGLU and per-head
qk-layernorm.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab=100352,
    ffn="swiglu",
    norm="layernorm",
    qk_norm=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        ffn="swiglu",
        norm="layernorm",
        qk_norm=True,
    )
