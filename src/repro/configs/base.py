"""Configuration dataclasses for the whole framework.

`ModelConfig` is the single source of truth a model is built from; every
assigned architecture gets one exact instance in `repro/configs/<id>.py`
plus a `reduced()` variant for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.quant import QuantConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encoder | vlm
    num_layers: int
    d_model: int
    n_heads: int                 # query heads (0 for attn-free)
    n_kv_heads: int              # GQA kv heads
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 → d_model // n_heads

    # Block flavour
    ffn: str = "swiglu"          # swiglu | relu2 | geglu | gelu
    norm: str = "rmsnorm"        # rmsnorm | layernorm | nonparam_ln
    causal: bool = True
    rope_theta: float = 10000.0
    attn_window: int = 0         # 0 = full attention; >0 = sliding window
    attn_logit_softcap: float = 0.0
    qk_norm: bool = False
    tie_embeddings: bool = False

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 1
    moe_capacity_factor: float = 1.25
    moe_shard: str = "expert"    # expert (EP) | ffn (TP inside expert)

    # Hybrid (recurrentgemma): layer pattern unit, e.g. ("rglru","rglru","attn")
    block_pattern: Tuple[str, ...] = ()
    rnn_width: int = 0           # RG-LRU recurrent width (0 → d_model)
    conv_width: int = 4          # temporal conv kernel in recurrent block
    local_window: int = 2048     # local attention window in hybrid blocks

    # SSM (rwkv6)
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64    # low-rank width of the data-dependent decay

    # Modality frontend stubs
    num_prefix_embeds: int = 0   # vlm: image patches prepended (stub SigLIP)
    frontend: str = "none"       # none | patch_stub | frame_stub
    frontend_dim: int = 0        # raw embedding dim from the (stub) frontend

    # Numerics / technique integration
    dtype: str = "bfloat16"
    quant: Optional[QuantConfig] = None
    remat: bool = True
    scan_layers: bool = True
    fsdp: bool = True            # shard params/opt over the data axis too
    logits_softcap: float = 0.0

    # Perf-iteration knobs (§Perf hillclimbing levers)
    attn_q_chunk: int = 512      # flash-attention query block
    attn_kv_chunk: int = 1024    # flash-attention key/value block
    attn_shard: str = "heads"    # heads (TP) | seq (sequence-parallel)
    rwkv_chunk: int = 64         # wkv6 chunk length (memory ∝ chunk)
    kv_cache_quant: bool = False # int8 KV cache (decode memory-term lever)

    def __post_init__(self):
        if self.n_heads and self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.rnn_width == 0:
            object.__setattr__(self, "rnn_width", self.d_model)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1) if self.n_heads else 0

    def with_quant(self, quant: QuantConfig) -> "ModelConfig":
        return dataclasses.replace(self, quant=quant)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.num_layers
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            # rwkv6: time-mix (r,k,v,g,o ≈ 5 d²) + decay lora + channel-mix
            per = 5 * d * d + 2 * d * self.rwkv_decay_lora + 2 * d * f
            return emb + L * per
        nq, nkv, hd = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * (nq * hd) + 2 * d * (nkv * hd) + (nq * hd) * d
        if self.ffn in ("swiglu", "geglu"):
            ffn = 3 * d * f
        else:
            ffn = 2 * d * f
        if self.moe_experts:
            ffn = self.moe_experts * ffn + d * self.moe_experts
        if self.block_pattern:
            # hybrid: recurrent blocks replace attention in 2/3 of layers
            n_attn = sum(1 for b in self._expanded_pattern() if b == "attn")
            n_rec = L - n_attn
            rec = 2 * d * self.rnn_width + self.rnn_width * d + 3 * self.rnn_width
            return emb + n_attn * (attn + ffn) + n_rec * (rec + ffn)
        return emb + L * (attn + ffn)

    def _expanded_pattern(self) -> Tuple[str, ...]:
        if not self.block_pattern:
            return tuple("attn" for _ in range(self.num_layers))
        out = []
        while len(out) < self.num_layers:
            out.extend(self.block_pattern)
        return tuple(out[: self.num_layers])

    def active_param_count(self) -> int:
        """MoE: params touched per token (top-k experts)."""
        if not self.moe_experts:
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.num_layers
        per_expert = (3 if self.ffn in ("swiglu", "geglu") else 2) * d * f
        total = self.param_count()
        return total - L * (self.moe_experts - self.moe_top_k) * per_expert


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    lr_min_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatches: int = 1        # gradient-accumulation splits
    grad_compress_bits: int = 0  # 0 = off; 8 → int8 compressed all-reduce
    seed: int = 0
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    log_every: int = 10


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    data: int = 16
    model: int = 16
    pods: int = 1

    @property
    def devices(self) -> int:
        return self.data * self.model * self.pods
