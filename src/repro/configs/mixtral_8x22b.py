"""mixtral-8x22b — MoE decoder, 8 experts top-2, sliding-window attention.

[arXiv:2401.04088; hf:mistralai/Mixtral-8x22B] 56L, d_model 6144, 48 Q
heads, 8 KV heads, d_ff 16384 per expert, vocab 32768, SWA window 4096.
8 experts < |model axis| = 16: the shard_map EP dispatch replicates each
expert across 16/8 = 2 shards with disjoint capacity slices
(models/moe.py; EXPERIMENTS.md §Perf B) — measured 5.5× lower collective
term than the TP-inside-expert fallback. SWA makes this arch
sub-quadratic → it runs the long_500k decode cell.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    ffn="swiglu",
    norm="rmsnorm",
    rope_theta=1000000.0,
    attn_window=4096,
    moe_experts=8,
    moe_top_k=2,
    moe_shard="expert",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=512,
        ffn="swiglu",
        norm="rmsnorm",
        attn_window=16,
        moe_experts=4,
        moe_top_k=2,
        moe_shard="ffn",
    )
