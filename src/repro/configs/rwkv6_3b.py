"""rwkv6-3b — "Finch": attention-free RNN with data-dependent decay.

[arXiv:2404.05892; hf:RWKV/rwkv-6-world-3b] 32L, d_model 2560 (40 heads of
64), d_ff 8960 (channel-mix with squared-ReLU), vocab 65536. The wkv6
mixer runs through the chunked Pallas kernel on TPU and a chunked
lax.scan in the distributed path. Attention-free → runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=8960,
    vocab=65536,
    ffn="relu2",
    norm="layernorm",
    rwkv_head_dim=64,
    rwkv_decay_lora=64,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        n_heads=0,
        n_kv_heads=0,
        d_ff=128,
        vocab=512,
        ffn="relu2",
        norm="layernorm",
        rwkv_head_dim=16,
        rwkv_decay_lora=8,
    )
