"""hubert-xlarge — encoder-only audio transformer (wav2vec2-style backbone).

[arXiv:2106.07447] 48L, d_model 1280, 16 heads (MHA), d_ff 5120,
vocab 504 (cluster-target classification head). The CNN feature extractor
is a STUB per the assignment: input_specs() provides precomputed frame
embeddings (B, T, d_model). Encoder-only → no decode step (decode shapes
skipped, DESIGN.md §6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    num_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    ffn="gelu",
    norm="layernorm",
    causal=False,
    frontend="frame_stub",
    frontend_dim=512,  # w2v2/HuBERT conv feature-extractor width
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke",
        family="encoder",
        num_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=32,
        ffn="gelu",
        norm="layernorm",
        causal=False,
        frontend="frame_stub",
        frontend_dim=16,
    )
