"""paligemma-3b — VLM: stub SigLIP patch frontend + gemma decoder backbone.

[arXiv:2407.07726; hf:google/paligemma-3b] Backbone: 18L, d_model 2048,
8 Q heads, 1 KV head (MQA), d_ff 16384 (GeGLU), vocab 257216. The modality
frontend is a STUB per the assignment: input_specs() provides 256
precomputed patch embeddings that are prepended to the text sequence, and
attention is prefix-LM (bidirectional over the image prefix).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab=257216,
    head_dim=256,
    ffn="geglu",
    norm="rmsnorm",
    num_prefix_embeds=256,
    frontend="patch_stub",
    frontend_dim=1152,  # SigLIP-So400m embedding width
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab=512,
        head_dim=16,
        ffn="geglu",
        norm="rmsnorm",
        num_prefix_embeds=8,
        frontend="patch_stub",
        frontend_dim=32,
        tie_embeddings=True,
    )
