"""RWKV-6 "Finch" — attention-free mixer with data-dependent decay.

Faithful to the assigned arch's defining mechanism: per-channel decay
``w_t = exp(-exp(base + tanh(x W_a) W_b))`` computed from the input (the
data-dependent decay that distinguishes Finch from RWKV-5), current-token
bonus ``u``, head-wise state ``S ∈ R^{K×V}``, token-shift on both mixers,
squared-ReLU channel-mix. Token-shift interpolation factors are static
(per-stream μ) rather than the paper's second LoRA — noted simplification;
the decay LoRA (the headline feature) is implemented in full.

Sequence processing is *chunked* (the same math as the Pallas wkv6 kernel,
expressed in collective-friendly jnp for the distributed path): per chunk
all work is dense matmul + elementwise, and only the (H, K, V) state crosses
chunk boundaries. Decode is a single O(1) state update — this is why rwkv6
runs the long_500k cell with a constant-size "cache".
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models.kv_cache import DecodeCache, RwkvState
from repro.parallel.sharding import constrain


def _dims(cfg: ModelConfig) -> Tuple[int, int]:
    hd = cfg.rwkv_head_dim
    return cfg.d_model // hd, hd  # (H, K)


# --------------------------------------------------------------------------
# wkv6 — chunked jnp path (same algebra as kernels/wkv6.py)
# --------------------------------------------------------------------------


def wkv6_chunked(r, k, v, w, u, state, chunk: int = 64):
    """r/k/w: (B, T, H, K); v: (B, T, H, V); u: (H, K);
    state: (B, H, K, V) carry-in. Returns (out (B, T, H, V), state_out)."""
    B, T, H, K = r.shape
    V = v.shape[-1]
    C = min(chunk, T)
    if T % C:
        pad = C - T % C
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    NC = r.shape[1] // C

    def to_chunks(a):
        return jnp.moveaxis(a.reshape(B, NC, C, H, -1), 1, 0)  # (NC,B,C,H,·)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))

    def step(S, inp):
        rb, kb, vb, wb = (a.astype(jnp.float32) for a in inp)  # (B,C,H,·)
        lw = jnp.log(jnp.maximum(wb, 1e-12))
        L = jnp.cumsum(lw, axis=1)
        Lsh = L - lw
        # carry-in term: (B,C,H,V)
        term1 = jnp.einsum("bchk,bhkv->bchv", rb * jnp.exp(Lsh), S)
        # intra-chunk: diff[b,t,s,h,k] = Lsh[t]-L[s] (<=0 for s<t)
        diff = Lsh[:, :, None, :, :] - L[:, None, :, :, :]
        tri = (jnp.arange(C)[:, None] > jnp.arange(C)[None, :])[None, :, :, None, None]
        gate = jnp.where(tri, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
        P = jnp.einsum("bthk,bshk,btshk->bths", rb, kb, gate)  # (B,C_t,H,C_s)
        Pd = jnp.einsum("bthk,hk,bthk->bth", rb, u.astype(jnp.float32), kb)
        eye = jnp.eye(C, dtype=jnp.float32)[None, :, None, :]  # (1,C_t,1,C_s)
        P = P + eye * Pd[:, :, :, None]
        out = term1 + jnp.einsum("bths,bshv->bthv", P, vb)
        # state update
        L_last = L[:, -1:, :, :]
        dk = kb * jnp.exp(L_last - L)
        S = jnp.exp(L_last[:, 0])[..., None] * S + jnp.einsum(
            "bshk,bshv->bhkv", dk, vb
        )
        return S, out

    state, outs = jax.lax.scan(step, state.astype(jnp.float32), (rc, kc, vc, wc))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, NC * C, H, V)[:, :T]
    return out, state


def wkv6_step(r, k, v, w, u, state):
    """Single-token wkv: r/k/w (B, H, K); v (B, H, V); state (B, H, K, V)."""
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    kv = kf[..., :, None] * vf[..., None, :]                     # (B,H,K,V)
    out = jnp.einsum("bhk,bhkv->bhv", rf, state + u[None, ..., None] * kv)
    state = wf[..., None] * state + kv
    return out, state


# --------------------------------------------------------------------------
# Layers
# --------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    H, K = _dims(cfg)
    R = cfg.rwkv_decay_lora
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 10)
    return {
        "ln1": cm.norm_init("layernorm", d, dt),
        "ln2": cm.norm_init("layernorm", d, dt),
        "tm": {
            "mu": jnp.full((5, d), 0.5, dt),  # r,k,v,w,g token-shift mix
            "w_recept": cm.dense_init(ks[0], d, d, dt),
            "w_key": cm.dense_init(ks[1], d, d, dt),
            "w_value": cm.dense_init(ks[2], d, d, dt),
            "w_gate": cm.dense_init(ks[3], d, d, dt),
            "w_out": cm.dense_init(ks[4], d, d, dt),
            "decay_base": jnp.full((d,), -4.0, jnp.float32),
            "decay_a": cm.dense_init(ks[5], d, R, dt),
            "decay_b": (jax.random.normal(ks[6], (R, d), jnp.float32) * 0.01).astype(dt),
            "u": (jax.random.normal(ks[7], (H, K), jnp.float32) * 0.1).astype(jnp.float32),
            "gn_scale": jnp.ones((d,), dt),
            "gn_bias": jnp.zeros((d,), dt),
        },
        "cmx": {
            "mu": jnp.full((2, d), 0.5, dt),  # k, r
            "w_key": cm.dense_init(ks[8], d, f, dt),
            "w_value": cm.dense_init(ks[9], f, d, dt),
            "w_recept": cm.dense_init(ks[0], d, d, dt),
        },
    }


def init_params(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    layer_keys = jax.random.split(keys[0], cfg.num_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(layer_keys)
    return {
        "embed": cm.embed_init(keys[1], cfg.vocab, cfg.d_model, dt),
        "blocks": blocks,
        "final_norm": cm.norm_init("layernorm", cfg.d_model, dt),
        "head": cm.dense_init(keys[2], cfg.d_model, cfg.vocab, dt),
    }


def _shift(x: jax.Array, tail: jax.Array) -> jax.Array:
    """Token shift: y_t = x_{t-1}; position 0 receives `tail` (B, d)."""
    return jnp.concatenate([tail[:, None, :], x[:, :-1, :]], axis=1)


def _decay(tm: dict, xw: jax.Array) -> jax.Array:
    lora = jnp.tanh(xw @ tm["decay_a"].astype(xw.dtype)) @ tm["decay_b"].astype(xw.dtype)
    dw = tm["decay_base"].astype(jnp.float32) + lora.astype(jnp.float32)
    return jnp.exp(-jnp.exp(dw))  # (…, d) in (0, 1)


def _group_norm(x: jax.Array, H: int, scale, bias, eps=1e-5) -> jax.Array:
    """Per-head normalization of (..., H*K)."""
    shp = x.shape
    xh = x.reshape(*shp[:-1], H, shp[-1] // H).astype(jnp.float32)
    mean = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    xh = (xh - mean) * jax.lax.rsqrt(var + eps)
    out = xh.reshape(shp) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def _last_real(x: jax.Array, lengths) -> jax.Array:
    """(B, T, d) → (B, d) at the per-row last real token (T-1 when
    `lengths` is None)."""
    return cm.last_token_slice(x, lengths)[:, 0]


def time_mix(p: dict, cfg: ModelConfig, x: jax.Array, tail, wkv_state,
             chunk: int = 64, lengths=None):
    """x: (B, T, d) normalized input. Returns (out, new_tail, new_state).

    `lengths` marks right-padded serving prompts: pad positions contribute
    k = 0 (no state injection) and decay w = 1 (no state decay), so the
    carried wkv state after T steps equals the state after lengths real
    steps exactly — bucketed prefill matches exact-length prefill."""
    B, T, d = x.shape
    H, K = _dims(cfg)
    tm = p
    xx = _shift(x, tail)
    mu = tm["mu"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + (xx - x) * mu[i] for i in range(5))
    r = (xr @ tm["w_recept"].astype(x.dtype)).reshape(B, T, H, K)
    k = (xk @ tm["w_key"].astype(x.dtype)).reshape(B, T, H, K)
    v = (xv @ tm["w_value"].astype(x.dtype)).reshape(B, T, H, K)
    g = jax.nn.silu(xg @ tm["w_gate"].astype(x.dtype))
    w = _decay(tm, xw).reshape(B, T, H, K)
    if lengths is not None:
        real = (jnp.arange(T)[None, :] < lengths[:, None])[..., None, None]
        k = jnp.where(real, k, 0)
        w = jnp.where(real, w, 1.0)
    r = constrain(r, "batch", None, None, None)
    out, state = wkv6_chunked(r, k, v, w, tm["u"], wkv_state,
                              chunk=cfg.rwkv_chunk)
    out = out.reshape(B, T, d).astype(x.dtype)
    out = _group_norm(out, H, tm["gn_scale"], tm["gn_bias"]) * g
    out = out @ tm["w_out"].astype(x.dtype)
    return out, _last_real(x, lengths), state


def time_mix_step(p, cfg, x, tail, wkv_state):
    """Single token: x (B, 1, d). Returns (out, new_tail, new_state)."""
    B, _, d = x.shape
    H, K = _dims(cfg)
    tm = p
    xt = x[:, 0]
    mu = tm["mu"].astype(x.dtype)
    xr, xk, xv, xw, xg = (xt + (tail - xt) * mu[i] for i in range(5))
    r = (xr @ tm["w_recept"].astype(x.dtype)).reshape(B, H, K)
    k = (xk @ tm["w_key"].astype(x.dtype)).reshape(B, H, K)
    v = (xv @ tm["w_value"].astype(x.dtype)).reshape(B, H, K)
    g = jax.nn.silu(xg @ tm["w_gate"].astype(x.dtype))
    w = _decay(tm, xw).reshape(B, H, K)
    out, state = wkv6_step(r, k, v, w, tm["u"], wkv_state)
    out = out.reshape(B, d).astype(x.dtype)
    out = _group_norm(out, H, tm["gn_scale"], tm["gn_bias"]) * g
    return (out @ tm["w_out"].astype(x.dtype))[:, None, :], xt, state


def channel_mix(p: dict, x: jax.Array, tail, lengths=None):
    xx = _shift(x, tail)
    mu = p["mu"].astype(x.dtype)
    xk = x + (xx - x) * mu[0]
    xr = x + (xx - x) * mu[1]
    kk = jnp.square(jax.nn.relu(xk @ p["w_key"].astype(x.dtype)))
    out = jax.nn.sigmoid(xr @ p["w_recept"].astype(x.dtype)) * (
        kk @ p["w_value"].astype(x.dtype)
    )
    return out, _last_real(x, lengths)


def channel_mix_step(p, x, tail):
    xt = x[:, 0]
    mu = p["mu"].astype(x.dtype)
    xk = xt + (tail - xt) * mu[0]
    xr = xt + (tail - xt) * mu[1]
    kk = jnp.square(jax.nn.relu(xk @ p["w_key"].astype(x.dtype)))
    out = jax.nn.sigmoid(xr @ p["w_recept"].astype(x.dtype)) * (
        kk @ p["w_value"].astype(x.dtype)
    )
    return out[:, None, :], xt


# --------------------------------------------------------------------------
# Full model
# --------------------------------------------------------------------------


def _forward(params, cfg: ModelConfig, tokens, state: RwkvState | None,
             lengths=None):
    """Full-seq forward. Returns (hidden, final RwkvState stacked over L)."""
    B, T = tokens.shape
    H, K = _dims(cfg)
    x = cm.embed_lookup(params["embed"], tokens)
    x = constrain(x, "batch", None, None)
    if state is None:
        z = jnp.zeros((cfg.num_layers, B, H, K, K), jnp.float32)
        zt = jnp.zeros((cfg.num_layers, B, cfg.d_model), x.dtype)
        state = RwkvState(wkv=z, tm_shift=zt, cm_shift=zt)

    def body(carry, layer_in):
        xc = carry
        bp, wkv0, tm_tail, cm_tail = layer_in
        h = cm.apply_norm(xc, bp["ln1"], "layernorm")
        out, tm_tail2, wkv1 = time_mix(bp["tm"], cfg, h, tm_tail, wkv0,
                                       lengths=lengths)
        xc = xc + out
        h2 = cm.apply_norm(xc, bp["ln2"], "layernorm")
        out2, cm_tail2 = channel_mix(bp["cmx"], h2, cm_tail, lengths=lengths)
        xc = xc + out2
        xc = constrain(xc, "batch", None, None)
        return xc, (wkv1, tm_tail2, cm_tail2)

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, (wkv, tmt, cmt) = jax.lax.scan(
        body_fn, x, (params["blocks"], state.wkv, state.tm_shift, state.cm_shift)
    )
    hidden = cm.apply_norm(x, params["final_norm"], "layernorm")
    return hidden, RwkvState(wkv=wkv, tm_shift=tmt, cm_shift=cmt)


def train_loss(params, cfg: ModelConfig, batch):
    hidden, _ = _forward(params, cfg, batch["tokens"], None)
    logits = cm.logits_head(hidden, params["head"])
    logits = constrain(logits, "batch", None, "model")
    loss = cm.cross_entropy(logits[:, :-1], batch["tokens"][:, 1:]).mean()
    return loss, {"loss": loss, "aux_loss": jnp.zeros((), jnp.float32)}


def prefill(params, cfg: ModelConfig, batch):
    """``batch["lengths"]`` (B,) marks right-padded serving prompts: the
    wkv state passes through pad steps untouched (k = 0, w = 1), shift
    tails and logits come from the per-row last real token — bucketed
    prefill is exact."""
    B, S = batch["tokens"].shape
    lengths = batch.get("lengths")
    if lengths is not None:
        lengths = jnp.asarray(lengths, jnp.int32)
    hidden, state = _forward(params, cfg, batch["tokens"], None, lengths)
    logits = cm.logits_head(cm.last_token_slice(hidden, lengths),
                            params["head"])
    pos = jnp.full((B,), S, jnp.int32) if lengths is None else lengths
    return DecodeCache(pos=pos, rwkv=state), logits


def decode_step(params, cfg: ModelConfig, cache: DecodeCache, tokens):
    x = cm.embed_lookup(params["embed"], tokens)  # (B, 1, d)
    st = cache.rwkv

    def body(xc, layer_in):
        bp, wkv0, tm_tail, cm_tail = layer_in
        h = cm.apply_norm(xc, bp["ln1"], "layernorm")
        out, tm2, wkv1 = time_mix_step(bp["tm"], cfg, h, tm_tail, wkv0)
        xc = xc + out
        h2 = cm.apply_norm(xc, bp["ln2"], "layernorm")
        out2, cm2 = channel_mix_step(bp["cmx"], h2, cm_tail)
        return xc + out2, (wkv1, tm2, cm2)

    x, (wkv, tmt, cmt) = jax.lax.scan(
        body, x, (params["blocks"], st.wkv, st.tm_shift, st.cm_shift)
    )
    hidden = cm.apply_norm(x, params["final_norm"], "layernorm")
    logits = cm.logits_head(hidden, params["head"])
    new = DecodeCache(pos=cache.pos + 1,
                      rwkv=RwkvState(wkv=wkv, tm_shift=tmt, cm_shift=cmt))
    return new, logits


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> DecodeCache:
    H, K = _dims(cfg)
    z = jnp.zeros((cfg.num_layers, batch, H, K, K), jnp.float32)
    zt = jnp.zeros((cfg.num_layers, batch, cfg.d_model), jnp.dtype(cfg.dtype))
    # Distinct buffers per leaf: the serving scheduler passes this cache to
    # donating jitted calls, which reject one buffer appearing twice.
    return DecodeCache(
        pos=jnp.full((batch,), seq_len, jnp.int32),
        rwkv=RwkvState(wkv=z, tm_shift=zt, cm_shift=jnp.copy(zt)),
    )
