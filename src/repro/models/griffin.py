"""Griffin hybrid (recurrentgemma): RG-LRU recurrent blocks + local attention
in a 2:1 pattern, GeGLU MLPs, MQA with RoPE.

Recurrence (RG-LRU, arXiv:2402.19427):
    r_t = sigmoid(y_t A_r + b_r)           # recurrence gate
    i_t = sigmoid(y_t A_i + b_i)           # input gate
    a_t = exp(-c · softplus(Λ) · r_t)      # c = 8
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ y_t)

Train/prefill evaluates the recurrence with jax.lax.associative_scan
(log-depth — the TPU-friendly parallel form); decode is an O(1) update.
The temporal conv (width 4) is causal-depthwise, expressed as 4 shifted
adds. Layers are scanned in groups of (rglru, rglru, attn); a partial
remainder group covers num_layers % 3 (38 = 12×3 + 2 for the 9b config).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models.kv_cache import DecodeCache, KVCache, RecurrentState, cache_write
from repro.parallel.sharding import constrain

_C_RGLRU = 8.0


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------


def _init_rec_mix(key, cfg: ModelConfig) -> dict:
    d, W = cfg.d_model, cfg.rnn_width
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    return {
        "rg_in": cm.dense_init(ks[0], d, W, dt),
        "rg_gate": cm.dense_init(ks[1], d, W, dt),
        "rg_out": cm.dense_init(ks[2], W, d, dt),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_width, W), jnp.float32)
                   * (1.0 / cfg.conv_width)).astype(dt),
        "rg_a_proj": cm.dense_init(ks[4], W, W, dt),
        "rg_i_proj": cm.dense_init(ks[5], W, W, dt),
        "rg_a_bias": jnp.zeros((W,), jnp.float32),
        "rg_i_bias": jnp.zeros((W,), jnp.float32),
        "lambda_p": jnp.full((W,), 0.65, jnp.float32),
    }


def _init_attn_mix(key, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "wq": cm.dense_init(ks[0], d, cfg.n_heads * hd, dt),
        "wk": cm.dense_init(ks[1], d, cfg.n_kv_heads * hd, dt),
        "wv": cm.dense_init(ks[2], d, cfg.n_kv_heads * hd, dt),
        "wo": cm.dense_init(ks[3], cfg.n_heads * hd, d, dt),
    }


def _init_layer(key, cfg: ModelConfig, kind: str) -> dict:
    k1, k2 = jax.random.split(key)
    dt = jnp.dtype(cfg.dtype)
    return {
        "ln1": cm.norm_init(cfg.norm, cfg.d_model, dt),
        "ln2": cm.norm_init(cfg.norm, cfg.d_model, dt),
        "mix": _init_rec_mix(k1, cfg) if kind == "rglru" else _init_attn_mix(k1, cfg),
        "ffn": cm.ffn_init(k2, cfg, cfg.d_model, cfg.d_ff, dt),
    }


def init_params(key, cfg: ModelConfig) -> dict:
    pattern = cfg.block_pattern or ("rglru", "rglru", "attn")
    n_groups = cfg.num_layers // len(pattern)
    rem = cfg.num_layers % len(pattern)
    keys = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)

    def init_group(k):
        gks = jax.random.split(k, len(pattern))
        return {f"l{i}_{kind}": _init_layer(gks[i], cfg, kind)
                for i, kind in enumerate(pattern)}

    group_keys = jax.random.split(keys[0], n_groups)
    groups = jax.vmap(init_group)(group_keys)
    params = {
        "embed": cm.embed_init(keys[1], cfg.vocab, cfg.d_model, dt),
        "groups": groups,
        "final_norm": cm.norm_init(cfg.norm, cfg.d_model, dt),
        "head": cm.dense_init(keys[2], cfg.d_model, cfg.vocab, dt),
    }
    if rem:
        rem_keys = jax.random.split(keys[3], rem)
        params["rem"] = {
            f"l{i}_{pattern[i]}": _init_layer(rem_keys[i], cfg, pattern[i])
            for i in range(rem)
        }
    return params


# --------------------------------------------------------------------------
# RG-LRU + conv
# --------------------------------------------------------------------------


def _causal_conv(a: jax.Array, conv_w: jax.Array,
                 tail: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv via shifted adds. a: (B, T, W); conv_w: (cw, W);
    tail: (B, cw-1, W) history for decode/streaming (zeros if None)."""
    cw = conv_w.shape[0]
    B, T, W = a.shape
    if tail is None:
        tail = jnp.zeros((B, cw - 1, W), a.dtype)
    ext = jnp.concatenate([tail, a], axis=1)  # (B, T+cw-1, W)
    out = jnp.zeros_like(a)
    for i in range(cw):
        out = out + ext[:, i : i + T, :] * conv_w[cw - 1 - i].astype(a.dtype)
    return out


def _rglru_coeffs(mix: dict, y: jax.Array):
    yf = y.astype(jnp.float32)
    r = jax.nn.sigmoid(yf @ mix["rg_a_proj"].astype(jnp.float32) + mix["rg_a_bias"])
    i = jax.nn.sigmoid(yf @ mix["rg_i_proj"].astype(jnp.float32) + mix["rg_i_bias"])
    log_a = -_C_RGLRU * jax.nn.softplus(mix["lambda_p"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * yf)
    return a, gated


def _rglru_scan(a: jax.Array, b: jax.Array, h0: Optional[jax.Array]):
    """h_t = a_t h_{t-1} + b_t over axis 1 via associative scan."""
    if h0 is not None:
        # Fold carry-in into the first step: b_0 += a_0 * h0.
        b = b.at[:, 0].add(a[:, 0] * h0.astype(b.dtype))

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rec_mix_apply(mix: dict, cfg: ModelConfig, x: jax.Array,
                  rec: Optional[Tuple[jax.Array, jax.Array]] = None,
                  lengths: Optional[jax.Array] = None):
    """Full-seq recurrent temporal mix. x: (B, T, d) normalized.
    rec: optional (h0 (B, W), conv_tail (B, cw-1, W)); lengths: optional
    per-row real-token counts for right-padded serving prompts — the
    recurrence is causal, so carrying out the state at lengths-1 makes a
    bucketed prefill exact (trailing pads never touch the carried state).
    Returns (out, (h_last, conv_tail_new))."""
    gate = jax.nn.gelu(cm.linear(x, mix["rg_gate"], cfg.quant,
                                 "fake" if cfg.quant else "none"), approximate=True)
    a_in = cm.linear(x, mix["rg_in"], cfg.quant, "fake" if cfg.quant else "none")
    a_in = constrain(a_in, "batch", None, "model")
    h0, conv_tail = rec if rec is not None else (None, None)
    y = _causal_conv(a_in, mix["conv_w"], conv_tail)
    a, b = _rglru_coeffs(mix, y)
    h = _rglru_scan(a, b, h0)
    out = cm.linear((h.astype(x.dtype) * gate), mix["rg_out"], cfg.quant,
                    "fake" if cfg.quant else "none")
    cw = mix["conv_w"].shape[0]
    B, T, W = a_in.shape
    # Conv tail = the cw-1 inputs before position `length` (zero history
    # when the sequence is shorter than the conv support).
    ext = jnp.concatenate(
        [jnp.zeros((B, cw - 1, W), a_in.dtype), a_in], axis=1
    )
    if lengths is None:
        h_last = h[:, -1]
        new_tail = ext[:, T : T + cw - 1]
    else:
        idx = (lengths.astype(jnp.int32) - 1)[:, None, None]
        h_last = jnp.take_along_axis(h, jnp.maximum(idx, 0), axis=1)[:, 0]
        new_tail = jax.vmap(
            lambda e, n: jax.lax.dynamic_slice_in_dim(e, n, cw - 1, axis=0)
        )(ext, lengths.astype(jnp.int32))
    return out, (h_last, new_tail)


def rec_mix_step(mix: dict, cfg: ModelConfig, x: jax.Array, h0, conv_tail):
    """Single token. x: (B, 1, d). Returns (out, h_new, conv_tail_new)."""
    gate = jax.nn.gelu(cm.linear(x, mix["rg_gate"]), approximate=True)
    a_in = cm.linear(x, mix["rg_in"])  # (B, 1, W)
    y = _causal_conv(a_in, mix["conv_w"], conv_tail)
    a, b = _rglru_coeffs(mix, y)
    h = a[:, 0] * h0 + b[:, 0]
    out = cm.linear((h[:, None].astype(x.dtype) * gate), mix["rg_out"])
    new_tail = jnp.concatenate([conv_tail[:, 1:], a_in], axis=1)
    return out, h, new_tail


# --------------------------------------------------------------------------
# Layer / group application
# --------------------------------------------------------------------------


def _attn_apply(mix, cfg, x, positions):
    B, T, _ = x.shape
    hd = cfg.head_dim
    q = cm.linear(x, mix["wq"]).reshape(B, T, cfg.n_heads, hd)
    k = cm.linear(x, mix["wk"]).reshape(B, T, cfg.n_kv_heads, hd)
    v = cm.linear(x, mix["wv"]).reshape(B, T, cfg.n_kv_heads, hd)
    q = cm.rope(q, positions, cfg.rope_theta)
    k = cm.rope(k, positions, cfg.rope_theta)
    mask = cm.AttnMask(causal=True, window=cfg.local_window)
    attn = cm.chunked_attention(q, k, v, mask,
                                q_chunk=min(cfg.attn_q_chunk, T),
                                kv_chunk=min(cfg.attn_kv_chunk, T))
    out = cm.linear(attn.reshape(B, T, cfg.n_heads * hd), mix["wo"])
    return out, k, v


def layer_apply(lp: dict, kind: str, cfg: ModelConfig, x, positions,
                rec_state=None, lengths=None):
    """Full-seq layer. Returns (x, mix_state) where mix_state is
    (h, conv_tail) for rglru or (k, v) for attn."""
    h = cm.apply_norm(x, lp["ln1"], cfg.norm)
    if kind == "rglru":
        out, state = rec_mix_apply(lp["mix"], cfg, h, rec_state, lengths)
    else:
        out, k, v = _attn_apply(lp["mix"], cfg, h, positions)
        state = (k, v)
    x = x + out
    h2 = cm.apply_norm(x, lp["ln2"], cfg.norm)
    x = x + cm.ffn_apply(lp["ffn"], h2, cfg)
    return constrain(x, "batch", None, None), state


# --------------------------------------------------------------------------
# Full model
# --------------------------------------------------------------------------


def _pattern(cfg: ModelConfig):
    return cfg.block_pattern or ("rglru", "rglru", "attn")


def _forward(params, cfg: ModelConfig, tokens, collect: bool, lengths=None):
    pattern = _pattern(cfg)
    B, T = tokens.shape
    x = cm.embed_lookup(params["embed"], tokens, scale=True)
    x = constrain(x, "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def group_body(carry, gp):
        xc = carry
        states = {}
        for i, kind in enumerate(pattern):
            xc, st = layer_apply(gp[f"l{i}_{kind}"], kind, cfg, xc, positions,
                                 lengths=lengths)
            if collect:
                states[f"l{i}_{kind}"] = st
        return xc, states if collect else None

    body_fn = jax.checkpoint(group_body) if cfg.remat else group_body
    x, gstates = jax.lax.scan(body_fn, x, params["groups"])

    rstates = {}
    if "rem" in params:
        for name, lp in params["rem"].items():
            kind = name.split("_", 1)[1]
            x, st = layer_apply(lp, kind, cfg, x, positions, lengths=lengths)
            if collect:
                rstates[name] = st
    hidden = cm.apply_norm(x, params["final_norm"], cfg.norm)
    return hidden, (gstates, rstates)


def train_loss(params, cfg: ModelConfig, batch):
    hidden, _ = _forward(params, cfg, batch["tokens"], False)
    logits = cm.logits_head(hidden, params["head"])
    logits = constrain(logits, "batch", None, "model")
    loss = cm.cross_entropy(logits[:, :-1], batch["tokens"][:, 1:]).mean()
    return loss, {"loss": loss, "aux_loss": jnp.zeros((), jnp.float32)}


def _pack_cache(cfg: ModelConfig, states, B: int, S: int,
                lengths=None) -> DecodeCache:
    """Convert per-group collected states into stacked decode caches."""
    gstates, rstates = states
    pattern = _pattern(cfg)
    w = cfg.local_window

    # Interleave group-stacked states into sequential execution order:
    # [g0·l0, g0·l1, ..., g1·l0, ...] — the order decode_step indexes with.
    rec_slots = [i for i, k in enumerate(pattern) if k == "rglru"]
    att_slots = [i for i, k in enumerate(pattern) if k == "attn"]
    hs_list, tails_list, ks_list, vs_list = [], [], [], []
    if rec_slots:
        hs = jnp.stack([gstates[f"l{i}_rglru"][0] for i in rec_slots], axis=1)
        tails = jnp.stack([gstates[f"l{i}_rglru"][1] for i in rec_slots], axis=1)
        hs_list.append(hs.reshape(-1, *hs.shape[2:]))
        tails_list.append(tails.reshape(-1, *tails.shape[2:]))
    if att_slots:
        ks = jnp.stack([gstates[f"l{i}_attn"][0] for i in att_slots], axis=1)
        vs = jnp.stack([gstates[f"l{i}_attn"][1] for i in att_slots], axis=1)
        ks_list.append(ks.reshape(-1, *ks.shape[2:]))
        vs_list.append(vs.reshape(-1, *vs.shape[2:]))
    for name, st in rstates.items():
        kind = name.split("_", 1)[1]
        if kind == "rglru":
            hs_list.append(st[0][None])
            tails_list.append(st[1][None])
        else:
            ks_list.append(st[0][None])
            vs_list.append(st[1][None])
    B_ = 1
    if not hs_list:  # degenerate attn-only pattern
        hs_list = [jnp.zeros((0, B_, cfg.rnn_width), jnp.float32)]
        tails_list = [jnp.zeros((0, B_, cfg.conv_width - 1, cfg.rnn_width),
                                jnp.dtype(cfg.dtype))]
    if not ks_list:  # degenerate rglru-only pattern
        ks_list = [jnp.zeros((0, B_, 1, cfg.n_kv_heads, cfg.head_dim),
                             jnp.dtype(cfg.dtype))]
        vs_list = [jnp.zeros_like(ks_list[0])]
    hs = jnp.concatenate(hs_list, 0)
    tails = jnp.concatenate(tails_list, 0)
    k_cat = jnp.concatenate(ks_list, 0)
    v_cat = jnp.concatenate(vs_list, 0)
    from repro.models.kv_cache import ring_align

    k_all, v_all, slot_pos = ring_align(k_cat, v_cat, lengths, w)

    length = jnp.full((B,), S, jnp.int32) if lengths is None else (
        lengths.astype(jnp.int32))
    rec = RecurrentState(h=hs, conv_tail=tails)
    kv = KVCache(
        k=k_all, v=v_all, slot_pos=slot_pos, length=length, window=w,
    )
    return DecodeCache(pos=length, kv=kv, rec=rec)


def prefill(params, cfg: ModelConfig, batch):
    """``batch["lengths"]`` (B,) marks right-padded serving prompts; the
    carried recurrent state, conv tails, attention ring and logits are all
    taken at the per-row last real token, so bucketed prefill is exact."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    lengths = batch.get("lengths")
    if lengths is not None:
        lengths = jnp.asarray(lengths, jnp.int32)
    hidden, states = _forward(params, cfg, tokens, True, lengths)
    logits = cm.logits_head(cm.last_token_slice(hidden, lengths),
                            params["head"])
    return _pack_cache(cfg, states, B, S, lengths), logits


def decode_step(params, cfg: ModelConfig, cache: DecodeCache, tokens):
    pattern = _pattern(cfg)
    n_rec_per_group = sum(1 for k in pattern if k == "rglru")
    n_att_per_group = len(pattern) - n_rec_per_group
    pos = cache.pos
    x = cm.embed_lookup(params["embed"], tokens, scale=True)

    rec_h, rec_tail = cache.rec.h, cache.rec.conv_tail
    kvk, kvv, kvp = cache.kv.k, cache.kv.v, cache.kv.slot_pos

    def layer_dec(lp, kind, xc, ri, ai, rh, rt, kk, vv, sp):
        h = cm.apply_norm(xc, lp["ln1"], cfg.norm)
        if kind == "rglru":
            out, hn, tn = rec_mix_step(lp["mix"], cfg, h, rh[ri], rt[ri])
            rh = rh.at[ri].set(hn)
            rt = rt.at[ri].set(tn)
            ri += 1
        else:
            B = xc.shape[0]
            hd = cfg.head_dim
            q = cm.linear(h, lp["mix"]["wq"]).reshape(B, 1, cfg.n_heads, hd)
            k = cm.linear(h, lp["mix"]["wk"]).reshape(B, 1, cfg.n_kv_heads, hd)
            v = cm.linear(h, lp["mix"]["wv"]).reshape(B, 1, cfg.n_kv_heads, hd)
            pp = pos[:, None]                     # (B, 1) per-slot positions
            q = cm.rope(q, pp, cfg.rope_theta)
            k = cm.rope(k, pp, cfg.rope_theta)
            kc, vc, spc = cache_write(kk[ai], vv[ai], sp[ai], k, v, pos,
                                      cfg.local_window)
            attn = cm.decode_attention(q, kc, vc, spc, pos, window=cfg.local_window)
            out = cm.linear(attn.reshape(B, 1, cfg.n_heads * hd), lp["mix"]["wo"])
            kk = kk.at[ai].set(kc)
            vv = vv.at[ai].set(vc)
            sp = sp.at[ai].set(spc)
            ai += 1
        xc = xc + out
        h2 = cm.apply_norm(xc, lp["ln2"], cfg.norm)
        xc = xc + cm.ffn_apply(lp["ffn"], h2, cfg)
        return xc, ri, ai, rh, rt, kk, vv, sp

    n_groups = jax.tree_util.tree_leaves(params["groups"])[0].shape[0]
    ri_base, ai_base = 0, 0
    for g in range(n_groups):
        gp = jax.tree_util.tree_map(lambda a: a[g], params["groups"])
        ri, ai = ri_base, ai_base
        for i, kind in enumerate(pattern):
            x, ri, ai, rec_h, rec_tail, kvk, kvv, kvp = layer_dec(
                gp[f"l{i}_{kind}"], kind, x, ri, ai,
                rec_h, rec_tail, kvk, kvv, kvp,
            )
        ri_base, ai_base = ri, ai
    if "rem" in params:
        for name, lp in params["rem"].items():
            kind = name.split("_", 1)[1]
            x, ri_base, ai_base, rec_h, rec_tail, kvk, kvv, kvp = layer_dec(
                lp, kind, x, ri_base, ai_base, rec_h, rec_tail, kvk, kvv, kvp
            )

    hidden = cm.apply_norm(x, params["final_norm"], cfg.norm)
    logits = cm.logits_head(hidden, params["head"])
    new = DecodeCache(
        pos=pos + 1,
        kv=KVCache(k=kvk, v=kvv, slot_pos=kvp, length=cache.kv.length + 1,
                   window=cfg.local_window),
        rec=RecurrentState(h=rec_h, conv_tail=rec_tail),
    )
    return new, logits


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> DecodeCache:
    pattern = _pattern(cfg)
    n_rec = sum(1 for i in range(cfg.num_layers) if pattern[i % len(pattern)] == "rglru")
    n_att = cfg.num_layers - n_rec
    dt = jnp.dtype(cfg.dtype)
    w = cfg.local_window
    kv = KVCache.init(n_att, batch, min(seq_len, w), cfg.n_kv_heads,
                      cfg.head_dim, window=w, dtype=dt)
    rec = RecurrentState(
        h=jnp.zeros((n_rec, batch, cfg.rnn_width), jnp.float32),
        conv_tail=jnp.zeros((n_rec, batch, cfg.conv_width - 1, cfg.rnn_width), dt),
    )
    return DecodeCache(pos=jnp.full((batch,), seq_len, jnp.int32), kv=kv, rec=rec)
