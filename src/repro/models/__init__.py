"""Model zoo: 10 assigned architectures over 4 family implementations.

  transformer.py : dense GQA decoders, MoE decoders, encoder, VLM
  moe.py         : capacity-bounded sort-dispatch MoE FFN (EP / TP)
  rwkv6.py       : attention-free Finch (chunked wkv6)
  griffin.py     : RG-LRU + local-attention hybrid
  kv_cache.py    : decode caches (ring buffers, recurrent states)
  model_zoo.py   : build_model / input_specs / smoke_batch
"""
from repro.models.model_zoo import build_model  # noqa: F401
