"""Decode-time state structures (registered pytrees).

Attention KV caches are ring buffers when the arch uses sliding-window /
local attention (cache size = window, not sequence length — this is what
makes long_500k decode cells feasible for mixtral/recurrentgemma), and
full-length buffers for global attention. Recurrent families carry O(1)
states (RG-LRU hidden, conv tail, RWKV wkv state + token-shift tails).

All leaves carry a leading layer (or group) axis so decode steps scan over
layers exactly like training does.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class KVCache:
    """k/v: (L, B, S, NKV, H); slot_pos: (L, B, S) absolute position of each
    cache slot *per batch row* (−1 = empty); length: (B,) per-row count of
    tokens written.

    Every position-tracking leaf carries a batch axis so the continuous-
    batching scheduler can hold sequences at different decode depths in one
    cache: batch row b advances independently, and admitting a new request
    only rewrites row b (see `scatter_into_slot`).

    Optional int8 quantization (§Perf lever, the paper's activation-
    quantization idea applied to the cache): k/v hold int8 codes and
    k_scale/v_scale hold per-(slot, head) fp32 scales — HBM traffic per
    decode step drops ~2× (int8 + one scale per head vs bf16)."""

    k: jax.Array
    v: jax.Array
    slot_pos: jax.Array
    length: jax.Array
    k_scale: Optional[jax.Array] = None  # (L, B, S, NKV, 1) fp32
    v_scale: Optional[jax.Array] = None
    window: int = 0  # 0 = full cache; >0 = ring buffer of this size

    def tree_flatten(self):
        return (self.k, self.v, self.slot_pos, self.length,
                self.k_scale, self.v_scale), (self.window,)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, window=aux[0])

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    @staticmethod
    def init(layers: int, batch: int, size: int, n_kv: int, head_dim: int,
             window: int = 0, dtype=jnp.bfloat16,
             quantized: bool = False) -> "KVCache":
        # Windowed caches are always window-sized rings (slot = pos % window
        # must never collide with a live position).
        s = window if window else size
        kd = jnp.int8 if quantized else dtype
        scale = (
            jnp.zeros((layers, batch, s, n_kv, 1), jnp.float32)
            if quantized else None
        )
        return KVCache(
            k=jnp.zeros((layers, batch, s, n_kv, head_dim), kd),
            v=jnp.zeros((layers, batch, s, n_kv, head_dim), kd),
            slot_pos=jnp.full((layers, batch, s), -1, jnp.int32),
            length=jnp.zeros((batch,), jnp.int32),
            k_scale=scale,
            v_scale=jnp.copy(scale) if quantized else None,
            window=window,
        )


def quantize_kv(x: jax.Array):
    """Per-(token, head) int8 symmetric quantization of (..., NKV, H)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = absmax / 127.0
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) * inv), -128, 127)
    return codes.astype(jnp.int8), scale


def dequantize_kv(codes: jax.Array, scale: jax.Array) -> jax.Array:
    """Exact read-side inverse of `quantize_kv`: fp32 ``codes * scale``.

    Every consumer that reads quantized K/V *values* (rather than scoring
    on raw codes like `decode_attention`) must go through this one helper:
    the prefix-cache bit-identity contract requires a warm prefill reading
    pool codes to see the very same floats a cold prefill saw when it read
    its own freshly quantized K/V."""
    return codes.astype(jnp.float32) * scale


def ring_align(k_full, v_full, lengths, window: int):
    """Pack full-sequence prefill K/V (L, B, S, NKV, H) into the ring-buffer
    invariant used by cache_write: position p lives at slot p % window.

    `lengths` is the per-row count of real (right-padded) tokens, or None
    for "every row is full length S". Each row keeps its own last
    min(length, window) positions; empty ring slots carry slot_pos = -1
    (their values are never read — decode_attention masks them).

    Returns (k (L, B, window, NKV, H), v, slot_pos (L, B, window))."""
    L, B, S = k_full.shape[:3]
    if lengths is None:
        lengths = jnp.full((B,), S, jnp.int32)
    lengths = lengths.astype(jnp.int32)
    B = max(B, lengths.shape[0])  # degenerate layer stacks keep batch = 1
    r = jnp.arange(window, dtype=jnp.int32)
    base = jnp.maximum(lengths - window, 0)[:, None]        # (B, 1)
    # p[b, r]: the absolute position living in ring slot r of row b —
    # the unique p in [len-window, len) with p % window == r.
    p = base + jnp.mod(r[None, :] - base, window)           # (B, window)
    valid = p < lengths[:, None]
    idx = jnp.minimum(p, S - 1)[None, :, :, None, None]     # clip for gather

    def gather(a):
        return jnp.take_along_axis(a, idx.astype(jnp.int32), axis=2)

    slot_pos = jnp.where(valid, p, -1)
    return gather(k_full), gather(v_full), jnp.broadcast_to(
        slot_pos[None], (L, B, window)
    )


def full_slot_pos(layers: int, batch: int, size: int, lengths) -> jax.Array:
    """slot_pos (layers, batch, size) for a full (non-ring) cache where
    array slot == absolute position. Slots at or beyond the per-row length
    (right-pad slots, decode headroom) are marked empty (-1)."""
    s = jnp.arange(size, dtype=jnp.int32)
    if lengths is None:
        sp = jnp.broadcast_to(s, (batch, size))
    else:
        sp = jnp.where(s[None, :] < lengths[:, None].astype(jnp.int32),
                       s[None, :], -1)
    return jnp.broadcast_to(sp[None], (layers, batch, size))


def write_slot(pos, size, window: int):
    """Cache slot index for absolute position(s) `pos`.
    Full cache: slot = pos (clamped). Ring buffer: slot = pos % size."""
    return jnp.where(window > 0, pos % size, jnp.minimum(pos, size - 1))


def row_write(cache, new, slot):
    """Per-row slot write: cache (B, S, ...), new (B, 1, ...), slot (B,).
    Each batch row writes its own slot (lowered as a batched scatter)."""
    return jax.vmap(
        lambda c, n, s: jax.lax.dynamic_update_slice_in_dim(
            c, n.astype(c.dtype), s, axis=0
        )
    )(cache, new, slot)


def cache_write(k_cache, v_cache, slot_pos, k_new, v_new, pos, window: int):
    """Write one token's k/v (B, 1, NKV, H) at per-row absolute positions
    `pos` (B,) — each batch row advances independently (per-slot decode).

    Full cache: slot = pos. Ring buffer: slot = pos % size. slot_pos is
    (B, S). Returns updated (k_cache, v_cache, slot_pos).
    """
    size = k_cache.shape[1]
    slot = write_slot(pos, size, window)
    k_cache = row_write(k_cache, k_new, slot)
    v_cache = row_write(v_cache, v_new, slot)
    slot_pos = row_write(slot_pos, pos[:, None].astype(jnp.int32), slot)
    return k_cache, v_cache, slot_pos


# --------------------------------------------------------------------------
# Paged KV cache: shared block pool + per-slot block tables
# --------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedKVCache:
    """Block-pool KV cache for full-attention decode (the serving analogue
    of the paper's utilization argument: capacity is sized for the *actual*
    resident tokens, not a worst-case per-slot reservation).

    k/v: (L, num_blocks, block_size, NKV, H) — one pool shared by every
    batch slot. block_table: (B, max_blocks) int32 maps a row's virtual
    block j (covering absolute positions [j·bs, (j+1)·bs)) to a pool block;
    -1 = unallocated. Pool block 0 is a reserved trash block: writes from
    free slots and unallocated virtual blocks land there and are never
    read. length: (B,) tokens written per row.

    Absolute position p of row b resolves to
    (block_table[b, p // block_size], p % block_size); gathering a row's
    blocks in table order therefore reproduces the contiguous layout slot
    == position, which is what makes the paged path bit-identical to the
    contiguous one.

    Optional int8 pool (the contiguous cache's kv_cache_quant applied to
    the block pool): k/v hold int8 codes and k_scale/v_scale hold
    per-(slot, head) fp32 scale planes (L, num_blocks, block_size, NKV, 1)
    written by the quantizing `paged_cache_write` — roughly 2× the tokens
    per pooled byte.

    Pool blocks have no intrinsic owner: nothing stops two rows' tables
    from mapping to the same pool block, which is exactly how the
    cross-request prefix cache shares prompt-prefix blocks (scale planes
    included for an int8 pool). Ownership lives host-side in the
    scheduler's allocator — per-block reference counts, an LRU of
    unreferenced-but-cached prefix blocks, and copy-on-write
    (`copy_pool_block`) before a row appends into a shared block.

    That host-side ownership is also what makes preemption free at this
    layer: evicting a row clears its block-table ROW, never the pool
    bytes. The K/V a preempted request computed stays resident in its
    (now refcount-0, prefix-indexed) blocks, so a warm resume just maps
    them into a fresh table row; nothing device-side is saved, restored,
    or recomputed unless the blocks were meanwhile evicted for capacity.
    Corollary: a pool block's bytes must be treated as immutable from
    the moment any digest is registered against it (the scheduler
    enforces this by copy-on-write even for a sole referencer)."""

    k: jax.Array
    v: jax.Array
    block_table: jax.Array
    length: jax.Array
    k_scale: Optional[jax.Array] = None  # (L, num_blocks, bs, NKV, 1) fp32
    v_scale: Optional[jax.Array] = None
    block_size: int = 16

    def tree_flatten(self):
        return (self.k, self.v, self.block_table, self.length,
                self.k_scale, self.v_scale), (self.block_size,)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, block_size=aux[0])

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    @property
    def num_blocks(self) -> int:
        return self.k.shape[1]

    @property
    def blocks_per_row(self) -> int:
        return self.block_table.shape[1]

    @staticmethod
    def init(layers: int, batch: int, num_blocks: int, block_size: int,
             max_blocks: int, n_kv: int, head_dim: int,
             dtype=jnp.bfloat16, quantized: bool = False) -> "PagedKVCache":
        kd = jnp.int8 if quantized else dtype
        scale = (
            jnp.zeros((layers, num_blocks, block_size, n_kv, 1), jnp.float32)
            if quantized else None
        )
        return PagedKVCache(
            k=jnp.zeros((layers, num_blocks, block_size, n_kv, head_dim), kd),
            v=jnp.zeros((layers, num_blocks, block_size, n_kv, head_dim), kd),
            block_table=jnp.full((batch, max_blocks), -1, jnp.int32),
            length=jnp.zeros((batch,), jnp.int32),
            k_scale=scale,
            v_scale=jnp.copy(scale) if quantized else None,
            block_size=block_size,
        )


def paged_slot(block_table, pos, block_size: int):
    """Resolve per-row absolute positions `pos` (B,) to (pool block (B,),
    in-block offset (B,)). Unallocated virtual blocks (and free slots,
    whose tables are all -1) resolve to the trash block 0."""
    idx = jnp.clip(pos // block_size, 0, block_table.shape[1] - 1)
    blk = jnp.take_along_axis(block_table, idx[:, None].astype(jnp.int32),
                              axis=1)[:, 0]
    return jnp.maximum(blk, 0), pos % block_size


def paged_cache_write(pool_k, pool_v, block_table, k_new, v_new, pos,
                      block_size: int, k_scale=None, v_scale=None):
    """Write one token's k/v (B, 1, NKV, H) into a single layer's pool
    (num_blocks, block_size, NKV, H) at per-row positions `pos` (B,).
    Live rows own disjoint blocks; free rows all write the trash block.

    When the pool is int8 (scale planes passed), the incoming bf16 k/v is
    quantized here — per-(token, head) symmetric codes land in the pool
    and their fp32 scales in the matching scale-plane slots. Returns
    (pool_k, pool_v, k_scale, v_scale); the scales are None passthroughs
    for an unquantized pool."""
    blk, off = paged_slot(block_table, pos, block_size)
    if k_scale is not None:
        k_new, ks = quantize_kv(k_new)
        v_new, vs = quantize_kv(v_new)
        k_scale = k_scale.at[blk, off].set(ks[:, 0])
        v_scale = v_scale.at[blk, off].set(vs[:, 0])
    pool_k = pool_k.at[blk, off].set(k_new[:, 0].astype(pool_k.dtype))
    pool_v = pool_v.at[blk, off].set(v_new[:, 0].astype(pool_v.dtype))
    return pool_k, pool_v, k_scale, v_scale


def paged_chunk_write(pool_k, pool_v, blocks, k_new, v_new, start, length,
                      block_size: int, k_scale=None, v_scale=None):
    """Write one row's `length`-token chunk (1, Lc, NKV, H) into a single
    layer's pool at absolute positions [start, start + length), routed
    through the row's own block table `blocks` (mb,). Padded chunk slots
    (t >= length) and positions past the table are routed to the trash
    block 0, so a fixed-shape Lc never touches blocks a later chunk owns.
    int8 pools quantize on write exactly like `paged_cache_write`.
    Returns (pool_k, pool_v, k_scale, v_scale)."""
    Lc = k_new.shape[1]
    pos = jnp.asarray(start, jnp.int32) + jnp.arange(Lc, dtype=jnp.int32)
    valid = jnp.arange(Lc) < length
    idx = jnp.clip(pos // block_size, 0, blocks.shape[0] - 1)
    blk = jnp.where(valid, jnp.maximum(blocks[idx], 0), 0)
    off = pos % block_size
    k_new, v_new = k_new[0], v_new[0]
    if k_scale is not None:
        k_new, ks = quantize_kv(k_new)
        v_new, vs = quantize_kv(v_new)
        k_scale = k_scale.at[blk, off].set(ks)
        v_scale = v_scale.at[blk, off].set(vs)
    pool_k = pool_k.at[blk, off].set(k_new.astype(pool_k.dtype))
    pool_v = pool_v.at[blk, off].set(v_new.astype(pool_v.dtype))
    return pool_k, pool_v, k_scale, v_scale


def paged_gather(pool_k, pool_v, block_table, k_scale=None, v_scale=None,
                 max_blocks: Optional[int] = None):
    """Gather each row's blocks in table order from a single layer's pool:
    returns (k (B, S, NKV, H), v, kpos (B, S), k_scale, v_scale) with
    S = max_blocks · block_size and kpos[b, p] = p where row b's virtual
    block p // bs is allocated, -1 elsewhere — the exact
    (values, positions) layout of the contiguous cache, ready for
    decode_attention. Scale planes of an int8 pool gather the same way
    ((B, S, NKV, 1) — decode_attention's quantized-cache layout) and come
    back None for a bf16 pool.

    `max_blocks` (host-known, static) clamps the gather to the first
    `max_blocks` table columns: when the caller knows no live row has
    more than that many allocated blocks (the scheduler's allocator
    does), the dead-weight gather of guaranteed-unallocated trash-block
    columns is skipped entirely instead of copying blocks_per_row blocks
    per row every step."""
    if max_blocks is not None:
        block_table = block_table[:, :max_blocks]
    B, n_blocks = block_table.shape
    bs = pool_k.shape[1]
    tbl = jnp.maximum(block_table, 0)
    k_rows = pool_k[tbl].reshape(B, n_blocks * bs, *pool_k.shape[2:])
    v_rows = pool_v[tbl].reshape(B, n_blocks * bs, *pool_v.shape[2:])
    virt = jnp.arange(n_blocks * bs, dtype=jnp.int32)
    alloc = jnp.repeat(block_table >= 0, bs, axis=1)
    kpos = jnp.where(alloc, virt[None, :], -1)
    ks_rows = vs_rows = None
    if k_scale is not None:
        ks_rows = k_scale[tbl].reshape(B, n_blocks * bs, *k_scale.shape[2:])
        vs_rows = v_scale[tbl].reshape(B, n_blocks * bs, *v_scale.shape[2:])
    return k_rows, v_rows, kpos, ks_rows, vs_rows


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RecurrentState:
    """Griffin recurrent-block state: RG-LRU hidden + causal-conv tail.

    h: (L, B, W); conv_tail: (L, B, conv_width-1, W).
    """

    h: jax.Array
    conv_tail: jax.Array

    def tree_flatten(self):
        return (self.h, self.conv_tail), ()

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RwkvState:
    """RWKV-6 per-layer state: wkv (L, B, H, K, V) + token-shift tails
    (L, B, d) for time-mix and channel-mix."""

    wkv: jax.Array
    tm_shift: jax.Array
    cm_shift: jax.Array

    def tree_flatten(self):
        return (self.wkv, self.tm_shift, self.cm_shift), ()

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DecodeCache:
    """Top-level decode carry: whichever sub-states the family uses, plus
    per-slot position counters.

    pos: (B,) int32 — the absolute position each batch slot decodes at.
    Slots are independent: the continuous-batching scheduler holds requests
    at different depths in one cache and one compiled decode signature."""

    pos: jax.Array
    kv: Optional[KVCache] = None
    rec: Optional[RecurrentState] = None
    rwkv: Optional[RwkvState] = None

    def tree_flatten(self):
        return (self.pos, self.kv, self.rec, self.rwkv), ()

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


# --------------------------------------------------------------------------
# Slot scatter: admit one prefilled request into a batch cache row
# --------------------------------------------------------------------------


def _write_row(big, small, slot):
    """Overwrite batch row `slot` of `big` (batch axis 1) with `small`
    (batch axis 1 of size 1)."""
    start = (0, slot) + (0,) * (big.ndim - 2)
    return jax.lax.dynamic_update_slice(big, small.astype(big.dtype), start)


def _pad_seq(x, size: int, fill):
    """Pad the cache-slot axis (axis 2) of a solo-prefill leaf up to the
    batch cache's fixed size."""
    s = x.shape[2]
    if s == size:
        return x
    if s > size:
        raise ValueError(
            f"prefilled cache ({s} slots) exceeds batch cache capacity "
            f"({size}); raise the scheduler's max_ctx"
        )
    pad = jnp.full((*x.shape[:2], size - s, *x.shape[3:]), fill, x.dtype)
    return jnp.concatenate([x, pad], axis=2)


def _scatter_kv(big: KVCache, small: KVCache, slot) -> KVCache:
    size = big.k.shape[2]
    k = _write_row(big.k, _pad_seq(small.k, size, 0), slot)
    v = _write_row(big.v, _pad_seq(small.v, size, 0), slot)
    sp = _write_row(big.slot_pos, _pad_seq(small.slot_pos, size, -1), slot)
    length = jax.lax.dynamic_update_slice(
        big.length, small.length.astype(big.length.dtype), (slot,)
    )
    ks = vs = None
    if big.quantized:
        ks = _write_row(big.k_scale, _pad_seq(small.k_scale, size, 0.0), slot)
        vs = _write_row(big.v_scale, _pad_seq(small.v_scale, size, 0.0), slot)
    return KVCache(k=k, v=v, slot_pos=sp, length=length,
                   k_scale=ks, v_scale=vs, window=big.window)


def scatter_into_slot(batch: DecodeCache, solo: DecodeCache, slot) -> DecodeCache:
    """Admit a solo-prefilled request (batch axis of size 1) into batch
    row `slot` of a live decode cache. Only row `slot` changes — every
    other slot's KV / recurrent / RWKV state and position is untouched,
    which is what makes mid-decode admission safe.

    `slot` may be a traced scalar: one compiled scatter serves all slots
    (per solo-prefill length)."""
    slot = jnp.asarray(slot, jnp.int32)
    pos = jax.lax.dynamic_update_slice(
        batch.pos, solo.pos.astype(batch.pos.dtype), (slot,)
    )
    kv = _scatter_kv(batch.kv, solo.kv, slot) if batch.kv is not None else None
    rec = None
    if batch.rec is not None:
        rec = RecurrentState(
            h=_write_row(batch.rec.h, solo.rec.h, slot),
            conv_tail=_write_row(batch.rec.conv_tail, solo.rec.conv_tail, slot),
        )
    rwkv = None
    if batch.rwkv is not None:
        rwkv = RwkvState(
            wkv=_write_row(batch.rwkv.wkv, solo.rwkv.wkv, slot),
            tm_shift=_write_row(batch.rwkv.tm_shift, solo.rwkv.tm_shift, slot),
            cm_shift=_write_row(batch.rwkv.cm_shift, solo.rwkv.cm_shift, slot),
        )
    return DecodeCache(pos=pos, kv=kv, rec=rec, rwkv=rwkv)


def scatter_into_paged(batch: DecodeCache, solo: DecodeCache, slot,
                       row_blocks) -> DecodeCache:
    """Admit a solo-prefilled request into the paged pool. `solo` carries a
    contiguous full cache (right-padded: array slot == absolute position);
    its virtual block j goes to pool block row_blocks[j]. Entries past the
    allocated prompt blocks are -1 and land in the trash block (they hold
    only right-pad / headroom slots, which are empty anyway).

    `slot` may be traced; `row_blocks` is the (max_blocks,) block-table row
    the allocator filled for this request."""
    kv: PagedKVCache = batch.kv
    bs = kv.block_size
    s_solo = solo.kv.k.shape[2]
    nb = -(-s_solo // bs)
    pad = nb * bs - s_solo

    def as_blocks(a):
        if pad:
            a = jnp.pad(a, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 3))
        return a[:, 0].reshape(a.shape[0], nb, bs, *a.shape[3:])

    slot = jnp.asarray(slot, jnp.int32)
    row_blocks = jnp.asarray(row_blocks, jnp.int32)
    dst = jnp.maximum(
        jnp.take(row_blocks, jnp.arange(nb), mode="fill", fill_value=-1), 0
    )
    k = kv.k.at[:, dst].set(as_blocks(solo.kv.k).astype(kv.k.dtype))
    v = kv.v.at[:, dst].set(as_blocks(solo.kv.v).astype(kv.v.dtype))
    ks = vs = None
    if kv.quantized:
        # The solo prefill cache is quantized too (same cfg): its codes
        # scattered above, its per-(slot, head) scales go to the matching
        # scale-plane blocks.
        ks = kv.k_scale.at[:, dst].set(
            as_blocks(solo.kv.k_scale).astype(kv.k_scale.dtype))
        vs = kv.v_scale.at[:, dst].set(
            as_blocks(solo.kv.v_scale).astype(kv.v_scale.dtype))
    table = jax.lax.dynamic_update_slice(
        kv.block_table, row_blocks[None, : kv.blocks_per_row], (slot, 0)
    )
    length = jax.lax.dynamic_update_slice(
        kv.length, solo.kv.length.astype(kv.length.dtype), (slot,)
    )
    pos = jax.lax.dynamic_update_slice(
        batch.pos, solo.pos.astype(batch.pos.dtype), (slot,)
    )
    return DecodeCache(pos=pos, kv=PagedKVCache(
        k=k, v=v, block_table=table, length=length,
        k_scale=ks, v_scale=vs, block_size=bs))


def scatter_suffix_into_paged(batch: DecodeCache, solo: DecodeCache, slot,
                              row_blocks, start_block) -> DecodeCache:
    """Admit a *suffix-only* prefill (prefix-cache hit) into the paged
    pool. `solo` holds only the uncached tail of the prompt: its cache
    slot ``t`` corresponds to absolute position ``start_block·bs + t``
    (suffix writes always begin at a block boundary — only whole prompt
    blocks are ever shared), so virtual block ``start_block + j`` of the
    suffix goes to pool block ``row_blocks[start_block + j]``. Entries
    past the allocated span are -1 and land in the trash block, exactly
    like `scatter_into_paged`'s right-pad handling.

    ``slot`` and ``start_block`` may be traced; ``row_blocks`` is the
    full (max_blocks,) block-table row — shared prefix blocks included —
    which is written to the device table alongside the suffix data."""
    kv: PagedKVCache = batch.kv
    bs = kv.block_size
    s_solo = solo.kv.k.shape[2]
    nb = -(-s_solo // bs)
    pad = nb * bs - s_solo

    def as_blocks(a):
        if pad:
            a = jnp.pad(a, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 3))
        return a[:, 0].reshape(a.shape[0], nb, bs, *a.shape[3:])

    slot = jnp.asarray(slot, jnp.int32)
    row_blocks = jnp.asarray(row_blocks, jnp.int32)
    start_block = jnp.asarray(start_block, jnp.int32)
    dst = jnp.maximum(
        jnp.take(row_blocks, start_block + jnp.arange(nb), mode="fill",
                 fill_value=-1), 0
    )
    k = kv.k.at[:, dst].set(as_blocks(solo.kv.k).astype(kv.k.dtype))
    v = kv.v.at[:, dst].set(as_blocks(solo.kv.v).astype(kv.v.dtype))
    ks = vs = None
    if kv.quantized:
        ks = kv.k_scale.at[:, dst].set(
            as_blocks(solo.kv.k_scale).astype(kv.k_scale.dtype))
        vs = kv.v_scale.at[:, dst].set(
            as_blocks(solo.kv.v_scale).astype(kv.v_scale.dtype))
    table = jax.lax.dynamic_update_slice(
        kv.block_table, row_blocks[None, : kv.blocks_per_row], (slot, 0)
    )
    length = jax.lax.dynamic_update_slice(
        kv.length, solo.kv.length.astype(kv.length.dtype), (slot,)
    )
    pos = jax.lax.dynamic_update_slice(
        batch.pos, solo.pos.astype(batch.pos.dtype), (slot,)
    )
    return DecodeCache(pos=pos, kv=PagedKVCache(
        k=k, v=v, block_table=table, length=length,
        k_scale=ks, v_scale=vs, block_size=bs))


def set_paged_row(batch: DecodeCache, solo: DecodeCache, slot,
                  row_blocks) -> DecodeCache:
    """Admission metadata write for a *fully* prefix-cached prompt: every
    prompt position is already resident in shared pool blocks, so only the
    row's block table, length, and decode position change — no KV data
    moves. (`solo` is the one-token logits prefill; only its length/pos
    leaves are read.)"""
    kv: PagedKVCache = batch.kv
    slot = jnp.asarray(slot, jnp.int32)
    row_blocks = jnp.asarray(row_blocks, jnp.int32)
    table = jax.lax.dynamic_update_slice(
        kv.block_table, row_blocks[None, : kv.blocks_per_row], (slot, 0)
    )
    length = jax.lax.dynamic_update_slice(
        kv.length, solo.kv.length.astype(kv.length.dtype), (slot,)
    )
    pos = jax.lax.dynamic_update_slice(
        batch.pos, solo.pos.astype(batch.pos.dtype), (slot,)
    )
    return DecodeCache(pos=pos, kv=dataclasses.replace(
        kv, block_table=table, length=length))


def set_decode_positions(cache: DecodeCache, pos, length) -> DecodeCache:
    """Overwrite every row's decode position and live length in one device
    write — the speculative-decode bookkeeping op.

    Drafting advances each row's ``pos``/``length`` one token per draft
    step (the jitted decode step advances *all* rows) and the verify chunk
    sets its slot past every drafted position; after greedy acceptance the
    host knows the true position of every row (accepted prefix boundary
    for speculating rows, the pre-draft value for everyone else) and
    restores it here. Rejected positions' pool bytes are left stale — the
    position mask (`kpos <= q_pos`) hides them from every subsequent read,
    and the row's next writes land there anyway, so no pool rollback is
    needed; the entire rollback IS this metadata write."""
    kv: PagedKVCache = cache.kv
    return DecodeCache(
        pos=jnp.asarray(pos, jnp.int32),
        kv=dataclasses.replace(kv, length=jnp.asarray(length, jnp.int32)),
    )


def copy_pool_block(cache: DecodeCache, src, dst) -> DecodeCache:
    """Copy-on-write support: duplicate pool block `src` into `dst` across
    every layer (k, v, and the int8 scale planes when present). The
    allocator calls this before a row appends into a block it shares with
    other rows or with the prefix cache — the sharers keep reading the
    pristine block, the appender writes into its private copy. Copying the
    whole block (appended slots included) is safe: a row only ever reads
    slots below its own position, and its next writes overwrite the rest.

    `src`/`dst` may be traced scalars — one compiled copy serves every
    (src, dst) pair."""
    kv: PagedKVCache = cache.kv
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    k = kv.k.at[:, dst].set(kv.k[:, src])
    v = kv.v.at[:, dst].set(kv.v[:, src])
    ks = vs = None
    if kv.quantized:
        ks = kv.k_scale.at[:, dst].set(kv.k_scale[:, src])
        vs = kv.v_scale.at[:, dst].set(kv.v_scale[:, src])
    return dataclasses.replace(cache, kv=dataclasses.replace(
        kv, k=k, v=v, k_scale=ks, v_scale=vs))


def write_pool_block(cache: DecodeCache, dst, k, v,
                     k_scale=None, v_scale=None) -> DecodeCache:
    """Write one block's worth of K/V bytes into pool block `dst` across
    every layer — the swap-in half of the host-RAM block tier. `k`/`v`
    are (L, block_size, NKV, H) arrays in the pool's dtype (int8 codes
    for a quantized pool, with the fp32 `k_scale`/`v_scale` planes
    (L, block_size, NKV, 1) alongside); they round-trip device → pinned
    host numpy → device verbatim, which is what makes a warm-from-host
    admission bitwise identical to the blocks' original residency.

    `dst` may be a traced scalar — one compiled write serves every
    destination block."""
    kv: PagedKVCache = cache.kv
    dst = jnp.asarray(dst, jnp.int32)
    kk = kv.k.at[:, dst].set(jnp.asarray(k, kv.k.dtype))
    vv = kv.v.at[:, dst].set(jnp.asarray(v, kv.v.dtype))
    ks = vs = None
    if kv.quantized:
        ks = kv.k_scale.at[:, dst].set(
            jnp.asarray(k_scale, kv.k_scale.dtype))
        vs = kv.v_scale.at[:, dst].set(
            jnp.asarray(v_scale, kv.v_scale.dtype))
    return dataclasses.replace(cache, kv=dataclasses.replace(
        kv, k=kk, v=vv, k_scale=ks, v_scale=vs))


def grow_cache(cache: DecodeCache, size: int) -> DecodeCache:
    """Extend a full-attention contiguous cache's slot axis to at least
    `size` empty slots (ring buffers and recurrent states are position-
    unbounded and pass through untouched). This is what lets the static
    engine decode past the prefill headroom instead of silently rewriting
    the last slot via write_slot's clamp."""
    kv = cache.kv
    if kv is None or not isinstance(kv, KVCache) or kv.window:
        return cache
    cur = kv.k.shape[2]
    if cur >= size:
        return cache
    pad = size - cur
    zk = jnp.zeros((*kv.k.shape[:2], pad, *kv.k.shape[3:]), kv.k.dtype)
    sp = jnp.full((*kv.slot_pos.shape[:2], pad), -1, jnp.int32)
    ks = vs = None
    if kv.quantized:
        zs = jnp.zeros((*kv.k_scale.shape[:2], pad, *kv.k_scale.shape[3:]),
                       kv.k_scale.dtype)
        ks = jnp.concatenate([kv.k_scale, zs], axis=2)
        vs = jnp.concatenate([kv.v_scale, jnp.copy(zs)], axis=2)
    return dataclasses.replace(cache, kv=KVCache(
        k=jnp.concatenate([kv.k, zk], axis=2),
        v=jnp.concatenate([kv.v, jnp.copy(zk)], axis=2),
        slot_pos=jnp.concatenate([kv.slot_pos, sp], axis=2),
        length=kv.length, k_scale=ks, v_scale=vs, window=kv.window,
    ))
