"""Decode-time state structures (registered pytrees).

Attention KV caches are ring buffers when the arch uses sliding-window /
local attention (cache size = window, not sequence length — this is what
makes long_500k decode cells feasible for mixtral/recurrentgemma), and
full-length buffers for global attention. Recurrent families carry O(1)
states (RG-LRU hidden, conv tail, RWKV wkv state + token-shift tails).

All leaves carry a leading layer (or group) axis so decode steps scan over
layers exactly like training does.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class KVCache:
    """k/v: (L, B, S, NKV, H); slot_pos: (L, B, S) absolute position of each
    cache slot *per batch row* (−1 = empty); length: (B,) per-row count of
    tokens written.

    Every position-tracking leaf carries a batch axis so the continuous-
    batching scheduler can hold sequences at different decode depths in one
    cache: batch row b advances independently, and admitting a new request
    only rewrites row b (see `scatter_into_slot`).

    Optional int8 quantization (§Perf lever, the paper's activation-
    quantization idea applied to the cache): k/v hold int8 codes and
    k_scale/v_scale hold per-(slot, head) fp32 scales — HBM traffic per
    decode step drops ~2× (int8 + one scale per head vs bf16)."""

    k: jax.Array
    v: jax.Array
    slot_pos: jax.Array
    length: jax.Array
    k_scale: Optional[jax.Array] = None  # (L, B, S, NKV, 1) fp32
    v_scale: Optional[jax.Array] = None
    window: int = 0  # 0 = full cache; >0 = ring buffer of this size

    def tree_flatten(self):
        return (self.k, self.v, self.slot_pos, self.length,
                self.k_scale, self.v_scale), (self.window,)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, window=aux[0])

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    @staticmethod
    def init(layers: int, batch: int, size: int, n_kv: int, head_dim: int,
             window: int = 0, dtype=jnp.bfloat16,
             quantized: bool = False) -> "KVCache":
        # Windowed caches are always window-sized rings (slot = pos % window
        # must never collide with a live position).
        s = window if window else size
        kd = jnp.int8 if quantized else dtype
        scale = (
            jnp.zeros((layers, batch, s, n_kv, 1), jnp.float32)
            if quantized else None
        )
        return KVCache(
            k=jnp.zeros((layers, batch, s, n_kv, head_dim), kd),
            v=jnp.zeros((layers, batch, s, n_kv, head_dim), kd),
            slot_pos=jnp.full((layers, batch, s), -1, jnp.int32),
            length=jnp.zeros((batch,), jnp.int32),
            k_scale=scale,
            v_scale=jnp.copy(scale) if quantized else None,
            window=window,
        )


def quantize_kv(x: jax.Array):
    """Per-(token, head) int8 symmetric quantization of (..., NKV, H)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = absmax / 127.0
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) * inv), -128, 127)
    return codes.astype(jnp.int8), scale


def ring_align(k_last, v_last, S: int, window: int):
    """Align prefill K/V (last min(S, window) positions in sequence order,
    layer-stacked: (L, B, s, NKV, H)) to the ring-buffer invariant used by
    cache_write: position p lives at slot p % ring_size.

    Returns (k, v, slot_pos (L, B, ring)) with ring = window (padded when
    S < window; rolled by S % window when S > window so array index and
    slot agree)."""
    import jax.numpy as jnp

    L, B = k_last.shape[0], k_last.shape[1]
    s = k_last.shape[2]
    if S <= window:
        pad = window - s
        if pad:
            zk = jnp.zeros((*k_last.shape[:2], pad, *k_last.shape[3:]), k_last.dtype)
            k_last = jnp.concatenate([k_last, zk], axis=2)
            v_last = jnp.concatenate([v_last, zk], axis=2)
        slot_pos = jnp.concatenate(
            [jnp.arange(s, dtype=jnp.int32),
             jnp.full((pad,), -1, jnp.int32)]
        )
    else:
        shift = S % window
        k_last = jnp.roll(k_last, shift, axis=2)
        v_last = jnp.roll(v_last, shift, axis=2)
        kept = jnp.arange(S - window, S, dtype=jnp.int32)
        slot_pos = jnp.zeros((window,), jnp.int32).at[kept % window].set(kept)
    return k_last, v_last, jnp.broadcast_to(slot_pos, (L, B, window))


def write_slot(pos, size, window: int):
    """Cache slot index for absolute position(s) `pos`.
    Full cache: slot = pos (clamped). Ring buffer: slot = pos % size."""
    return jnp.where(window > 0, pos % size, jnp.minimum(pos, size - 1))


def row_write(cache, new, slot):
    """Per-row slot write: cache (B, S, ...), new (B, 1, ...), slot (B,).
    Each batch row writes its own slot (lowered as a batched scatter)."""
    return jax.vmap(
        lambda c, n, s: jax.lax.dynamic_update_slice_in_dim(
            c, n.astype(c.dtype), s, axis=0
        )
    )(cache, new, slot)


def cache_write(k_cache, v_cache, slot_pos, k_new, v_new, pos, window: int):
    """Write one token's k/v (B, 1, NKV, H) at per-row absolute positions
    `pos` (B,) — each batch row advances independently (per-slot decode).

    Full cache: slot = pos. Ring buffer: slot = pos % size. slot_pos is
    (B, S). Returns updated (k_cache, v_cache, slot_pos).
    """
    size = k_cache.shape[1]
    slot = write_slot(pos, size, window)
    k_cache = row_write(k_cache, k_new, slot)
    v_cache = row_write(v_cache, v_new, slot)
    slot_pos = row_write(slot_pos, pos[:, None].astype(jnp.int32), slot)
    return k_cache, v_cache, slot_pos


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RecurrentState:
    """Griffin recurrent-block state: RG-LRU hidden + causal-conv tail.

    h: (L, B, W); conv_tail: (L, B, conv_width-1, W).
    """

    h: jax.Array
    conv_tail: jax.Array

    def tree_flatten(self):
        return (self.h, self.conv_tail), ()

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RwkvState:
    """RWKV-6 per-layer state: wkv (L, B, H, K, V) + token-shift tails
    (L, B, d) for time-mix and channel-mix."""

    wkv: jax.Array
    tm_shift: jax.Array
    cm_shift: jax.Array

    def tree_flatten(self):
        return (self.wkv, self.tm_shift, self.cm_shift), ()

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DecodeCache:
    """Top-level decode carry: whichever sub-states the family uses, plus
    per-slot position counters.

    pos: (B,) int32 — the absolute position each batch slot decodes at.
    Slots are independent: the continuous-batching scheduler holds requests
    at different depths in one cache and one compiled decode signature."""

    pos: jax.Array
    kv: Optional[KVCache] = None
    rec: Optional[RecurrentState] = None
    rwkv: Optional[RwkvState] = None

    def tree_flatten(self):
        return (self.pos, self.kv, self.rec, self.rwkv), ()

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


# --------------------------------------------------------------------------
# Slot scatter: admit one prefilled request into a batch cache row
# --------------------------------------------------------------------------


def _write_row(big, small, slot):
    """Overwrite batch row `slot` of `big` (batch axis 1) with `small`
    (batch axis 1 of size 1)."""
    start = (0, slot) + (0,) * (big.ndim - 2)
    return jax.lax.dynamic_update_slice(big, small.astype(big.dtype), start)


def _pad_seq(x, size: int, fill):
    """Pad the cache-slot axis (axis 2) of a solo-prefill leaf up to the
    batch cache's fixed size."""
    s = x.shape[2]
    if s == size:
        return x
    if s > size:
        raise ValueError(
            f"prefilled cache ({s} slots) exceeds batch cache capacity "
            f"({size}); raise the scheduler's max_ctx"
        )
    pad = jnp.full((*x.shape[:2], size - s, *x.shape[3:]), fill, x.dtype)
    return jnp.concatenate([x, pad], axis=2)


def _scatter_kv(big: KVCache, small: KVCache, slot) -> KVCache:
    size = big.k.shape[2]
    k = _write_row(big.k, _pad_seq(small.k, size, 0), slot)
    v = _write_row(big.v, _pad_seq(small.v, size, 0), slot)
    sp = _write_row(big.slot_pos, _pad_seq(small.slot_pos, size, -1), slot)
    length = jax.lax.dynamic_update_slice(
        big.length, small.length.astype(big.length.dtype), (slot,)
    )
    ks = vs = None
    if big.quantized:
        ks = _write_row(big.k_scale, _pad_seq(small.k_scale, size, 0.0), slot)
        vs = _write_row(big.v_scale, _pad_seq(small.v_scale, size, 0.0), slot)
    return KVCache(k=k, v=v, slot_pos=sp, length=length,
                   k_scale=ks, v_scale=vs, window=big.window)


def scatter_into_slot(batch: DecodeCache, solo: DecodeCache, slot) -> DecodeCache:
    """Admit a solo-prefilled request (batch axis of size 1) into batch
    row `slot` of a live decode cache. Only row `slot` changes — every
    other slot's KV / recurrent / RWKV state and position is untouched,
    which is what makes mid-decode admission safe.

    `slot` may be a traced scalar: one compiled scatter serves all slots
    (per solo-prefill length)."""
    slot = jnp.asarray(slot, jnp.int32)
    pos = jax.lax.dynamic_update_slice(
        batch.pos, solo.pos.astype(batch.pos.dtype), (slot,)
    )
    kv = _scatter_kv(batch.kv, solo.kv, slot) if batch.kv is not None else None
    rec = None
    if batch.rec is not None:
        rec = RecurrentState(
            h=_write_row(batch.rec.h, solo.rec.h, slot),
            conv_tail=_write_row(batch.rec.conv_tail, solo.rec.conv_tail, slot),
        )
    rwkv = None
    if batch.rwkv is not None:
        rwkv = RwkvState(
            wkv=_write_row(batch.rwkv.wkv, solo.rwkv.wkv, slot),
            tm_shift=_write_row(batch.rwkv.tm_shift, solo.rwkv.tm_shift, slot),
            cm_shift=_write_row(batch.rwkv.cm_shift, solo.rwkv.cm_shift, slot),
        )
    return DecodeCache(pos=pos, kv=kv, rec=rec, rwkv=rwkv)
