"""Decoder / encoder / VLM transformer (dense and MoE) with scan-over-layers,
remat, GQA attention and the M4BRAM QuantizedLinear at every projection.

One implementation serves six assigned archs:
  dense   : nemotron-4-15b/340b, olmo-1b, stablelm-12b
  vlm     : paligemma-3b (stub patch frontend, prefix-LM masking)
  encoder : hubert-xlarge (bidirectional, frame-stub frontend, class head)
  moe     : mixtral-8x22b (+SWA), llama4-maverick (top-1, 128 experts)
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import moe as moe_mod
from repro.models.kv_cache import (
    DecodeCache,
    KVCache,
    PagedKVCache,
    cache_write,
    full_slot_pos,
    paged_cache_write,
    paged_gather,
)
from repro.parallel.sharding import constrain


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# Free cache slots appended after prefill for subsequently decoded tokens
# (serving engines re-prefill/rebatch past this; the dry-run decode cell
# allocates seq_len + headroom the same way via init_cache).
DECODE_HEADROOM = 8


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 8)
    d, hd = cfg.d_model, cfg.head_dim
    dt = _dtype(cfg)
    p = {
        "ln1": cm.norm_init(cfg.norm, d, dt),
        "ln2": cm.norm_init(cfg.norm, d, dt),
        "wq": cm.dense_init(ks[0], d, cfg.n_heads * hd, dt),
        "wk": cm.dense_init(ks[1], d, cfg.n_kv_heads * hd, dt),
        "wv": cm.dense_init(ks[2], d, cfg.n_kv_heads * hd, dt),
        "wo": cm.dense_init(ks[3], cfg.n_heads * hd, d, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dt)
        p["k_norm"] = jnp.zeros((hd,), dt)
    if cfg.moe_experts:
        p["moe"] = moe_mod.init_moe(ks[4], cfg)
    else:
        p["ffn"] = cm.ffn_init(ks[5], cfg, d, cfg.d_ff, dt)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, 4)
    dt = _dtype(cfg)
    layer_keys = jax.random.split(keys[0], cfg.num_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(layer_keys)
    params = {
        "embed": cm.embed_init(keys[1], cfg.vocab, cfg.d_model, dt),
        "blocks": blocks,
        "final_norm": cm.norm_init(cfg.norm, cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = cm.dense_init(keys[2], cfg.d_model, cfg.vocab, dt)
    if cfg.frontend == "patch_stub":
        params["patch_proj"] = cm.dense_init(keys[3], cfg.frontend_dim, cfg.d_model, dt)
    elif cfg.frontend == "frame_stub":
        params["frame_proj"] = cm.dense_init(keys[3], cfg.frontend_dim, cfg.d_model, dt)
    return params


# --------------------------------------------------------------------------
# Block apply (shared across train / prefill / decode)
# --------------------------------------------------------------------------


def _attention_qkv(p, cfg: ModelConfig, x, positions):
    q_cfg, qm = cfg.quant, ("fake" if cfg.quant else "none")
    B, T, _ = x.shape
    hd = cfg.head_dim
    q = cm.linear(x, p["wq"], q_cfg, qm).reshape(B, T, cfg.n_heads, hd)
    k = cm.linear(x, p["wk"], q_cfg, qm).reshape(B, T, cfg.n_kv_heads, hd)
    v = cm.linear(x, p["wv"], q_cfg, qm).reshape(B, T, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = cm.rms_head_norm(q, p["q_norm"])
        k = cm.rms_head_norm(k, p["k_norm"])
    q = cm.rope(q, positions, cfg.rope_theta)
    k = cm.rope(k, positions, cfg.rope_theta)
    from repro.parallel.sharding import axis_size

    if cfg.attn_shard == "heads" and cfg.n_heads % max(axis_size("model"), 1) == 0:
        # TP attention: heads sharded over `model`.
        q = constrain(q, "batch", None, "model", None)
        k = constrain(k, "batch", None, "model", None)
        v = constrain(v, "batch", None, "model", None)
    else:
        # Sequence-parallel attention (heads don't divide the model axis,
        # e.g. llama4's 40 or paligemma's 8 heads on a 16-way mesh): shard
        # the query sequence dim instead — scores/softmax stay 16-way
        # sharded, k/v are gathered once per layer. See EXPERIMENTS §Perf B.
        q = constrain(q, "batch", "model", None, None)
        k = constrain(k, "batch", None, None, None)
        v = constrain(v, "batch", None, None, None)
    return q, k, v


def _kv_attn_view(k, v, kv_quant_attn: bool):
    """The K/V values attention actually reads. For an int8 KV cache the
    prefill reads its own K/V *through the quantizer* (quantize →
    dequantize), so attending over codes later gathered from the cache —
    the cross-request prefix-cache admission path — is bit-identical to
    attending over the in-flight prefill K/V: both sides see exactly
    `dequantize_kv(quantize_kv(kv))`, and quantization is deterministic
    per (token, head). Without kv_cache_quant this is the identity."""
    if not kv_quant_attn:
        return k, v
    from repro.models.kv_cache import dequantize_kv, quantize_kv

    return dequantize_kv(*quantize_kv(k)), dequantize_kv(*quantize_kv(v))


def block_apply(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    mask: cm.AttnMask,
    kv_quant_attn: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Full-sequence block (train / prefill). Returns (x, k, v, aux_loss).

    `kv_quant_attn` (prefill with an int8 KV cache only) makes attention
    read K/V through the quantizer — see `_kv_attn_view`; the returned
    k/v stay unquantized (the cache quantizes them once, at the end of
    prefill, with the same deterministic `quantize_kv`)."""
    h = cm.apply_norm(x, p["ln1"], cfg.norm)
    q, k, v = _attention_qkv(p, cfg, h, positions)
    k_att, v_att = _kv_attn_view(k, v, kv_quant_attn)
    attn = cm.chunked_attention(
        q, k_att, v_att, mask, softcap=cfg.attn_logit_softcap,
        q_chunk=min(cfg.attn_q_chunk, q.shape[1]),
        kv_chunk=min(cfg.attn_kv_chunk, k.shape[1]),
    )
    x, aux = _block_post_attn_seq(p, cfg, x, attn)
    return x, k, v, aux


def _block_post_attn_seq(p: dict, cfg: ModelConfig, x, attn):
    """Full-sequence post-attention tail (output projection + FFN/MoE
    residual), shared by `block_apply` and `prefill_suffix` — one copy so
    the warm (suffix) path can never drift from the cold path. Returns
    (x, aux_loss)."""
    attn = attn.reshape(*x.shape[:2], cfg.n_heads * cfg.head_dim)
    x = x + cm.linear(attn, p["wo"], cfg.quant, "fake" if cfg.quant else "none")
    h2 = cm.apply_norm(x, p["ln2"], cfg.norm)
    if cfg.moe_experts:
        y, aux = moe_mod.moe_apply_shardmap(p["moe"], h2, cfg)
    else:
        y, aux = cm.ffn_apply(p["ffn"], h2, cfg), jnp.zeros((), jnp.float32)
    x = x + y
    x = constrain(x, "batch", None, None)
    return x, aux


def block_decode(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,            # (B, 1, d)
    pos: jax.Array,          # (B,) per-slot positions
    k_cache, v_cache, slot_pos, k_scale=None, v_scale=None,
):
    """Single-token block against a (ring) cache. Every batch slot decodes
    at its own position. Returns x + new cache."""
    h = cm.apply_norm(x, p["ln1"], cfg.norm)
    positions = pos[:, None]                      # (B, 1)
    q, k, v = _attention_qkv(p, cfg, h, positions)
    if cfg.kv_cache_quant:
        from repro.models.kv_cache import quantize_kv, row_write, write_slot

        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        k_cache, v_cache, slot_pos = cache_write(
            k_cache, v_cache, slot_pos, kq, vq, pos, cfg.attn_window
        )
        slot = write_slot(pos, k_cache.shape[1], cfg.attn_window)
        k_scale = row_write(k_scale, ks, slot)
        v_scale = row_write(v_scale, vs, slot)
    else:
        k_cache, v_cache, slot_pos = cache_write(
            k_cache, v_cache, slot_pos, k, v, pos, cfg.attn_window
        )
        k_scale = v_scale = None  # ignore dummy scan placeholders
    attn = cm.decode_attention(
        q, k_cache, v_cache, slot_pos, pos,
        window=cfg.attn_window, softcap=cfg.attn_logit_softcap,
        k_scale=k_scale, v_scale=v_scale,
    )
    x = _block_post_attn(p, cfg, x, attn)
    return x, k_cache, v_cache, slot_pos, k_scale, v_scale


def _block_post_attn(p: dict, cfg: ModelConfig, x, attn):
    """Shared decode tail: output projection + FFN/MoE residual."""
    attn = attn.reshape(x.shape[0], 1, cfg.n_heads * cfg.head_dim)
    x = x + cm.linear(attn, p["wo"], cfg.quant, "fake" if cfg.quant else "none")
    h2 = cm.apply_norm(x, p["ln2"], cfg.norm)
    if cfg.moe_experts:
        y, _ = moe_mod.moe_apply_shardmap(p["moe"], h2, cfg)
    else:
        y = cm.ffn_apply(p["ffn"], h2, cfg)
    return x + y


def block_decode_paged(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,            # (B, 1, d)
    pos: jax.Array,          # (B,) per-slot positions
    pool_k, pool_v,          # (num_blocks, block_size, NKV, H)
    block_table,             # (B, max_blocks)
    block_size: int,
    k_scale=None, v_scale=None,  # (num_blocks, bs, NKV, 1) int8-pool planes
    fused: bool = True,
    gather_blocks: Optional[int] = None,
):
    """Single-token block against one layer's slice of the paged pool:
    scatter the new k/v into pos's (block, offset) — quantizing on the way
    in when the pool is int8 — then attend through the fused
    `ops.paged_attention` kernel, which resolves the block table inside
    the kernel and never materializes a contiguous copy of the pool.

    `fused=False` keeps the original gather-then-attend composition
    (`paged_gather` → `decode_attention`, value/position layout identical
    to the contiguous cache) as the reference path for bit-exactness
    tests; `gather_blocks` clamps its gather to a host-known live-block
    bound."""
    h = cm.apply_norm(x, p["ln1"], cfg.norm)
    q, k, v = _attention_qkv(p, cfg, h, pos[:, None])
    pool_k, pool_v, k_scale, v_scale = paged_cache_write(
        pool_k, pool_v, block_table, k, v, pos, block_size,
        k_scale=k_scale, v_scale=v_scale,
    )
    if fused:
        from repro.kernels import ops

        attn = ops.paged_attention(
            q, pool_k, pool_v, block_table, pos,
            k_scale=k_scale, v_scale=v_scale,
            softcap=cfg.attn_logit_softcap,
        )
    else:
        k_rows, v_rows, kpos, ks_rows, vs_rows = paged_gather(
            pool_k, pool_v, block_table, k_scale, v_scale,
            max_blocks=gather_blocks,
        )
        attn = cm.decode_attention(
            q, k_rows, v_rows, kpos, pos, softcap=cfg.attn_logit_softcap,
            k_scale=ks_rows, v_scale=vs_rows,
        )
    return _block_post_attn(p, cfg, x, attn), pool_k, pool_v, k_scale, v_scale


# --------------------------------------------------------------------------
# Embedding / inputs
# --------------------------------------------------------------------------


def _embed_scale(cfg: ModelConfig) -> bool:
    """Whether token embeddings are scaled by sqrt(d_model) at lookup —
    one rule for every path (train/prefill/suffix-prefill/decode); the
    warm ≡ cold bit-identity contract depends on these agreeing."""
    return cfg.family in ("vlm",) or cfg.name.startswith("recurrentgemma")


def embed_inputs(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, jax.Array]:
    """Returns (x (B, T, d), positions (B, T)) handling frontend stubs."""
    scale = _embed_scale(cfg)
    if cfg.frontend == "patch_stub":
        patches = batch["patches"].astype(_dtype(cfg))  # (B, P, frontend_dim)
        pe = cm.linear(patches, params["patch_proj"])
        te = cm.embed_lookup(params["embed"], batch["tokens"], scale=scale)
        x = jnp.concatenate([pe, te], axis=1)
    elif cfg.frontend == "frame_stub":
        frames = batch["frames"].astype(_dtype(cfg))    # (B, T, frontend_dim)
        x = cm.linear(frames, params["frame_proj"])
    else:
        x = cm.embed_lookup(params["embed"], batch["tokens"], scale=scale)
    B, T = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    x = constrain(x, "batch", None, None)
    return x, positions


def _mask_for(cfg: ModelConfig) -> cm.AttnMask:
    return cm.AttnMask(
        causal=cfg.causal,
        window=cfg.attn_window,
        prefix_len=cfg.num_prefix_embeds if cfg.family == "vlm" else 0,
    )


# --------------------------------------------------------------------------
# Forward passes
# --------------------------------------------------------------------------


def _scan_blocks(params, cfg, x, positions, mask, collect_kv: bool,
                 kv_quant_attn: bool = False):
    def body(carry, block_p):
        xc, aux = carry
        xn, k, v, a = block_apply(block_p, cfg, xc, positions, mask,
                                  kv_quant_attn)
        out = (k, v) if collect_kv else None
        return (xn, aux + a), out

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        (x, aux), kv = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                    params["blocks"])
    else:
        aux = jnp.zeros((), jnp.float32)
        kvs = []
        L = jax.tree_util.tree_leaves(params["blocks"])[0].shape[0]
        for i in range(L):
            block_p = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
            (x, aux), out = body_fn((x, aux), block_p)
            kvs.append(out)
        kv = (
            tuple(jnp.stack([o[j] for o in kvs]) for j in range(2))
            if collect_kv else None
        )
    return x, aux, kv


def forward_hidden(params, cfg: ModelConfig, batch):
    x, positions = embed_inputs(params, cfg, batch)
    x, aux, _ = _scan_blocks(params, cfg, x, positions, _mask_for(cfg), False)
    return cm.apply_norm(x, params["final_norm"], cfg.norm), aux


def compute_logits(params, cfg: ModelConfig, hidden):
    if cfg.tie_embeddings:
        logits = cm.logits_head(hidden, params["embed"],
                                softcap=cfg.logits_softcap, transpose=True)
    else:
        logits = cm.logits_head(hidden, params["head"], softcap=cfg.logits_softcap)
    return constrain(logits, "batch", None, "model")


def train_loss(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, dict]:
    hidden, aux = forward_hidden(params, cfg, batch)
    logits = compute_logits(params, cfg, hidden)
    if cfg.family == "encoder":
        labels = batch["labels"]
        loss = cm.cross_entropy(logits, labels).mean()
    elif cfg.family == "vlm":
        P = cfg.num_prefix_embeds
        text_logits = logits[:, P:-1]
        labels = batch["tokens"][:, 1:]
        loss = cm.cross_entropy(text_logits, labels).mean()
    else:
        loss = cm.cross_entropy(logits[:, :-1], batch["tokens"][:, 1:]).mean()
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux}


# --------------------------------------------------------------------------
# Serving: prefill + decode
# --------------------------------------------------------------------------


def prefill(params, cfg: ModelConfig, batch) -> Tuple[DecodeCache, jax.Array]:
    """Full-sequence forward; returns a DecodeCache and last-token logits.

    ``batch["lengths"]`` (B,) marks right-padded serving prompts: row b's
    real tokens sit at positions 0..lengths[b]-1 and trailing pad slots are
    excluded from the cache (slot_pos = -1) and from the returned logits,
    so a prompt bucketed up to any length prefills bit-identically to an
    exact-length prefill (causal attention never looks at trailing pads)."""
    x, positions = embed_inputs(params, cfg, batch)
    B, S = x.shape[:2]
    lengths = batch.get("lengths")
    if lengths is not None:
        lengths = jnp.asarray(lengths, jnp.int32)
    x, _, kv = _scan_blocks(params, cfg, x, positions, _mask_for(cfg), True,
                            kv_quant_attn=cfg.kv_cache_quant)
    k_all, v_all = kv  # (L, B, S, NKV, H)
    w = cfg.attn_window
    if w:
        from repro.models.kv_cache import ring_align

        k_all, v_all, slot_pos = ring_align(k_all, v_all, lengths, w)
    else:
        # Full cache: leave headroom slots for tokens decoded next.
        pad = DECODE_HEADROOM
        zk = jnp.zeros((*k_all.shape[:2], pad, *k_all.shape[3:]), k_all.dtype)
        k_all = jnp.concatenate([k_all, zk], axis=2)
        v_all = jnp.concatenate([v_all, zk], axis=2)
        slot_pos = full_slot_pos(cfg.num_layers, B, S + pad,
                                 jnp.full((B,), S, jnp.int32)
                                 if lengths is None else lengths)
    if cfg.kv_cache_quant:
        from repro.models.kv_cache import quantize_kv

        k_all, k_scale = quantize_kv(k_all)
        v_all, v_scale = quantize_kv(v_all)
    else:
        k_all = k_all.astype(_dtype(cfg))
        v_all = v_all.astype(_dtype(cfg))
        k_scale = v_scale = None
    length = jnp.full((B,), S, jnp.int32) if lengths is None else lengths
    kvc = KVCache(
        k=k_all,
        v=v_all,
        slot_pos=slot_pos,
        length=length,
        k_scale=k_scale,
        v_scale=v_scale,
        window=w,
    )
    hidden = cm.apply_norm(cm.last_token_slice(x, lengths),
                           params["final_norm"], cfg.norm)
    logits = compute_logits(params, cfg, hidden)
    return DecodeCache(pos=length, kv=kvc), logits


def prefill_suffix(params, cfg: ModelConfig, batch):
    """Prefill only the *uncached tail* of a prompt against prefix K/V
    already resident in the paged block pool — the compute half of the
    cross-request prefix cache (the memory half is block sharing in the
    scheduler's allocator). Each layer gathers its prefix K/V straight
    from the pool blocks, the suffix computes q/k/v at its true absolute
    positions, and attention runs over ``[prefix KV ++ suffix KV]`` with
    explicit key positions — per-row math identical to the cold full
    prefill, so a prefix-hit request's tokens are bit-identical to a cold
    request's (int8 pools included: both sides read K/V through
    `dequantize_kv`/`quantize_kv`, see `_kv_attn_view`).

    ``batch`` keys:
      tokens (1, Ls)        right-padded suffix token ids
      lengths (1,)          real suffix length
      start ()              absolute position of the first suffix token ==
                            number of prefix positions resident in the pool
      pool_k / pool_v       (L, num_blocks, bs, NKV, H) pool planes
      prefix_blocks (mb,)   the row's pool blocks covering positions
                            [0, start) in virtual-block order; -1 entries
                            gather the trash block and are masked out
      pool_k_scale / pool_v_scale   int8-pool scale planes (quantized only)

    Returns ``(DecodeCache, logits)``; the solo cache holds ONLY the
    suffix: cache slot ``t`` ↔ absolute position ``start + t`` (see
    `kv_cache.scatter_suffix_into_paged`), and ``pos``/``length`` carry
    the full row length ``start + lengths``."""
    if cfg.attn_window:
        raise ValueError("prefix caching requires a full-attention cache")
    tokens = batch["tokens"]
    B, Ls = tokens.shape
    lengths = jnp.asarray(batch["lengths"], jnp.int32)
    start = jnp.asarray(batch["start"], jnp.int32)
    pool_k, pool_v = batch["pool_k"], batch["pool_v"]
    blocks = jnp.asarray(batch["prefix_blocks"], jnp.int32)
    L = cfg.num_layers
    bs = pool_k.shape[2]
    P = blocks.shape[0] * bs
    quant = cfg.kv_cache_quant

    from repro.models.kv_cache import dequantize_kv, quantize_kv

    tbl = jnp.maximum(blocks, 0)
    pk = pool_k[:, tbl].reshape(L, P, *pool_k.shape[3:])
    pv = pool_v[:, tbl].reshape(L, P, *pool_v.shape[3:])
    if quant:
        ksc = batch["pool_k_scale"][:, tbl].reshape(L, P, cfg.n_kv_heads, 1)
        vsc = batch["pool_v_scale"][:, tbl].reshape(L, P, cfg.n_kv_heads, 1)
        pk = dequantize_kv(pk, ksc)
        pv = dequantize_kv(pv, vsc)

    ppos = jnp.arange(P, dtype=jnp.int32)
    prefix_kpos = jnp.where(ppos < start, ppos, -1)
    spos = start + jnp.arange(Ls, dtype=jnp.int32)
    suffix_kpos = jnp.where(jnp.arange(Ls) < lengths[0], spos, -1)
    kpos_cat = jnp.concatenate([prefix_kpos, suffix_kpos])
    positions = jnp.broadcast_to(spos[None], (B, Ls))
    mask = cm.AttnMask(causal=cfg.causal)

    x = cm.embed_lookup(params["embed"], tokens, scale=_embed_scale(cfg))
    x = constrain(x, "batch", None, None)

    def body(xc, layer_in):
        block_p, pk_l, pv_l = layer_in
        h = cm.apply_norm(xc, block_p["ln1"], cfg.norm)
        q, k, v = _attention_qkv(block_p, cfg, h, positions)
        k_att, v_att = _kv_attn_view(k, v, quant)
        k_cat = jnp.concatenate([pk_l[None].astype(k_att.dtype), k_att], axis=1)
        v_cat = jnp.concatenate([pv_l[None].astype(v_att.dtype), v_att], axis=1)
        attn = cm.chunked_attention(
            q, k_cat, v_cat, mask, q_offset=start, kpos=kpos_cat,
            softcap=cfg.attn_logit_softcap,
            q_chunk=min(cfg.attn_q_chunk, Ls),
            kv_chunk=min(cfg.attn_kv_chunk, P + Ls),
        )
        xn, _ = _block_post_attn_seq(block_p, cfg, xc, attn)
        return xn, (k, v)

    x, (k_all, v_all) = jax.lax.scan(body, x, (params["blocks"], pk, pv))
    if quant:
        k_all, k_scale = quantize_kv(k_all)
        v_all, v_scale = quantize_kv(v_all)
    else:
        k_all = k_all.astype(_dtype(cfg))
        v_all = v_all.astype(_dtype(cfg))
        k_scale = v_scale = None
    total = start + lengths
    kvc = KVCache(
        k=k_all, v=v_all,
        slot_pos=jnp.broadcast_to(suffix_kpos[None, None], (L, B, Ls)),
        length=total, k_scale=k_scale, v_scale=v_scale, window=0,
    )
    hidden = cm.apply_norm(cm.last_token_slice(x, lengths),
                           params["final_norm"], cfg.norm)
    logits = compute_logits(params, cfg, hidden)
    return DecodeCache(pos=total, kv=kvc), logits


def prefill_chunk(params, cfg: ModelConfig, cache: DecodeCache, batch,
                  all_logits: bool = False):
    """Prefill one token *chunk* of a single row's prompt directly against
    the shared paged pool — the decode-path model method behind
    Sarathi-style chunked prefill. Each layer runs the fused
    ``ops.paged_prefill`` kernel: the chunk attends causally over
    ``[pool-resident prefix ++ chunk]`` with prefix blocks streamed
    through the row's block table, and the chunk's K/V lands in its
    destination pool blocks from the kernel epilogue (quantize-on-write
    for int8 pools). No contiguous prefix copy is ever materialized and
    no post-prefill scatter runs — admission becomes a sequence of these
    calls, interleaved with decode steps by the scheduler.

    ``batch`` keys:
      tokens (1, Lc)   right-padded chunk token ids
      lengths (1,)     real chunk length (<= Lc)
      start ()         absolute position of the chunk's first token; the
                       positions [0, start) are already pool-resident —
                       either a shared warm prefix or this row's earlier
                       chunks (byte-identical by the quantize-on-write
                       contract, so the kernel can't tell them apart)
      slot ()          the row's batch slot in `cache`
      blocks (nbp,)    the row's pool blocks covering positions
                       [0, start + lengths[0]) in virtual-block order;
                       -1 entries are dead (trash-block remapped)

    Returns ``(cache, logits (1, 1, V))`` — the pool planes updated in
    place, ``cache.pos``/``kv.length`` advanced to ``start + lengths[0]``
    at ``slot``, and logits for the chunk's last real token (only the
    final chunk's logits are meaningful: they sample the first output
    token). Chunk boundaries never change the math — attention depends
    only on absolute positions and pool bytes — so any chunk split of a
    prompt is bit-identical to the whole-prompt prefill.

    ``all_logits=True`` returns logits for every chunk position
    ``(1, Lc, V)`` instead of the last real token — the speculative-decode
    *verify* shape, where every position's argmax is compared against the
    draft (see :func:`prefill_chunk_logits`). Positions past ``lengths[0]``
    are padding; their logits are meaningless and must be ignored."""
    if cfg.attn_window:
        raise ValueError("chunked prefill requires a full-attention "
                         f"paged cache (attn_window={cfg.attn_window})")
    from repro.kernels import ops

    tokens = batch["tokens"]
    B, Lc = tokens.shape
    lengths = jnp.asarray(batch["lengths"], jnp.int32)
    start = jnp.asarray(batch["start"], jnp.int32)
    slot = jnp.asarray(batch["slot"], jnp.int32)
    blocks = jnp.asarray(batch["blocks"], jnp.int32)
    kv: PagedKVCache = cache.kv
    quant = kv.quantized
    L = cfg.num_layers
    length = lengths[0]

    spos = start + jnp.arange(Lc, dtype=jnp.int32)
    positions = jnp.broadcast_to(spos[None], (B, Lc))
    x = cm.embed_lookup(params["embed"], tokens, scale=_embed_scale(cfg))
    x = constrain(x, "batch", None, None)

    def body(xc, layer_in):
        block_p, pk, pv, ks, vs = layer_in
        h = cm.apply_norm(xc, block_p["ln1"], cfg.norm)
        q, k, v = _attention_qkv(block_p, cfg, h, positions)
        attn, pk, pv, ks_new, vs_new = ops.paged_prefill(
            q, k, v, pk, pv, blocks, start, length,
            k_scale=ks if quant else None,
            v_scale=vs if quant else None,
            softcap=cfg.attn_logit_softcap,
        )
        xn, _ = _block_post_attn_seq(block_p, cfg, xc, attn)
        if quant:
            ks, vs = ks_new, vs_new
        return xn, (pk, pv, ks, vs)

    ks_in = kv.k_scale if quant else jnp.zeros((L, 0))
    vs_in = kv.v_scale if quant else jnp.zeros((L, 0))
    x, (k_new, v_new, ks_new, vs_new) = jax.lax.scan(
        body, x, (params["blocks"], kv.k, kv.v, ks_in, vs_in)
    )
    hidden = (cm.apply_norm(x, params["final_norm"], cfg.norm)
              if all_logits else
              cm.apply_norm(cm.last_token_slice(x, lengths),
                            params["final_norm"], cfg.norm))
    logits = compute_logits(params, cfg, hidden)
    total = start + length
    new_cache = DecodeCache(
        pos=cache.pos.at[slot].set(total),
        kv=PagedKVCache(k=k_new, v=v_new, block_table=kv.block_table,
                        length=kv.length.at[slot].set(total),
                        k_scale=ks_new if quant else None,
                        v_scale=vs_new if quant else None,
                        block_size=kv.block_size),
    )
    return new_cache, logits


def prefill_chunk_logits(params, cfg: ModelConfig, cache: DecodeCache, batch):
    """Speculative-decode verify step: :func:`prefill_chunk` returning
    logits for *every* chunk position ``(1, Lc, V)``.

    The verify call is shaped exactly like a prefill chunk over
    ``[current token, draft tokens]``: each position attends over the
    row's pool-resident history plus the earlier chunk positions, and the
    chunk K/V (recomputed at the *full* policy) overwrites the draft's
    speculative pool writes — K/V projections are per-token functions of
    (embedding, rope position), so the verified pool bytes are identical
    to what plain decode would have written. Position i's argmax is the
    token greedy decode would emit after accepting the first i chunk
    tokens, which is what the acceptance rule compares against."""
    return prefill_chunk(params, cfg, cache, batch, all_logits=True)


def prefill_chunk_logits_multi(params, cfg: ModelConfig, cache: DecodeCache,
                               batch):
    """Batched speculative-verify: R independent chunk rows through ONE
    call — :func:`prefill_chunk_logits` per row, stacked. The scheduler
    verifies a whole tier group's speculation windows in one dispatch
    instead of one call per slot (fixed ``R = max_batch`` rows keeps one
    compiled signature per bucketed block count, exactly like the decode
    step's fixed batch).

    ``batch`` keys (all leading-R where the single-row call is scalar):
      tokens (R, Lc)    right-padded chunk token ids per row
      lengths (R,)      real chunk length per row (0 for dead rows)
      starts (R,)       absolute position of each row's first chunk token
      slots (R,)        each row's batch slot in `cache`; -1 marks a DEAD
                        row (slot not verifying this call)
      blocks (R, nbp)   each row's pool blocks; dead rows pass all -1

    Dead rows are inert by construction: an all--1 block table routes
    their K/V writes to the trash block (``paged_chunk_write`` remaps
    invalid positions to block 0) and masks every attention key (the
    kernel's online softmax over fully-masked blocks is a guarded no-op,
    the reference zeroes the probabilities exactly), their ``pos``/
    ``length`` entries are untouched (`slots` < 0 gates the update), and
    their logits rows are garbage the caller ignores.

    Rows are computed by an outer ``lax.scan`` carrying the pool planes:
    each row attends only through its own block table (its own blocks
    plus read-only shared prefix blocks) and writes only its own
    destination blocks, so row order cannot change any row's math — each
    row's logits are bitwise what its solo :func:`prefill_chunk_logits`
    call would return. Returns ``(cache, logits (R, Lc, V))``."""
    if cfg.attn_window:
        raise ValueError("chunked prefill requires a full-attention "
                         f"paged cache (attn_window={cfg.attn_window})")
    from repro.kernels import ops

    tokens = batch["tokens"]
    R, Lc = tokens.shape
    lengths = jnp.asarray(batch["lengths"], jnp.int32)
    starts = jnp.asarray(batch["starts"], jnp.int32)
    slots = jnp.asarray(batch["slots"], jnp.int32)
    blocks = jnp.asarray(batch["blocks"], jnp.int32)
    kv: PagedKVCache = cache.kv
    quant = kv.quantized
    L = cfg.num_layers

    def row(carry, row_in):
        pk_all, pv_all, ks_all, vs_all, pos, lng = carry
        toks_r, len_r, start_r, slot_r, blocks_r = row_in
        positions = (start_r + jnp.arange(Lc, dtype=jnp.int32))[None]
        x = cm.embed_lookup(params["embed"], toks_r[None],
                            scale=_embed_scale(cfg))
        x = constrain(x, "batch", None, None)

        def body(xc, layer_in):
            block_p, pk, pv, ks, vs = layer_in
            h = cm.apply_norm(xc, block_p["ln1"], cfg.norm)
            q, k, v = _attention_qkv(block_p, cfg, h, positions)
            attn, pk, pv, ks_new, vs_new = ops.paged_prefill(
                q, k, v, pk, pv, blocks_r, start_r, len_r,
                k_scale=ks if quant else None,
                v_scale=vs if quant else None,
                softcap=cfg.attn_logit_softcap,
            )
            xn, _ = _block_post_attn_seq(block_p, cfg, xc, attn)
            if quant:
                ks, vs = ks_new, vs_new
            return xn, (pk, pv, ks, vs)

        x, (pk_all, pv_all, ks_all, vs_all) = jax.lax.scan(
            body, x, (params["blocks"], pk_all, pv_all, ks_all, vs_all)
        )
        hidden = cm.apply_norm(x, params["final_norm"], cfg.norm)
        logits = compute_logits(params, cfg, hidden)
        total = start_r + len_r
        sc = jnp.maximum(slot_r, 0)      # .at[-1] would wrap — clamp + gate
        live = slot_r >= 0
        pos = pos.at[sc].set(jnp.where(live, total, pos[sc]))
        lng = lng.at[sc].set(jnp.where(live, total, lng[sc]))
        return (pk_all, pv_all, ks_all, vs_all, pos, lng), logits[0]

    ks_in = kv.k_scale if quant else jnp.zeros((L, 0))
    vs_in = kv.v_scale if quant else jnp.zeros((L, 0))
    carry = (kv.k, kv.v, ks_in, vs_in, cache.pos, kv.length)
    (k_new, v_new, ks_new, vs_new, pos, lng), logits = jax.lax.scan(
        row, carry, (tokens, lengths, starts, slots, blocks)
    )
    new_cache = DecodeCache(
        pos=pos,
        kv=PagedKVCache(k=k_new, v=v_new, block_table=kv.block_table,
                        length=lng,
                        k_scale=ks_new if quant else None,
                        v_scale=vs_new if quant else None,
                        block_size=kv.block_size),
    )
    return new_cache, logits


def decode_step(params, cfg: ModelConfig, cache: DecodeCache, tokens: jax.Array,
                paged_fused: bool = True,
                gather_blocks: Optional[int] = None):
    """tokens: (B, 1) → (new_cache, logits (B, 1, V)). cache.pos is (B,):
    each slot decodes at its own position (continuous batching). Dispatches
    on the cache flavour: contiguous KVCache or block-table PagedKVCache
    (fused paged-attention kernel by default; `paged_fused=False` runs the
    gather-then-attend reference, optionally clamped to `gather_blocks`)."""
    if isinstance(cache.kv, PagedKVCache):
        return _decode_step_paged(params, cfg, cache, tokens,
                                  fused=paged_fused,
                                  gather_blocks=gather_blocks)
    x = cm.embed_lookup(params["embed"], tokens, scale=_embed_scale(cfg))
    x = constrain(x, "batch", None, None)
    pos = cache.pos

    quant = cache.kv.quantized

    def body(xc, layer_in):
        block_p, kc, vc, sp, ks, vs = layer_in
        xn, kc, vc, sp, ks, vs = block_decode(
            block_p, cfg, xc, pos, kc, vc, sp, ks, vs
        )
        return xn, (kc, vc, sp, ks, vs)

    L = cfg.num_layers
    ks_in = cache.kv.k_scale if quant else jnp.zeros((L, 0))
    vs_in = cache.kv.v_scale if quant else jnp.zeros((L, 0))
    x, (k_new, v_new, sp_new, ks_new, vs_new) = jax.lax.scan(
        body, x,
        (params["blocks"], cache.kv.k, cache.kv.v, cache.kv.slot_pos,
         ks_in, vs_in),
    )
    hidden = cm.apply_norm(x, params["final_norm"], cfg.norm)
    logits = compute_logits(params, cfg, hidden)
    new_cache = DecodeCache(
        pos=pos + 1,
        kv=KVCache(k=k_new, v=v_new, slot_pos=sp_new,
                   length=cache.kv.length + 1,
                   k_scale=ks_new if quant else None,
                   v_scale=vs_new if quant else None,
                   window=cfg.attn_window),
    )
    return new_cache, logits


def _decode_step_paged(params, cfg: ModelConfig, cache: DecodeCache, tokens,
                       fused: bool = True,
                       gather_blocks: Optional[int] = None):
    """decode_step over the shared block pool: one compiled signature for
    any mix of slot depths and block-table layouts. `fused`/
    `gather_blocks` select the fused kernel (default) vs the clamped
    gather-then-attend reference path."""
    x = cm.embed_lookup(params["embed"], tokens, scale=_embed_scale(cfg))
    x = constrain(x, "batch", None, None)
    pos = cache.pos
    kv: PagedKVCache = cache.kv
    table = kv.block_table
    quant = kv.quantized
    L = cfg.num_layers

    def body(xc, layer_in):
        block_p, pk, pv, ks, vs = layer_in
        xn, pk, pv, ks, vs = block_decode_paged(
            block_p, cfg, xc, pos, pk, pv, table, kv.block_size,
            k_scale=ks if quant else None,
            v_scale=vs if quant else None,
            fused=fused, gather_blocks=gather_blocks,
        )
        if not quant:
            ks, vs = layer_in[3], layer_in[4]  # dummy scan placeholders
        return xn, (pk, pv, ks, vs)

    ks_in = kv.k_scale if quant else jnp.zeros((L, 0))
    vs_in = kv.v_scale if quant else jnp.zeros((L, 0))
    x, (k_new, v_new, ks_new, vs_new) = jax.lax.scan(
        body, x, (params["blocks"], kv.k, kv.v, ks_in, vs_in)
    )
    hidden = cm.apply_norm(x, params["final_norm"], cfg.norm)
    logits = compute_logits(params, cfg, hidden)
    new_cache = DecodeCache(
        pos=pos + 1,
        kv=PagedKVCache(k=k_new, v=v_new, block_table=table,
                        length=kv.length + 1,
                        k_scale=ks_new if quant else None,
                        v_scale=vs_new if quant else None,
                        block_size=kv.block_size),
    )
    return new_cache, logits


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> DecodeCache:
    """Empty cache sized for decoding after `seq_len` tokens of context."""
    kvc = KVCache.init(
        cfg.num_layers, batch, seq_len + DECODE_HEADROOM, cfg.n_kv_heads,
        cfg.head_dim, window=cfg.attn_window, dtype=_dtype(cfg),
        quantized=cfg.kv_cache_quant,
    )
    return DecodeCache(pos=jnp.full((batch,), seq_len, jnp.int32), kv=kvc)


def init_paged_cache(cfg: ModelConfig, batch: int, num_blocks: int,
                     block_size: int, max_blocks: int) -> DecodeCache:
    """Empty paged cache: `num_blocks` pool blocks (block 0 = trash) shared
    by `batch` slots of up to `max_blocks` blocks each. Full causal
    attention only — ring buffers are already window-bounded and stay
    contiguous. With cfg.kv_cache_quant the pool holds int8 codes plus
    per-(slot, head) fp32 scale planes (~2× tokens per pooled byte)."""
    if cfg.attn_window:
        raise ValueError("paged KV cache requires full attention "
                         f"(attn_window={cfg.attn_window})")
    kvc = PagedKVCache.init(
        cfg.num_layers, batch, num_blocks, block_size, max_blocks,
        cfg.n_kv_heads, cfg.head_dim, dtype=_dtype(cfg),
        quantized=cfg.kv_cache_quant,
    )
    return DecodeCache(pos=jnp.zeros((batch,), jnp.int32), kv=kvc)
