"""build_model(cfg) — family dispatch + input_specs for the dry-run.

Every architecture exposes the same surface:
  init(key)                      → params
  train_loss(params, batch)      → (loss, metrics)
  prefill(params, batch)         → (DecodeCache, logits)
  decode_step(params, cache, tok)→ (DecodeCache, logits)
  init_cache(batch, seq_len)     → DecodeCache (for decode-shape lowering)
  input_specs(shape)             → ShapeDtypeStruct pytree (no allocation)
  smoke_batch(key, shape)        → real small arrays for CPU tests
"""
from __future__ import annotations

import dataclasses
from types import SimpleNamespace
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.models import griffin, rwkv6, transformer


def _family_module(cfg: ModelConfig):
    if cfg.family == "ssm":
        return rwkv6
    if cfg.family == "hybrid":
        return griffin
    return transformer  # dense | moe | encoder | vlm


def build_model(cfg: ModelConfig) -> SimpleNamespace:
    mod = _family_module(cfg)

    def init(key):
        return mod.init_params(key, cfg)

    def train_loss(params, batch):
        return mod.train_loss(params, cfg, batch)

    def prefill(params, batch):
        return mod.prefill(params, cfg, batch)

    def decode_step(params, cache, tokens):
        return mod.decode_step(params, cfg, cache, tokens)

    def init_cache(batch, seq_len):
        return mod.init_cache(cfg, batch, seq_len)

    def input_specs(shape: ShapeSpec):
        return make_input_specs(cfg, shape)

    def smoke_batch(key, seq_len: int = 32, batch: int = 2):
        return make_smoke_batch(cfg, key, seq_len, batch)

    ns = SimpleNamespace(
        cfg=cfg, init=init, train_loss=train_loss, prefill=prefill,
        decode_step=decode_step, init_cache=init_cache,
        input_specs=input_specs, smoke_batch=smoke_batch,
    )
    if hasattr(mod, "init_paged_cache"):
        # Block-pool decode cache (full-attention transformer families).
        ns.init_paged_cache = (
            lambda batch, num_blocks, block_size, max_blocks:
            mod.init_paged_cache(cfg, batch, num_blocks, block_size,
                                 max_blocks)
        )
    if (hasattr(mod, "prefill_suffix") and not cfg.attn_window
            and not cfg.moe_experts and cfg.frontend == "none"):
        # Suffix-only prefill against pool-resident prefix K/V — the
        # compute half of the scheduler's cross-request prefix cache.
        # Only where it is bit-identical to cold prefill: full-attention
        # token-input transformers. MoE routing is capacity-bounded
        # across the whole token batch (not per-row reproducible), and
        # frontend/prefix-LM archs need masks prefill_suffix doesn't
        # build — so those archs simply don't advertise the capability.
        ns.prefill_suffix = (
            lambda params, batch: mod.prefill_suffix(params, cfg, batch)
        )
    if (hasattr(mod, "prefill_chunk") and not cfg.attn_window
            and not cfg.moe_experts and cfg.frontend == "none"):
        # Chunked prefill straight into the paged pool (fused
        # attend + epilogue-write kernel) — same eligibility gate as
        # prefill_suffix: the bit-identity contract needs full attention,
        # per-row-reproducible routing, and token inputs.
        ns.prefill_chunk = (
            lambda params, cache, batch:
            mod.prefill_chunk(params, cfg, cache, batch)
        )
        # All-position logits variant of the chunk call — the verify step
        # of self-speculative decoding. Same eligibility: the draft/verify
        # bit-identity argument leans on the chunked ≡ whole-prompt
        # contract the chunk kernel already guarantees.
        ns.prefill_chunk_logits = (
            lambda params, cache, batch:
            mod.prefill_chunk_logits(params, cfg, cache, batch)
        )
        # Multi-row verify: a whole tier group's speculation windows in
        # one dispatch (R = max_batch rows, dead rows masked by slot -1 /
        # all--1 block tables). Same eligibility gate, same math per row.
        ns.prefill_chunk_logits_multi = (
            lambda params, cache, batch:
            mod.prefill_chunk_logits_multi(params, cfg, cache, batch)
        )
    return ns


def make_input_specs(cfg: ModelConfig, shape: ShapeSpec):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    i32 = jnp.int32
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.frontend == "frame_stub":
        batch = {"frames": jax.ShapeDtypeStruct((B, S, cfg.frontend_dim), dt)}
        if shape.kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return batch
    if cfg.frontend == "patch_stub":
        P = cfg.num_prefix_embeds
        return {
            "patches": jax.ShapeDtypeStruct((B, P, cfg.frontend_dim), dt),
            "tokens": jax.ShapeDtypeStruct((B, S - P), i32),
        }
    return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}


def make_smoke_batch(cfg: ModelConfig, key, seq_len: int, batch: int):
    """Real random arrays matching input_specs at reduced scale."""
    k1, k2, k3 = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    if cfg.frontend == "frame_stub":
        return {
            "frames": jax.random.normal(k1, (batch, seq_len, cfg.frontend_dim), dt),
            "labels": jax.random.randint(k2, (batch, seq_len), 0, cfg.vocab),
        }
    if cfg.frontend == "patch_stub":
        P = cfg.num_prefix_embeds
        return {
            "patches": jax.random.normal(k1, (batch, P, cfg.frontend_dim), dt),
            "tokens": jax.random.randint(k2, (batch, seq_len - P), 0, cfg.vocab),
        }
    return {"tokens": jax.random.randint(k1, (batch, seq_len), 0, cfg.vocab)}
