"""Shared model components: norms, rotary embeddings, GQA attention
(full / sliding-window / prefix-LM / bidirectional; train+prefill+decode),
FFN variants, and the quantization-aware linear used everywhere.

Attention is implemented as a *chunked online-softmax* (flash-style) scan in
pure JAX: memory stays O(q_chunk × kv_chunk) per step regardless of sequence
length, which is what lets 32k-prefill cells compile with bounded
memory_analysis, and sliding-window attention only ever loads the
(window + q_chunk) keys a query block can see — sub-quadratic in compute
*and* memory (required for the long_500k cells).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import QuantConfig
from repro.core.quantized_linear import qmatmul

# --------------------------------------------------------------------------
# Initializers / linear
# --------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    scale = (1.0 / d_in) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def linear(
    x: jax.Array,
    w,
    quant: Optional[QuantConfig] = None,
    quant_mode: str = "none",
) -> jax.Array:
    """All model matmuls route through the paper's technique.

    PackedWeight leaves carry their own per-layer (w_bits, a_bits) from the
    PrecisionPolicy they were packed under, so they always dispatch with
    cfg=None — a global QuantConfig must not override a per-layer decision.
    """
    if hasattr(w, "packed"):  # PackedWeight: leaf-carried precision wins
        return qmatmul(x, w, None)
    if quant is None or quant_mode == "none":
        return x @ w.astype(x.dtype)
    return qmatmul(x, w, quant, mode=quant_mode)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------


def norm_init(cfg_norm: str, d: int, dtype=jnp.float32):
    if cfg_norm == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)
    if cfg_norm == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    if cfg_norm == "nonparam_ln":
        return {}
    raise ValueError(cfg_norm)


def apply_norm(x: jax.Array, params: dict, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    # nonparam_ln (olmo): no affine parameters at all
    return y.astype(x.dtype)


def rms_head_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head qk-norm (stablelm / llama4)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))).astype(
        x.dtype
    )


# --------------------------------------------------------------------------
# Rotary embeddings
# --------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, n, h) rotated by per-position angles; positions: (..., T)."""
    h = x.shape[-1]
    half = h // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., T, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Chunked (flash-style) attention
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnMask:
    causal: bool = True
    window: int = 0        # >0: key j visible iff q_pos - window < j <= q_pos
    prefix_len: int = 0    # >0: positions < prefix_len attend bidirectionally


def _mask_block(qpos, kpos, m: AttnMask):
    """(Tq, Tk) boolean visibility."""
    q = qpos[:, None]
    k = kpos[None, :]
    ok = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if m.causal:
        vis = k <= q
        if m.prefix_len:
            vis = vis | ((k < m.prefix_len) & (q < m.prefix_len)) | (k < m.prefix_len)
        ok = ok & vis
    if m.window:
        ok = ok & (k > q - m.window)
    return ok


def _sdp_block(q, k, v, mask, softcap: float, scale: float):
    """One (q-block × kv-block) attention piece → (scores_exp_sum inputs).

    q: (B, Tq, NKV, G, H); k/v: (B, Tk, NKV, H); mask: (Tq, Tk) bool.
    Returns scores (B, NKV, G, Tq, Tk) float32, already masked with -inf.
    """
    s = jnp.einsum("btngh,bsnh->bngts", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    neg = jnp.finfo(jnp.float32).min
    return jnp.where(mask[None, None, None], s, neg)


def chunked_attention(
    q: jax.Array,  # (B, T, NQ, H)
    k: jax.Array,  # (B, S, NKV, H)
    v: jax.Array,  # (B, S, NKV, H)
    mask: AttnMask,
    *,
    q_offset=0,
    softcap: float = 0.0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    kpos: Optional[jax.Array] = None,
) -> jax.Array:
    """Memory-bounded attention; supports GQA, causal, window, prefix-LM.

    Sliding-window attention only slices the (window + q_chunk) keys each
    query block can see → compute and memory are O(T·window), not O(T²).

    By default key slot ``s`` holds absolute position ``s`` and slots at or
    beyond ``S`` are padding. ``kpos`` (S,) overrides that: each key slot
    carries an explicit absolute position (−1 = invalid/padding), which is
    what lets a *suffix* prefill attend over ``[pool-resident prefix KV ++
    freshly computed suffix KV]`` — the prefix-cache admission path — with
    exactly the same per-row math as a cold full prefill (real positions
    stay in order; masked slots contribute exact zeros). ``q_offset`` may
    be a traced scalar for the same reason (the suffix start position is a
    runtime value, one compiled signature per shape)."""
    B, T, NQ, H = q.shape
    S = k.shape[1]
    NKV = k.shape[2]
    G = NQ // NKV
    scale = H**-0.5

    qc = min(q_chunk, T)
    Tp = -(-T // qc) * qc
    if Tp != T:
        q = jnp.pad(q, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    qg = q.reshape(B, Tp // qc, qc, NKV, G, H)

    if mask.window and mask.causal and S > mask.window + qc and kpos is None:
        return _windowed_attention(
            qg, k, v, mask, q_offset, softcap, scale, qc, T, S
        )

    kc = min(kv_chunk, S)
    Sp = -(-S // kc) * kc
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    kg = k.reshape(B, Sp // kc, kc, NKV, H)
    vg = v.reshape(B, Sp // kc, kc, NKV, H)
    if kpos is None:
        kpos_full = jnp.arange(Sp, dtype=jnp.int32)
        kvalid_full = kpos_full < S
    else:
        kpos_full = jnp.asarray(kpos, jnp.int32)
        if Sp != S:
            kpos_full = jnp.pad(kpos_full, (0, Sp - S), constant_values=-1)
        kvalid_full = kpos_full >= 0
    kposg = kpos_full.reshape(Sp // kc, kc)
    kvalidg = kvalid_full.reshape(Sp // kc, kc)

    def q_block(qi, qb):
        qpos = q_offset + qi * qc + jnp.arange(qc)

        def kv_step(carry, inp):
            m_run, l_run, acc = carry
            kposc, kvalc, kb, vb = inp
            blk_mask = _mask_block(qpos, kposc, mask) & kvalc[None, :]
            s = _sdp_block(qb, kb, vb, blk_mask, softcap, scale)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - safe_m[..., None])
            p = jnp.where(blk_mask[None, None, None], p, 0.0)
            alpha = jnp.where(jnp.isfinite(m_run), jnp.exp(m_run - safe_m), 0.0)
            l_new = l_run * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bngts,bsnh->bngth", p, vb.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        neg = jnp.finfo(jnp.float32).min
        m0 = jnp.full((B, NKV, G, qc), neg)
        l0 = jnp.zeros((B, NKV, G, qc))
        a0 = jnp.zeros((B, NKV, G, qc, H))
        ks = jnp.moveaxis(kg, 1, 0)
        vs = jnp.moveaxis(vg, 1, 0)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (kposg, kvalidg, ks, vs)
        )
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1)  # (B, qc, NKV, G, H)

    outs = jax.lax.map(
        lambda args: q_block(*args),
        (jnp.arange(Tp // qc), jnp.moveaxis(qg, 1, 0)),
    )  # (nq, B, qc, NKV, G, H)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Tp, NQ, H)[:, :T]
    return out.astype(q.dtype)


def _windowed_attention(qg, k, v, mask, q_offset, softcap, scale, qc, T, S):
    """Sliding-window path: per q block, slice only the visible keys."""
    B, nQ, _, NKV, G, H = qg.shape
    w = mask.window
    span = w + qc
    # Pad keys at the front so start index arithmetic stays in range.
    if S < span:
        k = jnp.pad(k, ((0, 0), (0, span - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, span - S), (0, 0), (0, 0)))

    def q_block(qi, qb):
        q_lo = q_offset + qi * qc
        start = jnp.clip(q_lo - w, 0, max(S - span, 0))
        kb = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
        qpos = q_lo + jnp.arange(qc)
        kpos = start + jnp.arange(span)
        blk_mask = _mask_block(qpos, kpos, mask) & (kpos < S)[None, :]
        s = _sdp_block(qb, kb, vb, blk_mask, softcap, scale)
        m = jnp.max(s, axis=-1, keepdims=True)
        m = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.exp(s - m)
        p = jnp.where(blk_mask[None, None, None], p, 0.0)
        l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
        out = jnp.einsum("bngts,bsnh->bngth", p / l, vb.astype(jnp.float32))
        return jnp.moveaxis(out, 3, 1)  # (B, qc, NKV, G, H)

    outs = jax.lax.map(
        lambda args: q_block(*args), (jnp.arange(nQ), jnp.moveaxis(qg, 1, 0))
    )
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nQ * qc, NKV * G, H)[:, :T]
    return out.astype(qg.dtype)


def decode_attention(
    q: jax.Array,        # (B, 1, NQ, H) — single new token
    k_cache: jax.Array,  # (B, S, NKV, H) (bf16, or int8 codes if k_scale)
    v_cache: jax.Array,
    kpos: jax.Array,     # (B, S) per-row absolute slot positions (−1 = empty)
    q_pos: jax.Array,    # (B,) per-row current position
    window: int = 0,
    softcap: float = 0.0,
    k_scale: jax.Array | None = None,  # (B, S, NKV, 1) int8-cache scales
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """One-token attention over a (possibly ring-buffered, possibly
    int8-quantized) cache. Every batch row carries its own slot positions
    and decode position, so rows at different depths (continuous batching)
    coexist in one call. Legacy shared positions — kpos (S,), scalar q_pos
    — are broadcast. For the quantized cache, scores are computed on
    int8 codes and rescaled per key slot — the dequant never materializes
    a bf16 copy of the cache."""
    B, _, NQ, H = q.shape
    NKV = k_cache.shape[2]
    G = NQ // NKV
    scale = H**-0.5
    q_pos = jnp.broadcast_to(jnp.asarray(q_pos, jnp.int32), (B,))
    if kpos.ndim == 1:
        kpos = jnp.broadcast_to(kpos[None], (B, kpos.shape[0]))
    qr = q.reshape(B, NKV, G, H)
    s = jnp.einsum("bngh,bsnh->bngs", qr.astype(jnp.float32),
                   k_cache.astype(jnp.float32))
    if k_scale is not None:
        s = s * jnp.moveaxis(k_scale[..., 0], -1, 1)[:, :, None, :]  # (B,NKV,1,S)
    s = s * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    valid = (kpos >= 0) & (kpos <= q_pos[:, None])           # (B, S)
    if window:
        valid = valid & (kpos > q_pos[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, jnp.finfo(jnp.float32).min)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:
        p = p * jnp.moveaxis(v_scale[..., 0], -1, 1)[:, :, None, :]
    out = jnp.einsum("bngs,bsnh->bngh", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, NQ, H).astype(q.dtype)


# --------------------------------------------------------------------------
# FFN variants
# --------------------------------------------------------------------------


def ffn_init(key, cfg, d: int, f: int, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.ffn in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(k1, d, f, dtype),
            "w_up": dense_init(k2, d, f, dtype),
            "w_down": dense_init(k3, f, d, dtype),
        }
    return {"w_up": dense_init(k1, d, f, dtype), "w_down": dense_init(k2, f, d, dtype)}


def ffn_apply(params: dict, x: jax.Array, cfg) -> jax.Array:
    q, qm = cfg.quant, ("fake" if cfg.quant else "none")
    if cfg.ffn == "swiglu":
        g = linear(x, params["w_gate"], q, qm)
        u = linear(x, params["w_up"], q, qm)
        h = jax.nn.silu(g) * u
    elif cfg.ffn == "geglu":
        g = linear(x, params["w_gate"], q, qm)
        u = linear(x, params["w_up"], q, qm)
        h = jax.nn.gelu(g, approximate=True) * u
    elif cfg.ffn == "relu2":
        h = jnp.square(jax.nn.relu(linear(x, params["w_up"], q, qm)))
    elif cfg.ffn == "gelu":
        h = jax.nn.gelu(linear(x, params["w_up"], q, qm), approximate=True)
    else:
        raise ValueError(cfg.ffn)
    return linear(h, params["w_down"], q, qm)


# --------------------------------------------------------------------------
# Embeddings / logits
# --------------------------------------------------------------------------


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def last_token_slice(x: jax.Array, lengths) -> jax.Array:
    """(B, T, d) → (B, 1, d) at the last *real* token per row: T-1 when
    `lengths` is None, lengths-1 for right-padded serving batches."""
    if lengths is None:
        return x[:, -1:]
    idx = (lengths.astype(jnp.int32) - 1)[:, None, None]
    return jnp.take_along_axis(x, jnp.maximum(idx, 0), axis=1)


def embed_lookup(table: jax.Array, ids: jax.Array, scale: bool = False) -> jax.Array:
    out = jnp.take(table, ids, axis=0)
    if scale:
        out = out * (table.shape[1] ** 0.5)
    return out


def logits_head(x: jax.Array, table_or_w, softcap: float = 0.0, transpose: bool = False):
    w = table_or_w
    if transpose:
        out = jnp.einsum("...d,vd->...v", x, w.astype(x.dtype))
    else:
        out = x @ w.astype(x.dtype)
    out = out.astype(jnp.float32)
    if softcap:
        out = softcap * jnp.tanh(out / softcap)
    return out


def cross_entropy(logits: jax.Array, labels: jax.Array, z_loss: float = 1e-4):
    """Token-level CE with optional z-loss; logits float32 (..., V)."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return loss
