"""Mixture-of-Experts FFN with capacity-bounded sort-based dispatch.

Two sharding regimes (config `moe_shard`):
  'expert' (EP): expert dim sharded over 'model' — llama4 (128 % 16 == 0).
                 Dispatch/combine scatter-gathers become all-to-alls under
                 SPMD, the canonical EP communication pattern.
  'ffn'    (TP): expert hidden dim sharded over 'model' — mixtral (8 < 16).

Dispatch: tokens are routed top-k, then *sorted by expert id*; each expert
processes a fixed-capacity block (C = ceil(N·k/E · capacity_factor)), with
overflow dropped (standard Switch-style dropping — keeps the step shape
static, which pjit requires). The router runs in fp32 and contributes the
usual load-balance auxiliary loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.parallel.sharding import constrain


def init_moe(key, cfg) -> dict:
    ks = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    dt = jnp.dtype(cfg.dtype)
    group = "experts_ep" if cfg.moe_shard == "expert" else "experts_tp"
    glu = cfg.ffn in ("swiglu", "geglu")

    def stack(k, din, dout):
        keys = jax.random.split(k, e)
        return jax.vmap(lambda kk: cm.dense_init(kk, din, dout, dt))(keys)

    experts = {"w_up": stack(ks[0], d, f), "w_down": stack(ks[1], f, d)}
    if glu:
        experts["w_gate"] = stack(ks[2], d, f)
    return {"router": cm.dense_init(ks[3], d, e, jnp.float32), group: experts}


def _expert_ffn(experts: dict, xe: jax.Array, cfg) -> jax.Array:
    """xe: (E, C, d) → (E, C, d) via per-expert FFN (batched einsum)."""
    def mm(a, w):
        return jnp.einsum("ecd,edf->ecf", a, w.astype(a.dtype))

    if cfg.ffn in ("swiglu", "geglu"):
        g = mm(xe, experts["w_gate"])
        u = mm(xe, experts["w_up"])
        act = jax.nn.silu(g) if cfg.ffn == "swiglu" else jax.nn.gelu(g, approximate=True)
        h = act * u
    else:
        h = jnp.square(jax.nn.relu(mm(xe, experts["w_up"])))
    h = constrain(h, "model" if cfg.moe_shard == "expert" else None, None,
                  None if cfg.moe_shard == "expert" else "model")
    return mm(h, experts["w_down"])


def moe_apply_shardmap(params: dict, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """EP dispatch as an explicit shard_map (§Perf cell B resolution).

    XLA SPMD replicates the data-dependent dispatch scatter of
    :func:`moe_apply`, producing multi-TB all-gathers at llama4 scale.
    This path takes dispatch out of SPMD's hands: activations are
    replicated across the `model` axis between layers (the TP layout), so
    every model shard can rout locally, run ONLY its own E/|model| experts
    on a local capacity buffer, and the combine is a single psum over
    `model` of the (N_local, d) output — per-layer wire bytes drop from
    O(E·cap·d) gathers to one activation-sized all-reduce (~86× for
    llama4 train_4k; see EXPERIMENTS.md).

    Requires: an active mesh context, moe_shard='expert', and
    E % |model| == 0. Falls back to moe_apply otherwise.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.parallel import sharding as shlib

    mesh = shlib.get_mesh()
    E = cfg.moe_experts
    n_model = dict(zip(mesh.axis_names, mesh.devices.shape))["model"] \
        if mesh is not None and "model" in mesh.axis_names else 0
    # E ≥ axis: each shard owns E/n experts. E < axis (mixtral: 8 on 16):
    # experts replicate across n/E shards, each replica taking a disjoint
    # slice of the expert's capacity — still one psum to combine.
    if (
        mesh is None
        or not n_model
        or cfg.moe_shard != "expert"
        or (E % n_model != 0 and n_model % E != 0)
    ):
        return moe_apply(params, x, cfg)

    baxes = shlib.batch_axes()
    B, T, d = x.shape
    bsize = 1
    for a in baxes:
        bsize *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
    x_spec = P(baxes if B % bsize == 0 else None, None, None)
    experts = params["experts_ep"]
    glu = "w_gate" in experts

    # E ≥ axis: pure EP — each shard owns E/n experts (my0 slice, full f).
    # E < axis: TP-inside-shard_map — every shard keeps ALL experts but
    # only f/n of their hidden dim (weights stay sharded, zero movement);
    # each shard computes partial down-projections for every token and the
    # combine psum reconstructs them exactly (GLU is elementwise in f).
    tp_mode = n_model > E
    E_loc = E if tp_mode else E // n_model

    def body(router, w_up, w_down, w_gate, xs):
        Bl, Tl, _ = xs.shape
        N = Bl * Tl
        K = cfg.moe_top_k
        idx = jax.lax.axis_index("model")
        my0 = jnp.int32(0) if tp_mode else idx * E_loc
        xt = xs.reshape(N, d)
        logits = xt.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, gate_idx = jax.lax.top_k(probs, K)
        if K > 1:
            gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], E, dtype=jnp.float32), axis=0)
        aux = E * jnp.sum(me * ce)

        cap = int(-(-N * K // E) * cfg.moe_capacity_factor)
        cap = max(8, -(-cap // 8) * 8)
        fe = gate_idx.reshape(-1)
        ft = jnp.repeat(jnp.arange(N, dtype=jnp.int32), K)
        fg = gate_w.reshape(-1)
        order = jnp.argsort(fe)
        se, st, sg = fe[order], ft[order], fg[order]
        counts = jnp.bincount(fe, length=E)
        starts = jnp.concatenate(
            [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]]
        )
        pos = jnp.arange(N * K, dtype=jnp.int32) - starts[se].astype(jnp.int32)
        mine = (se >= my0) & (se < my0 + E_loc) & (pos < cap)
        e_loc = jnp.where(mine, se - my0, E_loc)        # OOB → dropped
        p_loc = jnp.where(mine, pos, 0)
        xe = jnp.zeros((E_loc, cap, d), xs.dtype).at[e_loc, p_loc].set(
            xt[st], mode="drop", unique_indices=True
        )

        def mm(a, w):
            # in_specs deliver each shard exactly the slice it computes
            # with: (E_loc, d, f) in EP mode, (E, d, f/n) in TP mode.
            return jnp.einsum("ecd,edf->ecf", a, w.astype(a.dtype))

        if glu:
            g = mm(xe, w_gate)
            u = mm(xe, w_up)
            act = jax.nn.silu(g) if cfg.ffn == "swiglu" else jax.nn.gelu(
                g, approximate=True)
            h = act * u
        else:
            h = jnp.square(jax.nn.relu(mm(xe, w_up)))
        ye = mm(h, w_down)

        got = ye[jnp.where(mine, e_loc, 0), p_loc]
        got = got * mine[:, None] * sg[:, None].astype(xs.dtype)
        out = jnp.zeros((N, d), xs.dtype).at[st].add(got)
        out = jax.lax.psum(out, "model")                # the ONLY collective
        return out.reshape(Bl, Tl, d), aux

    w_gate = experts.get("w_gate", experts["w_up"])  # placeholder if non-GLU
    if tp_mode:
        up_spec = P(None, None, "model")     # (E, d, f/n)
        down_spec = P(None, "model", None)   # (E, f/n, d) → partial sums
    else:
        up_spec = down_spec = P("model", None, None)
    out, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), up_spec, down_spec, up_spec, x_spec),
        out_specs=(x_spec, P()),
        check_rep=False,
    )(params["router"], experts["w_up"], experts["w_down"], w_gate, x)
    return out, aux.astype(jnp.float32)


def moe_apply(params: dict, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B, T, d) → (out (B, T, d), aux_loss scalar)."""
    B, T, d = x.shape
    E, K = cfg.moe_experts, cfg.moe_top_k
    N = B * T
    xt = x.reshape(N, d)

    gate_logits = xt.astype(jnp.float32) @ params["router"]      # (N, E)
    probs = jax.nn.softmax(gate_logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, K)                   # (N, K)
    if K > 1:
        gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    # Load-balance aux loss (Switch): E * sum_e fraction_e * prob_e.
    me = jnp.mean(probs, axis=0)
    one_hot_top = jax.nn.one_hot(gate_idx[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top, axis=0)
    aux = E * jnp.sum(me * ce)

    cap = int(-(-N * K // E) * cfg.moe_capacity_factor)
    cap = max(8, -(-cap // 8) * 8)

    # ---- sort-based dispatch -------------------------------------------
    # NOTE (§Perf cell B, EXPERIMENTS.md): both this flat (E·cap, d)
    # scatter and a 2-D (expert, slot) formulation are replicated by XLA
    # SPMD (data-dependent scatter over the sharded expert dim), producing
    # the all-gathers that make llama4 train collective-bound. The flat
    # form measures ~25% fewer wire bytes, so it is the checked-in
    # variant; the real fix is a shard_map ragged all-to-all dispatch.
    flat_expert = gate_idx.reshape(-1)                            # (N*K,)
    flat_token = jnp.repeat(jnp.arange(N, dtype=jnp.int32), K)
    flat_gate = gate_w.reshape(-1)
    order = jnp.argsort(flat_expert)                              # stable
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    counts = jnp.bincount(flat_expert, length=E)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(N * K, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = pos_in_e < cap
    dest = jnp.where(keep, se * cap + pos_in_e, E * cap)          # E*cap = drop

    buf = jnp.zeros((E * cap, d), x.dtype).at[dest].set(
        xt[st], mode="drop", unique_indices=True
    )
    xe = buf.reshape(E, cap, d)
    xe = constrain(xe, "model" if cfg.moe_shard == "expert" else None, None, None)

    ye = _expert_ffn(params["experts_ep" if cfg.moe_shard == "expert"
                            else "experts_tp"], xe, cfg)
    ybuf = ye.reshape(E * cap, d)

    # ---- combine --------------------------------------------------------
    gathered = jnp.take(ybuf, jnp.where(keep, dest, 0), axis=0)
    gathered = gathered * keep[:, None] * sg[:, None].astype(x.dtype)
    out = jnp.zeros((N, d), x.dtype).at[st].add(gathered)
    return out.reshape(B, T, d), aux.astype(jnp.float32)
