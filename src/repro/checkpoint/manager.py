"""Atomic, elastic checkpoint manager.

Fault-tolerance contract:
  * saves are atomic (write to `<step>.tmp/`, fsync, rename to `<step>/`)
    so a preemption mid-save never corrupts the latest checkpoint;
  * keep-K retention with the newest always preserved;
  * restore picks the newest *complete* checkpoint (a COMMIT marker file
    written last);
  * topology-agnostic: leaves are stored as host numpy arrays keyed by
    tree path, so a restart may load onto a different mesh / device count
    (elastic scaling) — the caller re-shards with jax.device_put against
    its own shardings;
  * optional async mode: the device→host transfer happens synchronously
    (cheap) and the disk write runs on a background thread so training is
    not stalled on I/O.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Callable, Optional, Tuple

import jax
import numpy as np

_COMMIT = "COMMITTED"


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ #
    def latest_step(self) -> Optional[int]:
        steps = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.isdigit() and (p / _COMMIT).exists():
                steps.append(int(p.name))
        return max(steps) if steps else None

    def save(self, step: int, state: Any, data_state: Optional[dict] = None) -> None:
        # Device→host synchronously (so donated buffers are safe to reuse).
        # Non-native numpy dtypes (bf16) are widened to fp32 on disk — the
        # manifest keeps the logical dtype and restore casts back.
        import jax.numpy as jnp

        def to_host(l):
            arr = np.asarray(l)
            if arr.dtype.kind not in "fiub" or str(arr.dtype) == "bfloat16":
                arr = np.asarray(jnp.asarray(l, jnp.float32))
            return arr

        leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(state)
        host = [(_path_str(p), to_host(l)) for p, l in leaves_with_paths]

        def write():
            tmp = self.dir / f"{step}.tmp"
            final = self.dir / str(step)
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "leaves": []}
            arrays = {}
            for i, (path, arr) in enumerate(host):
                key = f"leaf_{i}"
                arrays[key] = arr
                manifest["leaves"].append(
                    {"key": key, "path": path, "dtype": str(arr.dtype),
                     "shape": list(arr.shape)}
                )
            np.savez(tmp / "arrays.npz", **arrays)
            if data_state is not None:
                (tmp / "data_state.json").write_text(json.dumps(data_state))
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            with open(tmp / _COMMIT, "w") as f:
                f.write("ok")
                f.flush()
                os.fsync(f.fileno())
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._gc()

        if self.async_save:
            self.wait()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            int(p.name) for p in self.dir.iterdir()
            if p.is_dir() and p.name.isdigit() and (p / _COMMIT).exists()
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / str(s), ignore_errors=True)

    # ------------------------------------------------------------------ #
    def restore(
        self,
        init_fn: Callable[[], Any],
        shardings: Any = None,
        step: Optional[int] = None,
    ) -> Tuple[Any, Optional[dict], int]:
        """Returns (state, data_state, step). The template from init_fn
        defines the tree structure; leaves are loaded by tree path so the
        restore survives refactors that only reorder the tree. If
        `shardings` is given, leaves are device_put with them (elastic
        re-layout onto the current mesh)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = self.dir / str(step)
        manifest = json.loads((d / "manifest.json").read_text())
        arrays = np.load(d / "arrays.npz")
        by_path = {
            leaf["path"]: arrays[leaf["key"]] for leaf in manifest["leaves"]
        }
        template = jax.eval_shape(init_fn)
        leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_leaves = (
            treedef.flatten_up_to(shardings) if shardings is not None else None
        )
        out = []
        for i, (p, tmpl) in enumerate(leaves_with_paths):
            key = _path_str(p)
            if key not in by_path:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = by_path[key]
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs {tmpl.shape}"
                )
            jarr = jax.numpy.asarray(arr).astype(tmpl.dtype)
            if shard_leaves is not None:
                out.append(jax.device_put(jarr, shard_leaves[i]))
            else:
                out.append(jax.device_put(jarr))
        state = treedef.unflatten(out)
        data_state = None
        ds = d / "data_state.json"
        if ds.exists():
            data_state = json.loads(ds.read_text())
        return state, data_state, step
