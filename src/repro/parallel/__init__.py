from repro.parallel.sharding import (  # noqa: F401
    batch_sharding,
    cache_shardings,
    constrain,
    make_param_shardings,
    set_mesh_context,
)
