"""Distributed-optimization collectives.

Gradient compression (beyond-paper, but built from the paper's own
quantizer): int8 block-quantized gradients with *error feedback* — the
residual of each compression round is added back before the next round, so
the scheme is unbiased in the long run (Karimireddy et al.-style EF-SGD).
On the wire this cuts DP all-reduce bytes 4× (fp32→int8), which directly
shrinks the collective roofline term of train cells; it is exercised by the
train driver when TrainConfig.grad_compress_bits == 8.

Hierarchical pod reduction: with a ('pod','data') batch sharding XLA already
emits reduce-scatter(data)+all-reduce(pod)+all-gather(data) for FSDP grads;
`hierarchical_psum` exposes the same pattern for explicit shard_map code.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import qmax


def quantize_block(x: jax.Array, bits: int = 8, block: int = 256):
    """Per-block symmetric quantization of a flat fp32 vector."""
    n = x.size
    pad = (-n) % block
    xf = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad))
    xb = xf.reshape(-1, block)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / qmax(bits)
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.round(xb * inv), -qmax(bits) - 1, qmax(bits)).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_block(q: jax.Array, scale: jax.Array, shape, block: int = 256):
    xb = q.astype(jnp.float32) * scale
    n = 1
    for s in shape:
        n *= s
    return xb.reshape(-1)[:n].reshape(shape)


def compress_gradients(grads, error, bits: int = 8, block: int = 256):
    """Error-feedback compression: returns (compressed pytree of (q, scale),
    new error pytree, decompressed gradients to feed the optimizer).

    The decompressed value equals what every peer reconstructs after the
    all-reduce of the quantized payload — applying it locally keeps replicas
    bit-identical (the payload is what gets summed by XLA's AR of int32
    partial sums in a real deployment; here we model value semantics).
    """
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize_block(gf, bits, block)
        deq = dequantize_block(q, s, g.shape, block)
        return (q, s), gf - deq, deq.astype(g.dtype)

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    comp = tdef.unflatten([o[0] for o in outs])
    new_err = tdef.unflatten([o[1] for o in outs])
    deq = tdef.unflatten([o[2] for o in outs])
    return comp, new_err, deq


def init_error(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def compressed_bytes(grads, bits: int = 8, block: int = 256) -> int:
    """Wire bytes of the compressed payload (for the roofline accounting)."""
    total = 0
    for g in jax.tree_util.tree_leaves(grads):
        n = g.size
        nb = -(-n // block)
        total += n * bits // 8 + nb * 4
    return total


def hierarchical_psum(x: jax.Array, data_axis: str = "data", pod_axis: str = "pod"):
    """reduce-scatter in-pod → all-reduce cross-pod → all-gather in-pod.

    For use inside shard_map bodies; equivalent to psum over both axes but
    moves (1/|data|) of the bytes over the slow inter-pod links.
    """
    scat = jax.lax.psum_scatter(x, data_axis, tiled=True)
    red = jax.lax.psum(scat, pod_axis)
    return jax.lax.all_gather(red, data_axis, tiled=True)
