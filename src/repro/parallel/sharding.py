"""Partition rules: DP / TP / FSDP / EP / SP over the production mesh.

Design (T5X/MaxText-style): parameters are matched by *tree-path regex* to a
PartitionSpec; activations are constrained at a handful of named cut points
inside the models via :func:`constrain`. One mesh-axis vocabulary everywhere:

  'pod'   — slowest axis; second data-parallel dim (multi-pod DP)
  'data'  — batch / FSDP axis inside a pod
  'model' — tensor/expert parallel axis

BATCH_AXES = ('pod', 'data') so a single rule set serves both meshes (specs
referencing 'pod' are valid on the single-pod mesh too once the axis exists;
for the single-pod mesh we build specs without 'pod').
"""
from __future__ import annotations

import re
import threading
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# --------------------------------------------------------------------------
# Mesh-context registry (set by the launcher; models call constrain()).
# --------------------------------------------------------------------------

_ctx = threading.local()


def set_mesh_context(mesh: Optional[Mesh], batch_axes: Tuple[str, ...] = ("data",)):
    _ctx.mesh = mesh
    _ctx.batch_axes = batch_axes


def get_mesh() -> Optional[Mesh]:
    return getattr(_ctx, "mesh", None)


def axis_size(name: str) -> int:
    mesh = get_mesh()
    if mesh is None or name not in mesh.axis_names:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def batch_axes() -> Tuple[str, ...]:
    return getattr(_ctx, "batch_axes", ("data",))


def constrain(x: jax.Array, *spec) -> jax.Array:
    """Apply with_sharding_constraint if a mesh context is active.

    `spec` entries: None, 'model', or 'batch' (expands to the batch axes).
    """
    mesh = get_mesh()
    if mesh is None:
        return x
    expanded = tuple(batch_axes() if s == "batch" else s for s in spec)
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*expanded)))
    except ValueError:
        # Dim not divisible by axis size (e.g. 8 kv heads on a 16-way model
        # axis): fall back to replicated on that dim — XLA would reject the
        # constraint, and sharding must stay a no-op semantically.
        return x


# --------------------------------------------------------------------------
# Parameter partition rules
# --------------------------------------------------------------------------

Rule = Tuple[str, Tuple]  # (path regex, spec template)


def default_param_rules(fsdp: bool) -> List[Rule]:
    """Regex → spec template. 'F' in a template is the FSDP ('data') axis
    when fsdp is on, else None. Templates are matched against the
    '/'-joined tree path of each parameter leaf.

    The TP layout is Megatron-style: column-parallel into attention/FFN,
    row-parallel out, vocab-sharded embeddings.
    """
    F = "data" if fsdp else None
    return [
        # Embeddings / heads: vocab on model (big), embed dim on FSDP.
        (r".*embed$", ("model", F)),
        (r".*head$", (F, "model")),
        (r".*patch_proj$", (None, F)),
        (r".*frame_proj$", (None, F)),
        # Attention projections.
        (r".*\bwq$", (F, "model")),
        (r".*\bwk$", (F, "model")),
        (r".*\bwv$", (F, "model")),
        (r".*\bwo$", ("model", F)),
        # Dense FFN.
        (r".*w_gate$", (F, "model")),
        (r".*w_up$", (F, "model")),
        (r".*w_down$", ("model", F)),
        # MoE experts (leading expert dim). moe_shard='expert' (EP):
        (r".*experts_ep/.*w_(gate|up)$", ("model", F, None)),
        (r".*experts_ep/.*w_down$", ("model", None, F)),
        # moe_shard='ffn' (TP inside expert):
        (r".*experts_tp/.*w_(gate|up)$", (None, F, "model")),
        (r".*experts_tp/.*w_down$", (None, "model", F)),
        (r".*router$", (F, None)),
        # Griffin recurrent block.
        (r".*rg_(in|gate)$", (F, "model")),
        (r".*rg_out$", ("model", F)),
        (r".*rg_(a|i)_proj$", (F, "model")),
        (r".*conv_w$", (None, "model")),
        (r".*(lambda_p|rg_a_bias|rg_i_bias)$", ("model",)),
        # RWKV6 time-mix / channel-mix.
        (r".*tm/w_(recept|key|value)$", (F, "model")),
        (r".*tm/w_out$", ("model", F)),
        (r".*tm/decay_a$", (F, None)),
        (r".*tm/decay_b$", (None, "model")),
        (r".*cmx/w_(recept|key)$", (F, "model")),
        (r".*cmx/w_value$", ("model", F)),
        # Norm scales / biases / small vectors: replicated.
        (r".*", ()),
    ]


def spec_for_path(path: str, shape: Tuple[int, ...], rules: Sequence[Rule],
                  mesh: Mesh) -> P:
    """Resolve a param leaf to a PartitionSpec, dropping axes that don't
    divide the dim (honest fallback, logged by the dry-run)."""
    for pat, template in rules:
        if re.fullmatch(pat, path):
            return _fit_spec(template, shape, mesh)
    return P()


def _fit_spec(template: Tuple, shape: Tuple[int, ...], mesh: Mesh) -> P:
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = []
    # Stacked layer/group params carry extra leading dims: left-pad template.
    pad = len(shape) - len(template)
    template = (None,) * pad + tuple(template) if pad >= 0 else template[-len(shape):]
    for dim, ax in zip(shape, template):
        if ax is None:
            spec.append(None)
        elif isinstance(ax, tuple):
            size = 1
            for a in ax:
                size *= axes.get(a, 1)
            spec.append(ax if dim % size == 0 else None)
        else:
            spec.append(ax if dim % axes.get(ax, 1) == 0 else None)
    return P(*spec)


def tree_path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def make_param_shardings(params_shape, mesh: Mesh, fsdp: bool):
    """ShapeDtypeStruct (or array) pytree → NamedSharding pytree."""
    rules = default_param_rules(fsdp)

    def resolve(path, leaf):
        spec = spec_for_path(tree_path_str(path), leaf.shape, rules, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(resolve, params_shape)


def batch_sharding(mesh: Mesh, spec, batch_axes_: Tuple[str, ...]):
    """Shard dim 0 (global batch) over the batch axes; replicate the rest.
    Falls back to replicated when the batch dim doesn't divide (e.g. the
    long_500k cell's global_batch=1)."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    size = _size(axes, batch_axes_)
    shape = spec.shape if hasattr(spec, "shape") else spec
    if not shape or shape[0] % size:
        return NamedSharding(mesh, P())
    return NamedSharding(mesh, P(batch_axes_, *(None,) * (len(shape) - 1)))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def cache_shardings(mesh: Mesh, cache_shape, batch_axes_: Tuple[str, ...]):
    """Decode caches: KV tensors (L, B, S, NKV, H) → batch over data axes and
    sequence over 'model' (sequence-sharded decode: every model shard scores
    its slice of the cache; XLA inserts the softmax reductions). States with
    no sequence dim shard batch only. Scalars replicate."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def resolve(leaf):
        shp = leaf.shape
        if len(shp) == 5:  # (L, B, S, NKV, H)
            b_ok = shp[1] % _size(axes, batch_axes_) == 0
            s_ok = shp[2] % axes.get("model", 1) == 0
            return NamedSharding(
                mesh,
                P(None, batch_axes_ if b_ok else None, "model" if s_ok else None),
            )
        if len(shp) >= 2 and shp[1] % _size(axes, batch_axes_) == 0:
            return NamedSharding(mesh, P(None, batch_axes_))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(resolve, cache_shape)


def _size(axes: dict, names: Tuple[str, ...]) -> int:
    n = 1
    for a in names:
        n *= axes.get(a, 1)
    return n
