"""Production mesh construction.

A FUNCTION, not a module constant — importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi_pod prepends a 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pods: int = 0):
    """Small mesh for tests (requires xla_force_host_platform_device_count)."""
    if pods:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes_of(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
