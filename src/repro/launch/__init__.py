"""Launchers: production mesh, multi-pod dry-run, sweep, train/serve drivers."""
