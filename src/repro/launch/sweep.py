"""Dry-run sweep driver: every (arch × shape × mesh) cell via subprocesses.

Each cell runs in its own process (fresh XLA, crash isolation) and appends
one JSON record to the output file; the sweep is resumable — cells already
recorded are skipped. Skipped-by-applicability cells are recorded too, so
the output accounts for all 40 assigned cells per mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.sweep --out results/dryrun.jsonl \
      [--mesh single|multi|both] [--arch <id> ...] [--timeout 1800]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

from repro.configs import ARCH_IDS, SHAPES, applicable


def load_done(path: Path):
    done = set()
    if path.exists():
        for line in path.read_text().splitlines():
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if r.get("status") in ("ok", "skipped"):
                done.add((r["arch"], r["shape"], r["mesh"], r.get("quant", "none")))
    return done


def run_cell(arch, shape, mesh, out, timeout, quant="none", extra=()):
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", arch, "--shape", shape, "--mesh", mesh,
        "--quant", quant, "--out", str(out), *extra,
    ]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[2])
    t0 = time.time()
    try:
        proc = subprocess.run(
            cmd, env=env, timeout=timeout,
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        )
        status = "ok" if proc.returncode == 0 else "error"
        tail = proc.stdout[-1500:]
    except subprocess.TimeoutExpired:
        status, tail = "timeout", ""
        with open(out, "a") as f:
            f.write(json.dumps({
                "arch": arch, "shape": shape, "mesh": mesh, "quant": quant,
                "status": "timeout", "timeout_s": timeout,
            }) + "\n")
    print(f"[sweep] {arch} × {shape} × {mesh} ({quant}): {status} "
          f"({time.time()-t0:.0f}s)", flush=True)
    if status == "error":
        print(tail, flush=True)
    return status


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--arch", nargs="*", default=None)
    ap.add_argument("--shape", nargs="*", default=None)
    ap.add_argument("--timeout", type=int, default=2400)
    args = ap.parse_args()

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    done = load_done(out)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = args.arch or list(ARCH_IDS)
    shapes = args.shape or list(SHAPES)

    cells = []
    for mesh in meshes:
        for arch in archs:
            for shape in shapes:
                cells.append((arch, shape, mesh))
    # Cheap cells first (decode before prefill/train is not knowable a
    # priori; order by arch size proxy = param count asc so failures in
    # small archs surface early).
    from repro.configs import get_config

    cells.sort(key=lambda c: (get_config(c[0]).param_count(), c[1]))

    n_done = n_err = 0
    for arch, shape, mesh in cells:
        if (arch, shape, mesh, "none") in done:
            continue
        ok, reason = applicable(arch, shape)
        if not ok:
            with open(out, "a") as f:
                f.write(json.dumps({
                    "arch": arch, "shape": shape, "mesh": mesh, "quant": "none",
                    "status": "skipped", "reason": reason,
                }) + "\n")
            print(f"[sweep] {arch} × {shape} × {mesh}: skipped ({reason})",
                  flush=True)
            continue
        status = run_cell(arch, shape, mesh, out, args.timeout)
        n_done += status == "ok"
        n_err += status != "ok"
    print(f"[sweep] finished: {n_done} ok, {n_err} failed", flush=True)


if __name__ == "__main__":
    main()
