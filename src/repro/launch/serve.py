"""Serving driver: load (or init) a checkpointed model and serve a batch
of synthetic requests through the quantized engine.

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
      [--quant w4a8] [--policy "w4a8;wo=w8a8;head=w8a8"] [--backend interpret] \
      [--kv-int8] [--ckpt /tmp/ckpt] [--requests 8] \
      [--continuous] [--rate 20] [--static]

--quant applies one uniform QuantConfig; --policy is a per-layer
PrecisionPolicy spec ("default;pattern=wXaY[rZZ];..." matched against
parameter paths). --backend selects the kernel backend through the
registry (interpret | mosaic | reference; default = platform default).

--continuous serves through the continuous-batching scheduler with
Poisson-ish staggered arrivals at --rate requests/s (0 = all at once);
--static keeps the classic static batch. Either way the driver runs one
warmup pass first, so steady-state throughput (what the hardware does)
and total throughput (including compile) are reported separately.

Full-attention archs serve from the paged block-pool KV cache by default:
--block-size sets the pool granularity, --pool-blocks caps the shared
pool (defaults to the contiguous worst case; set it lower to overcommit —
admission then queues on actual free blocks), --no-paged forces the
contiguous per-slot max_ctx reservation. Pool utilization is reported
after a continuous run. --kv-int8 composes with the paged pool: blocks
hold int8 codes plus fp32 scale planes and the fused paged-attention
decode kernel dequantizes in-kernel (~2× tokens per pooled byte).

Cross-request prefix caching is on by default whenever the pool is paged
(and the arch supports suffix-only prefill): prompts sharing a prefix —
--shared-prefix N prepends a common N-token system prompt to every
synthetic request — reuse each other's resident prompt blocks with
refcounts and copy-on-write, and admission prefills only the uncached
suffix, bit-identical to a cold prefill. --no-prefix-cache disables it
(--prefix-cache forces it on, erroring if unsupported); the hit rate is
reported after a continuous run.

Chunked prefill (Sarathi-style) is also on by default on the paged pool:
admission enqueues a chunk *plan* and the scheduler spends at most
--prefill-budget prompt tokens of prefill per step through the fused
paged chunked-prefill kernel, interleaved with the live batch's decode
steps — a long prompt never stalls decoding for more than one budgeted
chunk. --no-chunked-prefill reverts to solo whole-prompt prefill at
admission. Chunk/stall counters are reported after a continuous run.

Self-speculative decoding: --speculate K drafts K tokens per scheduler
step from a truncated-plane view of the resident packed weights (the
draft reads only the top bit-planes — no second weight copy) and
verifies all K+1 positions in one chunk-shaped full-policy call,
emitting the longest matching prefix. Greedy requests' tokens are
bitwise identical to --speculate 0; sampled requests decode normally.
--draft-policy picks the draft precision (w4a8 / w2a8 — the plane
subset to keep). Requires a quant policy (--quant/--policy) and the
paged pool. Draft/acceptance counters are reported after a continuous
run.

Per-request precision tiers: --tiers "w8a8,w4a8,w2a8" assigns each
synthetic request a quality–latency class round-robin, all served from
the ONE packed weight set inside the same continuous batch — a tier is a
plane-truncated view of the stored weights (w4 reads half the weight
bytes of w8, w2 a quarter), and the scheduler runs one decode call per
tier group per step. A request served at tier T is greedy bit-identical
to a solo engine whose whole policy is T. Composes with --speculate (the
draft must sit strictly below a slot's tier to speculate) and with the
prefix cache (hashes are tier-scoped). Requires --continuous and a quant
policy; per-tier counters are reported after the run.

Request lifecycle & robustness: --deadline-ms gives every synthetic
request a wall-clock deadline (missed ones retire with error="deadline",
freeing their blocks like any retirement). On the paged pool, admission
under pool pressure preempts a victim slot by default (--no-preempt to
queue instead): the victim's resident blocks are registered into the
prefix index and the request is requeued as prompt ++ generated, so it
resumes warm — its greedy tokens are bitwise the uninterrupted stream.
--victim-policy picks the victim (most-blocks | lowest-tier |
latest-deadline). --degrade admits at the lowest precision tier once
pool pressure persists (needs --tiers). --chaos-seed N arms a seeded
FaultInjector that fires alloc/kernel/nan/callback faults at
--chaos-rate per seam visit — the engine must survive every fault by
degrading one request or one call; the chaos report prints what fired.

--plans FILE persists the kernel registry's block-plan cache (autotune
winners, e.g. the paged-attention bh knob) across process restarts:
loaded before serving if the file exists, written back on exit.

Tiered block pool: --host-pool-bytes N arms a host-RAM spill tier under
the paged pool — refcount-0 cached blocks evicted under pool pressure
move to a pinned numpy store instead of dying, and a prefix hit on a
host-resident chain swaps the blocks back into free device slots before
admission (warm-from-host greedy streams are bitwise the cold streams).
--victim-policy block-to-host makes preemption spill the victim's
resident K/V to host too, so it resumes warm even after its device
blocks were reclaimed. --index FILE persists the prefix index itself
(digest chains + block bytes, versioned JSON) across process restarts,
mirroring --plans: loaded before serving if the file exists, written
back on exit — a restarted server serves repeat prefixes warm from
host instead of re-prefilling cold. Swap/host-hit counters are
reported after a continuous run.
"""
import argparse

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--quant", default=None)
    ap.add_argument("--policy", default=None,
                    help="per-layer precision spec, e.g. 'w4a8;wo=w8a8'")
    ap.add_argument("--backend", default=None,
                    choices=("interpret", "mosaic", "reference"))
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--continuous", action="store_true",
                    help="serve via the continuous-batching scheduler")
    ap.add_argument("--static", action="store_true",
                    help="serve via the static batch baseline")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="continuous mode: Poisson arrival rate in "
                         "requests/s (0 = all requests queued at t=0)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged KV cache block size (tokens per block)")
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="shared KV pool size in blocks (default: the "
                         "contiguous worst case max_batch * max_ctx)")
    ap.add_argument("--no-paged", action="store_true",
                    help="force the contiguous per-slot KV reservation")
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true", default=None,
                    help="force cross-request prefix caching on (default: "
                         "auto — on whenever the pool is paged and the "
                         "arch supports suffix-only prefill)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false",
                    help="disable cross-request prefix caching")
    ap.add_argument("--prefill-budget", type=int, default=32,
                    help="chunked prefill: max prompt tokens prefilled "
                         "per scheduler step (the decode-stall bound)")
    ap.add_argument("--no-chunked-prefill", dest="chunked_prefill",
                    action="store_false", default=None,
                    help="disable Sarathi-style chunked prefill (solo "
                         "whole-prompt prefill at admission instead)")
    ap.add_argument("--speculate", type=int, default=0,
                    help="self-speculative decoding: draft tokens per "
                         "scheduler step from the truncated-plane view "
                         "of the packed weights (0 = off; greedy "
                         "requests only, needs --quant/--policy)")
    ap.add_argument("--draft-policy", default="w4a8",
                    help="draft precision for --speculate: the plane "
                         "subset of the resident weights the draft "
                         "contracts (e.g. w4a8, w2a8)")
    ap.add_argument("--tiers", default=None,
                    help="per-request precision tiers, e.g. "
                         "'w8a8,w4a8,w2a8': requests are assigned a tier "
                         "round-robin and served through plane-truncated "
                         "views of the one packed weight set inside the "
                         "same continuous batch (needs --continuous and "
                         "--quant/--policy)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request wall-clock deadline: requests not "
                         "finished this many ms after arrival retire "
                         "with error='deadline'")
    ap.add_argument("--no-preempt", dest="preempt", action="store_false",
                    default=None,
                    help="never preempt a live slot under pool pressure "
                         "(queue instead; default: preempt on the paged "
                         "pool, resume warm from prefix-cached blocks)")
    ap.add_argument("--victim-policy", default="most-blocks",
                    choices=("most-blocks", "lowest-tier",
                             "latest-deadline", "block-to-host"),
                    help="which live slot pool-pressure preemption evicts "
                         "(block-to-host picks like most-blocks and spills "
                         "the victim's resident K/V to the host tier; "
                         "needs --host-pool-bytes)")
    ap.add_argument("--degrade", action="store_true",
                    help="under sustained pool pressure admit new "
                         "requests at the lowest precision tier "
                         "(needs --tiers; sticky for the request's life)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="arm the seeded fault injector (alloc/kernel/"
                         "nan/callback seams) with this seed")
    ap.add_argument("--chaos-rate", type=float, default=0.05,
                    help="per-seam-visit fault probability when "
                         "--chaos-seed is set")
    ap.add_argument("--chaos-max-faults", type=int, default=None,
                    help="cap total injected faults (default unbounded)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a common N-token system prompt to every "
                         "synthetic request (exercises the prefix cache)")
    ap.add_argument("--plans", default=None,
                    help="block-plan cache JSON: loaded at startup if it "
                         "exists, saved back (with any new plans) on exit")
    ap.add_argument("--host-pool-bytes", type=int, default=0,
                    help="host-RAM spill tier budget in bytes (0 = off): "
                         "evicted refcount-0 prefix blocks move to a "
                         "pinned host store and swap back bit-identically "
                         "on a prefix hit")
    ap.add_argument("--index", default=None,
                    help="prefix-index JSON (digest chains + block bytes): "
                         "loaded into the host tier at startup if it "
                         "exists, saved back on exit (needs "
                         "--host-pool-bytes)")
    args = ap.parse_args()

    if args.index and not args.host_pool_bytes:
        raise SystemExit("--index persists blocks into the host tier; "
                         "add --host-pool-bytes")
    if args.victim_policy == "block-to-host" and not args.host_pool_bytes:
        raise SystemExit("--victim-policy block-to-host spills to the host "
                         "tier; add --host-pool-bytes")

    if args.quant and args.policy:
        raise SystemExit("--quant and --policy are mutually exclusive")
    if args.continuous and args.static:
        raise SystemExit("--continuous and --static are mutually exclusive")
    if args.speculate and not args.continuous:
        raise SystemExit("--speculate runs inside the continuous "
                         "scheduler; add --continuous")
    if args.speculate and not (args.quant or args.policy):
        raise SystemExit("--speculate drafts from the resident bit-plane "
                         "weights; add a quant policy (e.g. --quant w8a8)")
    if args.tiers and not args.continuous:
        raise SystemExit("--tiers groups slots inside the continuous "
                         "scheduler; add --continuous")
    if args.tiers and not (args.quant or args.policy):
        raise SystemExit("--tiers serves plane-truncated views of packed "
                         "weights; add a quant policy (e.g. --quant w8a8)")
    if args.degrade and not args.tiers:
        raise SystemExit("--degrade lowers admissions to the floor tier; "
                         "add --tiers")
    from repro.kernels import get_registry

    if args.backend:
        get_registry().set_active(args.backend)
    if args.plans:
        import os

        if os.path.exists(args.plans):
            n = get_registry().load_plans(args.plans)
            print(f"loaded {n} block plans from {args.plans}")

    import dataclasses

    from repro.configs import get_config, get_reduced_config
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    cfg = (get_reduced_config if args.reduced else get_config)(args.arch)
    if cfg.family == "encoder":
        raise SystemExit("encoder-only arch has no decode step")
    cfg = dataclasses.replace(cfg, kv_cache_quant=args.kv_int8)
    model = build_model(cfg)

    if args.ckpt:
        from repro.checkpoint import CheckpointManager
        from repro.configs.base import TrainConfig
        from repro.train.loop import init_train_state

        mgr = CheckpointManager(args.ckpt)
        state, _, step = mgr.restore(
            lambda: init_train_state(model.init(jax.random.PRNGKey(0)),
                                     TrainConfig())
        )
        params = state.params
        print(f"restored checkpoint step {step}")
    else:
        params = model.init(jax.random.PRNGKey(0))
        print("serving randomly initialized weights (no --ckpt)")

    quant = None
    if args.policy:
        from repro.core.precision import parse_policy_spec

        quant = parse_policy_spec(args.policy)
        print(f"precision policy: {quant.describe()}")
    elif args.quant:
        from repro.launch.dryrun import _parse_quant

        quant = _parse_quant(args.quant)
    chaos = None
    if args.chaos_seed is not None:
        from repro.serving import FaultInjector

        p = args.chaos_rate
        chaos = FaultInjector(args.chaos_seed, p_alloc=p, p_kernel=p,
                              p_nan=p, p_callback=p,
                              max_faults=args.chaos_max_faults)
    engine = ServingEngine(cfg, params, max_batch=args.max_batch,
                           quant=quant, bucket=32,
                           paged=False if args.no_paged else None,
                           block_size=args.block_size,
                           pool_blocks=args.pool_blocks,
                           prefix_cache=args.prefix_cache,
                           chunked_prefill=args.chunked_prefill,
                           prefill_budget=args.prefill_budget,
                           speculate=args.speculate,
                           draft_policy=args.draft_policy,
                           tiers=args.tiers,
                           preempt=args.preempt,
                           victim_policy=args.victim_policy,
                           degrade=args.degrade,
                           chaos=chaos,
                           host_pool_bytes=args.host_pool_bytes)
    if args.index:
        import os

        if os.path.exists(args.index):
            n = engine.load_index(args.index)
            print(f"loaded {n} prefix digests from {args.index}")

    def make_requests():
        # Self-contained stream: every call reproduces the exact same
        # requests (shared system prompt, tails, arrivals), so the timed
        # pass serves precisely the stream the warmup pass compiled for.
        rng = np.random.default_rng(0)
        shared = rng.integers(0, cfg.vocab, args.shared_prefix)
        tier_list = (args.tiers.split(",") if args.tiers else [None])
        reqs = [Request(rid=i,
                        prompt=np.concatenate([
                            shared, rng.integers(0, cfg.vocab, 8 + (i % 5))
                        ]).astype(np.int64),
                        max_new_tokens=args.max_new,
                        temperature=0.0 if i % 2 == 0 else 0.7,
                        tier=tier_list[i % len(tier_list)],
                        deadline_s=(args.deadline_ms / 1e3
                                    if args.deadline_ms else None))
                for i in range(args.requests)]
        if args.continuous and args.rate > 0:
            t = 0.0
            for r in reqs:
                r.arrival_time = t
                t += float(rng.exponential(1.0 / args.rate))
        return reqs

    serve = engine.generate if args.continuous else engine.generate_static
    import time

    # Warmup: one full pass compiles every prefill bucket + the decode
    # step, so the timed pass measures steady-state serving.
    t0 = time.perf_counter()
    serve(make_requests())
    t_warm = time.perf_counter() - t0

    reqs = make_requests()          # identical request stream, warm jit
    t1 = time.perf_counter()
    done = serve(reqs)
    dt = time.perf_counter() - t1
    total = sum(len(r.out_tokens or ()) for r in done)
    mode = "continuous" if args.continuous else "static"
    print(f"{len(done)} requests, {total} tokens, {dt:.1f}s [{mode}]")
    print(f"  steady-state: {total/dt:.1f} tok/s | "
          f"total incl. compile: {total/(t_warm + dt):.1f} tok/s "
          f"(warmup {t_warm:.1f}s)")
    if args.continuous:
        lat = [r.t_done - r.arrival_time for r in done if r.t_done is not None]
        print(f"  mean request latency: {np.mean(lat)*1e3:.0f} ms "
              f"(rate={args.rate or 'inf'}/s)")
        stats = engine.pool_stats()
        if stats and stats.get("paged"):
            print(f"  paged KV pool: {stats['peak_allocated_blocks']}/"
                  f"{stats['pool_blocks']} blocks peak "
                  f"(block_size={stats['block_size']}) — peak resident "
                  f"{stats['peak_resident_kv_bytes']/1e6:.2f} MB vs "
                  f"{stats['reserved_kv_bytes']/1e6:.2f} MB contiguous "
                  "reservation")
            if stats.get("prefix_cache"):
                print(f"  prefix cache: {stats['prefix_hit_rate']:.0%} of "
                      f"prompt tokens served from resident blocks "
                      f"({stats['prefix_hit_blocks']} block hits, "
                      f"{stats['cow_copies']} CoW copies, "
                      f"{stats['prefix_evictions']} evictions, "
                      f"{stats['retained_prefix_blocks']} retained)")
            if stats.get("host_tier"):
                print(f"  host tier: {stats['host_hit_rate']:.0%} of "
                      f"prompt tokens served warm-from-host "
                      f"({stats['host_hit_blocks']} block hits, "
                      f"{stats['swap_outs']} swap-outs, "
                      f"{stats['swap_ins']} swap-ins, "
                      f"{stats['host_blocks']} resident / "
                      f"{stats['host_bytes']/1e6:.2f} MB of "
                      f"{stats['host_pool_bytes']/1e6:.2f} MB budget, "
                      f"{stats['host_evictions']} host evictions)")
            if stats.get("chunked_prefill"):
                print(f"  chunked prefill: {stats['prefill_chunks_run']} "
                      f"chunks (budget={stats['prefill_budget']}), "
                      f"{stats['decode_steps_stalled']} decode steps "
                      f"shared a step with a chunk, "
                      f"{stats['prefill_tokens_per_step']:.1f} prefill "
                      f"tok/step")
            if stats.get("speculate"):
                print(f"  speculative decode: k={stats['speculate']}, "
                      f"{stats['spec_accepted_tokens']}/"
                      f"{stats['spec_draft_tokens']} drafts accepted "
                      f"({stats['spec_acceptance_rate']:.0%}) over "
                      f"{stats['spec_rounds']} rounds, "
                      f"{stats['spec_verify_rows']} rows in "
                      f"{stats['spec_verify_calls']} verify calls")
            if stats.get("tier_serving"):
                print("  precision tiers:")
                for name, tc in stats["tiers"].items():
                    if not tc["requests"]:
                        continue
                    line = (f"    {name}: {tc['requests']} requests, "
                            f"{tc['tokens']} tokens, "
                            f"{tc['decode_calls']} decode calls")
                    if tc["spec_draft_tokens"]:
                        line += (f", {tc['spec_accepted_tokens']}/"
                                 f"{tc['spec_draft_tokens']} drafts "
                                 f"accepted "
                                 f"({tc['spec_acceptance_rate']:.0%})")
                    print(line)
        elif stats:
            print(f"  contiguous KV cache: "
                  f"{stats['resident_kv_bytes']/1e6:.2f} MB resident "
                  "(full per-slot reservation)")
        if stats:
            failed = [r for r in done if r.error]
            if (failed or stats["preemptions"] or stats["deadline_misses"]
                    or stats["pool_pressure_events"]):
                print(f"  lifecycle: {stats['preemptions']} preemptions "
                      f"(policy={stats['victim_policy']}), "
                      f"{stats['deadline_misses']} deadline misses, "
                      f"{stats['cancellations']} cancellations, "
                      f"{stats['pool_pressure_events']} pressure events, "
                      f"{stats['head_bypasses']} head-of-line bypasses, "
                      f"{stats['degraded_requests']} degraded admissions")
            if stats["chaos"]:
                ch = stats["chaos"]
                fired = ", ".join(f"{k}={v}" for k, v in ch["fired"].items())
                print(f"  chaos: seed={ch['seed']} "
                      f"{ch['total_fired']} faults fired ({fired}); "
                      f"{stats['kernel_fallbacks']} reference-backend "
                      f"fallbacks, {stats['nan_logit_events']} NaN-logit "
                      f"retirements, {stats['callback_errors']} callback "
                      f"errors survived")
            for r in failed[:4]:
                print(f"  req {r.rid} failed: {r.error}")
    print(f"  quant={args.policy or args.quant or 'off'} "
          f"kv_int8={args.kv_int8}")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req {r.rid}: {(r.out_tokens or [])[:10]}")
    if args.plans:
        n = get_registry().save_plans(args.plans)
        print(f"saved {n} block plans to {args.plans}")
    if args.index:
        n = engine.save_index(args.index)
        print(f"saved {n} prefix digests to {args.index}")


if __name__ == "__main__":
    main()
