import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST precede every other import: jax locks the device
# count at first initialization, and the multi-pod dry-run needs 512
# placeholder host devices to build the production mesh.

"""Multi-pod dry-run: AOT lower + compile every (arch × shape × mesh) cell.

For each cell this driver:
  1. builds the production mesh (16×16 single-pod or 2×16×16 multi-pod),
  2. resolves parameter / optimizer / batch / cache shardings from the
     partition rules (DP/TP/FSDP/EP),
  3. lowers the appropriate step (train_step / prefill / decode_step) with
     ShapeDtypeStruct inputs — no allocation anywhere,
  4. compiles, prints memory_analysis() and cost_analysis(),
  5. extracts the three roofline terms (compute / memory / collective) and
     appends a JSON record to --out.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b \
      --shape train_4k --mesh single [--quant w4a8] [--out results.jsonl]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, applicable, get_config
from repro.configs.base import TrainConfig
from repro.core.quant import QuantConfig
from repro.core.quantized_linear import quantize_params_for_serving
from repro.launch.mesh import batch_axes_of, make_production_mesh
from repro.models import build_model
from repro.optim import adamw
from repro.parallel import sharding as shlib
from repro.roofline import analysis as roof
from repro.train.loop import TrainState, make_train_step


def _parse_quant(s: str):
    """e.g. w4a8, w2a4, w8a8, w4a8r10 (r10 = 10% 8-bit filter group).

    One grammar for quant tokens everywhere: delegates to the policy
    module's parser (a bare token is just a uniform policy's default)."""
    if not s or s == "none":
        return None
    from repro.core.precision import parse_quant_token

    return parse_quant_token(s)


def _parse_overrides(items):
    """key=value model-config overrides (ints/floats/bools auto-coerced)."""
    out = {}
    for item in items or ():
        key, _, val = item.partition("=")
        if val.lower() in ("true", "false"):
            out[key] = val.lower() == "true"
        else:
            try:
                out[key] = int(val)
            except ValueError:
                try:
                    out[key] = float(val)
                except ValueError:
                    out[key] = val
    return out


def lower_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    quant: str = "none",
    fsdp: bool | None = None,
    microbatches: int = 1,
    remat: bool | None = None,
    overrides: dict | None = None,
    verbose: bool = True,
):
    """Lower + compile one cell; returns the result record dict."""
    import dataclasses

    cfg = get_config(arch)
    qcfg = _parse_quant(quant)
    ov = dict(overrides or {})
    if fsdp is not None:
        ov["fsdp"] = fsdp
    if remat is not None:
        ov["remat"] = remat
    if ov:
        cfg = dataclasses.replace(cfg, **ov)
    shape = SHAPES[shape_name]
    ok, reason = applicable(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    baxes = batch_axes_of(mesh)
    shlib.set_mesh_context(mesh, baxes)
    model = build_model(cfg)
    specs = model.input_specs(shape)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "quant": quant, "chips": chips, "microbatches": microbatches,
        "fsdp": cfg.fsdp, "remat": cfg.remat,
    }

    t0 = time.time()
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_shape = jax.eval_shape(model.init, key_spec)
    param_shardings = shlib.make_param_shardings(params_shape, mesh, cfg.fsdp)
    batch_shardings = jax.tree_util.tree_map(
        lambda s: shlib.batch_sharding(mesh, s, baxes), specs
    )
    repl = shlib.replicated(mesh)

    if shape.kind == "train":
        tc = TrainConfig(microbatches=microbatches)
        step = make_train_step(model, tc)
        state_shape = jax.eval_shape(
            lambda p: TrainState(p, adamw.init_state(p), None), params_shape
        )
        state_shardings = TrainState(
            params=param_shardings,
            opt=adamw.AdamState(step=repl, mu=param_shardings, nu=param_shardings),
            err=None,
        )
        jitted = jax.jit(
            step,
            in_shardings=(state_shardings, batch_shardings),
            out_shardings=(state_shardings, None),
            donate_argnums=(0,),
        )
        with mesh:
            lowered = jitted.lower(state_shape, specs)
    elif shape.kind == "prefill":
        if qcfg is not None:
            params_shape = jax.eval_shape(
                lambda p: quantize_params_for_serving(p, qcfg), params_shape
            )
            param_shardings = shlib.make_param_shardings(params_shape, mesh, cfg.fsdp)

        def prefill_fn(params, batch):
            return model.prefill(params, batch)

        jitted = jax.jit(prefill_fn, in_shardings=(param_shardings, batch_shardings))
        with mesh:
            lowered = jitted.lower(params_shape, specs)
    else:  # decode
        if qcfg is not None:
            params_shape = jax.eval_shape(
                lambda p: quantize_params_for_serving(p, qcfg), params_shape
            )
            param_shardings = shlib.make_param_shardings(params_shape, mesh, cfg.fsdp)
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len)
        )
        cache_shardings = shlib.cache_shardings(mesh, cache_shape, baxes)

        def decode_fn(params, cache, tokens):
            return model.decode_step(params, cache, tokens)

        jitted = jax.jit(
            decode_fn,
            in_shardings=(param_shardings, cache_shardings, batch_shardings["tokens"]),
            out_shardings=(cache_shardings, None),
            donate_argnums=(1,),
        )
        with mesh:
            lowered = jitted.lower(params_shape, cache_shape, specs["tokens"])

    # Analytic parameter-byte accounting (the kernel-contract HBM view for
    # quantized weights: packed bytes are what a TPU kernel actually reads).
    def _tree_bytes(tree):
        total = 0
        for leaf in jax.tree_util.tree_leaves(tree):
            n = 1
            for s in leaf.shape:
                n *= s
            total += n * jnp.dtype(leaf.dtype).itemsize
        return total

    rec["params_bytes"] = _tree_bytes(params_shape)
    rec["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    mf = roof.model_flops(cfg, shape.kind, shape.seq_len, shape.global_batch)
    hlo_text = compiled.as_text()
    report = roof.analyze(compiled, chips, mf, hlo_text=hlo_text)
    rec.update(report.as_dict())
    rec["status"] = "ok"
    rec["hlo_bytes"] = len(hlo_text)

    if verbose:
        try:
            print(compiled.memory_analysis())
        except Exception as e:  # CPU backend may not implement it
            print(f"memory_analysis unavailable: {e}")
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        print({k: ca[k] for k in sorted(ca)[:8]})
        print(
            f"[{arch} × {shape_name} × {rec['mesh']}] "
            f"compute={report.compute_s*1e3:.2f}ms memory={report.memory_s*1e3:.2f}ms "
            f"collective={report.collective_s*1e3:.2f}ms → {report.bottleneck}-bound; "
            f"useful-flops={report.useful_flops_ratio:.2f}"
        )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--quant", default="none")
    ap.add_argument("--fsdp", default=None, choices=[None, "on", "off"])
    ap.add_argument("--remat", default=None, choices=[None, "on", "off"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--override", action="append", default=None,
                    help="ModelConfig override key=value (repeatable)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    try:
        rec = lower_cell(
            args.arch,
            args.shape,
            multi_pod=args.mesh == "multi",
            quant=args.quant,
            fsdp=None if args.fsdp is None else args.fsdp == "on",
            remat=None if args.remat is None else args.remat == "on",
            microbatches=args.microbatches,
            overrides=_parse_overrides(args.override),
        )
        if args.override:
            rec["overrides"] = args.override
    except Exception:
        rec = {
            "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
            "quant": args.quant, "status": "error",
            "error": traceback.format_exc()[-2000:],
        }
        print(rec["error"])
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
    print(json.dumps({k: v for k, v in rec.items() if k != "error"}, default=str))
    return 0 if rec.get("status") in ("ok", "skipped") else 1


if __name__ == "__main__":
    raise SystemExit(main())
