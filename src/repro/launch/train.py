"""Production training driver.

Wires the full stack: arch config → model → mesh + partition rules →
sharded train state → data pipeline (host-sharded) → fault-tolerant loop
(checkpoint/resume, straggler monitor, preemption saves).

On a real TPU pod this runs under `jax.distributed.initialize()` with one
process per host; in this CPU container it exercises the identical code
path on a 1-device mesh (or a fake multi-device mesh via
--fake-devices N, which must be set before jax initializes — hence the
env-var handling at the top).

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --steps 50 --ckpt /tmp/ckpt [--fake-devices 4 --mesh-shape 2,2]
"""
import argparse
import os
import sys


def _preparse_fake_devices():
    if "--fake-devices" in sys.argv:
        n = sys.argv[sys.argv.index("--fake-devices") + 1]
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n} "
            + os.environ.get("XLA_FLAGS", "")
        )


_preparse_fake_devices()

import jax  # noqa: E402  (after XLA_FLAGS)
import jax.numpy as jnp  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--qat", default=None)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--fake-devices", type=int, default=0)
    ap.add_argument("--mesh-shape", default=None,
                    help="data,model (e.g. 2,2); default: all devices on data")
    args = ap.parse_args()

    from repro.checkpoint import CheckpointManager
    from repro.configs import get_config, get_reduced_config
    from repro.configs.base import TrainConfig
    from repro.data import DataIterator
    from repro.models import build_model
    from repro.optim import adamw
    from repro.parallel import sharding as sh
    from repro.train.loop import TrainState, init_train_state, make_train_step, run_training

    cfg = (get_reduced_config if args.reduced else get_config)(args.arch)
    if args.qat:
        from repro.launch.dryrun import _parse_quant

        cfg = cfg.with_quant(_parse_quant(args.qat))
    model = build_model(cfg)

    n_dev = len(jax.devices())
    if args.mesh_shape:
        dshape = tuple(int(x) for x in args.mesh_shape.split(","))
    else:
        dshape = (n_dev, 1)
    mesh = jax.make_mesh(dshape, ("data", "model"))
    sh.set_mesh_context(mesh, ("data",))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}, "
          f"arch: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")

    tc = TrainConfig(
        lr=args.lr, warmup_steps=min(20, args.steps // 5), total_steps=args.steps,
        microbatches=args.microbatches,
        grad_compress_bits=8 if args.compress else 0,
        log_every=max(1, args.steps // 20),
        checkpoint_every=max(1, args.steps // 3),
    )
    # Host sharding: in multi-process deployments each host materializes
    # its slice; single-process here → host 0 of 1.
    data = DataIterator(cfg, global_batch=args.global_batch, seq_len=args.seq,
                        seed=tc.seed, host_id=jax.process_index(),
                        host_count=jax.process_count(), branch=8)
    mgr = CheckpointManager(args.ckpt, keep=2, async_save=True) if args.ckpt else None

    def hook(step, rec):
        print(f"step {rec['step']:5d}  loss {rec['loss']:.4f}  "
              f"gnorm {rec['grad_norm']:.2f}  {rec['dt']*1e3:.0f} ms"
              + ("  [STRAGGLER]" if rec.get("straggler") else ""))

    with mesh:
        state, history = run_training(model, tc, data, checkpoint_mgr=mgr,
                                      hooks=hook)
    if mgr:
        mgr.wait()
    print(f"done: {len(history)} logged steps, "
          f"final loss {history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
