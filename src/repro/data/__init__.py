from repro.data.pipeline import DataIterator, SyntheticLM  # noqa: F401
