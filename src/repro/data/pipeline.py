"""Deterministic, checkpointable synthetic LM data pipeline.

Production-shaped even though the corpus is synthetic: the stream is
deterministic in (seed, step, host), sharded by host (each host materializes
only its slice of the global batch — the multi-host contract), double-
buffered with a background prefetch thread (the paper's load/compute/store
pipelining at the input layer), and the iterator state (step counter) is
part of the checkpoint so restarts resume mid-epoch exactly.

The token distribution is a Zipfian mixture with a Markov backbone so that
a ~100M-param model actually has something learnable (examples/train_lm.py
shows loss dropping well below the unigram entropy floor).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


class SyntheticLM:
    """Markov-chain token stream with Zipfian unigram marginals."""

    def __init__(self, vocab: int, seed: int = 0, branch: int = 32):
        self.vocab = vocab
        self.branch = branch
        rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        # Each token transitions to `branch` successors (deterministic table)
        self.succ = rng.integers(0, vocab, size=(min(vocab, 4096), branch))

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        out = np.empty((batch, seq), dtype=np.int32)
        cur = rng.choice(self.vocab, size=batch, p=self.unigram)
        for t in range(seq):
            out[:, t] = cur
            explore = rng.random(batch) < 0.1
            nxt = self.succ[cur % self.succ.shape[0],
                            rng.integers(0, self.branch, batch)]
            cur = np.where(
                explore, rng.choice(self.vocab, size=batch, p=self.unigram), nxt
            ).astype(np.int64)
        return out


class DataIterator:
    """Deterministic per-host iterator with get_state/set_state.

    Batches are a dict matching the model's input_specs: tokens for LM
    archs; frames+labels for the encoder; patches+tokens for the VLM.
    """

    def __init__(
        self,
        cfg,
        global_batch: int,
        seq_len: int,
        seed: int = 0,
        host_id: int = 0,
        host_count: int = 1,
        prefetch: int = 2,
        branch: int = 32,
    ):
        assert global_batch % host_count == 0
        self.cfg = cfg
        self.local_batch = global_batch // host_count
        self.seq_len = seq_len
        self.seed = seed
        self.host_id = host_id
        self.step = 0
        self.source = SyntheticLM(cfg.vocab, seed, branch=branch)
        self._queue: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- deterministic batch construction --------------------------------
    def _rng_for(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.host_id
        )

    def batch_at(self, step: int) -> dict:
        rng = self._rng_for(step)
        cfg = self.cfg
        if cfg.frontend == "frame_stub":
            frames = rng.standard_normal(
                (self.local_batch, self.seq_len, cfg.frontend_dim), np.float32
            )
            labels = rng.integers(
                0, cfg.vocab, (self.local_batch, self.seq_len), dtype=np.int32
            )
            return {"frames": frames, "labels": labels}
        if cfg.frontend == "patch_stub":
            P = cfg.num_prefix_embeds
            patches = rng.standard_normal(
                (self.local_batch, P, cfg.frontend_dim), np.float32
            )
            tokens = self.source.sample(rng, self.local_batch, self.seq_len - P)
            return {"patches": patches, "tokens": tokens}
        return {"tokens": self.source.sample(rng, self.local_batch, self.seq_len)}

    # -- iterator protocol with prefetch ---------------------------------
    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            try:
                self._queue.put((step, self.batch_at(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        while True:
            step, batch = self._queue.get()
            if step == self.step:  # drop stale prefetches after set_state
                self.step += 1
                return batch
            if step > self.step:  # worker ahead of a rewind: restart it
                self._restart_worker()

    def _restart_worker(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
        while not self._queue.empty():
            self._queue.get_nowait()
        self._stop.clear()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # -- checkpointable state ---------------------------------------------
    def get_state(self) -> dict:
        return {"step": self.step, "seed": self.seed, "host_id": self.host_id}

    def set_state(self, state: dict) -> None:
        self.step = int(state["step"])
        assert int(state["seed"]) == self.seed, "data seed mismatch on restore"
        if self._thread is not None:
            self._restart_worker()
