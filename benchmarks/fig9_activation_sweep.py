"""Paper Fig. 9 — activation-precision sweep (4–8b) at 8-bit weights,
GX650, Hetero-DLA with DP-M4S / SY-M4L / DP-M4L.

Paper claims reproduced here:
  * average speedup at 6-bit activations ≈ 2.16× (DP-M4S 1.92×,
    SY-M4L 2.26×, DP-M4L 2.31×),
  * a speedup dip when activations reach 5 bits (DSP-packing factor
    doubles for the DLA baseline),
  * DSP stalls ≈ 4.8% of execution for VGG-16 (8b W, 4–8b A).
Accuracy columns report the paper's published ImageNet top-1 (we cannot
train ImageNet in this container); our quantization-error proxy (SQNR on
matched-distribution tensors) is in benchmarks/quant_error.py.
"""
from __future__ import annotations

from benchmarks.common import emit, mean, timed


# Paper Fig. 9 published top-1 accuracy anchors (FP32 → per-activation-bit).
PAPER_TOP1 = {
    "vgg16": {"fp32": 73.52, 6: 73.19, 5: 72.9, 4: 71.9},
    "resnet18": {"fp32": 71.44, 6: 71.09, 5: 70.5, 4: 69.2},
    "resnet34": {"fp32": 75.16, 6: 74.9, 5: 74.3, 4: 73.0},
}


def run() -> dict:
    from repro.core import dse, simulate as sim
    from repro.core.workloads import NETWORKS

    nets = ("alexnet", "vgg16", "resnet18")
    configs = ("DP-M4S", "SY-M4L", "DP-M4L")
    results = {}
    for cfg_name in configs:
        cim = sim.CIM_ARCHS[cfg_name]
        by_a = {}
        for a in (8, 7, 6, 5, 4):
            sp, us = timed(
                lambda: [
                    dse.speedup(NETWORKS[n], 8, a, sim.GX650, cim) for n in nets
                ],
                repeat=1,
            )
            by_a[a] = mean(sp)
            emit(f"fig9/{cfg_name}/a{a}", us, f"speedup={by_a[a]:.2f}x")
        results[cfg_name] = by_a

    avg6 = mean(results[c][6] for c in configs)
    emit("fig9/avg@a6", 0.0, f"speedup={avg6:.2f}x paper=2.16x")

    # DSP stall share for VGG-16 (paper: ~4.8%).
    from repro.core.workloads import NETWORKS as NW

    cim = sim.CIM_ARCHS["SY-M4L"]
    best = dse.search(NW["vgg16"], 8, 6, sim.GX650, cim)
    tot = stall = 0.0
    for layer, ni in zip(NW["vgg16"], best.per_layer_ni):
        import dataclasses

        lanes = cim.lanes(8)
        t = dataclasses.replace(best.tile, n_w=lanes // ni, n_i=ni)
        r = sim.simulate_layer(layer, t, 8, 6, sim.GX650, cim)
        tot += r.cycles
        stall += r.stall_cycles
    emit("fig9/vgg16_dsp_stall", 0.0,
         f"stall_frac={stall/tot:.3f} paper~0.048")
    results["avg@a6"] = avg6
    results["stall_frac"] = stall / tot
    return results


if __name__ == "__main__":
    run()
