"""Paper Fig. 12 — iso-area M4BRAM vs DSP: GX-M4 (2489 M4BRAM-L, no DSP)
vs GX-DSP (2489 plain BRAM + 640 DSP), weight 8-bit, activations 4–8b,
AlexNet/ResNet-18/ResNet-34. Paper: 1.98× (sync) / 2.95× (double-pumped).

This is the figure the simulator's single free constant
(_BPE_EFFICIENCY) is calibrated against — see core/simulate.py.
"""
from __future__ import annotations

from benchmarks.common import emit, mean, timed

NETS = ("alexnet", "resnet18", "resnet34")


def run() -> dict:
    from repro.core import dse, simulate as sim
    from repro.core.workloads import NETWORKS

    gx_m4 = sim.Fpga("GX-M4", 0, 2489)
    gx_dsp = sim.Fpga("GX-DSP", 640, 2489)
    results = {}
    for cfg_name, paper in (("SY-M4L", 1.98), ("DP-M4L", 2.95)):
        cim = sim.CIM_ARCHS[cfg_name]
        vals = []
        for net in NETS:
            for a in (4, 5, 6, 7, 8):
                def one():
                    base = dse.search(NETWORKS[net], 8, a, gx_dsp, None)
                    m4 = dse.search(NETWORKS[net], 8, a, gx_m4, cim)
                    return base.cycles / m4.cycles

                s, us = timed(one, repeat=1)
                vals.append(s)
                emit(f"fig12/{cfg_name}/{net}/a{a}", us, f"speedup={s:.2f}x")
        results[cfg_name] = mean(vals)
        emit(f"fig12/{cfg_name}/avg", 0.0,
             f"speedup={results[cfg_name]:.2f}x paper={paper}x")
    return results


if __name__ == "__main__":
    run()
