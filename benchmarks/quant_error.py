"""Quantization-error proxy table (accuracy side of Fig 9 / Table III).

ImageNet accuracy cannot be measured in this container; this module
reports the measurable error statistics of the exact quantizers used by
the technique, across every supported precision: per-channel MAE-optimal
weight quantization (2/4/8b) and per-token activation quantization
(2–8b), on Gaussian tensors matched to trained-layer statistics — plus
the end-to-end matmul relative error of the packed serving path.
"""
from __future__ import annotations

from benchmarks.common import emit, timed


def run() -> dict:
    import jax.numpy as jnp
    import numpy as np

    from repro.core.quant import QuantConfig, quant_error_stats
    from repro.core.quantized_linear import pack_weight, qmatmul

    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((1024, 512)) * 0.03, jnp.float32)
    x = jnp.asarray(rng.standard_normal((64, 1024)), jnp.float32)
    results = {}

    for bits in (8, 6, 4, 3, 2):
        stats, us = timed(lambda: quant_error_stats(w, bits), repeat=1)
        emit(f"quant_error/weights_b{bits}", us,
             f"sqnr_db={float(stats['sqnr_db']):.1f} mae={float(stats['mae']):.5f}")
        results[f"w{bits}"] = float(stats["sqnr_db"])

    y_ref = x @ w
    for w_bits, a_bits in ((8, 8), (4, 8), (4, 6), (2, 8), (2, 4)):
        cfg = QuantConfig(w_bits=w_bits, a_bits=a_bits)
        pw = pack_weight(w, cfg)

        def one():
            y = qmatmul(x, pw, cfg)
            return float(jnp.linalg.norm(y - y_ref) / jnp.linalg.norm(y_ref))

        rel, us = timed(one, repeat=1)
        emit(f"quant_error/matmul_w{w_bits}a{a_bits}", us,
             f"rel_err={rel:.4f} packed_bytes={pw.hbm_bytes()}")
        results[f"w{w_bits}a{a_bits}"] = rel
    return results


if __name__ == "__main__":
    run()
