"""Robustness benchmark: preemption vs queueing under an overcommitted
pool, plus a chaos-survival row.

An 8-request burst is served through a pool holding roughly half its
peak demand, twice: with pool-pressure preemption (victims resume warm
from prefix-cached blocks) and with plain FIFO queueing (--no-preempt).
Reported per mode: throughput and the p50/p99 inter-token latency (ITL)
measured from `on_token` wall-clock timestamps — preemption trades a
victim's ITL spike for head-of-queue progress, so the interesting
comparison is p99 vs throughput, not either number alone.

The chaos row replays the same workload with every fault seam armed
(seeded, capped) and reports what fired and what survived; every
survivor's tokens are asserted in-run to be bitwise identical to the
fault-free preemption run — the alloc/kernel faults and any preemptions
they trigger must be invisible in surviving outputs.

Wall-clock numbers are CPU interpret/jit-mode magnitudes: relative
ordering between the rows is the signal, not absolute tok/s.

Run:  PYTHONPATH=src python -m benchmarks.chaos_bench [--quick]
Writes BENCH_chaos.json at the repo root.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from benchmarks.common import emit


def _workload(cfg, n, max_new):
    import numpy as np

    from repro.serving import Request

    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, 8 + 2 * (i % 5)
                                        ).astype(np.int64),
                    max_new_tokens=max_new)
            for i in range(n)]


def _serve(cfg, params, reqs, *, chaos=None, preempt=None, pool_blocks=14):
    from repro.serving import ContinuousScheduler

    stamps = {}                      # rid -> [t0, t1, ...] per-token clocks

    def stamp(req, tok):
        stamps.setdefault(req.rid, []).append(time.perf_counter())

    sched = ContinuousScheduler(
        cfg, params, max_batch=3, max_ctx=64, bucket=16, paged=True,
        block_size=4, pool_blocks=pool_blocks, chunked_prefill=True,
        prefill_budget=16, preempt=preempt, chaos=chaos, on_token=stamp)
    t0 = time.perf_counter()
    done = sched.run(list(reqs))
    dt = time.perf_counter() - t0
    return done, sched, stamps, dt


def _itl_ms(stamps):
    import numpy as np

    gaps = [1e3 * (ts[i + 1] - ts[i])
            for ts in stamps.values() for i in range(len(ts) - 1)]
    if not gaps:
        return 0.0, 0.0
    return (float(np.percentile(gaps, 50)), float(np.percentile(gaps, 99)))


def run(quick: bool = False) -> dict:
    import jax

    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.serving import FaultInjector

    cfg = get_reduced_config("olmo-1b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    n = 6 if quick else 8
    max_new = 8 if quick else 14
    reqs = lambda: _workload(cfg, n, max_new)   # noqa: E731

    # Warmup compiles every prefill bucket (including warm-resume
    # lengths) + the decode step, so the timed rows measure scheduling.
    _serve(cfg, params, reqs())

    rows = []
    clean = None
    for mode, preempt in (("preempt", True), ("queue", False)):
        done, sched, stamps, dt = _serve(cfg, params, reqs(),
                                         preempt=preempt)
        assert all(r.error is None for r in done)
        if preempt:
            clean = {r.rid: r.out_tokens for r in done}
        tokens = sum(len(r.out_tokens) for r in done)
        st = sched.pool_stats()
        p50, p99 = _itl_ms(stamps)
        rows.append({
            "mode": mode, "tokens": tokens, "seconds": round(dt, 3),
            "tok_s": round(tokens / dt, 1),
            "itl_p50_ms": round(p50, 2), "itl_p99_ms": round(p99, 2),
            "preemptions": st["preemptions"],
            "pool_pressure_events": st["pool_pressure_events"],
            "head_bypasses": st["head_bypasses"],
            "prefix_hit_tokens": st["prefix_hit_tokens"],
        })
        emit(f"chaos/{mode}", 0.0,
             f"tok/s={rows[-1]['tok_s']} p99_itl={rows[-1]['itl_p99_ms']}ms "
             f"preemptions={st['preemptions']}")

    chaos = FaultInjector(13, p_alloc=0.1, p_kernel=0.1, p_nan=0.03,
                          p_callback=0.03, max_faults=10)
    done, sched, stamps, dt = _serve(cfg, params, reqs(), chaos=chaos)
    survivors = [r for r in done if r.error is None]
    for r in survivors:
        assert r.out_tokens == clean[r.rid], (
            f"chaos survivor {r.rid} diverged from the fault-free run")
    st = sched.pool_stats()
    tokens = sum(len(r.out_tokens or ()) for r in done)
    p50, p99 = _itl_ms(stamps)
    chaos_row = {
        "mode": "chaos", "tokens": tokens, "seconds": round(dt, 3),
        "tok_s": round(tokens / dt, 1),
        "itl_p50_ms": round(p50, 2), "itl_p99_ms": round(p99, 2),
        "faults_fired": st["chaos"]["fired"],
        "total_faults": st["chaos"]["total_fired"],
        "survivors": len(survivors), "failed": len(done) - len(survivors),
        "survivors_bit_identical": True,
        "kernel_fallbacks": st["kernel_fallbacks"],
        "nan_logit_events": st["nan_logit_events"],
        "preemptions": st["preemptions"],
    }
    rows.append(chaos_row)
    emit("chaos/faulted", 0.0,
         f"{chaos_row['total_faults']} faults, "
         f"{chaos_row['survivors']}/{len(done)} survived bit-identical")

    results = {f"{r['mode']}_tok_s": r["tok_s"] for r in rows}
    if quick:
        return results
    bench_path = Path(__file__).resolve().parents[1] / "BENCH_chaos.json"
    bench_path.write_text(json.dumps({
        "note": ("preemption vs FIFO queueing on an overcommitted paged "
                 "pool (reduced olmo-1b, random init, CPU jit — relative "
                 "ordering is the signal), plus the same workload under "
                 "seeded alloc/kernel/nan/callback fault injection. "
                 "Survivor streams are asserted in-run bitwise identical "
                 "to the fault-free preemption run"),
        "config": {"arch": "olmo-1b (reduced)", "requests": n,
                   "max_new": max_new, "pool_blocks": 14, "max_batch": 3,
                   "chaos_seed": 13},
        "rows": rows,
    }, indent=2) + "\n")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workload, no JSON artifact (CI smoke)")
    args = ap.parse_args()
    run(quick=args.quick)
