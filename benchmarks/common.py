"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import statistics
import sys
import time
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))


def emit(name: str, us_per_call: float, derived: str) -> None:
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.2f},{derived}")


def timed(fn, *args, repeat: int = 3, **kwargs):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6


def mean(xs):
    return statistics.mean(xs)
