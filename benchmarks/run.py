"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Run:
    PYTHONPATH=src python -m benchmarks.run [--only fig9,fig10,...]
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (
    chaos_bench,
    decode_bench,
    fig9_activation_sweep,
    fig10_vs_bramac,
    fig11_parallelism_ablation,
    fig12_vs_dsp,
    kernel_bench,
    prefix_bench,
    quant_error,
    roofline_table,
    serving_bench,
    spec_bench,
    swap_bench,
    table3_intralayer,
    tier_bench,
)

MODULES = {
    "fig9": fig9_activation_sweep,
    "fig10": fig10_vs_bramac,
    "fig11": fig11_parallelism_ablation,
    "fig12": fig12_vs_dsp,
    "table3": table3_intralayer,
    "quant_error": quant_error,
    "kernels": kernel_bench,
    "decode": decode_bench,
    "roofline": roofline_table,
    "serving": serving_bench,
    "prefix": prefix_bench,
    "spec": spec_bench,
    "swap": swap_bench,
    "tiers": tier_bench,
    "chaos": chaos_bench,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(MODULES))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(MODULES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            MODULES[name].run()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
