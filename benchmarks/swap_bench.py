"""Host-RAM spill-tier benchmark: repeat-prefix traffic on a small pool.

The capacity story behind ROADMAP item 4: a device pool sized to a
fraction of the working set (the M4BRAM/FINN framing — the paged pool is
the BRAM/HBM working set, the host store the capacity behind it) serving
multi-turn-style traffic where every conversation comes back. Several
distinct conversations (each with its OWN long history prefix + a short
turn tail, so nothing stays hot by being shared) are served in rounds
through ONE scheduler; by the time a conversation returns, the pool has
churned its blocks out. The same
workload runs twice:

  * host tier ON  — evicted refcount-0 blocks spill to the pinned host
    store and swap back into free device slots on the return visit:
    repeat admissions prefill (almost) nothing.
  * host tier OFF (--no-host-pool equivalent) — eviction is death; every
    return visit re-prefills the full prompt through the device pool.

Reported per mode: prefill tokens actually computed on the return
rounds (the deterministic compute metric — interpret-mode wall time is
not a perf signal), wall time, and for the ON mode the host-tier hit
rate and swap counters. The ON mode must recompute strictly fewer
prefill tokens, its host hit rate must be > 0, and its outputs must be
greedy bit-identical to the OFF mode's — asserted in-run, so `--quick`
doubles as the CI host-tier smoke.

Writes BENCH_swap.json at the repo root (full mode only).

Run:  PYTHONPATH=src python -m benchmarks.swap_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from benchmarks.common import emit

import jax
import numpy as np


SYS_LEN = 40          # per-conversation history (10 blocks at block_size 4)
TAIL_LEN = 4          # turn tail
MAX_NEW = 4
BLOCK = 4
POOL_BLOCKS = 28      # a ~40% slice of the full-run working set
HOST_BYTES = 64 << 20


def _conversations(n, vocab):
    from repro.serving import Request

    rng = np.random.default_rng(0)
    histories = [rng.integers(0, vocab, SYS_LEN) for _ in range(n)]
    tails = [rng.integers(0, vocab, TAIL_LEN) for _ in range(n)]

    def round_reqs(rnd):
        return [Request(rid=rnd * n + i,
                        prompt=np.concatenate([histories[i], tails[i]]),
                        max_new_tokens=MAX_NEW)
                for i in range(n)]

    return round_reqs


def run(quick: bool = False) -> dict:
    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.serving import ContinuousScheduler, assert_pool_invariants

    cfg = get_reduced_config("olmo-1b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    n = 4 if quick else 6
    rounds = 2 if quick else 3
    round_reqs = _conversations(n, cfg.vocab)

    results, tokens = {}, {}
    for mode, host_bytes in (("host_on", HOST_BYTES), ("host_off", 0)):
        sched = ContinuousScheduler(
            cfg, params, max_batch=2, max_ctx=64, bucket=8,
            paged=True, block_size=BLOCK, pool_blocks=POOL_BLOCKS,
            host_pool_bytes=host_bytes,
        )
        sched.run(round_reqs(0))            # round 0: everyone cold (+jit)
        base = sched.pool_stats()["prefill_tokens_computed"]
        out = {}
        t0 = time.perf_counter()
        for rnd in range(1, rounds):        # return visits: the contest
            for r in sched.run(round_reqs(rnd)):
                out[r.rid] = list(r.out_tokens)
            assert_pool_invariants(sched)
        wall = time.perf_counter() - t0
        stats = sched.pool_stats()
        tokens[mode] = out
        results[mode] = {
            "wall_s": round(wall, 4),
            "return_prefill_tokens": int(
                stats["prefill_tokens_computed"] - base),
            "peak_live_blocks": stats["peak_allocated_blocks"],
        }
        if host_bytes:
            results[mode].update(
                host_hit_rate=round(stats["host_hit_rate"], 3),
                host_hit_blocks=stats["host_hit_blocks"],
                swap_ins=stats["swap_ins"],
                swap_outs=stats["swap_outs"],
                host_blocks=stats["host_blocks"],
                host_bytes=stats["host_bytes"],
            )
        emit(f"swap/{mode}", results[mode]["wall_s"] * 1e6,
             f"return_prefill_tokens="
             f"{results[mode]['return_prefill_tokens']}")

    on, off = results["host_on"], results["host_off"]
    assert tokens["host_on"] == tokens["host_off"], \
        "warm-from-host outputs diverged from cold outputs"
    assert on["host_hit_rate"] > 0, \
        "return visits never hit the host tier — pool not under pressure?"
    assert on["swap_ins"] > 0 and on["swap_outs"] > 0
    assert on["return_prefill_tokens"] < off["return_prefill_tokens"], (
        f"host tier saved no prefill compute: "
        f"{on['return_prefill_tokens']} vs {off['return_prefill_tokens']}")
    summary = {
        "pool_fraction_of_working_set": round(
            POOL_BLOCKS / (n * -(-(SYS_LEN + TAIL_LEN) // BLOCK)), 2),
        "return_prefill_tokens_ratio": round(
            off["return_prefill_tokens"]
            / max(on["return_prefill_tokens"], 1), 2),
        "host_hit_rate": on["host_hit_rate"],
        "swap_ins": on["swap_ins"],
        "swap_outs": on["swap_outs"],
        "bit_identical": True,
    }
    emit("swap/summary", 0.0,
         f"prefill_tokens_ratio={summary['return_prefill_tokens_ratio']} "
         f"host_hit_rate={summary['host_hit_rate']} "
         f"swap_ins={summary['swap_ins']}")

    if quick:
        return summary
    bench_path = Path(__file__).resolve().parents[1] / "BENCH_swap.json"
    bench_path.write_text(json.dumps({
        "note": ("reduced olmo-1b on CPU; repeat-prefix rounds over a "
                 f"device pool holding {POOL_BLOCKS} blocks (~"
                 f"{int(100 * summary['pool_fraction_of_working_set'])}% "
                 "of the working set); host_on spills evicted blocks to "
                 "the pinned host store and swaps them back on return "
                 "visits, host_off re-prefills cold; outputs asserted "
                 "greedy bit-identical between the modes"),
        "config": {"conversations": n, "rounds": rounds, "max_batch": 2,
                   "block_size": BLOCK, "pool_blocks": POOL_BLOCKS,
                   "sys_prompt_tokens": SYS_LEN, "tail_tokens": TAIL_LEN,
                   "max_new_tokens": MAX_NEW, "host_pool_bytes": HOST_BYTES},
        "modes": results,
        "summary": summary,
    }, indent=2) + "\n")
    print(f"wrote {bench_path}")
    return summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick)


if __name__ == "__main__":
    main()
