"""Paper Fig. 10 — M4BRAM vs BRAMAC at uniform 2/4/8-bit precision.

Paper claims: speedup over DLA — M4BRAM-S 2.16×, M4BRAM-L 2.13×,
BRAMAC-1DA 1.35×, BRAMAC-2SA 1.67× (averages over AlexNet/VGG-16/
ResNet-18/ResNet-34/ViT-attn × {2,4,8}-bit); M4BRAM / BRAMAC = 1.43×.
8-bit VGG/ResNets use GX650 (DLA buffer model), everything else GX400.
"""
from __future__ import annotations

from benchmarks.common import emit, mean, timed

NETS = ("alexnet", "vgg16", "resnet18", "resnet34", "vit-attn")
CONFIGS = ("DP-M4S", "SY-M4L", "BRAMAC-1DA", "BRAMAC-2SA")


def _fpga_for(net: str, p: int):
    from repro.core import simulate as sim

    return sim.GX650 if (p == 8 and net in ("vgg16", "resnet18", "resnet34")) \
        else sim.GX400


def run() -> dict:
    from repro.core import dse, simulate as sim
    from repro.core.workloads import NETWORKS

    results = {}
    for cfg_name in CONFIGS:
        cim = sim.CIM_ARCHS[cfg_name]
        vals = []
        for net in NETS:
            for p in (2, 4, 8):
                s, us = timed(
                    lambda: dse.speedup(NETWORKS[net], p, p, _fpga_for(net, p), cim),
                    repeat=1,
                )
                vals.append(s)
                emit(f"fig10/{cfg_name}/{net}/w{p}a{p}", us, f"speedup={s:.2f}x")
        results[cfg_name] = mean(vals)
        emit(f"fig10/{cfg_name}/avg", 0.0, f"speedup={results[cfg_name]:.2f}x")

    m4 = mean([results["DP-M4S"], results["SY-M4L"]])
    br = mean([results["BRAMAC-1DA"], results["BRAMAC-2SA"]])
    results["m4_over_bramac"] = m4 / br
    emit("fig10/m4_over_bramac", 0.0,
         f"ratio={m4/br:.2f}x paper=1.43x")
    return results


if __name__ == "__main__":
    run()
