"""Cross-request prefix-cache benchmark: shared-system-prompt serving.

Every request carries the same long system prompt plus a short unique
tail — the canonical production shape (chat serving, RAG preambles,
few-shot headers). The same workload is served twice through the
continuous scheduler's paged pool:

  * prefix-cache ON  (default) — the system prompt's blocks are resident
    after the first request; later admissions map them into their block
    tables (refcounted, copy-on-write on append) and prefill only the
    unique tail.
  * prefix-cache OFF — every request re-allocates and re-prefills the
    full prompt (the PR 3/4 behaviour).

Reported per mode: mean time-to-first-token measured at its source (the
admission step — solo/suffix prefill + first sampled token — timed on an
idle scheduler, best of several identical passes, so queueing and
neighbouring decode steps can't pollute it), the wall time of a
concurrent all-at-once pass, and that pass's peak *live* pool footprint
(blocks referenced by a row's table — the memory a right-sized pool must
actually hold). The ON mode must win both TTFT and footprint, and its
outputs must be greedy bit-identical to the OFF mode's — that equality
is asserted, so `--quick` doubles as the CI prefix-cache smoke (hit
rate > 0 + bit-identity vs cold).

Writes BENCH_prefix.json at the repo root (full mode only).

Run:  PYTHONPATH=src python -m benchmarks.prefix_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from benchmarks.common import emit

import jax
import numpy as np


SYS_LEN = 120         # shared system prompt (30 blocks at block_size 4)
TAIL_LEN = 4          # unique per-request tail
MAX_NEW = 4
BLOCK = 4
REPEATS = 5           # best-of-N admission passes (CPU wall noise ~ the win)


def _workload(rng, n, vocab, shared):
    from repro.serving import Request

    return [
        Request(rid=i,
                prompt=np.concatenate(
                    [shared, rng.integers(0, vocab, TAIL_LEN)]),
                max_new_tokens=MAX_NEW)
        for i in range(n)
    ]


def _admission_ms(sched, make_reqs):
    """Time-to-first-token measured at its source: the admission step
    (solo prefill or suffix-only prefill + first sampled token), one
    request at a time on an otherwise idle scheduler so queueing and
    neighbouring decode steps can't pollute the number. Best of REPEATS
    identical passes per request index (pass 1 leaves the prefix cache
    hot — the steady state a long-running server sits in)."""
    best = None
    for _ in range(REPEATS):
        times = []
        for req in make_reqs():
            sched.submit(req)
            t0 = time.perf_counter()
            sched.step()                  # admit + first decode step
            times.append(time.perf_counter() - t0)
            while sched.num_active:
                sched.step()              # drain before the next request
        times = np.asarray(times)
        best = times if best is None else np.minimum(best, times)
    return best


def _serve_concurrent(sched, reqs):
    """One all-at-once pass (max_batch rows live together): deterministic
    peak-live-blocks measurement + the output tokens for the bit-identity
    assert."""
    sched.reset_pool_peak()
    t0 = time.perf_counter()
    done = sched.run(reqs)
    wall = time.perf_counter() - t0
    return wall, {r.rid: list(r.out_tokens) for r in done}


def run(quick: bool = False) -> dict:
    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.serving import ContinuousScheduler

    cfg = get_reduced_config("olmo-1b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    n = 4 if quick else 8
    shared = np.random.default_rng(0).integers(0, cfg.vocab, SYS_LEN)

    results = {}
    tokens = {}
    for mode, enabled in (("prefix_on", True), ("prefix_off", False)):
        sched = ContinuousScheduler(
            cfg, params, max_batch=2, max_ctx=192, bucket=8,
            paged=True, block_size=BLOCK, prefix_cache=enabled,
        )
        # Warmup: compiles every prefill/suffix bucket + the decode step.
        sched.run(_workload(np.random.default_rng(1), 2, cfg.vocab, shared))
        base = sched.pool_stats()["prefill_tokens_computed"]
        adm = _admission_ms(
            sched,
            lambda: _workload(np.random.default_rng(7), n, cfg.vocab, shared))
        adm_tokens = (sched.pool_stats()["prefill_tokens_computed"] - base)
        wall, tokens[mode] = _serve_concurrent(
            sched, _workload(np.random.default_rng(7), n, cfg.vocab, shared))
        stats = sched.pool_stats()
        results[mode] = {
            "wall_s": round(wall, 4),
            "mean_ttft_ms": round(1e3 * float(adm.mean()), 2),
            "p90_ttft_ms": round(1e3 * float(np.quantile(adm, 0.9)), 2),
            # Deterministic admission-compute metric (interpret-mode wall
            # time is not a perf signal — kernel-bench convention): how
            # many bucketed tokens actually ran through prefill.
            "admission_prefill_tokens": int(adm_tokens),
            "peak_live_blocks": stats["peak_allocated_blocks"],
            "peak_resident_kv_bytes": stats["peak_resident_kv_bytes"],
        }
        if enabled:
            results[mode]["prefix_hit_rate"] = round(
                stats["prefix_hit_rate"], 3)
            results[mode]["prefix_hit_blocks"] = stats["prefix_hit_blocks"]
            results[mode]["cow_copies"] = stats["cow_copies"]
        emit(f"prefix/{mode}", results[mode]["wall_s"] * 1e6,
             f"mean_ttft_ms={results[mode]['mean_ttft_ms']} "
             f"peak_live_blocks={results[mode]['peak_live_blocks']}")

    on, off = results["prefix_on"], results["prefix_off"]
    assert tokens["prefix_on"] == tokens["prefix_off"], \
        "prefix-hit outputs diverged from cold outputs"
    assert on["prefix_hit_rate"] > 0, "shared prompts should hit the cache"
    summary = {
        "ttft_speedup": round(off["mean_ttft_ms"]
                              / max(on["mean_ttft_ms"], 1e-9), 2),
        "admission_prefill_tokens_ratio": round(
            off["admission_prefill_tokens"]
            / max(on["admission_prefill_tokens"], 1), 2),
        "pool_bytes_ratio": round(
            off["peak_resident_kv_bytes"]
            / max(on["peak_resident_kv_bytes"], 1), 2),
        "bit_identical": True,
        "prefix_hit_rate": on["prefix_hit_rate"],
    }
    assert summary["admission_prefill_tokens_ratio"] > 1
    assert summary["pool_bytes_ratio"] > 1
    emit("prefix/summary", 0.0,
         f"ttft_speedup={summary['ttft_speedup']} "
         f"prefill_tokens_ratio={summary['admission_prefill_tokens_ratio']} "
         f"pool_bytes_ratio={summary['pool_bytes_ratio']} "
         f"hit_rate={summary['prefix_hit_rate']}")

    if quick:
        return summary
    bench_path = Path(__file__).resolve().parents[1] / "BENCH_prefix.json"
    bench_path.write_text(json.dumps({
        "note": ("reduced olmo-1b on CPU; every request = one shared "
                 f"{SYS_LEN}-token system prompt + a unique "
                 f"{TAIL_LEN}-token tail; prefix_on admits via refcounted "
                 "shared blocks + suffix-only prefill, prefix_off "
                 "re-prefills the full prompt; outputs asserted greedy "
                 "bit-identical between the modes"),
        "config": {"requests": n, "max_batch": 2, "block_size": BLOCK,
                   "sys_prompt_tokens": SYS_LEN, "tail_tokens": TAIL_LEN,
                   "max_new_tokens": MAX_NEW},
        "modes": results,
        "summary": summary,
    }, indent=2) + "\n")
    print(f"wrote {bench_path}")
    return summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick)


if __name__ == "__main__":
    main()
