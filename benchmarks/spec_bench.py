"""Self-speculative decoding benchmark: accepted tokens per step and the
modeled per-token weight traffic, swept over draft depth and precision.

Serves a fixed greedy workload through the continuous scheduler at
k ∈ {0, 2, 4} draft tokens per step with w2a8 and w4a8 truncated-plane
drafts, measuring the real acceptance rate, and models the HBM weight
traffic per emitted token. The traffic story is M4BRAM's: the draft is a
*plane subset* of the one resident packed buffer, so a draft step reads
only ``draft_bits / target_bits`` of the weight bytes (w4 of w8 = 1/2,
w2 of w8 = 1/4) and the verify pass reads the full buffer once for all
k+1 positions. A speculation round therefore costs

    k · frac · W  (drafts)  +  W  (verify)  +  W  (trailing decode)

weight bytes and emits ``accepted + 2`` tokens (verify's bonus token plus
the trailing decode's), against W per token for plain decode — so bytes
per token drop whenever the measured acceptance beats the draft
overhead. Wall time in CPU interpret/jit mode tracks call counts, not
TPU bytes; the modeled bytes column is the TPU-relevant number, exactly
like decode_bench's traffic model.

Run:  PYTHONPATH=src python -m benchmarks.spec_bench [--quick]
Writes BENCH_spec.json at the repo root.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.common import emit


def _packed_weight_bytes(params, draft_bits=None):
    """Total packed GEMM weight bytes in `params`; with `draft_bits`, the
    bytes a truncated-plane draft actually streams (top planes only).
    Thin alias of :func:`repro.core.quantized_linear.packed_weight_bytes`
    (shared with ``benchmarks/tier_bench.py``)."""
    from repro.core.quantized_linear import packed_weight_bytes

    return packed_weight_bytes(params, draft_bits)


def _serve(cfg, params, quant, k, draft, prompts, max_new):
    import numpy as np

    from repro.serving import ContinuousScheduler, Request

    sched = ContinuousScheduler(
        cfg, params, max_batch=2, max_ctx=64, quant=quant, bucket=16,
        paged=True, block_size=4, chunked_prefill=True, prefill_budget=8,
        speculate=k, draft_policy=draft)
    reqs = [Request(rid=i, prompt=np.asarray(p), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    done = sched.run(reqs)
    return done, sched


def run(quick: bool = False) -> dict:
    import jax
    import numpy as np

    from repro.configs import get_reduced_config
    from repro.core.quant import QuantConfig
    from repro.core.quantized_linear import quantize_params_for_serving

    from repro.models import build_model

    cfg = get_reduced_config("olmo-1b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    quant = QuantConfig(w_bits=8, a_bits=8)

    qp = quantize_params_for_serving(params, quant, min_size=1024)
    W = _packed_weight_bytes(qp)
    frac = {d: _packed_weight_bytes(qp, b) / W
            for d, b in (("w2a8", 2), ("w4a8", 4))}

    # Degenerate prompts keep even the 2-bit draft partially on-script at
    # random init; real checkpoints accept far more.
    prompts = [np.zeros(8, np.int64), (np.arange(8) % 64).astype(np.int64)]
    max_new = 12 if quick else 24
    ks = [0, 2] if quick else [0, 2, 4]
    drafts = ["w4a8"] if quick else ["w2a8", "w4a8"]

    base_done, base = _serve(cfg, params, quant, 0, "w4a8", prompts, max_new)
    base_tokens = sum(len(r.out_tokens) for r in base_done)
    base_steps = base.steps_run
    ref_streams = {r.rid: r.out_tokens for r in base_done}

    rows = []
    results = {}
    for draft in drafts:
        for k in ks:
            if k == 0:
                done, sched = base_done, base
            else:
                done, sched = _serve(cfg, params, quant, k, draft,
                                     prompts, max_new)
            st = sched.pool_stats()
            tokens = sum(len(r.out_tokens) for r in done)
            # greedy speculation is a scheduling change only
            assert {r.rid: r.out_tokens for r in done} == ref_streams
            steps = sched.steps_run
            rounds = st["spec_rounds"]
            acc = st["spec_acceptance_rate"]
            # Weight bytes: every decode step streams W once (batched —
            # shared across slots), every draft step streams the plane
            # fraction once (also batched), and every verify call streams
            # W. Verify is batched too (one multi-row call per tier group
            # per round — all slots here are untiered, so one per round),
            # which the spec_verify_calls counter already reflects.
            step_bytes = (steps * W + rounds * k * frac[draft] * W
                          + sched.spec_verify_calls * W)
            row = {
                "draft": draft, "k": k,
                "draft_weight_frac": round(frac[draft], 3),
                "tokens": tokens, "steps": steps, "spec_rounds": rounds,
                "accepted_tokens_per_step":
                    round(st["spec_accepted_tokens"] / max(steps, 1), 3),
                "tokens_per_step": round(tokens / max(steps, 1), 3),
                "acceptance_rate": round(acc, 3),
                "weight_bytes_per_token_model":
                    round(step_bytes / max(tokens, 1)),
                "vs_k0_bytes_per_token": round(
                    (step_bytes / max(tokens, 1))
                    / (base_steps * W / max(base_tokens, 1)), 3),
            }
            rows.append(row)
            results[f"{draft}_k{k}_tokens_per_step"] = row["tokens_per_step"]
            emit(f"spec/{draft}/k{k}", 0.0,
                 f"acc={acc:.2f} tok/step={row['tokens_per_step']} "
                 f"bytes/tok={row['weight_bytes_per_token_model']}")

    if quick:
        return results
    bench_path = Path(__file__).resolve().parents[1] / "BENCH_spec.json"
    bench_path.write_text(json.dumps({
        "note": ("self-speculative decoding from the resident bit-plane "
                 "weights on the reduced olmo-1b at random init (greedy, "
                 "bit-identity asserted against k=0 in-run). "
                 "weight_bytes_per_token_model is MODELED, not measured: "
                 "drafts stream only the kept top planes of the one "
                 "packed buffer (w4 of w8 = 1/2 the bytes, w2 = 1/4), "
                 "verify streams it fully once per round. Acceptance at "
                 "random init is a floor — trained checkpoints accept "
                 "far more, and bytes/token falls as acceptance rises "
                 "while the k=0 row always pays full-precision reads"),
        "config": {"arch": "olmo-1b (reduced)", "quant": "w8a8",
                   "packed_weight_bytes": W,
                   "draft_weight_frac": {d: round(f, 3)
                                         for d, f in frac.items()},
                   "max_new": max_new, "prompts": len(prompts)},
        "rows": rows,
    }, indent=2) + "\n")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer cells, no JSON artifact (CI smoke)")
    args = ap.parse_args()
    run(quick=args.quick)
