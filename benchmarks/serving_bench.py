"""Static vs continuous-batching serving benchmark + paged-pool
utilization.

For each arrival rate, the same mixed-length workload (short and long
prompts, short and long outputs) is served two ways:

  * static     — requests queue until a batch slot opens, then run as a
    classic static batch (`ServingEngine.generate_static`): every request
    in a batch waits for the slowest one, and queued requests wait for the
    whole batch to drain.
  * continuous — `ContinuousScheduler` over the paged block-pool KV cache:
    a request is admitted the moment a slot frees mid-decode and retires
    at its own max_new/EOS; KV blocks are committed per actual footprint.

Each continuous row carries a pool_utilization column (peak paged
resident KV bytes vs the contiguous per-slot reservation), and a separate
overcommit section serves a workload through a pool smaller than the
summed contiguous `max_ctx` reservations of its concurrently-live
requests — with outputs bit-identical to the contiguous scheduler's.

Reports per-mode throughput and mean/p90 request latency (completion −
arrival, wall clock) and writes BENCH_serving.json at the repo root.
Continuous batching should win mean latency at every rate — that gap is
the point of the subsystem.

A chunked-prefill section sweeps prompt length × arrival rate with a
short victim request decoding throughout: each cell serves the same
stream with Sarathi-style chunked prefill on and off and reports the
victim's inter-token latency (p50/p99 — the p99 captures the admission
stall) plus the long requests' mean TTFT. Outputs must be bit-identical
between the two modes (asserted), and chunking must win p99 ITL at the
longest prompt (asserted — that bound is the point of the feature).

Run:  PYTHONPATH=src python -m benchmarks.serving_bench [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

from benchmarks.common import emit

import jax
import numpy as np


def _requests(rng, n, vocab, rate):
    """Mixed prompt/output lengths; Poisson-ish arrivals at `rate` req/s
    (rate 0 = everything queued at t=0)."""
    reqs = []
    t = 0.0
    from repro.serving import Request

    for i in range(n):
        plen = int(rng.integers(4, 24))
        max_new = int(rng.integers(2, 14))
        reqs.append(Request(rid=i, prompt=rng.integers(0, vocab, plen),
                            max_new_tokens=max_new, arrival_time=t))
        if rate > 0:
            t += float(rng.exponential(1.0 / rate))
    return reqs


def _run_static(engine, reqs):
    """Arrival-aware static serving: collect due requests, run them as a
    static batch, repeat. Latency = completion − arrival."""
    queue = sorted(reqs, key=lambda r: r.arrival_time)
    t0 = time.perf_counter()
    done = []
    while queue:
        now = time.perf_counter() - t0
        if queue[0].arrival_time > now:
            time.sleep(min(queue[0].arrival_time - now, 0.05))
            continue
        # Due requests are a prefix of the arrival-sorted queue.
        n_due = sum(r.arrival_time <= now for r in queue)
        batch = queue[:min(n_due, engine.max_batch)]
        queue = queue[len(batch):]
        engine.generate_static(batch)
        t_done = time.perf_counter() - t0
        for r in batch:
            r.t_done = t_done
        done.extend(batch)
    return done, time.perf_counter() - t0


def _run_continuous(engine, reqs):
    t0 = time.perf_counter()
    done = engine.generate(reqs)
    return done, time.perf_counter() - t0


def _stats(done, wall):
    lats = [r.t_done - r.arrival_time for r in done]
    toks = sum(len(r.out_tokens) for r in done)
    return {
        "requests": len(done),
        "tokens": toks,
        "wall_s": round(wall, 3),
        "tok_per_s": round(toks / wall, 1),
        "mean_latency_ms": round(float(np.mean(lats)) * 1e3, 1),
        "p90_latency_ms": round(float(np.percentile(lats, 90)) * 1e3, 1),
    }


def _pool_overcommit(cfg, params, quick: bool) -> dict:
    """Serve a workload through a paged pool smaller than the summed
    contiguous max_ctx reservations of its concurrently-live requests,
    and check bit-identity against the contiguous scheduler."""
    from repro.serving import ContinuousScheduler

    max_batch, max_ctx, bs = 4, 64, 4
    pool_blocks = 10  # 40 pooled tokens << 4 slots * 64 reserved tokens
    n = 4 if quick else 8

    def workload():
        return _requests(np.random.default_rng(11), n, cfg.vocab, 0.0)

    contig = ContinuousScheduler(cfg, params, max_batch=max_batch,
                                 max_ctx=max_ctx, bucket=8, paged=False)
    contig_done = {r.rid: r.out_tokens for r in contig.run(workload())}

    sched = ContinuousScheduler(cfg, params, max_batch=max_batch,
                                max_ctx=max_ctx, bucket=8, paged=True,
                                block_size=bs, pool_blocks=pool_blocks)
    reqs = workload()
    for r in reqs:
        sched.submit(r)
    peak_active = 0
    while sched.num_active or sched.num_waiting:
        sched.step()
        peak_active = max(peak_active, sched.num_active)
    stats = sched.pool_stats()
    identical = all(r.out_tokens == contig_done[r.rid] for r in reqs)
    return {
        "note": ("paged pool admits concurrent requests whose summed "
                 "contiguous max_ctx reservations exceed the pool"),
        "pool_capacity_tokens": stats["capacity_tokens"],
        "peak_concurrent_requests": peak_active,
        "peak_concurrent_max_ctx_reservation_tokens": peak_active * max_ctx,
        "overcommitted": peak_active * max_ctx > stats["capacity_tokens"],
        "peak_resident_kv_bytes": stats["peak_resident_kv_bytes"],
        "contiguous_reserved_kv_bytes": stats["reserved_kv_bytes"],
        "bit_identical_to_contiguous": identical,
    }


def _chunked_sweep(cfg, params, quick: bool) -> list:
    """Prompt length × arrival rate, chunked prefill on vs off.

    One short "victim" request decodes throughout while long prompts are
    admitted into the remaining slots. Solo prefill runs the whole
    prompt in the admission step — the victim's inter-token latency
    spikes by the full prefill cost (the p99). Chunked prefill bounds
    every step to --prefill-budget prompt tokens. Both modes serve the
    identical stream; outputs are asserted bit-identical per cell.

    The sweep runs both modes under the XLA ``reference`` backend: on
    CPU the default interpret backend executes pallas grids in Python,
    so its per-call overhead (a correctness-simulator artifact) would
    swamp the per-step work bound being measured. Under one compiled
    backend for both modes, each cell isolates exactly what this
    subsystem changes — how much prefill work shares a step with
    decode."""
    from repro.kernels import get_registry
    from repro.serving import ContinuousScheduler, Request

    # Prompts long enough that the solo prefill's token-dependent cost
    # dominates per-call dispatch overhead on the reduced CPU model —
    # below ~128 tokens both modes' steps are all fixed cost and the
    # cells measure noise.
    budget, bs, bucket = 16, 8, 64
    plens = [384] if quick else [96, 256, 512]
    rates = [0.0] if quick else [0.0, 20.0]
    n_long = 2 if quick else 3
    victim_new = 24 if quick else 48
    max_ctx = max(-(-p // bucket) * bucket for p in plens) + bucket

    def stream(rng_seed, plen, rate):
        rng = np.random.default_rng(rng_seed)
        reqs = [Request(0, rng.integers(0, cfg.vocab, 8),
                        max_new_tokens=victim_new, arrival_time=0.0)]
        t = 0.01
        for i in range(n_long):
            reqs.append(Request(i + 1, rng.integers(0, cfg.vocab, plen),
                                max_new_tokens=6, arrival_time=t))
            t += 1.0 / rate if rate else 0.01
        return reqs

    # One scheduler per mode, reused across cells so jit caches warm up
    # once. Prefix caching is off: every admission must be a cold
    # prefill, or the second pass over a stream would skip the very work
    # being measured. Built (= traced) inside the reference-backend
    # scope so every compiled step uses it.
    with get_registry().use("reference"):
        scheds = {}
        for chunked in (True, False):
            scheds[chunked] = ContinuousScheduler(
                cfg, params, max_batch=3, max_ctx=max_ctx, bucket=bucket,
                paged=True, block_size=bs, prefix_cache=False,
                chunked_prefill=chunked, prefill_budget=budget)
        for chunked, sched in scheds.items():  # compile every cell's shapes
            for plen in plens:
                sched.run(stream(3, plen, 0.0))

        rows = _sweep_cells(scheds, stream, plens, rates)
    longest = [c for c in rows if c["prompt_len"] == max(plens)]
    assert all(c["p99_itl_speedup"] > 1.0 for c in longest), \
        "chunked prefill did not improve p99 ITL at the longest prompt"
    return rows


def _sweep_cells(scheds, stream, plens, rates):
    rows = []
    for plen in plens:
        for rate in rates:
            cell = {"prompt_len": plen,
                    "arrival_rate_per_s": rate if rate else "all-at-once"}
            outs = {}
            for chunked, sched in scheds.items():
                stamps = {}
                sched.on_token = (lambda req, tok:
                                  stamps.setdefault(req.rid, [])
                                  .append(time.perf_counter()))
                done = sched.run(stream(7, plen, rate))
                sched.on_token = None
                outs[chunked] = {r.rid: r.out_tokens for r in done}
                itl = np.diff(stamps[0]) * 1e3
                ttft = [r.t_first - r.arrival_time
                        for r in done if r.rid != 0]
                mode = "chunked" if chunked else "solo"
                cell[mode] = {
                    "victim_itl_p50_ms": round(float(np.percentile(itl, 50)), 2),
                    "victim_itl_p99_ms": round(float(np.percentile(itl, 99)), 2),
                    "ttft_mean_ms": round(float(np.mean(ttft)) * 1e3, 1),
                }
                if chunked:
                    cell["prefill_chunks_run"] = sched.prefill_chunks_run
                emit(f"serving/chunked_{chunked}/plen_{plen}_rate_"
                     f"{rate or 'inf'}",
                     cell[mode]["victim_itl_p99_ms"] * 1e3,
                     f"itl_p50_ms={cell[mode]['victim_itl_p50_ms']} "
                     f"ttft_ms={cell[mode]['ttft_mean_ms']}")
            assert outs[True] == outs[False], \
                f"chunked outputs diverged from solo at plen={plen}"
            cell["p99_itl_speedup"] = round(
                cell["solo"]["victim_itl_p99_ms"]
                / max(cell["chunked"]["victim_itl_p99_ms"], 1e-9), 2)
            rows.append(cell)
    return rows


def run(quick: bool = False) -> dict:
    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.serving import ServingEngine

    cfg = dataclasses.replace(get_reduced_config("olmo-1b"), vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    n = 6 if quick else 12
    rates = [0.0] if quick else [0.0, 20.0, 5.0]
    max_batch = 3
    rows = []
    results = {}

    # Warmup both paths once (compiles every prefill bucket + decode).
    warm_rng = np.random.default_rng(1)
    eng = ServingEngine(cfg, params, max_batch=max_batch, bucket=8,
                        max_ctx=64)
    eng.generate_static(_requests(warm_rng, 4, cfg.vocab, 0.0))
    warm_rng = np.random.default_rng(1)
    eng.generate(_requests(warm_rng, 4, cfg.vocab, 0.0))

    for rate in rates:
        row = {"arrival_rate_per_s": rate if rate else "all-at-once"}
        for mode, runner in (("static", _run_static),
                             ("continuous", _run_continuous)):
            rng = np.random.default_rng(7)  # same workload per mode
            reqs = _requests(rng, n, cfg.vocab, rate)
            if mode == "continuous":
                eng.scheduler().reset_pool_peak()
            done, wall = runner(eng, reqs)
            st = _stats(done, wall)
            row[mode] = st
            tag = rate if rate else "inf"
            emit(f"serving/{mode}/rate_{tag}", st["wall_s"] * 1e6,
                 f"mean_latency_ms={st['mean_latency_ms']} "
                 f"tok_per_s={st['tok_per_s']}")
            results[f"{mode}_rate_{tag}"] = st["mean_latency_ms"]
        stats = eng.pool_stats()
        if stats and stats.get("paged"):
            row["pool_utilization"] = {
                "paged_peak_resident_kv_bytes":
                    stats["peak_resident_kv_bytes"],
                "contiguous_resident_kv_bytes": stats["reserved_kv_bytes"],
                "block_size": stats["block_size"],
            }
        row["latency_speedup"] = round(
            row["static"]["mean_latency_ms"]
            / max(row["continuous"]["mean_latency_ms"], 1e-9), 2)
        rows.append(row)

    pool = _pool_overcommit(cfg, params, quick)
    results["pool_overcommitted"] = pool["overcommitted"]
    results["pool_bit_identical"] = pool["bit_identical_to_contiguous"]
    assert pool["bit_identical_to_contiguous"], \
        "paged outputs diverged from contiguous"

    chunk_rows = _chunked_sweep(cfg, params, quick)
    results["chunked_p99_itl_speedup"] = chunk_rows[-1]["p99_itl_speedup"]

    if quick:
        # CI smoke: don't overwrite the committed full-sweep artifact.
        return results
    bench_path = Path(__file__).resolve().parents[1] / "BENCH_serving.json"
    bench_path.write_text(json.dumps({
        "note": ("reduced olmo-1b on CPU; static = batched generate with "
                 "early exit, continuous = paged-KV slot scheduler with "
                 "mid-decode admission; latency is completion - arrival "
                 "(wall clock)"),
        "config": {"max_batch": max_batch, "requests": n},
        "rows": rows,
        "pool_overcommit": pool,
        "chunked_prefill_sweep": {
            "note": ("victim inter-token latency while long prompts are "
                     "admitted, chunked (budget=16) vs solo prefill; "
                     "p99 captures the admission stall; outputs "
                     "bit-identical between modes"),
            "rows": chunk_rows,
        },
    }, indent=2) + "\n")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="one rate, fewer requests (CI smoke)")
    args = ap.parse_args()
    run(quick=args.quick)
