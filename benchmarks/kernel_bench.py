"""Kernel microbenchmarks (interpret-mode wall time is NOT a TPU number —
these rows exist to track relative cost of the bit-plane path vs the dense
reference on CPU and to exercise the jit'd wrappers end-to-end)."""
from __future__ import annotations

from benchmarks.common import emit, timed


def run() -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.quant import QuantConfig
    from repro.core.quantized_linear import pack_weight, qmatmul
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    results = {}
    for (m, k, n, ab) in [(128, 512, 256, 8), (128, 512, 256, 4), (256, 1024, 512, 2)]:
        x = jnp.asarray(rng.integers(-(1 << (ab - 1)), 1 << (ab - 1), (m, k)), jnp.int8)
        w = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
        out, us = timed(
            lambda: jax.block_until_ready(
                ops.bitplane_matmul(x, w, a_bits=ab)
            ),
            repeat=3,
        )
        emit(f"kernel/bitplane_matmul/{m}x{k}x{n}_a{ab}", us,
             f"planes={-(-ab//2)}")
        results[f"bitplane_a{ab}"] = us

    xf = jnp.asarray(rng.normal(size=(256, 1024)), jnp.float32)
    _, us = timed(lambda: jax.block_until_ready(ops.quantize_rows(xf, bits=6)[0]),
                  repeat=3)
    emit("kernel/quantize_rows/256x1024_b6", us, "fused absmax+round")

    wf = jnp.asarray(rng.normal(size=(1024, 512)), jnp.float32)
    cfg = QuantConfig(w_bits=4, a_bits=8)
    pw = pack_weight(wf, cfg)
    _, us = timed(
        lambda: jax.block_until_ready(qmatmul(xf, pw, cfg, use_kernel=False)),
        repeat=3,
    )
    emit("kernel/qmatmul_serve_w4a8/256x1024x512", us,
         f"packed_bytes={pw.hbm_bytes()} dense_bytes={wf.size*4}")
    return results


if __name__ == "__main__":
    run()
