"""Kernel microbenchmarks (interpret-mode wall time is NOT a TPU number —
these rows exist to track relative cost of the bit-plane path vs the dense
reference on CPU and to exercise the jit'd wrappers end-to-end).

Also writes BENCH_fused_matmul.json at the repo root: fused vs unfused
serve-path wall time plus the HBM-bytes-moved model — the quantity the
fusion actually optimizes (interpret wall time only proves both paths run;
the bytes model is the TPU-relevant number).
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit, timed


def _hbm_bytes(m: int, k: int, n: int, a_bits: int, fused: bool) -> dict:
    """HBM traffic model for one serve-path matmul (fp32 x, int8 codes,
    int32 acc, fp32 scales; weights counted once as packed bytes)."""
    x_in = m * k * 4
    codes_roundtrip = 0 if fused else 2 * m * k  # int8 write + re-read
    w_in = k * n  # int8 codes (precision-scaled packing tracked elsewhere)
    out = m * n * 4 + m * 4
    total = x_in + codes_roundtrip + w_in + out
    return {"x_in": x_in, "codes_roundtrip": codes_roundtrip,
            "w_in": w_in, "out": out, "total": total}


def run() -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.quant import QuantConfig
    from repro.core.quantized_linear import pack_weight, qmatmul
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    results = {}
    for (m, k, n, ab) in [(128, 512, 256, 8), (128, 512, 256, 4), (256, 1024, 512, 2)]:
        x = jnp.asarray(rng.integers(-(1 << (ab - 1)), 1 << (ab - 1), (m, k)), jnp.int8)
        w = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
        out, us = timed(
            lambda: jax.block_until_ready(
                ops.bitplane_matmul(x, w, a_bits=ab)
            ),
            repeat=3,
        )
        emit(f"kernel/bitplane_matmul/{m}x{k}x{n}_a{ab}", us,
             f"planes={-(-ab//2)}")
        results[f"bitplane_a{ab}"] = us

    xf = jnp.asarray(rng.normal(size=(256, 1024)), jnp.float32)
    _, us = timed(lambda: jax.block_until_ready(ops.quantize_rows(xf, bits=6)[0]),
                  repeat=3)
    emit("kernel/quantize_rows/256x1024_b6", us, "fused absmax+round")

    wf = jnp.asarray(rng.normal(size=(1024, 512)), jnp.float32)
    cfg = QuantConfig(w_bits=4, a_bits=8)
    pw = pack_weight(wf, cfg)
    _, us = timed(
        lambda: jax.block_until_ready(qmatmul(xf, pw, cfg, use_kernel=False)),
        repeat=3,
    )
    emit("kernel/qmatmul_serve_w4a8/256x1024x512", us,
         f"packed_bytes={pw.hbm_bytes()} dense_bytes={wf.size*4}")

    # --- fused vs unfused serve path ------------------------------------
    fused_rows = []
    for (m, k, n, ab) in [(128, 512, 256, 8), (256, 1024, 512, 4)]:
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        w = jnp.asarray(rng.integers(-8, 8, (k, n)), jnp.int32)

        def unfused():
            q, s = ops.quantize_rows(x, bits=ab)
            return jax.block_until_ready(
                ops.bitplane_matmul(q, w, a_bits=ab))

        def fused():
            acc, s = ops.fused_quantize_matmul(x, w, a_bits=ab)
            return jax.block_until_ready(acc)

        _, us_u = timed(unfused, repeat=3)
        _, us_f = timed(fused, repeat=3)
        bytes_u = _hbm_bytes(m, k, n, ab, fused=False)
        bytes_f = _hbm_bytes(m, k, n, ab, fused=True)
        emit(f"kernel/serve_unfused/{m}x{k}x{n}_a{ab}", us_u,
             f"hbm_bytes={bytes_u['total']}")
        emit(f"kernel/serve_fused/{m}x{k}x{n}_a{ab}", us_f,
             f"hbm_bytes={bytes_f['total']} "
             f"saved={bytes_u['total'] - bytes_f['total']}")
        fused_rows.append({
            "shape": [m, k, n], "a_bits": ab,
            "unfused_us": round(us_u, 2), "fused_us": round(us_f, 2),
            "hbm_bytes_unfused": bytes_u, "hbm_bytes_fused": bytes_f,
            "hbm_bytes_saved": bytes_u["total"] - bytes_f["total"],
        })
        results[f"fused_a{ab}"] = us_f

    bench_path = Path(__file__).resolve().parents[1] / "BENCH_fused_matmul.json"
    bench_path.write_text(json.dumps({
        "note": ("interpret-mode wall time on CPU; the HBM-bytes model is "
                 "the TPU-relevant metric (fused path eliminates the int8 "
                 "activation-code round trip)"),
        "rows": fused_rows,
    }, indent=2) + "\n")
    return results


if __name__ == "__main__":
    run()
