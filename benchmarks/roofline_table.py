"""§Roofline table: reads the dry-run sweep JSONL and prints the
per-(arch × shape) roofline terms for the single-pod mesh."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun.jsonl"


def load(mesh: str = "single"):
    rows = {}
    if not RESULTS.exists():
        return rows
    for line in RESULTS.read_text().splitlines():
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        if r.get("mesh") == mesh and r.get("quant", "none") == "none":
            rows[(r["arch"], r["shape"])] = r  # later lines win (resumable)
    return rows


def run() -> dict:
    rows = load("single")
    ok = 0
    for (arch, shape), r in sorted(rows.items()):
        if r.get("status") == "skipped":
            emit(f"roofline/{arch}/{shape}", 0.0, f"SKIP({r.get('reason','')[:40]})")
            continue
        if r.get("status") != "ok":
            emit(f"roofline/{arch}/{shape}", 0.0, f"status={r.get('status')}")
            continue
        ok += 1
        emit(
            f"roofline/{arch}/{shape}",
            r.get("compile_s", 0.0) * 1e6,
            f"compute={r['compute_s']*1e3:.1f}ms memory={r['memory_s']*1e3:.1f}ms "
            f"collective={r['collective_s']*1e3:.1f}ms bound={r['bottleneck']} "
            f"useful={r['useful_flops_ratio']:.2f}",
        )
    emit("roofline/cells_ok", 0.0, f"count={ok}")
    return {"cells_ok": ok}


if __name__ == "__main__":
    run()
