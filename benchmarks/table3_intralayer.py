"""Paper Table III — intra-layer weight quantization (ResNet-34, GX400,
SY-M4L, 6-bit activations): R% of filters at 8-bit, rest 4-bit, speedup
measured over the all-4-bit model on plain DLA.

Paper: R=5% → 2.33×; R=15% → 2.02×; R=25% → 2.02× (the drop comes from
the GX400 running out of DSPs for the richer tiling; our DSE reproduces a
monotone non-increasing trend). Accuracy rows quote the paper (ImageNet
training is out of scope for this container); our quantization-error proxy
for the same weight mixes is reported alongside from synthetic tensors.
"""
from __future__ import annotations

from benchmarks.common import emit, timed

PAPER = {0.05: 2.33, 0.15: 2.02, 0.25: 2.02}
PAPER_TOP1 = {0.05: 75.22, 0.15: 75.26, 0.25: 75.37}


def run() -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core import dse, simulate as sim
    from repro.core.quant import QuantConfig, quant_error_stats, quantize_weights_mixed
    from repro.core.workloads import NETWORKS

    results = {}
    for r, paper in PAPER.items():
        def one():
            base = dse.search(NETWORKS["resnet34"], 4, 6, sim.GX400, None)
            het = dse.search(
                NETWORKS["resnet34"], 4, 6, sim.GX400,
                sim.CIM_ARCHS["SY-M4L"], pw8_fraction=r,
            )
            return base.cycles / het.cycles

        s, us = timed(one, repeat=1)
        results[r] = s
        # Quantization-error proxy: mixed 4b/8b vs pure 4b on a Gaussian
        # weight tensor (the direction matches Table III's accuracy gain).
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (256, 512), jnp.float32) * 0.05
        q, sc, n8 = quantize_weights_mixed(
            w, QuantConfig(w_bits=4, a_bits=6, mixed_ratio_8b=r)
        )
        err_mixed = float(jnp.mean(jnp.abs(w - q * sc)))
        e4 = quant_error_stats(w, 4)
        emit(
            f"table3/r{int(r*100)}", us,
            f"speedup={s:.2f}x paper={paper}x mae_mixed={err_mixed:.5f} "
            f"mae_4b={float(e4['mae']):.5f} paper_top1={PAPER_TOP1[r]}",
        )
    return results


if __name__ == "__main__":
    run()
