"""Paged decode-attention benchmark: gather-then-attend vs the fused
paged-attention kernel, bf16 vs int8 pool, swept over context length.

Measures one decode step's attention (single layer) against a paged KV
pool three ways:

  * gather       — `paged_gather` materializes the full contiguous
    (B, max_blocks·bs, NKV, H) copy of every row's table span, then
    `decode_attention` reads it back: the "separate buffer" the fused
    kernel eliminates. Cost scales with `max_blocks`, not live tokens.
  * gather-clamp — the same composition with the gather clamped to the
    host-known live block count (`paged_gather(..., max_blocks=live)`),
    the cheaper surviving reference path.
  * fused        — `ops.paged_attention`: block-table resolution inside
    the Pallas kernel, one pool block streamed per grid step, online
    softmax in VMEM scratch, no materialized copy.

Interpret-mode wall time proves all paths run and tracks their relative
CPU cost; the HBM-traffic model (and its v5e `memory_time_s` projection)
is the TPU-relevant number — the fused path moves ~1/3 the bytes at full
occupancy and the gap widens with context because the gather's staging
copy grows with it.

The int8 section demonstrates the ROADMAP's "paged support for the int8
KV cache": the same pooled byte budget holds ~2× the tokens (int8 codes +
per-(slot, head) fp32 scales vs bf16), verified by serving through an
int8 pool end to end.

Run:  PYTHONPATH=src python -m benchmarks.decode_bench [--quick]
Writes BENCH_paged_attention.json at the repo root.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.common import emit, timed


def _pool_case(rng, ctx, *, B, n_kv, group, H, bs, quantized):
    """A fully-occupied paged pool: every row holds ctx live tokens."""
    import jax.numpy as jnp

    from repro.models.kv_cache import quantize_kv

    maxb = ctx // bs
    nb = B * maxb + 1  # + trash block 0
    kf = jnp.asarray(rng.normal(size=(nb, bs, n_kv, H)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(nb, bs, n_kv, H)), jnp.float32)
    if quantized:
        pool_k, k_scale = quantize_kv(kf)
        pool_v, v_scale = quantize_kv(vf)
    else:
        pool_k, pool_v = kf.astype(jnp.bfloat16), vf.astype(jnp.bfloat16)
        k_scale = v_scale = None
    table = jnp.arange(1, B * maxb + 1, dtype=jnp.int32).reshape(B, maxb)
    q = jnp.asarray(rng.normal(size=(B, 1, n_kv * group, H)), jnp.bfloat16)
    q_pos = jnp.full((B,), ctx - 1, jnp.int32)
    return q, pool_k, pool_v, table, q_pos, k_scale, v_scale


def _hbm_bytes(span_tokens, *, B, n_kv, H, bs, itemsize, scale_bytes,
               fused):
    """Per-(layer, step) attention HBM traffic model over `span_tokens`
    cache slots per row. The gather path reads every table-mapped pool
    block (trash for unallocated entries), writes the contiguous staging
    copy, and re-reads it in the attention — 3× its span's pool bytes;
    the fused path streams each live block once (its span IS the live
    tokens)."""
    per_tok = n_kv * (2 * H * itemsize + scale_bytes)  # k+v (+scales)
    span = B * span_tokens * per_tok
    return span if fused else 3 * span


def run(quick: bool = False) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops
    from repro.models.common import decode_attention
    from repro.models.kv_cache import paged_gather
    from repro.roofline.hw import memory_time_s

    B, n_kv, group, H, bs = 2, 2, 2, 64, 16
    ctxs = [64, 128] if quick else [64, 128, 256, 512]
    rng = np.random.default_rng(0)
    rows = []
    results = {}

    def gather_fn(max_blocks):
        def f(q, pk, pv, tbl, pos, ks, vs):
            k_r, v_r, kpos, ks_r, vs_r = paged_gather(
                pk, pv, tbl, ks, vs, max_blocks=max_blocks)
            return decode_attention(q, k_r, v_r, kpos, pos,
                                    k_scale=ks_r, v_scale=vs_r)
        return jax.jit(f, static_argnums=())

    fused_fn = jax.jit(lambda q, pk, pv, tbl, pos, ks, vs:
                       ops.paged_attention(q, pk, pv, tbl, pos,
                                           k_scale=ks, v_scale=vs,
                                           backend="interpret"))

    for quantized in (False, True):
        dt = "int8" if quantized else "bf16"
        itemsize = 1 if quantized else 2
        scale_bytes = 8 if quantized else 0  # k+v fp32 scale per (slot, head)
        for ctx in ctxs:
            case = _pool_case(rng, ctx, B=B, n_kv=n_kv, group=group, H=H,
                              bs=bs, quantized=quantized)
            # Oversized table span: the pool is provisioned for 2x the live
            # context (the realistic serving shape — tables sized for
            # max_ctx, rows shorter), which is exactly the dead weight the
            # unclamped gather pays for and the fused kernel skips.
            q, pk, pv, tbl, pos, ks, vs = case
            pad_tbl = jnp.concatenate(
                [tbl, jnp.full_like(tbl, -1)], axis=1)
            live_blocks = ctx // bs

            paths = {
                "gather": (gather_fn(None), pad_tbl),
                "gather_clamp": (gather_fn(live_blocks), pad_tbl),
                "fused": (fused_fn, pad_tbl),
            }
            row = {"ctx": ctx, "pool_dtype": dt, "paths": {}}
            outs = {}
            spans = {"gather": 2 * ctx, "gather_clamp": ctx, "fused": ctx}
            for name, (fn, table) in paths.items():
                fn(q, pk, pv, table, pos, ks, vs)  # compile outside timing
                out, us = timed(
                    lambda fn=fn, table=table: jax.block_until_ready(
                        fn(q, pk, pv, table, pos, ks, vs)),
                    repeat=3)
                outs[name] = np.asarray(out, np.float32)
                model = _hbm_bytes(spans[name], B=B, n_kv=n_kv, H=H, bs=bs,
                                   itemsize=itemsize,
                                   scale_bytes=scale_bytes,
                                   fused=name == "fused")
                row["paths"][name] = {
                    "wall_us": round(us, 1),
                    "hbm_bytes_model": model,
                    "v5e_projected_us": round(memory_time_s(model) * 1e6, 3),
                }
                emit(f"decode/paged_attention/{dt}/ctx{ctx}/{name}", us,
                     f"hbm_bytes={model}")
            # All three compute the same attention.
            np.testing.assert_allclose(outs["fused"], outs["gather"],
                                       rtol=5e-2, atol=5e-2)
            np.testing.assert_allclose(outs["gather_clamp"], outs["gather"],
                                       rtol=0, atol=0)
            g = row["paths"]["gather"]
            f = row["paths"]["fused"]
            row["fused_bytes_reduction"] = round(
                g["hbm_bytes_model"] / f["hbm_bytes_model"], 2)
            row["fused_projected_speedup"] = round(
                g["v5e_projected_us"] / f["v5e_projected_us"], 2)
            # The absolute per-decode-step saving grows with context: the
            # staging copy the gather writes + re-reads scales with the
            # table span while the fused kernel adds only live-block reads.
            row["fused_projected_gap_us"] = round(
                g["v5e_projected_us"] - f["v5e_projected_us"], 3)
            row["fused_wall_speedup"] = round(g["wall_us"] / f["wall_us"], 2)
            rows.append(row)
            results[f"{dt}_ctx{ctx}_projected_speedup"] = (
                row["fused_projected_speedup"])

    # --- int8 pool capacity: ~2x tokens per pooled byte ------------------
    capacity = _int8_capacity_demo(quick, H=H)
    results["int8_capacity_ratio"] = capacity["capacity_ratio"]

    if quick:
        return results
    bench_path = (Path(__file__).resolve().parents[1]
                  / "BENCH_paged_attention.json")
    bench_path.write_text(json.dumps({
        "note": ("one decode step's paged attention, single layer, fully "
                 "occupied pool with tables provisioned for 2x the live "
                 "context. wall_us is MEASURED in CPU interpret mode, "
                 "where the fused kernel's serial grid emulation loses "
                 "to the gather's memcpy (interpret wall time is not a "
                 "TPU number; use --backend reference for fastest CPU "
                 "serving). hbm_bytes_model / v5e_projected_us is "
                 "MODELED, not measured: fused streams each live block "
                 "once, gather reads + stages + re-reads its full table "
                 "span (3x span bytes), so the speedup ratio is fixed by "
                 "the 2x provisioning (6x) and the widening "
                 "fused_projected_gap_us is the absolute per-step saving "
                 "growing linearly with context"),
        "config": {"batch": B, "n_kv": n_kv, "gqa_group": group,
                   "head_dim": H, "block_size": bs},
        "rows": rows,
        "int8_pool_capacity": capacity,
    }, indent=2) + "\n")
    return results


def _int8_capacity_demo(quick: bool, *, H: int) -> dict:
    """Serve end to end through an int8 paged pool holding ~2x the tokens
    of a bf16 pool with the same byte budget."""
    import dataclasses

    import jax
    import numpy as np

    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.serving import ContinuousScheduler, Request

    cfg = get_reduced_config("olmo-1b")
    hd = cfg.head_dim
    per_tok_bf16 = 2 * hd * 2            # k+v bf16, per (layer, head)
    per_tok_int8 = 2 * (hd + 4)          # k+v int8 codes + fp32 scales
    ratio = per_tok_bf16 / per_tok_int8
    # The reduced model's tiny head_dim understates the win; at the
    # benchmark/serving head dim the scale plane amortizes to ~2x.
    ratio_h = (2 * H * 2) / (2 * (H + 4))

    bs, bf16_blocks = 4, 8
    budget = bf16_blocks * bs * per_tok_bf16
    int8_blocks = int(budget // (bs * per_tok_int8))

    cfg8 = dataclasses.replace(cfg, kv_cache_quant=True)
    params = build_model(cfg8).init(jax.random.PRNGKey(0))
    sched = ContinuousScheduler(cfg8, params, max_batch=2, max_ctx=40,
                                bucket=8, paged=True, block_size=bs,
                                pool_blocks=int8_blocks)
    rng = np.random.default_rng(3)
    n = 2 if quick else 3
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8),
                    max_new_tokens=6) for i in range(n)]
    done = sched.run(reqs)
    stats = sched.pool_stats()
    served = sum(len(r.out_tokens) for r in done)
    emit("decode/int8_pool_capacity", 0.0,
         f"tokens_per_budget_ratio={ratio:.2f} (h{H}: {ratio_h:.2f}) "
         f"int8_capacity={stats['capacity_tokens']} "
         f"bf16_capacity={bf16_blocks * bs}")
    return {
        "note": ("equal pooled byte budget; int8 pool = codes + "
                 "per-(slot, head) fp32 scale planes, dequantized "
                 "in-kernel by the fused paged-attention op"),
        "byte_budget": budget,
        "bf16_capacity_tokens": bf16_blocks * bs,
        "int8_capacity_tokens": stats["capacity_tokens"],
        "capacity_ratio": round(stats["capacity_tokens"]
                                / (bf16_blocks * bs), 2),
        "bytes_per_token_ratio": round(ratio, 2),
        f"bytes_per_token_ratio_h{H}": round(ratio_h, 2),
        "requests_served": len(done),
        "tokens_served": served,
        "all_completed": all(not r.failed for r in done),
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="two contexts, no JSON artifact (CI smoke)")
    args = ap.parse_args()
    run(quick=args.quick)
