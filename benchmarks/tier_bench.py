"""Per-request precision tiers benchmark: tokens per step and the modeled
per-token weight traffic, swept over tier mixes in one continuous batch.

One w8a8 packed weight set serves three quality–latency classes — w8a8,
w4a8, w2a8 — as plane-truncated views (``core.precision
.truncate_policy_view``): a tier-T decode call contracts only the top
``T/8`` of the resident weight bytes, so lower-tier requests stream less
HBM per step with zero extra weight memory. The scheduler runs one
decode call per tier group per step; each group call streams its tier's
byte fraction once, shared across the group's slots. Modeled weight
bytes per token for a mix is therefore

    Σ_tier decode_calls[tier] · frac(tier) · W  /  emitted tokens

where frac(w8)=1, frac(w4)=1/2, frac(w2)=1/4 of the packed bytes W —
exactly the fractions ``spec_bench`` models for drafts, because tier
views and draft views are the same code path. Wall time in CPU
interpret/jit mode tracks call counts, not TPU bytes; the modeled bytes
column is the TPU-relevant number.

Quality is not modeled here (random init): the benchmark's correctness
claim is the bit-identity contract, asserted in-run — every request in
every mix must produce tokens bitwise identical to a solo engine whose
single configured tier (and every request) is that request's tier.

Run:  PYTHONPATH=src python -m benchmarks.tier_bench [--quick]
Writes BENCH_tiers.json at the repo root.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.common import emit

TIER_BITS = {"w8a8": 8, "w4a8": 4, "w2a8": 2}

# (name, per-request tier assignment) — cycled over the request list.
MIXES = [
    ("all_w8", ["w8a8"]),
    ("mixed_w8_w4_w2", ["w8a8", "w4a8", "w2a8"]),
    ("all_w4", ["w4a8"]),
    ("all_w2", ["w2a8"]),
]


def _serve(cfg, params, quant, tiers, assignment, prompts, max_new):
    import numpy as np

    from repro.serving import ContinuousScheduler, Request

    sched = ContinuousScheduler(
        cfg, params, max_batch=3, max_ctx=64, quant=quant, bucket=16,
        paged=True, block_size=4, chunked_prefill=True, prefill_budget=8,
        tiers=tiers)
    reqs = [Request(rid=i, prompt=np.asarray(p), max_new_tokens=max_new,
                    tier=assignment[i % len(assignment)])
            for i, p in enumerate(prompts)]
    done = sched.run(reqs)
    return done, sched


def run(quick: bool = False) -> dict:
    import jax
    import numpy as np

    from repro.configs import get_reduced_config
    from repro.core.quant import QuantConfig
    from repro.core.quantized_linear import (
        packed_weight_bytes,
        quantize_params_for_serving,
    )
    from repro.models import build_model

    cfg = get_reduced_config("olmo-1b")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    quant = QuantConfig(w_bits=8, a_bits=8)

    qp = quantize_params_for_serving(params, quant, min_size=1024)
    W = packed_weight_bytes(qp)
    frac = {t: packed_weight_bytes(qp, b) / W for t, b in TIER_BITS.items()}

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 7 + i).astype(np.int64)
               for i in range(2 if quick else 3)]
    max_new = 8 if quick else 16
    mixes = MIXES[:2] if quick else MIXES

    # Solo references, one per tier: every request pinned to that tier in
    # an engine whose only configured tier is that tier — the engine the
    # bit-identity contract names. Computed once, reused across mixes.
    solo_streams = {}
    for tier in TIER_BITS:
        done, _ = _serve(cfg, params, quant, tier, [tier], prompts, max_new)
        solo_streams[tier] = {r.rid: r.out_tokens for r in done}

    rows = []
    results = {}
    for name, assignment in mixes:
        tiers = ",".join(dict.fromkeys(assignment))
        done, sched = _serve(cfg, params, quant, tiers, assignment,
                             prompts, max_new)
        # Bit-identity: request i at tier T inside the mix == the same
        # request in the solo tier-T engine, token for token.
        for r in done:
            tier = assignment[r.rid % len(assignment)]
            assert r.out_tokens == solo_streams[tier][r.rid], (
                f"{name}: request {r.rid} at {tier} diverged from solo")
        st = sched.pool_stats()
        tokens = sum(len(r.out_tokens) for r in done)
        steps = sched.steps_run
        # Each tier-group decode call streams that tier's plane fraction
        # of the packed bytes once, shared across the group's rows.
        step_bytes = sum(tc["decode_calls"] * frac[t] * W
                         for t, tc in st["tiers"].items() if t in frac)
        row = {
            "mix": name, "tiers": tiers,
            "tokens": tokens, "steps": steps,
            "tokens_per_step": round(tokens / max(steps, 1), 3),
            "decode_calls": {t: tc["decode_calls"]
                             for t, tc in st["tiers"].items()
                             if tc["decode_calls"]},
            "weight_bytes_per_token_model":
                round(step_bytes / max(tokens, 1)),
            "vs_all_w8_bytes_per_token": None,  # filled below
        }
        rows.append(row)
        results[f"{name}_tokens_per_step"] = row["tokens_per_step"]
        emit(f"tiers/{name}", 0.0,
             f"tok/step={row['tokens_per_step']} "
             f"bytes/tok={row['weight_bytes_per_token_model']}")
    base = next(r for r in rows if r["mix"] == "all_w8")
    for row in rows:
        row["vs_all_w8_bytes_per_token"] = round(
            row["weight_bytes_per_token_model"]
            / max(base["weight_bytes_per_token_model"], 1), 3)

    if quick:
        return results
    bench_path = Path(__file__).resolve().parents[1] / "BENCH_tiers.json"
    bench_path.write_text(json.dumps({
        "note": ("per-request precision tiers on the reduced olmo-1b at "
                 "random init (greedy; every request's tokens asserted "
                 "bitwise identical in-run to a solo engine pinned to its "
                 "tier). weight_bytes_per_token_model is MODELED, not "
                 "measured: a tier-T decode call streams T/8 of the one "
                 "packed w8a8 buffer (plane truncation — same fractions "
                 "as the speculative drafts), once per tier group per "
                 "step. Mixed batches pay one group call per distinct "
                 "tier, so bytes/token interpolates between the pure "
                 "mixes as the tier population shifts"),
        "config": {"arch": "olmo-1b (reduced)", "quant": "w8a8",
                   "packed_weight_bytes": W,
                   "tier_weight_frac": {t: round(f, 3)
                                        for t, f in frac.items()},
                   "max_new": max_new, "prompts": len(prompts)},
        "rows": rows,
    }, indent=2) + "\n")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer cells, no JSON artifact (CI smoke)")
    args = ap.parse_args()
    run(quick=args.quick)
