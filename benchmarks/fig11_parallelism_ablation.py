"""Paper Fig. 11 — ablation: DP-M4S vs BRAMAC-1DA as the supported
(N_W, N_I) set grows. Paper: N_I=1 only → 1.06×; {1,2} → intermediate;
{1,2,4} → 1.64× (VGG-16 / ResNet-18 / ResNet-34).

Known fidelity gap (documented in EXPERIMENTS.md §Simulator-fidelity): our
filter-residency model replicates filter sets across spare CIM blocks,
which *is* a form of cross-block weight-sharing — it absorbs most of the
benefit the paper attributes to in-block duplication, so our ablation
spread is flatter than the paper's. The direction (more N_I options never
hurts; M4BRAM ≥ BRAMAC) is preserved.
"""
from __future__ import annotations

from benchmarks.common import emit, mean, timed

NETS = ("vgg16", "resnet18", "resnet34")


def run() -> dict:
    from repro.core import dse, simulate as sim
    from repro.core.workloads import NETWORKS

    results = {}
    for restrict, label in [((1,), "ni1"), ((1, 2), "ni12"), ((1, 2, 4), "ni124")]:
        vals = []
        for net in NETS:
            def one():
                b = dse.search(NETWORKS[net], 4, 4, sim.GX400,
                               sim.CIM_ARCHS["BRAMAC-1DA"])
                m = dse.search(NETWORKS[net], 4, 4, sim.GX400,
                               sim.CIM_ARCHS["DP-M4S"], ni_restrict=restrict)
                return b.cycles / m.cycles

            s, us = timed(one, repeat=1)
            vals.append(s)
            emit(f"fig11/{label}/{net}", us, f"speedup_vs_bramac={s:.2f}x")
        results[label] = mean(vals)
        emit(f"fig11/{label}/avg", 0.0, f"speedup={results[label]:.2f}x")
    emit("fig11/paper_anchors", 0.0, "ni1=1.06x ni124=1.64x")
    return results


if __name__ == "__main__":
    run()
