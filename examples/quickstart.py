"""Quickstart: the M4BRAM technique end-to-end in five minutes on CPU.

1.  Exact bit-serial MAC2 semantics (the paper's BPE dataflow),
2.  the bit-plane Pallas kernel vs a dense matmul,
3.  mixed-precision packed-weight serving (weights 2/4/8-bit, acts 2–8),
4.  the cycle-accurate Hetero-DLA simulator reproducing the paper's
    headline 2.16× speedup,
5.  a tiny quantization-aware training step.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np


def main():
    rng = np.random.default_rng(0)

    # -- 1. Bit-serial MAC2 == integer arithmetic -------------------------
    from repro.core import bitserial

    w1, w2 = jnp.asarray([3, -5]), jnp.asarray([7, 2])
    i1, i2 = jnp.asarray([-4, 6]), jnp.asarray([1, -8])
    mac2 = bitserial.mac2_bitserial(w1, w2, i1, i2, a_bits=4)
    print("MAC2   :", np.asarray(mac2), "== W1*I1 + W2*I2 =",
          np.asarray(w1 * i1 + w2 * i2))

    # -- 2. Bit-plane kernel (the BPE on the MXU) --------------------------
    from repro.kernels import ops

    x = rng.integers(-8, 8, (64, 256)).astype(np.int32)
    w = rng.integers(-128, 128, (256, 128)).astype(np.int32)
    acc = ops.bitplane_matmul(jnp.asarray(x), jnp.asarray(w), a_bits=4)
    assert np.array_equal(np.asarray(acc), x @ w)
    print("Kernel : bit-plane matmul exact over", x.shape, "x", w.shape)

    # -- 3. Packed mixed-precision serving matmul --------------------------
    from repro.core.quant import QuantConfig
    from repro.core.quantized_linear import pack_weight, qmatmul

    xf = jnp.asarray(rng.standard_normal((32, 512)), jnp.float32)
    wf = jnp.asarray(rng.standard_normal((512, 256)) * 0.05, jnp.float32)
    for bits in (8, 4, 2):
        cfg = QuantConfig(w_bits=bits, a_bits=8)
        pw = pack_weight(wf, cfg)
        y = qmatmul(xf, pw, cfg, use_kernel=False)
        rel = float(jnp.linalg.norm(y - xf @ wf) / jnp.linalg.norm(xf @ wf))
        print(f"Serve  : w{bits}a8 packed={pw.hbm_bytes():7d}B "
              f"(dense {wf.size * 4}B) rel-err={rel:.3f}")

    # -- 4. The paper's speedup, simulated ---------------------------------
    from repro.core import dse, simulate as sim
    from repro.core.workloads import NETWORKS

    s = dse.speedup(NETWORKS["resnet18"], 8, 6, sim.GX650,
                    sim.CIM_ARCHS["SY-M4L"])
    print(f"Sim    : Hetero-DLA(SY-M4L) vs DLA on ResNet-18 @w8a6 = {s:.2f}x "
          "(paper avg across DNNs: 2.16x)")

    # -- 5. One QAT train step ---------------------------------------------
    from repro.configs import get_reduced_config
    from repro.models import build_model

    cfg = get_reduced_config("olmo-1b").with_quant(QuantConfig(w_bits=4, a_bits=6))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = model.smoke_batch(jax.random.PRNGKey(1), seq_len=32, batch=2)
    loss, _ = model.train_loss(params, batch)
    print(f"QAT    : olmo-1b-smoke w4a6 fake-quant loss = {float(loss):.3f}")


if __name__ == "__main__":
    main()
