"""Accuracy-vs-performance sweep (the paper's Fig. 9 trade-off, end to
end on our stack):

  * trains a small LM briefly (FP32 reference),
  * evaluates held-out loss under post-training quantization at every
    (w_bits, a_bits) the paper supports (w ∈ {2,4,8}, a ∈ 2..8),
  * reports each point's simulated Hetero-DLA speedup next to the loss
    delta — reproducing the shape of the paper's trade-off curve on a
    task we can actually train in this container.

Run:  PYTHONPATH=src python examples/mixed_precision_sweep.py [--steps 120]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import dataclasses

import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    from repro.configs import get_reduced_config
    from repro.configs.base import TrainConfig
    from repro.core import dse, simulate as sim
    from repro.core.quant import QuantConfig
    from repro.core.workloads import NETWORKS
    from repro.data import DataIterator
    from repro.models import build_model
    from repro.train.loop import run_training

    cfg = dataclasses.replace(
        get_reduced_config("olmo-1b"), num_layers=4, d_model=256, d_ff=1024,
        n_heads=4, n_kv_heads=4, vocab=2048, dtype="float32",
    )
    model = build_model(cfg)
    tc = TrainConfig(lr=5e-3, warmup_steps=10, total_steps=args.steps,
                     log_every=20, checkpoint_every=10**9)
    data = DataIterator(cfg, global_batch=8, seq_len=128, seed=0, branch=8)
    print(f"training FP32 reference for {args.steps} steps ...")
    state, hist = run_training(model, tc, data)
    print(f"  loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    eval_batch = jax.tree_util.tree_map(jnp.asarray, data.batch_at(10_000))
    base_loss = float(model.train_loss(state.params, eval_batch)[0])
    print(f"held-out FP32 loss: {base_loss:.4f}\n")
    print(f"{'config':8s} {'loss':>8s} {'delta':>8s} {'sim speedup':>12s}")

    for w_bits in (8, 4, 2):
        for a_bits in (8, 6, 4, 2):
            qcfg = QuantConfig(w_bits=w_bits, a_bits=a_bits)
            qmodel = build_model(cfg.with_quant(qcfg))
            loss = float(qmodel.train_loss(state.params, eval_batch)[0])
            sp = dse.speedup(NETWORKS["resnet18"], w_bits, a_bits,
                             sim.GX650, sim.CIM_ARCHS["SY-M4L"],
                             baseline_pw=8, baseline_pa=8)
            print(f"w{w_bits}a{a_bits:<5d} {loss:8.4f} {loss-base_loss:+8.4f} "
                  f"{sp:11.2f}x")


if __name__ == "__main__":
    main()
