"""Serving example: batched generation with the M4BRAM quantized-weight
path — weights stored packed (2/4/8-bit) in memory, every matmul runs
bit-plane decode, KV cache optionally int8.

Run:  PYTHONPATH=src python examples/serve_lm.py [--quant w4a8] [--kv-int8]
      PYTHONPATH=src python examples/serve_lm.py --continuous --rate 10

--continuous streams tokens from the continuous-batching scheduler while
requests arrive staggered (Poisson-ish gaps at --rate requests/s) and are
admitted into decode slots as earlier requests retire.
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import dataclasses

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quant", default=None, help="e.g. w4a8")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--continuous", action="store_true")
    ap.add_argument("--rate", type=float, default=8.0,
                    help="continuous mode: arrival rate in requests/s")
    args = ap.parse_args()

    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    cfg = get_reduced_config("olmo-1b")
    cfg = dataclasses.replace(cfg, num_layers=4, d_model=128, d_ff=512,
                              n_heads=4, n_kv_heads=4, vocab=2048,
                              kv_cache_quant=args.kv_int8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    quant = None
    if args.quant:
        from repro.launch.dryrun import _parse_quant

        quant = _parse_quant(args.quant)
    engine = ServingEngine(cfg, params, max_batch=4, quant=quant, bucket=16)

    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8 + i),
                max_new_tokens=args.max_new,
                temperature=0.0 if i % 2 == 0 else 0.8)
        for i in range(args.requests)
    ]
    streamed = []
    if args.continuous:
        t = 0.0
        for r in reqs:
            r.arrival_time = t
            t += float(rng.exponential(1.0 / args.rate))
        engine.on_token = lambda req, tok: streamed.append((req.rid, tok))
    t0 = time.perf_counter()
    done = engine.generate(reqs) if args.continuous else \
        engine.generate_static(reqs)
    dt = time.perf_counter() - t0
    total_new = sum(len(r.out_tokens) for r in done)
    mode = "continuous" if args.continuous else "static"
    print(f"quant={args.quant or 'off'} kv_int8={args.kv_int8} [{mode}] — "
          f"{len(done)} requests, {total_new} tokens in {dt:.1f}s "
          f"({total_new/dt:.1f} tok/s incl. compile)")
    for r in sorted(done, key=lambda r: r.rid)[:3]:
        print(f"  req {r.rid}: prompt[:4]={list(r.prompt[:4])} -> "
              f"out={r.out_tokens}")
    if args.continuous:
        print(f"  streamed {len(streamed)} tokens; first 8: {streamed[:8]}")


if __name__ == "__main__":
    main()
