"""End-to-end training driver: a ~100M-parameter olmo-family LM trained for
a few hundred steps on the synthetic Markov corpus, with checkpointing,
straggler monitoring, and optional int8 gradient compression and QAT.

Run (CPU, ~10-20 min for the default 300 steps):
  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--qat w4a6]
      [--compress] [--ckpt /tmp/ckpt]

Loss should fall well below the unigram entropy floor (~ln vocab) as the
model learns the Markov structure; the script prints the trajectory and
final evaluation.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import dataclasses

import jax


def build_100m():
    from repro.configs.base import ModelConfig

    # ~100M params: 12L × d768 × ff3072, vocab 8192 (olmo-style recipe).
    return ModelConfig(
        name="olmo-100m", family="dense", num_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=3072, vocab=8192,
        ffn="swiglu", norm="nonparam_ln", tie_embeddings=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--qat", default=None, help="e.g. w4a6")
    ap.add_argument("--compress", action="store_true",
                    help="int8 gradient compression with error feedback")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--small", action="store_true",
                    help="8M-param model for quick runs")
    args = ap.parse_args()

    from repro.checkpoint import CheckpointManager
    from repro.configs.base import TrainConfig
    from repro.data import DataIterator
    from repro.models import build_model
    from repro.train.loop import run_training

    cfg = build_100m()
    if args.small:
        cfg = dataclasses.replace(cfg, num_layers=4, d_model=256, d_ff=1024,
                                  n_heads=4, n_kv_heads=4, vocab=2048)
    if args.qat:
        from repro.launch.dryrun import _parse_quant

        cfg = cfg.with_quant(_parse_quant(args.qat))
    model = build_model(cfg)
    n_params = cfg.param_count()
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params, "
          f"qat={args.qat or 'off'})")

    tc = TrainConfig(
        lr=args.lr, warmup_steps=20, total_steps=args.steps,
        grad_clip=1.0, log_every=10, checkpoint_every=100,
        grad_compress_bits=8 if args.compress else 0,
    )
    data = DataIterator(cfg, global_batch=args.batch, seq_len=args.seq,
                        seed=0, branch=8)
    mgr = CheckpointManager(args.ckpt, keep=2) if args.ckpt else None

    def hook(step, rec):
        print(f"step {rec['step']:4d}  loss {rec['loss']:.4f}  "
              f"lr {rec['lr']:.2e}  gnorm {rec['grad_norm']:.2f}  "
              f"{rec['dt']*1e3:.0f} ms" + ("  [STRAGGLER]" if rec["straggler"] else ""))

    state, history = run_training(model, tc, data, checkpoint_mgr=mgr,
                                  hooks=hook)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"(unigram floor ~= ln({cfg.vocab}) = "
          f"{__import__('math').log(cfg.vocab):.2f})")


if __name__ == "__main__":
    main()
